//! Small dense linear algebra for CP-ALS: symmetric R×R solves via Cholesky
//! with adaptive ridge, matrix multiply against the pseudo-inverse, and the
//! Khatri-Rao gram combinations (Line 3 of Algorithm 1).

use crate::mttkrp::dense::Matrix;

/// Hadamard product of all gram matrices except `skip`:
/// `V = ⊛_{n != skip} (AᵀA)_n` (Line 3 of Algorithm 1).
pub fn gram_hadamard(grams: &[Matrix], skip: usize) -> Matrix {
    let r = grams[0].rows;
    let mut v = Matrix::zeros(r, r);
    v.fill(1.0);
    for (n, g) in grams.iter().enumerate() {
        if n == skip {
            continue;
        }
        v.hadamard_assign(g);
    }
    v
}

/// Cholesky factorization of a symmetric positive-definite matrix,
/// in place lower-triangular. Returns `Err` if not positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix, ()> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.row(i)[j];
            for k in 0..j {
                sum -= l.row(i)[k] * l.row(j)[k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(());
                }
                l.row_mut(i)[j] = sum.sqrt();
            } else {
                l.row_mut(i)[j] = sum / l.row(j)[j];
            }
        }
    }
    Ok(l)
}

/// Solve `V x = b` for many right-hand sides given `L` (Cholesky of V):
/// forward + back substitution. `b` and the result are row vectors of a
/// row-major matrix (so this solves `X Vᵀ = B` row-wise; V symmetric).
fn chol_solve_row(l: &Matrix, b: &[f64], x: &mut [f64]) {
    let n = l.rows;
    // forward: L y = b
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.row(i)[k] * x[k];
        }
        x[i] = s / l.row(i)[i];
    }
    // backward: Lᵀ x = y
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in i + 1..n {
            s -= l.row(k)[i] * x[k];
        }
        x[i] = s / l.row(i)[i];
    }
}

/// `A ← M V⁺` for symmetric PSD `V`, i.e. solve `A V = M` row-wise.
/// Adds an adaptive ridge (scaled by trace) until Cholesky succeeds —
/// the pseudo-inverse regularization standard in CP-ALS implementations.
pub fn solve_pseudo(m: &Matrix, v: &Matrix) -> Matrix {
    let r = v.rows;
    assert_eq!(m.cols, r);
    let trace: f64 = (0..r).map(|i| v.row(i)[i]).sum();
    let mut ridge = 0.0f64;
    let l = loop {
        let mut vr = v.clone();
        if ridge > 0.0 {
            for i in 0..r {
                vr.row_mut(i)[i] += ridge;
            }
        }
        match cholesky(&vr) {
            Ok(l) => break l,
            Err(()) => {
                ridge = if ridge == 0.0 {
                    1e-12 * trace.max(1e-300)
                } else {
                    ridge * 10.0
                };
                assert!(
                    ridge.is_finite() && ridge < trace.max(1.0) * 1e6,
                    "V is catastrophically singular"
                );
            }
        }
    };
    let mut out = Matrix::zeros(m.rows, r);
    for i in 0..m.rows {
        chol_solve_row(&l, m.row(i), out.row_mut(i));
    }
    out
}

/// Column 2-norms of a matrix (the λ normalization of CP-ALS).
pub fn column_norms(a: &Matrix) -> Vec<f64> {
    let mut norms = vec![0.0f64; a.cols];
    for i in 0..a.rows {
        for (k, &x) in a.row(i).iter().enumerate() {
            norms[k] += x * x;
        }
    }
    norms.iter_mut().for_each(|x| *x = x.sqrt());
    norms
}

/// Divide each column by its norm (skip zero columns). Returns the norms.
pub fn normalize_columns(a: &mut Matrix) -> Vec<f64> {
    let norms = column_norms(a);
    for i in 0..a.rows {
        let row = a.row_mut(i);
        for (k, &nm) in norms.iter().enumerate() {
            if nm > 0.0 {
                row[k] /= nm;
            }
        }
    }
    norms
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Matrix {
        // A = BᵀB + I is SPD
        let b = Matrix::from_rows(vec![
            vec![1.0, 2.0, 0.5],
            vec![0.0, 1.0, 1.0],
            vec![2.0, 0.0, 1.0],
        ]);
        let mut g = b.gram();
        for i in 0..3 {
            g.row_mut(i)[i] += 1.0;
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd3();
        let l = cholesky(&a).unwrap();
        // L Lᵀ == A
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l.row(i)[k] * l.row(j)[k];
                }
                assert!((s - a.row(i)[j]).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_recovers_known_solution() {
        let v = spd3();
        // pick X, compute M = X V, then solve back
        let x = Matrix::from_rows(vec![
            vec![1.0, -2.0, 3.0],
            vec![0.5, 0.0, -1.0],
        ]);
        let mut m = Matrix::zeros(2, 3);
        for i in 0..2 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += x.row(i)[k] * v.row(k)[j];
                }
                m.row_mut(i)[j] = s;
            }
        }
        let got = solve_pseudo(&m, &v);
        assert!(got.max_abs_diff(&x) < 1e-9);
    }

    #[test]
    fn solve_singular_with_ridge() {
        // rank-1 V: pseudo-solve must still return finite values
        let v = Matrix::from_rows(vec![
            vec![1.0, 1.0],
            vec![1.0, 1.0],
        ]);
        let m = Matrix::from_rows(vec![vec![2.0, 2.0]]);
        let got = solve_pseudo(&m, &v);
        assert!(got.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn gram_hadamard_skips_target() {
        let a = Matrix::from_rows(vec![vec![2.0]]);
        let b = Matrix::from_rows(vec![vec![3.0]]);
        let c = Matrix::from_rows(vec![vec![5.0]]);
        let v = gram_hadamard(&[a, b, c], 1);
        assert_eq!(v.data, vec![10.0]);
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut a = Matrix::from_rows(vec![vec![3.0, 0.0], vec![4.0, 0.0]]);
        let norms = normalize_columns(&mut a);
        assert!((norms[0] - 5.0).abs() < 1e-12);
        assert_eq!(norms[1], 0.0);
        assert!((a.row(0)[0] - 0.6).abs() < 1e-12);
        assert!((a.row(1)[0] - 0.8).abs() < 1e-12);
    }
}
