//! CP-ALS (Algorithm 1 of the paper) on top of any [`Mttkrp`] engine, with
//! a self-contained dense R×R linear-algebra kernel set (Cholesky-based
//! pseudo-inverse) — no external linalg crates.

pub mod als;
pub mod linalg;

pub use als::{cp_als, CpAlsOptions, CpAlsReport, ModeTrace, StreamStats};
