//! The CP-ALS driver (Algorithm 1): alternating factor updates with the
//! MTTKRP supplied by any [`Mttkrp`] engine, λ column normalization, and
//! the standard fit monitor
//! `fit = 1 − ‖X − X̂‖ / ‖X‖`, with
//! `‖X − X̂‖² = ‖X‖² − 2⟨M_N, A_N⟩ + 1ᵀ(⊛_n AᵀA)1`.

use crate::coordinator::engine::ExecPath;
use crate::coordinator::schedule::ScheduleStats;
use crate::cpals::linalg::{gram_hadamard, normalize_columns, solve_pseudo};
use crate::device::Counters;
use crate::mttkrp::dense::Matrix;
use crate::mttkrp::oracle::random_factors;
use crate::mttkrp::Mttkrp;

#[derive(Clone, Copy, Debug)]
pub struct CpAlsOptions {
    pub rank: usize,
    pub max_iters: usize,
    /// stop when the fit improves by less than this
    pub tol: f64,
    pub threads: usize,
    pub seed: u64,
}

impl Default for CpAlsOptions {
    fn default() -> Self {
        CpAlsOptions {
            rank: 16,
            max_iters: 25,
            tol: 1e-5,
            threads: crate::util::pool::default_threads(),
            seed: 0xCA1,
        }
    }
}

/// Which execution paths served one mode's MTTKRPs across the run.
#[derive(Clone, Debug, Default)]
pub struct ModeTrace {
    /// calls served by the in-memory unified kernel
    pub in_memory: usize,
    /// calls served by single-device out-of-memory streaming
    pub streamed: usize,
    /// calls served by sharded cluster streaming
    pub clustered: usize,
    /// the final iteration's full path report (per-batch traces included
    /// for the streamed/clustered cases)
    pub last: Option<ExecPath>,
}

/// Aggregate out-of-memory traffic across every MTTKRP of a CP-ALS run.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    pub streamed_calls: usize,
    pub clustered_calls: usize,
    /// host→device bytes shipped across all streamed/clustered calls
    pub bytes: usize,
    /// device↔device bytes moved by cluster tree merges
    pub merge_bytes: usize,
    /// total modelled host-link transfer seconds
    pub transfer_s: f64,
    /// total modelled device compute seconds
    pub compute_s: f64,
    /// total pipeline-simulated end-to-end seconds
    pub overall_s: f64,
}

/// Per-iteration trace + final factors.
#[derive(Debug)]
pub struct CpAlsReport {
    pub factors: Vec<Matrix>,
    pub lambda: Vec<f64>,
    pub fits: Vec<f64>,
    pub iterations: usize,
    pub mttkrp_seconds: f64,
    pub total_seconds: f64,
    /// which execution path served each mode, per mode
    pub mode_traces: Vec<ModeTrace>,
    /// aggregate out-of-memory traffic of the whole decomposition
    pub stream: StreamStats,
    /// schedule-cache activity during this run: `built` must equal the
    /// number of distinct `(mode, rank)` pairs that streamed, not
    /// `modes × iterations` (zeros for engines without a cache)
    pub schedule: ScheduleStats,
}

/// Run CP-ALS over a tensor exposed through `engine`. `dims` and `norm_x`
/// describe the tensor (engines own their format, so the driver only needs
/// shape + Frobenius norm).
pub fn cp_als(
    engine: &dyn Mttkrp,
    dims: &[u64],
    norm_x: f64,
    opts: CpAlsOptions,
    counters: &Counters,
) -> CpAlsReport {
    let order = dims.len();
    let rank = opts.rank;
    let t_start = std::time::Instant::now();
    let sched_start = engine.schedule_stats();

    let mut factors = random_factors(dims, rank, opts.seed);
    let mut lambda = vec![1.0f64; rank];
    let mut grams: Vec<Matrix> = factors.iter().map(|f| f.gram()).collect();

    let mut fits = Vec::new();
    let mut prev_fit = 0.0f64;
    let mut mttkrp_seconds = 0.0f64;
    let mut last_m = Matrix::zeros(dims[order - 1] as usize, rank);
    let mut mode_traces = vec![ModeTrace::default(); order];
    let mut stream = StreamStats::default();

    let mut iterations = 0;
    for _it in 0..opts.max_iters {
        iterations += 1;
        for n in 0..order {
            // Line 3: V = ⊛_{m≠n} gram_m
            let v = gram_hadamard(&grams, n);
            // Line 4: M = MTTKRP(X, factors, n)
            let mut m = Matrix::zeros(dims[n] as usize, rank);
            let t0 = std::time::Instant::now();
            let path =
                engine.mttkrp_traced(n, &factors, &mut m, opts.threads, counters);
            mttkrp_seconds += t0.elapsed().as_secs_f64();
            if let Some(p) = path {
                let tr = &mut mode_traces[n];
                match &p {
                    ExecPath::InMemory(_) => tr.in_memory += 1,
                    ExecPath::Streamed(rep) => {
                        tr.streamed += 1;
                        stream.streamed_calls += 1;
                        stream.bytes += rep.bytes;
                        stream.transfer_s += rep.transfer_s;
                        stream.compute_s += rep.compute_s;
                        stream.overall_s += rep.overall_s;
                    }
                    ExecPath::Clustered(rep) => {
                        tr.clustered += 1;
                        stream.clustered_calls += 1;
                        stream.bytes += rep.bytes;
                        stream.merge_bytes += rep.merge_bytes;
                        stream.transfer_s += rep.transfer_s;
                        stream.compute_s += rep.compute_s;
                        stream.overall_s += rep.overall_s;
                    }
                }
                tr.last = Some(p);
            }
            // Line 5: A_n = M V⁺, then normalize columns into λ
            let mut a = solve_pseudo(&m, &v);
            lambda = normalize_columns(&mut a);
            grams[n] = a.gram();
            factors[n] = a;
            if n == order - 1 {
                last_m = m;
            }
        }
        // fit from the last-mode MTTKRP (standard SPLATT trick):
        // ⟨X, X̂⟩ = Σ_k λ_k ⟨M_N[:,k], A_N[:,k]⟩, ‖X̂‖² = 1ᵀ(⊛ grams ⊙ λλᵀ)1
        let inner: f64 = {
            let a = &factors[order - 1];
            let mut s = 0.0;
            for i in 0..a.rows {
                let (ra, rm) = (a.row(i), last_m.row(i));
                for k in 0..rank {
                    s += lambda[k] * ra[k] * rm[k];
                }
            }
            s
        };
        let norm_est_sq: f64 = {
            let v = gram_hadamard(&grams, usize::MAX); // ⊛ over all modes
            let mut s = 0.0;
            for a in 0..rank {
                for b in 0..rank {
                    s += lambda[a] * lambda[b] * v.row(a)[b];
                }
            }
            s
        };
        let resid_sq = (norm_x * norm_x - 2.0 * inner + norm_est_sq).max(0.0);
        let fit = 1.0 - resid_sq.sqrt() / norm_x.max(f64::MIN_POSITIVE);
        fits.push(fit);
        if (fit - prev_fit).abs() < opts.tol && iterations > 1 {
            break;
        }
        prev_fit = fit;
    }

    CpAlsReport {
        factors,
        lambda,
        fits,
        iterations,
        mttkrp_seconds,
        total_seconds: t_start.elapsed().as_secs_f64(),
        mode_traces,
        stream,
        schedule: engine.schedule_stats().delta_since(sched_start),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::blco::BlcoEngine;
    use crate::mttkrp::coo::CooAtomicEngine;
    use crate::mttkrp::oracle::mttkrp_oracle;
    use crate::tensor::coo::CooTensor;
    use crate::util::prng::Rng;

    /// Build an exactly rank-`r` tensor from random factors.
    fn low_rank_tensor(dims: &[u64], r: usize, seed: u64) -> CooTensor {
        let f = random_factors(dims, r, seed);
        let mut t = CooTensor::new(dims);
        // dense small tensor: every cell
        let mut idx = vec![0u32; dims.len()];
        loop {
            let mut v = 0.0;
            for k in 0..r {
                let mut p = 1.0;
                for (n, &i) in idx.iter().enumerate() {
                    p *= f[n].row(i as usize)[k];
                }
                v += p;
            }
            let coord = idx.clone();
            t.push(&coord, v);
            // odometer
            let mut n = dims.len();
            loop {
                if n == 0 {
                    return t;
                }
                n -= 1;
                idx[n] += 1;
                if (idx[n] as u64) < dims[n] {
                    break;
                }
                idx[n] = 0;
            }
        }
    }

    #[test]
    fn fit_increases_and_approaches_one_on_low_rank_data() {
        let dims = [8u64, 7, 6];
        let t = low_rank_tensor(&dims, 3, 5);
        let norm = t.norm();
        let eng = CooAtomicEngine::new(t);
        let opts = CpAlsOptions { rank: 8, max_iters: 60, tol: 1e-9, threads: 2, seed: 1 };
        let rep = cp_als(&eng, &dims, norm, opts, &Counters::new());
        let last = *rep.fits.last().unwrap();
        assert!(last > 0.98, "fit {last} (fits {:?})", &rep.fits);
        // fit grows (allow tiny numerical dips)
        assert!(rep.fits.last().unwrap() >= &(rep.fits[0] - 1e-9));
    }

    #[test]
    fn blco_engine_drives_cpals() {
        let dims = [10u64, 9, 8];
        let t = low_rank_tensor(&dims, 2, 9);
        let norm = t.norm();
        let eng = BlcoEngine::new(
            crate::format::blco::BlcoTensor::from_coo(&t),
            crate::device::Profile::a100(),
        );
        let opts = CpAlsOptions { rank: 4, max_iters: 40, tol: 1e-10, threads: 4, seed: 3 };
        let rep = cp_als(&eng, &dims, norm, opts, &Counters::new());
        assert!(*rep.fits.last().unwrap() > 0.95, "fits {:?}", rep.fits);
    }

    #[test]
    fn factors_reconstruct_mttkrp_consistently() {
        // after CP-ALS, both engines agree on a fresh MTTKRP of the factors
        let dims = [6u64, 5, 4];
        let t = low_rank_tensor(&dims, 2, 11);
        let eng = CooAtomicEngine::new(t.clone());
        let opts = CpAlsOptions { rank: 3, max_iters: 5, tol: 0.0, threads: 1, seed: 7 };
        let rep = cp_als(&eng, &dims, t.norm(), opts, &Counters::new());
        let oracle = mttkrp_oracle(&t, 0, &rep.factors);
        let mut out = Matrix::zeros(6, 3);
        eng.mttkrp(0, &rep.factors, &mut out, 2, &Counters::new());
        assert!(out.max_abs_diff(&oracle) < 1e-9);
    }

    #[test]
    fn report_bookkeeping() {
        let dims = [5u64, 5, 5];
        let mut t = CooTensor::new(&dims);
        let mut rng = Rng::new(2);
        for _ in 0..40 {
            let c: Vec<u32> = dims.iter().map(|&d| rng.below(d) as u32).collect();
            t.push(&c, rng.normal());
        }
        let eng = CooAtomicEngine::new(t.clone());
        let opts = CpAlsOptions { rank: 2, max_iters: 3, tol: 0.0, threads: 1, seed: 13 };
        let rep = cp_als(&eng, &dims, t.norm(), opts, &Counters::new());
        assert_eq!(rep.iterations, 3);
        assert_eq!(rep.fits.len(), 3);
        assert_eq!(rep.factors.len(), 3);
        assert_eq!(rep.lambda.len(), 2);
        assert!(rep.mttkrp_seconds <= rep.total_seconds);
        // a single-path engine reports no routing traces and no plans
        assert_eq!(rep.mode_traces.len(), 3);
        for tr in &rep.mode_traces {
            assert_eq!(tr.in_memory + tr.streamed + tr.clustered, 0);
            assert!(tr.last.is_none());
        }
        assert_eq!(rep.stream.streamed_calls + rep.stream.clustered_calls, 0);
        assert_eq!(rep.schedule, Default::default());
    }
}
