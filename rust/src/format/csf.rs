//! Compressed Sparse Fiber (CSF) trees (Smith & Karypis; Section 3.2 of the
//! paper) for an arbitrary mode ordering, plus the B-CSF balanced splitting
//! of heavy root sub-trees (Nisa et al., IPDPS '19).
//!
//! Level `l` of the tree stores mode `mode_order[l]`; `fptr[l][i]..fptr[l][i+1]`
//! are the children of node `i` at level `l+1`. Leaf nodes align with `vals`.

use crate::tensor::coo::CooTensor;

/// A CSF tensor with a fixed mode ordering.
#[derive(Clone, Debug)]
pub struct Csf {
    pub dims: Vec<u64>,
    /// level -> tensor mode stored at that level (root = 0, leaf = N-1)
    pub mode_order: Vec<usize>,
    /// per level: the index value of each node
    pub fids: Vec<Vec<u32>>,
    /// per non-leaf level: child ranges into the next level
    /// (`fptr[l].len() == fids[l].len() + 1`)
    pub fptr: Vec<Vec<u32>>,
    /// leaf values, aligned with `fids[order-1]`
    pub vals: Vec<f64>,
}

impl Csf {
    /// Build from COO with the given mode ordering (a permutation of modes).
    pub fn from_coo(t: &CooTensor, mode_order: &[usize]) -> Self {
        let order = t.order();
        assert_eq!(mode_order.len(), order);
        {
            let mut seen = vec![false; order];
            for &m in mode_order {
                assert!(m < order && !seen[m], "bad mode order {mode_order:?}");
                seen[m] = true;
            }
        }
        // sort non-zeros lexicographically along mode_order
        let mut perm: Vec<u32> = (0..t.nnz() as u32).collect();
        perm.sort_unstable_by(|&a, &b| {
            for &m in mode_order {
                match t.coords[m][a as usize].cmp(&t.coords[m][b as usize]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        });

        // pass 1: node ids per level (a new node opens at every level below
        // the longest common prefix with the previous non-zero)
        let mut fids: Vec<Vec<u32>> = vec![Vec::new(); order];
        let mut vals = Vec::with_capacity(t.nnz());
        let lcp_of = |a: usize, b: usize| -> usize {
            let mut lcp = 0usize;
            while lcp < order - 1
                && t.coords[mode_order[lcp]][a] == t.coords[mode_order[lcp]][b]
            {
                lcp += 1;
            }
            lcp
        };
        for (i, &e) in perm.iter().enumerate() {
            let e = e as usize;
            let from = if i == 0 { 0 } else { lcp_of(e, perm[i - 1] as usize) };
            for l in from..order {
                fids[l].push(t.coords[mode_order[l]][e]);
            }
            vals.push(t.vals[e]);
        }

        // pass 2: child ranges. fptr[l][i+1] tracks the running end of node
        // i's children; node_at[l] is the current (last-opened) node.
        let mut fptr: Vec<Vec<u32>> = (0..order.saturating_sub(1))
            .map(|l| vec![0u32; fids[l].len() + 1])
            .collect();
        if !perm.is_empty() {
            let mut node_at = vec![0usize; order];
            for l in 0..order.saturating_sub(1) {
                fptr[l][1] = 1;
            }
            for i in 1..perm.len() {
                let lcp = lcp_of(perm[i] as usize, perm[i - 1] as usize);
                for l in lcp..order {
                    node_at[l] += 1;
                }
                for l in 0..order.saturating_sub(1) {
                    fptr[l][node_at[l] + 1] = node_at[l + 1] as u32 + 1;
                }
            }
        }

        Csf { dims: t.dims.clone(), mode_order: mode_order.to_vec(), fids, fptr, vals }
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.mode_order.len()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Number of root sub-trees.
    pub fn roots(&self) -> usize {
        self.fids[0].len()
    }

    /// Leaf count under root `r` (its workload).
    pub fn root_nnz(&self, r: usize) -> usize {
        let (mut lo, mut hi) = (r, r + 1);
        for l in 0..self.order() - 1 {
            lo = self.fptr[l][lo] as usize;
            hi = self.fptr[l][hi] as usize;
        }
        hi - lo
    }

    /// Bytes of the representation (ids + pointers + values).
    pub fn footprint_bytes(&self) -> usize {
        let ids: usize = self.fids.iter().map(|v| v.len() * 4).sum();
        let ptrs: usize = self.fptr.iter().map(|v| v.len() * 4).sum();
        ids + ptrs + self.vals.len() * 8
    }

    /// B-CSF: split roots whose sub-tree exceeds `max_nnz` leaves at child
    /// granularity. Root ids may then repeat — the MTTKRP engines must
    /// combine repeated roots with atomic updates (that is B-CSF's tradeoff:
    /// balance for synchronization).
    pub fn split_roots(&self, max_nnz: usize) -> Csf {
        assert!(self.order() >= 2);
        let mut out = self.clone();
        let mut new_roots: Vec<u32> = Vec::new();
        let mut new_ptr: Vec<u32> = vec![0];
        for r in 0..self.roots() {
            let c0 = self.fptr[0][r] as usize;
            let c1 = self.fptr[0][r + 1] as usize;
            let mut run_start = c0;
            let mut run_nnz = 0usize;
            for c in c0..c1 {
                let sz = self.child_nnz(1, c);
                if run_nnz > 0 && run_nnz + sz > max_nnz {
                    new_roots.push(self.fids[0][r]);
                    new_ptr.push(c as u32);
                    run_start = c;
                    run_nnz = 0;
                }
                run_nnz += sz;
            }
            if c1 > run_start {
                new_roots.push(self.fids[0][r]);
                new_ptr.push(c1 as u32);
            }
        }
        out.fids[0] = new_roots;
        out.fptr[0] = new_ptr;
        out
    }

    /// Leaf count under node `node` at level `l`.
    pub fn child_nnz(&self, l: usize, node: usize) -> usize {
        let (mut lo, mut hi) = (node, node + 1);
        for lev in l..self.order() - 1 {
            lo = self.fptr[lev][lo] as usize;
            hi = self.fptr[lev][hi] as usize;
        }
        hi - lo
    }

    /// Reconstruct COO (round-trip tests).
    pub fn to_coo(&self) -> CooTensor {
        let mut t = CooTensor::with_capacity(&self.dims, self.nnz());
        let order = self.order();
        let mut coord = vec![0u32; order];
        // walk every leaf, tracking the ancestor node at each level
        for leaf in 0..self.nnz() {
            let mut node = leaf;
            coord[self.mode_order[order - 1]] = self.fids[order - 1][leaf];
            for l in (0..order - 1).rev() {
                // find parent of `node` at level l by binary search on fptr
                let p = match self.fptr[l].binary_search(&(node as u32)) {
                    Ok(mut i) => {
                        // fptr may contain repeated values for empty ranges;
                        // advance to the last equal entry
                        while i + 1 < self.fptr[l].len()
                            && self.fptr[l][i + 1] as usize == node
                        {
                            i += 1;
                        }
                        i
                    }
                    Err(i) => i - 1,
                };
                coord[self.mode_order[l]] = self.fids[l][p];
                node = p;
            }
            t.push(&coord, self.vals[leaf]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;
    use std::collections::HashMap;

    fn key_count(t: &CooTensor) -> HashMap<(Vec<u32>, u64), u32> {
        let mut m = HashMap::new();
        for e in 0..t.nnz() {
            *m.entry((t.coord(e), t.vals[e].to_bits())).or_insert(0u32) += 1;
        }
        m
    }

    fn paper_tensor() -> CooTensor {
        // Figure 4a, 0-based
        let mut t = CooTensor::new(&[4, 4, 4]);
        for (c, v) in [
            ([0u32, 0, 0], 1.0),
            ([0, 0, 1], 2.0),
            ([0, 2, 2], 3.0),
            ([1, 0, 1], 4.0),
            ([1, 0, 2], 5.0),
            ([2, 0, 1], 6.0),
            ([2, 3, 3], 7.0),
            ([3, 1, 0], 8.0),
            ([3, 1, 1], 9.0),
            ([3, 2, 2], 10.0),
            ([3, 2, 3], 11.0),
            ([3, 3, 3], 12.0),
        ] {
            t.push(&c, v);
        }
        t
    }

    #[test]
    fn paper_tensor_structure() {
        let t = paper_tensor();
        let c = Csf::from_coo(&t, &[0, 1, 2]);
        assert_eq!(c.roots(), 4); // i0 ∈ {0,1,2,3}
        assert_eq!(c.nnz(), 12);
        // root 0 = index 0 has fibers (0,0,*) and (0,2,*): 2 children
        assert_eq!(c.fptr[0][1] - c.fptr[0][0], 2);
        // root 3 = index 3 has fibers (3,1,*),(3,2,*),(3,3,*): 3 children
        assert_eq!(c.fptr[0][4] - c.fptr[0][3], 3);
        assert_eq!(c.root_nnz(0), 3);
        assert_eq!(c.root_nnz(3), 5);
    }

    #[test]
    fn roundtrip_all_mode_orders() {
        let t = synth::uniform(&[20, 15, 10], 800, 1);
        for mo in [[0usize, 1, 2], [2, 1, 0], [1, 0, 2], [1, 2, 0], [0, 2, 1], [2, 0, 1]] {
            let c = Csf::from_coo(&t, &mo);
            assert_eq!(c.nnz(), t.nnz());
            assert_eq!(key_count(&c.to_coo()), key_count(&t), "order {mo:?}");
        }
    }

    #[test]
    fn roundtrip_4mode() {
        let t = synth::uniform(&[10, 8, 6, 4], 500, 2);
        for mo in [[0usize, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]] {
            let c = Csf::from_coo(&t, &mo);
            assert_eq!(key_count(&c.to_coo()), key_count(&t), "order {mo:?}");
        }
    }

    #[test]
    fn fptr_invariants() {
        let t = synth::uniform(&[30, 20, 10], 1_000, 3);
        let c = Csf::from_coo(&t, &[0, 1, 2]);
        for l in 0..2 {
            assert_eq!(c.fptr[l].len(), c.fids[l].len() + 1);
            assert_eq!(c.fptr[l][0], 0);
            assert_eq!(*c.fptr[l].last().unwrap() as usize, c.fids[l + 1].len());
            for w in c.fptr[l].windows(2) {
                assert!(w[0] < w[1], "every node has at least one child");
            }
        }
        let total: usize = (0..c.roots()).map(|r| c.root_nnz(r)).sum();
        assert_eq!(total, c.nnz());
    }

    #[test]
    fn compression_beats_coo_on_dense_fibers() {
        let t = synth::fiber_clustered(&[200, 200, 200], 20_000, 2, 1.2, 4);
        let c = Csf::from_coo(&t, &[0, 1, 2]);
        // dense fibers: far fewer fiber nodes than nnz
        assert!(c.fids[1].len() < t.nnz() / 2);
        assert!(c.footprint_bytes() < t.footprint_bytes() * 2);
    }

    #[test]
    fn split_roots_balances() {
        let t = synth::fiber_clustered(&[10, 100, 100], 8_000, 2, 1.0, 5);
        let c = Csf::from_coo(&t, &[0, 1, 2]);
        let max_root = (0..c.roots()).map(|r| c.root_nnz(r)).max().unwrap();
        assert!(max_root > 500, "test premise: some root is heavy");
        let b = c.split_roots(500);
        // same leaves, same values
        assert_eq!(b.nnz(), c.nnz());
        assert_eq!(key_count(&b.to_coo()), key_count(&t));
        // no root exceeds the budget unless a single fiber does
        let max_fiber = (0..b.fids[1].len())
            .map(|f| b.child_nnz(1, f))
            .max()
            .unwrap();
        for r in 0..b.roots() {
            assert!(
                b.root_nnz(r) <= 500.max(max_fiber),
                "root {r}: {}",
                b.root_nnz(r)
            );
        }
        assert!(b.roots() > c.roots());
    }

    #[test]
    fn empty_tensor() {
        let t = CooTensor::new(&[4, 4, 4]);
        let c = Csf::from_coo(&t, &[0, 1, 2]);
        assert_eq!(c.roots(), 0);
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.to_coo().nnz(), 0);
    }
}
