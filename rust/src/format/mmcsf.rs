//! The Mixed-Mode CSF (MM-CSF) format (Nisa et al., SC '19; Section 3.2 /
//! Figure 5 of the paper): a *single* tensor copy partitioned by fiber
//! density — every non-zero is assigned to the orientation (leaf mode)
//! whose containing fiber is densest, and one CSF tree is built per
//! orientation. High compression, but mode-*specific*: each target mode
//! needs a different traversal per group, which is exactly the source of
//! the per-mode performance variance in Figure 1.

use std::collections::HashMap;

use super::csf::Csf;
use crate::tensor::coo::CooTensor;
use crate::tensor::stats;

/// One orientation group: a CSF tree whose leaf level is `leaf_mode`.
#[derive(Clone, Debug)]
pub struct MmGroup {
    pub leaf_mode: usize,
    pub csf: Csf,
}

/// The MM-CSF tensor: per-orientation CSF trees over a single nnz partition.
#[derive(Clone, Debug)]
pub struct MmCsf {
    pub dims: Vec<u64>,
    pub groups: Vec<MmGroup>,
    pub nnz: usize,
}

/// Canonical mode ordering for a given leaf: remaining modes ascending,
/// then the leaf (matches the MM-CSF paper's root-at-densest layout closely
/// enough for traversal/compression behaviour).
pub fn mode_order_for_leaf(order: usize, leaf: usize) -> Vec<usize> {
    let mut mo: Vec<usize> = (0..order).filter(|&n| n != leaf).collect();
    mo.push(leaf);
    mo
}

impl MmCsf {
    pub fn from_coo(t: &CooTensor) -> Self {
        let order = t.order();
        let nnz = t.nnz();
        // fiber histograms per candidate orientation
        let hists: Vec<HashMap<u128, u32>> =
            (0..order).map(|l| stats::fiber_histogram(t, l)).collect();

        // assign each non-zero to the orientation with the densest fiber
        let mut member: Vec<u8> = Vec::with_capacity(nnz);
        for e in 0..nnz {
            let mut best = 0usize;
            let mut best_len = 0u32;
            for l in 0..order {
                let len = hists[l][&stats::fiber_key(t, e, l)];
                if len > best_len {
                    best_len = len;
                    best = l;
                }
            }
            member.push(best as u8);
        }

        // build one sub-COO + CSF per non-empty orientation
        let mut groups = Vec::new();
        for leaf in 0..order {
            let idx: Vec<usize> =
                (0..nnz).filter(|&e| member[e] == leaf as u8).collect();
            if idx.is_empty() {
                continue;
            }
            let mut sub = CooTensor::with_capacity(&t.dims, idx.len());
            for &e in &idx {
                let c = t.coord(e);
                sub.push(&c, t.vals[e]);
            }
            let mo = mode_order_for_leaf(order, leaf);
            groups.push(MmGroup { leaf_mode: leaf, csf: Csf::from_coo(&sub, &mo) });
        }
        MmCsf { dims: t.dims.clone(), groups, nnz }
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    pub fn footprint_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.csf.footprint_bytes()).sum()
    }

    /// Round-trip reconstruction (tests).
    pub fn to_coo(&self) -> CooTensor {
        let mut t = CooTensor::new(&self.dims);
        for g in &self.groups {
            let part = g.csf.to_coo();
            for e in 0..part.nnz() {
                let c = part.coord(e);
                t.push(&c, part.vals[e]);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;
    use std::collections::HashMap as Map;

    fn key_count(t: &CooTensor) -> Map<(Vec<u32>, u64), u32> {
        let mut m = Map::new();
        for e in 0..t.nnz() {
            *m.entry((t.coord(e), t.vals[e].to_bits())).or_insert(0u32) += 1;
        }
        m
    }

    #[test]
    fn partition_covers_every_nnz_once() {
        let t = synth::fiber_clustered(&[50, 60, 70], 5_000, 1, 0.9, 1);
        let m = MmCsf::from_coo(&t);
        let total: usize = m.groups.iter().map(|g| g.csf.nnz()).sum();
        assert_eq!(total, t.nnz());
        assert_eq!(key_count(&m.to_coo()), key_count(&t));
    }

    #[test]
    fn dense_fiber_orientation_wins() {
        // all non-zeros on one mode-2 fiber (0,0,*) plus scattered others:
        // the fiber members must choose orientation leaf=2
        let mut t = CooTensor::new(&[8, 8, 64]);
        for k in 0..32u32 {
            t.push(&[0, 0, k], 1.0);
        }
        t.push(&[1, 2, 3], 1.0);
        t.push(&[4, 5, 6], 1.0);
        let m = MmCsf::from_coo(&t);
        let g2 = m.groups.iter().find(|g| g.leaf_mode == 2).unwrap();
        assert!(g2.csf.nnz() >= 32);
    }

    #[test]
    fn compresses_better_than_fcoo_on_skewed_data() {
        let t = synth::fiber_clustered(&[100, 100, 100], 20_000, 2, 1.2, 2);
        let m = MmCsf::from_coo(&t);
        let f = crate::format::fcoo::FCoo::from_coo(&t, 256);
        assert!(
            m.footprint_bytes() < f.footprint_bytes(),
            "mmcsf {} vs fcoo {}",
            m.footprint_bytes(),
            f.footprint_bytes()
        );
    }

    #[test]
    fn four_mode_partition() {
        let t = synth::uniform(&[12, 10, 8, 6], 2_000, 3);
        let m = MmCsf::from_coo(&t);
        assert_eq!(key_count(&m.to_coo()), key_count(&t));
        for g in &m.groups {
            assert_eq!(g.csf.mode_order.len(), 4);
            assert_eq!(*g.csf.mode_order.last().unwrap(), g.leaf_mode);
        }
    }

    #[test]
    fn mode_order_for_leaf_layout() {
        assert_eq!(mode_order_for_leaf(3, 0), vec![1, 2, 0]);
        assert_eq!(mode_order_for_leaf(3, 1), vec![0, 2, 1]);
        assert_eq!(mode_order_for_leaf(4, 2), vec![0, 1, 3, 2]);
    }
}
