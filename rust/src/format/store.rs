//! The on-disk `.blco` container and the host-out-of-core batch source.
//!
//! The paper's out-of-memory streaming makes *device* memory a non-issue;
//! this module removes the remaining binding constraint — host RAM — by
//! persisting a constructed [`BlcoTensor`] into a checksummed, versioned,
//! little-endian container that the streaming coordinator can read back
//! **block by block**. A [`BlcoStoreReader`] exposes every piece of
//! metadata (dims, order, nnz, per-block keys/sizes, batch maps) from the
//! header alone, and loads block payloads on demand through a
//! bounded-memory LRU [`BlockCache`], so the resident working set is the
//! cache budget — not the tensor size.
//!
//! # Container layout (version 2, everything little-endian)
//!
//! ```text
//! [0..8)    magic  "BLCOSTOR"
//! [8..12)   u32    version (currently 2; version-1 files still open)
//! [12..20)  u64    header length H (bytes of the header blob)
//! [20..20+H)       header blob:
//!                    u32        order
//!                    u64 × ord  dims
//!                    u64        nnz (of the base payload region)
//!                    f64        Frobenius norm of the base values
//!                    u64        max_block_nnz   (BlcoConfig)
//!                    u32        workgroup       (BlcoConfig)
//!                    u32        inblock_budget  (BlcoConfig)
//!                    u32        default codec tag (v2 only)
//!                    u64        number of base blocks B
//!                    B × { u64 key, u64 nnz, u8 codec,
//!                          u64 stored payload length, u32 stored crc32 }
//! [20+H..24+H) u32  crc32 of the header blob
//! [24+H..)         base block payloads, in block order, back to back,
//!                  each `stored length` bytes in its `codec`'s encoding
//! [...)            zero or more appended delta segments, each:
//!                    [0..8)   magic "BLCODSEG"
//!                    [8..16)  u64  segment blob length S
//!                    [16..16+S)    segment blob:
//!                               u64   segment nnz
//!                               f64   sum of squared segment values
//!                               u64   number of segment blocks
//!                               n × { same 29-byte entry as the header }
//!                    [16+S..20+S) u32 crc32 of the segment blob
//!                    [20+S..)     segment block payloads, back to back
//! ```
//!
//! A block's *stored* payload is its [`Codec`]'s encoding of the logical
//! payload (`nnz × u64` in-block indices then `nnz × u64` value bits):
//! sorted linearized indices delta-encode + varint-pack extremely well,
//! and values optionally byte-shuffle + run-length-encode. The per-block
//! crc32 covers the **stored** bytes, so a corrupted compressed payload
//! surfaces as [`StoreError::ChecksumMismatch`] before any decode runs.
//! The [`BlockCache`] holds and budgets *decompressed* payloads (that is
//! what competes for `host_mem_bytes`), while `Counters::bytes_disk`
//! charges the *stored* bytes actually read — which is how compression
//! lowers the modelled host-link traffic.
//!
//! Appends land as LSM-style delta segments at the end of the file — the
//! base header is never rewritten. Readers fold segment blocks into the
//! same block/batch machinery (duplicates across base and delta simply
//! accumulate in MTTKRP, which is the semantics of appending nonzeros);
//! [`read_amplification`](BlcoStoreReader::read_amplification) reports
//! `1 + segments` until [`crate::tensor::ooc::compact`] merges segments
//! back into a fresh base.
//!
//! Version-1 containers (raw payloads, 20-byte index entries, no codec
//! field, no segments) are still read in full; writing always produces
//! version 2.
//!
//! The fixed-layout regions (20-byte preamble, 29-byte index entries) are
//! parsed zero-copy through `#[repr(C)]` byte-array overlays
//! ([`RawPrefix`], [`RawIndexEntry`]) validated in place, instead of
//! field-by-field deserialization.
//!
//! Every open-time failure is a structured [`StoreError`]; payload
//! corruption discovered later (a crc mismatch on a lazily loaded block)
//! surfaces as an error from [`BlcoStoreReader::block`]. The streaming
//! executors treat that as fatal (they panic with the path and block id):
//! a half-streamed MTTKRP has no useful partial answer.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::device::counters::{Counters, Snapshot};
use crate::format::blco::{build_batches_from_nnz, Batch, BlcoConfig, Block, BlcoTensor};
use crate::linear::encode::BlcoSpec;
use crate::tensor::coo::CooTensor;

/// First 8 bytes of every `.blco` container.
pub const STORE_MAGIC: [u8; 8] = *b"BLCOSTOR";

/// First 8 bytes of every appended delta segment.
pub const SEGMENT_MAGIC: [u8; 8] = *b"BLCODSEG";

/// Container version this build writes. Version 1 is still readable.
pub const STORE_VERSION: u32 = 2;

/// Header bytes of one version-1 block-index entry (key, nnz, crc).
const V1_ENTRY_BYTES: usize = 20;

/// Header bytes of one version-2 block-index entry
/// (key, nnz, codec, stored length, crc) — see [`RawIndexEntry`].
const V2_ENTRY_BYTES: usize = 29;

/// Default [`BlockCache`] budget when the caller does not pass one
/// (CLI `inspect`, ad-hoc opens). Engines pass `Profile::host_mem_bytes`.
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

/// Why a container could not be written, opened or read. Open-time
/// variants carry the numbers needed to diagnose the file; all of them
/// are values, never panics.
#[derive(Debug)]
pub enum StoreError {
    /// underlying IO failure, with what we were doing at the time
    Io { context: String, source: std::io::Error },
    /// the first 8 bytes are not [`STORE_MAGIC`]
    BadMagic { found: [u8; 8] },
    /// a container written by an incompatible version of this layout
    UnsupportedVersion { found: u32, supported: u32 },
    /// the file ends before the region the header promises
    Truncated { what: String, needed: u64, available: u64 },
    /// stored checksum does not match the bytes on disk
    ChecksumMismatch { what: String, expected: u32, found: u32 },
    /// internally inconsistent metadata (bad counts, trailing bytes, ...)
    Malformed { what: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io { context, source } => {
                write!(f, "{context}: {source}")
            }
            StoreError::BadMagic { found } => write!(
                f,
                "not a .blco container: magic {found:02x?} != {:02x?}",
                STORE_MAGIC
            ),
            StoreError::UnsupportedVersion { found, supported } => write!(
                f,
                "unsupported container version {found} (this build reads \
                 versions 1..={supported})"
            ),
            StoreError::Truncated { what, needed, available } => write!(
                f,
                "truncated container: {what} needs {needed} bytes, file has \
                 {available}"
            ),
            StoreError::ChecksumMismatch { what, expected, found } => write!(
                f,
                "checksum mismatch in {what}: stored {expected:#010x}, \
                 computed {found:#010x}"
            ),
            StoreError::Malformed { what } => {
                write!(f, "malformed container: {what}")
            }
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

fn io_err(context: impl Into<String>) -> impl FnOnce(std::io::Error) -> StoreError {
    let context = context.into();
    move |source| StoreError::Io { context, source }
}

// ---------------------------------------------------------------- crc32

/// CRC-32 (IEEE 802.3, the zlib polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// --------------------------------------------------------------- codecs

/// Per-block payload encoding. The writer records the codec **actually
/// used** in each index entry, so a block whose encoding would expand
/// (adversarially random indices, incompressible values) silently falls
/// back to [`Codec::None`] — stored payloads never exceed the raw
/// `nnz * 16` bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    /// raw little-endian payload: `nnz × u64` lidx then `nnz × u64` bits
    #[default]
    None,
    /// lidx as zigzag-varint deltas (sorted streams pack to ~1–2 B each);
    /// values raw
    DeltaVarint,
    /// lidx as zigzag-varint deltas; value bits byte-plane transposed,
    /// each plane raw or run-length encoded, whichever is smaller (the
    /// high exponent/sign planes of real-world values are near-constant)
    Shuffled,
}

impl Codec {
    /// Wire tag recorded in the block index.
    pub fn tag(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::DeltaVarint => 1,
            Codec::Shuffled => 2,
        }
    }

    /// Inverse of [`tag`](Self::tag); `None` for unknown wire values.
    pub fn from_tag(t: u8) -> Option<Codec> {
        match t {
            0 => Some(Codec::None),
            1 => Some(Codec::DeltaVarint),
            2 => Some(Codec::Shuffled),
            _ => None,
        }
    }

    /// Stable CLI-facing name.
    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::DeltaVarint => "delta-varint",
            Codec::Shuffled => "shuffled",
        }
    }

    /// Parse a CLI-facing name (`--codec`); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "none" => Some(Codec::None),
            "delta-varint" => Some(Codec::DeltaVarint),
            "shuffled" => Some(Codec::Shuffled),
            _ => None,
        }
    }
}

/// Map a signed delta onto the unsigned varint domain: 0, -1, 1, -2, ...
/// become 0, 1, 2, 3, ... so small deltas of either sign stay short.
fn zigzag(v: i64) -> u64 {
    ((v as u64) << 1) ^ ((v >> 63) as u64)
}

fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

/// LEB128: 7 value bits per byte, high bit = continuation.
fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

/// Decode one LEB128 varint at `*pos`, advancing it. `None` when the
/// stream ends mid-varint (a u64 never needs more than 10 bytes, so the
/// shift loop is bounded and cannot overflow).
fn take_varint(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v = 0u64;
    for shift in (0..64).step_by(7) {
        let b = *buf.get(*pos)?;
        *pos += 1;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
    }
    None
}

/// Serialize one block's **raw** payload — `nnz × u64` in-block indices
/// then `nnz × u64` value bits, all little-endian — into the reusable
/// `buf`. This is the [`Codec::None`] stored form and the logical form
/// every codec round-trips to.
fn serialize_block_payload(buf: &mut Vec<u8>, lidx: &[u64], vals: &[f64]) {
    debug_assert_eq!(lidx.len(), vals.len());
    buf.clear();
    buf.reserve(lidx.len() * 16);
    for &l in lidx {
        buf.extend_from_slice(&l.to_le_bytes());
    }
    for &v in vals {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Append the zigzag-varint delta encoding of the (sorted, but not
/// required to be) lidx stream to `buf`.
fn encode_lidx_deltas(buf: &mut Vec<u8>, lidx: &[u64]) {
    let mut prev = 0u64;
    for &l in lidx {
        put_varint(buf, zigzag(l.wrapping_sub(prev) as i64));
        prev = l;
    }
}

/// Decode `nnz` zigzag-varint lidx deltas from `raw` at `*pos`.
fn decode_lidx_deltas(
    raw: &[u8],
    pos: &mut usize,
    nnz: usize,
    what: &str,
) -> Result<Vec<u64>, StoreError> {
    let mut lidx = Vec::with_capacity(nnz);
    let mut prev = 0u64;
    for _ in 0..nnz {
        let z = take_varint(raw, pos).ok_or_else(|| StoreError::Malformed {
            what: format!("{what}: varint lidx stream ends early"),
        })?;
        prev = prev.wrapping_add(unzigzag(z) as u64);
        lidx.push(prev);
    }
    Ok(lidx)
}

/// Append one byte plane of the value bits: `[flag][data]`, where flag 0
/// is the raw `nnz` bytes and flag 1 a run-length encoding (varint run
/// length ≥ 1, then the byte), whichever is smaller. Deterministic, so
/// the two-pass writer serializes identical bytes both times.
fn encode_value_plane(buf: &mut Vec<u8>, plane: &[u8]) {
    let mut rle: Vec<u8> = Vec::new();
    let mut i = 0usize;
    while i < plane.len() {
        let b = plane[i];
        let mut run = 1usize;
        while i + run < plane.len() && plane[i + run] == b {
            run += 1;
        }
        put_varint(&mut rle, run as u64);
        rle.push(b);
        i += run;
    }
    if rle.len() < plane.len() {
        buf.push(1);
        buf.extend_from_slice(&rle);
    } else {
        buf.push(0);
        buf.extend_from_slice(plane);
    }
}

/// Decode one value byte plane of `nnz` bytes from `raw` at `*pos`.
fn decode_value_plane(
    raw: &[u8],
    pos: &mut usize,
    nnz: usize,
    what: &str,
) -> Result<Vec<u8>, StoreError> {
    let malformed = |detail: &str| StoreError::Malformed {
        what: format!("{what}: {detail}"),
    };
    let flag = *raw.get(*pos).ok_or_else(|| malformed("value plane ends early"))?;
    *pos += 1;
    match flag {
        0 => {
            if *pos + nnz > raw.len() {
                return Err(malformed("raw value plane ends early"));
            }
            let plane = raw[*pos..*pos + nnz].to_vec();
            *pos += nnz;
            Ok(plane)
        }
        1 => {
            let mut plane = Vec::with_capacity(nnz);
            while plane.len() < nnz {
                let run = take_varint(raw, pos)
                    .ok_or_else(|| malformed("RLE value plane ends early"))?
                    as usize;
                let b = *raw
                    .get(*pos)
                    .ok_or_else(|| malformed("RLE value plane ends early"))?;
                *pos += 1;
                if run == 0 || plane.len() + run > nnz {
                    return Err(malformed("RLE run does not tile the value plane"));
                }
                plane.resize(plane.len() + run, b);
            }
            Ok(plane)
        }
        _ => Err(malformed("unknown value plane flag")),
    }
}

/// Encode one block's payload into `buf` with the requested codec,
/// returning the codec **actually stored**: when the encoding would not
/// beat the raw `nnz * 16` bytes, the block falls back to [`Codec::None`]
/// (deterministically — both writer passes make the same choice).
fn encode_block_payload(
    buf: &mut Vec<u8>,
    lidx: &[u64],
    vals: &[f64],
    requested: Codec,
) -> Codec {
    debug_assert_eq!(lidx.len(), vals.len());
    if requested == Codec::None {
        serialize_block_payload(buf, lidx, vals);
        return Codec::None;
    }
    buf.clear();
    encode_lidx_deltas(buf, lidx);
    match requested {
        Codec::None => unreachable!("handled above"),
        Codec::DeltaVarint => {
            for &v in vals {
                buf.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        Codec::Shuffled => {
            // byte-plane transpose: plane p holds byte p of every value's
            // little-endian bit pattern; near-constant high planes RLE away
            let mut plane = Vec::with_capacity(vals.len());
            for p in 0..8 {
                plane.clear();
                for &v in vals {
                    plane.push(v.to_bits().to_le_bytes()[p]);
                }
                encode_value_plane(buf, &plane);
            }
        }
    }
    if buf.len() >= lidx.len() * 16 {
        serialize_block_payload(buf, lidx, vals);
        Codec::None
    } else {
        requested
    }
}

/// Decode a stored payload of `nnz` entries back to `(lidx, vals)`. The
/// caller has already verified the stored crc, so any failure here means
/// the *writer* produced garbage (or the codec tag lies) — reported as
/// [`StoreError::Malformed`], never a panic. The whole stored slice must
/// be consumed: trailing bytes are malformed.
fn decode_block_payload(
    raw: &[u8],
    nnz: usize,
    codec: Codec,
    what: &str,
) -> Result<(Vec<u64>, Vec<f64>), StoreError> {
    match codec {
        Codec::None => {
            if raw.len() != nnz * 16 {
                return Err(StoreError::Malformed {
                    what: format!(
                        "{what}: raw payload is {} bytes, expected {}",
                        raw.len(),
                        nnz * 16
                    ),
                });
            }
            let mut lidx = Vec::with_capacity(nnz);
            for w in 0..nnz {
                lidx.push(u64::from_le_bytes(
                    raw[w * 8..w * 8 + 8].try_into().unwrap(),
                ));
            }
            let vbase = nnz * 8;
            let mut vals = Vec::with_capacity(nnz);
            for w in 0..nnz {
                vals.push(f64::from_bits(u64::from_le_bytes(
                    raw[vbase + w * 8..vbase + w * 8 + 8].try_into().unwrap(),
                )));
            }
            Ok((lidx, vals))
        }
        Codec::DeltaVarint => {
            let mut pos = 0usize;
            let lidx = decode_lidx_deltas(raw, &mut pos, nnz, what)?;
            if raw.len() - pos != nnz * 8 {
                return Err(StoreError::Malformed {
                    what: format!(
                        "{what}: value stream is {} bytes, expected {}",
                        raw.len() - pos,
                        nnz * 8
                    ),
                });
            }
            let mut vals = Vec::with_capacity(nnz);
            for w in 0..nnz {
                vals.push(f64::from_bits(u64::from_le_bytes(
                    raw[pos + w * 8..pos + w * 8 + 8].try_into().unwrap(),
                )));
            }
            Ok((lidx, vals))
        }
        Codec::Shuffled => {
            let mut pos = 0usize;
            let lidx = decode_lidx_deltas(raw, &mut pos, nnz, what)?;
            let mut bits = vec![0u64; nnz];
            for p in 0..8 {
                let plane = decode_value_plane(raw, &mut pos, nnz, what)?;
                for (w, &b) in plane.iter().enumerate() {
                    bits[w] |= (b as u64) << (8 * p);
                }
            }
            if pos != raw.len() {
                return Err(StoreError::Malformed {
                    what: format!(
                        "{what}: {} trailing bytes after the shuffled payload",
                        raw.len() - pos
                    ),
                });
            }
            Ok((lidx, bits.into_iter().map(f64::from_bits).collect()))
        }
    }
}

// ------------------------------------------------- little-endian helpers

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Sequential little-endian reader over a byte slice with
/// truncation-checked takes.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], StoreError> {
        if self.pos + n > self.buf.len() {
            return Err(StoreError::Truncated {
                what: format!("header field {what}"),
                needed: (self.pos + n) as u64,
                available: self.buf.len() as u64,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64, StoreError> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

// ------------------------------------------------- zero-copy fixed layout

/// The 20-byte file preamble, overlaid in place (SNIPPETS-style
/// `Ref::new_from_prefix` idiom, without the external crate): a `repr(C)`
/// struct of byte arrays has align 1, no padding, and every bit pattern
/// valid, so a plain pointer cast over the read buffer is sound.
#[repr(C)]
struct RawPrefix {
    magic: [u8; 8],
    version: [u8; 4],
    header_len: [u8; 8],
}

const _: () = assert!(std::mem::size_of::<RawPrefix>() == 20);

impl RawPrefix {
    /// Overlay the preamble on a 20-byte buffer.
    fn overlay(buf: &[u8; 20]) -> &RawPrefix {
        // SAFETY: RawPrefix is repr(C) of byte arrays only — size 20
        // (const-asserted), align 1, no padding, any bit pattern valid —
        // and the borrow of `buf` pins the bytes for the returned lifetime.
        unsafe { &*(buf.as_ptr() as *const RawPrefix) }
    }

    fn version(&self) -> u32 {
        u32::from_le_bytes(self.version)
    }

    fn header_len(&self) -> u64 {
        u64::from_le_bytes(self.header_len)
    }
}

/// One 29-byte version-2 block-index entry, overlaid in place over the
/// header (or segment) blob instead of field-by-field deserialization.
#[repr(C)]
struct RawIndexEntry {
    key: [u8; 8],
    nnz: [u8; 8],
    codec: u8,
    stored_len: [u8; 8],
    crc: [u8; 4],
}

const _: () = assert!(std::mem::size_of::<RawIndexEntry>() == V2_ENTRY_BYTES);

impl RawIndexEntry {
    /// Overlay `count` entries on a `count * 29`-byte region of a blob.
    fn overlay_slice(region: &[u8], count: usize) -> &[RawIndexEntry] {
        debug_assert_eq!(region.len(), count * V2_ENTRY_BYTES);
        // SAFETY: RawIndexEntry is repr(C) of u8/byte arrays only — size
        // 29 (const-asserted), align 1, no padding, any bit pattern
        // valid; the region's length is exactly count * 29 and the borrow
        // of `region` pins the bytes for the returned lifetime.
        unsafe {
            std::slice::from_raw_parts(region.as_ptr() as *const RawIndexEntry, count)
        }
    }

    fn key(&self) -> u64 {
        u64::from_le_bytes(self.key)
    }

    fn nnz(&self) -> u64 {
        u64::from_le_bytes(self.nnz)
    }

    fn stored_len(&self) -> u64 {
        u64::from_le_bytes(self.stored_len)
    }

    fn crc(&self) -> u32 {
        u32::from_le_bytes(self.crc)
    }
}

// ------------------------------------------------------------ the writer

/// Summary of a written container (what `blco convert` prints).
#[derive(Clone, Debug)]
pub struct StoreSummary {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub header_bytes: usize,
    /// logical (decompressed) payload bytes: `nnz * 16`
    pub payload_bytes: usize,
    /// payload bytes actually on disk after per-block encoding
    pub stored_bytes: usize,
    /// codec the writer was asked for (individual blocks may have fallen
    /// back to [`Codec::None`]; the block index records the truth)
    pub codec: Codec,
    pub blocks: usize,
    pub batches: usize,
    pub nnz: usize,
}

/// Summary of one [`BlcoStoreWriter::append`] delta segment.
#[derive(Clone, Debug)]
pub struct AppendSummary {
    pub path: PathBuf,
    pub appended_nnz: usize,
    /// blocks in the new segment
    pub blocks: usize,
    /// bytes the file grew by (segment framing + blob + payloads)
    pub segment_bytes: u64,
    /// delta segments now pending on the container, this one included
    pub segments: usize,
}

/// Per-block header-index currency both writers ([`BlcoStore::write_with`]
/// and [`BlcoStoreWriter`]) serialize the block index from, so their
/// headers are byte-identical by construction.
#[derive(Clone, Copy, Debug)]
pub struct BlockIndexEntry {
    pub key: u64,
    pub nnz: u64,
    /// codec actually stored (after any fallback)
    pub codec: Codec,
    /// stored payload length in bytes
    pub stored_len: u64,
    /// crc32 of the stored payload bytes
    pub crc: u32,
}

/// Build the version-2 header blob from streamed metadata alone. Both
/// writers call this, which is what guarantees the out-of-core path's
/// container is bit-for-bit the in-memory one (given equal blocks).
fn build_header_blob(
    dims: &[u64],
    nnz: u64,
    norm: f64,
    config: &BlcoConfig,
    default_codec: Codec,
    entries: &[BlockIndexEntry],
) -> Vec<u8> {
    let mut header = Vec::with_capacity(64 + entries.len() * V2_ENTRY_BYTES);
    put_u32(&mut header, dims.len() as u32);
    for &d in dims {
        put_u64(&mut header, d);
    }
    put_u64(&mut header, nnz);
    put_f64(&mut header, norm);
    put_u64(&mut header, config.max_block_nnz as u64);
    put_u32(&mut header, config.workgroup as u32);
    put_u32(&mut header, config.inblock_budget);
    put_u32(&mut header, default_codec.tag() as u32);
    put_u64(&mut header, entries.len() as u64);
    for e in entries {
        put_index_entry(&mut header, e);
    }
    header
}

/// Serialize one 29-byte index entry (the [`RawIndexEntry`] layout).
fn put_index_entry(buf: &mut Vec<u8>, e: &BlockIndexEntry) {
    put_u64(buf, e.key);
    put_u64(buf, e.nnz);
    buf.push(e.codec.tag());
    put_u64(buf, e.stored_len);
    put_u32(buf, e.crc);
}

/// Writer namespace for the `.blco` container.
pub struct BlcoStore;

impl BlcoStore {
    /// Serialize a constructed BLCO tensor into the container at `path`
    /// (overwriting any existing file) with raw ([`Codec::None`])
    /// payloads. The written payload is the exact block content — `u64`
    /// indices and `f64` bit patterns — so a read-back MTTKRP is
    /// bit-for-bit the resident one.
    pub fn write(t: &BlcoTensor, path: &Path) -> Result<StoreSummary, StoreError> {
        Self::write_with(t, path, Codec::None)
    }

    /// [`write`](Self::write) with a per-block payload codec. Whatever
    /// the codec, a read-back MTTKRP is bit-for-bit the resident one —
    /// every codec round-trips the exact u64 index and f64 bit patterns.
    pub fn write_with(
        t: &BlcoTensor,
        path: &Path,
        codec: Codec,
    ) -> Result<StoreSummary, StoreError> {
        // one reusable serialization buffer: each block is encoded twice
        // (pass 1 for the header index, pass 2 to stream the payload
        // region out — the codecs are deterministic, so both passes
        // produce identical bytes), keeping peak extra memory at O(one
        // block), not O(tensor)
        let mut buf: Vec<u8> = Vec::new();

        // ---- header blob (pass 1 over the blocks)
        let entries: Vec<BlockIndexEntry> = t
            .blocks
            .iter()
            .map(|blk| {
                let stored = encode_block_payload(&mut buf, &blk.lidx, &blk.vals, codec);
                BlockIndexEntry {
                    key: blk.key,
                    nnz: blk.nnz() as u64,
                    codec: stored,
                    stored_len: buf.len() as u64,
                    crc: crc32(&buf),
                }
            })
            .collect();
        let header =
            build_header_blob(t.dims(), t.nnz as u64, t.norm(), &t.config, codec, &entries);

        // ---- file (pass 2 streams the payloads)
        let file = File::create(path)
            .map_err(io_err(format!("create {}", path.display())))?;
        let mut w = std::io::BufWriter::new(file);
        let ctx = || format!("write {}", path.display());
        w.write_all(&STORE_MAGIC).map_err(io_err(ctx()))?;
        w.write_all(&STORE_VERSION.to_le_bytes()).map_err(io_err(ctx()))?;
        w.write_all(&(header.len() as u64).to_le_bytes()).map_err(io_err(ctx()))?;
        w.write_all(&header).map_err(io_err(ctx()))?;
        w.write_all(&crc32(&header).to_le_bytes()).map_err(io_err(ctx()))?;
        let mut stored_bytes = 0usize;
        let mut payload_bytes = 0usize;
        for blk in &t.blocks {
            encode_block_payload(&mut buf, &blk.lidx, &blk.vals, codec);
            w.write_all(&buf).map_err(io_err(ctx()))?;
            stored_bytes += buf.len();
            payload_bytes += blk.nnz() * 16;
        }
        w.flush().map_err(io_err(ctx()))?;

        Ok(StoreSummary {
            path: path.to_path_buf(),
            file_bytes: (24 + header.len() + stored_bytes) as u64,
            header_bytes: header.len(),
            payload_bytes,
            stored_bytes,
            codec,
            blocks: t.blocks.len(),
            batches: t.batches.len(),
            nnz: t.nnz,
        })
    }
}

// -------------------------------------------------- the incremental writer

/// Incremental `.blco` writer for block streams whose header (nnz, norm,
/// block index) is unknown until the last block: the out-of-core builder
/// ([`crate::tensor::ooc`]) emits merged blocks one at a time and never
/// holds the tensor.
///
/// The container's header *precedes* the payload region, so payloads are
/// staged in a sibling temp file (`<path>.payload.tmp`, same directory ⇒
/// same filesystem) and copied behind the finished header at
/// [`finish`](Self::finish). Peak memory is one encoded block; the
/// transient disk cost is one extra copy of the payload region. Dropping
/// the writer without `finish` removes the temp file and never touches
/// `path`.
///
/// Norm accounting mirrors [`BlcoTensor::norm`] bit for bit: values are
/// squared and summed in block-emission order, then rooted once at
/// finish, so a streamed build writes the exact header bytes the
/// in-memory `from_coo` → [`BlcoStore::write`] path would.
pub struct BlcoStoreWriter {
    path: PathBuf,
    tmp_path: PathBuf,
    payload: Option<std::io::BufWriter<File>>,
    dims: Vec<u64>,
    config: BlcoConfig,
    codec: Codec,
    entries: Vec<BlockIndexEntry>,
    nnz: u64,
    sumsq: f64,
    buf: Vec<u8>,
    payload_bytes: usize,
    stored_bytes: usize,
}

impl BlcoStoreWriter {
    /// Start a container at `path` for a tensor over `dims`, storing raw
    /// ([`Codec::None`]) payloads. Rejects the same config shapes
    /// `BlcoTensor::try_from_coo_with` does — as a structured error, not
    /// a panic, since a bad config here usually arrived from CLI flags.
    pub fn create(
        path: &Path,
        dims: &[u64],
        config: BlcoConfig,
    ) -> Result<Self, StoreError> {
        Self::create_with_codec(path, dims, config, Codec::None)
    }

    /// [`create`](Self::create) with a per-block payload codec.
    pub fn create_with_codec(
        path: &Path,
        dims: &[u64],
        config: BlcoConfig,
        codec: Codec,
    ) -> Result<Self, StoreError> {
        if config.workgroup == 0 {
            return Err(StoreError::Malformed {
                what: "BlcoConfig.workgroup must be > 0".into(),
            });
        }
        if config.max_block_nnz == 0 {
            return Err(StoreError::Malformed {
                what: "BlcoConfig.max_block_nnz must be > 0".into(),
            });
        }
        if dims.is_empty() || dims.iter().any(|&d| d == 0) {
            return Err(StoreError::Malformed {
                what: format!("bad dims {dims:?}: every mode must be > 0"),
            });
        }
        let tmp_path = PathBuf::from(format!("{}.payload.tmp", path.display()));
        let file = File::create(&tmp_path)
            .map_err(io_err(format!("create {}", tmp_path.display())))?;
        Ok(BlcoStoreWriter {
            path: path.to_path_buf(),
            tmp_path,
            payload: Some(std::io::BufWriter::new(file)),
            dims: dims.to_vec(),
            config,
            codec,
            entries: Vec::new(),
            nnz: 0,
            sumsq: 0.0,
            buf: Vec::new(),
            payload_bytes: 0,
            stored_bytes: 0,
        })
    }

    /// Append one finished block (non-empty, `≤ max_block_nnz`, keys
    /// non-decreasing across calls — the merge emits them in ALTO order).
    pub fn add_block(
        &mut self,
        key: u64,
        lidx: &[u64],
        vals: &[f64],
    ) -> Result<(), StoreError> {
        assert_eq!(lidx.len(), vals.len(), "ragged block");
        assert!(!vals.is_empty(), "empty block");
        assert!(vals.len() <= self.config.max_block_nnz, "block over budget");
        let stored = encode_block_payload(&mut self.buf, lidx, vals, self.codec);
        self.entries.push(BlockIndexEntry {
            key,
            nnz: vals.len() as u64,
            codec: stored,
            stored_len: self.buf.len() as u64,
            crc: crc32(&self.buf),
        });
        self.nnz += vals.len() as u64;
        for &v in vals {
            self.sumsq += v * v;
        }
        self.payload_bytes += vals.len() * 16;
        self.stored_bytes += self.buf.len();
        let w = self.payload.as_mut().expect("writer already finished");
        w.write_all(&self.buf)
            .map_err(io_err(format!("write {}", self.tmp_path.display())))
    }

    /// Blocks written so far.
    pub fn blocks(&self) -> usize {
        self.entries.len()
    }

    /// Bytes of writer-held state (block index + serialization buffer) —
    /// feeds the out-of-core builder's peak-memory accounting.
    pub fn held_bytes(&self) -> usize {
        self.entries.capacity() * std::mem::size_of::<BlockIndexEntry>()
            + self.buf.capacity()
    }

    /// Write the header in front of the staged payloads and produce the
    /// final container. Consumes the writer; the temp file is removed.
    pub fn finish(mut self) -> Result<StoreSummary, StoreError> {
        // flush + close the payload stage before reading it back
        let mut w = self.payload.take().expect("writer already finished");
        w.flush()
            .map_err(io_err(format!("flush {}", self.tmp_path.display())))?;
        drop(w);

        let norm = self.sumsq.sqrt();
        let header = build_header_blob(
            &self.dims,
            self.nnz,
            norm,
            &self.config,
            self.codec,
            &self.entries,
        );
        let batches = build_batches_from_nnz(
            &self.entries.iter().map(|e| e.nnz as usize).collect::<Vec<_>>(),
            &self.config,
        );

        let file = File::create(&self.path)
            .map_err(io_err(format!("create {}", self.path.display())))?;
        let mut out = std::io::BufWriter::new(file);
        let ctx = || format!("write {}", self.path.display());
        out.write_all(&STORE_MAGIC).map_err(io_err(ctx()))?;
        out.write_all(&STORE_VERSION.to_le_bytes()).map_err(io_err(ctx()))?;
        out.write_all(&(header.len() as u64).to_le_bytes())
            .map_err(io_err(ctx()))?;
        out.write_all(&header).map_err(io_err(ctx()))?;
        out.write_all(&crc32(&header).to_le_bytes()).map_err(io_err(ctx()))?;
        let mut stage = File::open(&self.tmp_path)
            .map_err(io_err(format!("open {}", self.tmp_path.display())))?;
        let copied = std::io::copy(&mut stage, &mut out).map_err(io_err(
            format!(
                "copy {} -> {}",
                self.tmp_path.display(),
                self.path.display()
            ),
        ))?;
        if copied != self.stored_bytes as u64 {
            return Err(StoreError::Malformed {
                what: format!(
                    "payload stage holds {copied} bytes, wrote {}",
                    self.stored_bytes
                ),
            });
        }
        out.flush().map_err(io_err(ctx()))?;
        drop(stage);

        Ok(StoreSummary {
            path: self.path.clone(),
            file_bytes: (24 + header.len() + self.stored_bytes) as u64,
            header_bytes: header.len(),
            payload_bytes: self.payload_bytes,
            stored_bytes: self.stored_bytes,
            codec: self.codec,
            blocks: self.entries.len(),
            batches: batches.len(),
            nnz: self.nnz as usize,
        })
        // Drop::drop removes the temp file
    }

    /// Append new nonzeros to an existing **version-2** container as one
    /// LSM-style delta segment at the end of the file. The base header is
    /// never rewritten; readers fold segment blocks into the batch maps,
    /// and duplicates across base and delta simply accumulate in MTTKRP —
    /// the semantics of appending. `codec` defaults to the container's
    /// default codec. The segment is built in memory (it is a memtable
    /// flush, not a bulk load — bulk loads go through
    /// [`crate::tensor::ooc`]); [`crate::tensor::ooc::compact`] later
    /// merges all segments back into a fresh base.
    pub fn append(
        path: &Path,
        t: &CooTensor,
        codec: Option<Codec>,
    ) -> Result<AppendSummary, StoreError> {
        let reader = BlcoStoreReader::open(path)?;
        if reader.version() != STORE_VERSION {
            return Err(StoreError::Malformed {
                what: format!(
                    "append requires a version-2 container; {} is version {} \
                     — rewrite it with `convert` first",
                    path.display(),
                    reader.version()
                ),
            });
        }
        t.validate().map_err(|e| StoreError::Malformed {
            what: format!("append tensor: {e}"),
        })?;
        if reader.dims() != t.dims.as_slice() {
            return Err(StoreError::Malformed {
                what: format!(
                    "append dims {:?} != container dims {:?}",
                    t.dims,
                    reader.dims()
                ),
            });
        }
        if t.nnz() == 0 {
            return Err(StoreError::Malformed {
                what: "append of zero non-zeros".into(),
            });
        }
        let codec = codec.unwrap_or(reader.default_codec());
        let spec = reader.spec().clone();
        let config = *reader.config();
        let prior_segments = reader.segments();
        drop(reader);

        // ALTO-linearize + sort, exactly the from_coo total order: ties on
        // the line keep input position, so duplicate coordinates land in
        // append order (what a from-scratch rebuild of base ++ appended
        // would produce — the compact bit-parity guarantee rests on this)
        let order = t.dims.len();
        let mut coord = vec![0u32; order];
        let mut pairs: Vec<(u128, u32)> = Vec::with_capacity(t.nnz());
        for e in 0..t.nnz() {
            for (m, c) in coord.iter_mut().enumerate() {
                *c = t.coords[m][e];
            }
            pairs.push((spec.alto.encode(&coord), e as u32));
        }
        pairs.sort_unstable();

        // split into blocks on key change or block-budget overflow, then
        // encode each block into the segment payload buffer
        let mut entries: Vec<BlockIndexEntry> = Vec::new();
        let mut payloads: Vec<u8> = Vec::new();
        let mut buf: Vec<u8> = Vec::new();
        let mut sumsq = 0.0f64;
        let mut cur_key = 0u64;
        let mut lidx: Vec<u64> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        let mut flush = |key: u64, lidx: &mut Vec<u64>, vals: &mut Vec<f64>| {
            if lidx.is_empty() {
                return;
            }
            let stored = encode_block_payload(&mut buf, lidx, vals, codec);
            entries.push(BlockIndexEntry {
                key,
                nnz: vals.len() as u64,
                codec: stored,
                stored_len: buf.len() as u64,
                crc: crc32(&buf),
            });
            for &v in vals.iter() {
                sumsq += v * v;
            }
            payloads.extend_from_slice(&buf);
            lidx.clear();
            vals.clear();
        };
        for &(line, e) in &pairs {
            let (key, l) = spec.reencode_alto(line);
            if (key != cur_key && !lidx.is_empty()) || lidx.len() >= config.max_block_nnz
            {
                flush(cur_key, &mut lidx, &mut vals);
            }
            cur_key = key;
            lidx.push(l);
            vals.push(t.vals[e as usize]);
        }
        flush(cur_key, &mut lidx, &mut vals);

        // segment blob + framing, appended in one go at EOF
        let mut blob = Vec::with_capacity(24 + entries.len() * V2_ENTRY_BYTES);
        put_u64(&mut blob, t.nnz() as u64);
        put_f64(&mut blob, sumsq);
        put_u64(&mut blob, entries.len() as u64);
        for e in &entries {
            put_index_entry(&mut blob, e);
        }
        let mut file = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(io_err(format!("append to {}", path.display())))?;
        let ctx = || format!("append segment to {}", path.display());
        file.write_all(&SEGMENT_MAGIC).map_err(io_err(ctx()))?;
        file.write_all(&(blob.len() as u64).to_le_bytes())
            .map_err(io_err(ctx()))?;
        file.write_all(&blob).map_err(io_err(ctx()))?;
        file.write_all(&crc32(&blob).to_le_bytes()).map_err(io_err(ctx()))?;
        file.write_all(&payloads).map_err(io_err(ctx()))?;
        file.flush().map_err(io_err(ctx()))?;

        Ok(AppendSummary {
            path: path.to_path_buf(),
            appended_nnz: t.nnz(),
            blocks: entries.len(),
            segment_bytes: (20 + blob.len() + payloads.len()) as u64,
            segments: prior_segments + 1,
        })
    }
}

impl Drop for BlcoStoreWriter {
    fn drop(&mut self) {
        // close the stage handle first (no-op if finish already took it),
        // then clean up; an aborted build must not leak temp payloads
        self.payload.take();
        std::fs::remove_file(&self.tmp_path).ok();
    }
}

// ------------------------------------------------------------- the cache

/// Point-in-time statistics of a [`BlockCache`]. `peak_resident_bytes`
/// never exceeding `budget_bytes` is the host-out-of-core acceptance
/// observable the round-trip tests assert.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// demand lookups that found a block staged by the prefetcher — the
    /// direct measure of I/O successfully hidden behind compute
    pub prefetch_hits: u64,
    /// prefetched blocks evicted before any demand touch (prefetch I/O
    /// that bought nothing; a high count means the budget is too small
    /// to hold the working set plus one batch of lookahead)
    pub prefetch_wasted: u64,
    /// **stored** bytes read from disk (the encoded payload of every
    /// miss) — compression lowers this, not residency
    pub disk_bytes: u64,
    /// decompressed block payload bytes currently held
    pub resident_bytes: usize,
    /// high-water mark of host payload residency (decompressed bytes —
    /// that is what competes for host RAM), *including* any single
    /// over-budget block handed out uncached — so the invariant
    /// `peak_resident_bytes <= budget_bytes` fails honestly when the
    /// budget cannot bound residency, rather than passing vacuously
    pub peak_resident_bytes: usize,
    pub budget_bytes: usize,
}

struct CacheEntry {
    block: Arc<Block>,
    /// last-touch tick (LRU recency)
    last: u64,
    /// staged by the prefetcher and not yet demanded: the first demand
    /// `get` clears this and counts a prefetch hit; eviction while still
    /// set counts a wasted prefetch
    prefetched: bool,
}

struct CacheInner {
    /// block id → cache entry
    map: HashMap<usize, CacheEntry>,
    resident_bytes: usize,
    tick: u64,
}

/// Bounded-memory LRU over loaded blocks: at most `budget` bytes of
/// **decompressed** payload stay resident; least-recently-used blocks are
/// evicted to make room. Disk traffic (`disk_bytes`) is charged by the
/// reader in *stored* bytes — the two currencies diverge exactly when a
/// codec is doing its job. A single block larger than the whole budget is
/// returned to the caller but never inserted — the cache map stays under
/// budget, and the over-budget hand-out is charged to
/// `peak_resident_bytes` so the violation is observable.
pub struct BlockCache {
    budget: usize,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    prefetch_hits: AtomicU64,
    prefetch_wasted: AtomicU64,
    disk_bytes: AtomicU64,
    peak: AtomicUsize,
}

impl BlockCache {
    pub fn new(budget: usize) -> Self {
        BlockCache {
            budget,
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                resident_bytes: 0,
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            prefetch_wasted: AtomicU64::new(0),
            disk_bytes: AtomicU64::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Whether block `i` is resident, without touching recency or stats —
    /// the prefetcher's peek must not perturb what it is measuring.
    fn contains(&self, i: usize) -> bool {
        self.inner.lock().expect("block cache poisoned").map.contains_key(&i)
    }

    /// Look up block `i`, refreshing its recency on a hit.
    fn get(&self, i: usize) -> Option<Arc<Block>> {
        let mut inner = self.inner.lock().expect("block cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&i) {
            Some(e) => {
                e.last = tick;
                if e.prefetched {
                    e.prefetched = false;
                    self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.block))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Charge stored bytes read from disk (the reader calls this on every
    /// miss with the block's *encoded* length — residency accounting in
    /// `insert` stays in decompressed bytes).
    fn add_disk_bytes(&self, stored: u64) {
        self.disk_bytes.fetch_add(stored, Ordering::Relaxed);
    }

    /// Insert a freshly loaded (decompressed) block, evicting LRU entries
    /// until it fits. Returns how many blocks were evicted.
    fn insert(&self, i: usize, block: Arc<Block>, prefetched: bool) -> usize {
        let bytes = block.bytes();
        if bytes > self.budget {
            // over-budget single block: hand it out uncached — but charge
            // it to the high-water mark, so `peak <= budget` assertions
            // honestly FAIL when the budget cannot bound residency at all
            // (raise the budget or shrink max_block_nnz), instead of
            // passing vacuously while the caller holds the payload anyway
            let inner = self.inner.lock().expect("block cache poisoned");
            self.peak.fetch_max(inner.resident_bytes + bytes, Ordering::Relaxed);
            return 0;
        }
        let mut inner = self.inner.lock().expect("block cache poisoned");
        let mut evicted = 0usize;
        while inner.resident_bytes + bytes > self.budget {
            let lru = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last)
                .map(|(&k, _)| k)
                .expect("resident_bytes > 0 implies a resident block");
            let gone = inner.map.remove(&lru).expect("lru key present");
            inner.resident_bytes -= gone.block.bytes();
            if gone.prefetched {
                self.prefetch_wasted.fetch_add(1, Ordering::Relaxed);
            }
            evicted += 1;
        }
        inner.tick += 1;
        let tick = inner.tick;
        // two threads can race a miss on the same block; replacing must
        // not double-count the payload
        if let Some(old) = inner.map.insert(i, CacheEntry { block, last: tick, prefetched }) {
            inner.resident_bytes -= old.block.bytes();
        }
        inner.resident_bytes += bytes;
        self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        self.peak.fetch_max(inner.resident_bytes, Ordering::Relaxed);
        evicted
    }

    /// Stage a block loaded by the prefetcher: counted as a miss (the
    /// payload did come off disk) and flagged so the first demand `get`
    /// reports a prefetch hit, and an eviction-before-use reports waste.
    fn stage_prefetched(&self, i: usize, block: Arc<Block>) -> usize {
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.insert(i, block, true)
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().expect("block cache poisoned");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_wasted: self.prefetch_wasted.load(Ordering::Relaxed),
            disk_bytes: self.disk_bytes.load(Ordering::Relaxed),
            resident_bytes: inner.resident_bytes,
            peak_resident_bytes: self.peak.load(Ordering::Relaxed),
            budget_bytes: self.budget,
        }
    }
}

// ------------------------------------------------------------ the reader

/// Header-resident metadata of one stored block (base or delta segment).
#[derive(Clone, Copy, Debug)]
pub struct BlockMeta {
    pub key: u64,
    pub nnz: usize,
    /// absolute stored-payload offset in the file
    pub offset: u64,
    /// decompressed payload length (`nnz * 16`) — the cache/residency and
    /// host→device wire currency, identical across tiers
    pub bytes: usize,
    /// stored (encoded) payload length on disk — the disk-read currency
    pub stored_len: usize,
    pub codec: Codec,
    /// crc32 of the stored payload bytes
    pub crc: u32,
}

/// Validate `count` zero-copy-overlaid version-2 index entries starting
/// at file offset `offset`, pushing a [`BlockMeta`] per entry. Shared by
/// the base header and every delta segment blob (`label` names which).
/// Returns `(end offset, nnz sum)`.
fn parse_v2_entries(
    region: &[u8],
    count: usize,
    label: &str,
    mut offset: u64,
    metas: &mut Vec<BlockMeta>,
) -> Result<(u64, u64), StoreError> {
    let raw = RawIndexEntry::overlay_slice(region, count);
    let mut total_nnz = 0u64;
    for (b, e) in raw.iter().enumerate() {
        let nnz64 = e.nnz();
        if nnz64 == 0 {
            return Err(StoreError::Malformed {
                what: format!("{label}[{b}] has zero non-zeros"),
            });
        }
        // decompressed size, with the wrap a crafted header could force
        // rejected instead of allocated
        let bytes = nnz64.checked_mul(16).ok_or_else(|| StoreError::Malformed {
            what: format!("{label}[{b}] non-zeros count {nnz64} overflows"),
        })?;
        let codec = Codec::from_tag(e.codec).ok_or_else(|| StoreError::Malformed {
            what: format!("{label}[{b}] has unknown codec tag {}", e.codec),
        })?;
        let stored_len = e.stored_len();
        match codec {
            // raw payloads have exactly one valid length
            Codec::None if stored_len != bytes => {
                return Err(StoreError::Malformed {
                    what: format!(
                        "{label}[{b}] claims {nnz64} non-zeros but stores \
                         {stored_len} bytes raw"
                    ),
                });
            }
            // every codec spends ≥ 1 stored byte per nonzero (varint lidx
            // delta + value planes), so this bounds the decompressed
            // allocation at 16× the stored bytes a crafted header can
            // actually point at
            Codec::DeltaVarint | Codec::Shuffled if nnz64 > stored_len => {
                return Err(StoreError::Malformed {
                    what: format!(
                        "{label}[{b}] claims {nnz64} non-zeros in only \
                         {stored_len} stored bytes"
                    ),
                });
            }
            _ => {}
        }
        metas.push(BlockMeta {
            key: e.key(),
            nnz: nnz64 as usize,
            offset,
            bytes: bytes as usize,
            stored_len: stored_len as usize,
            codec,
            crc: e.crc(),
        });
        offset = offset.checked_add(stored_len).ok_or_else(|| {
            StoreError::Malformed {
                what: format!("payload offsets overflow at {label}[{b}]"),
            }
        })?;
        total_nnz = total_nnz.checked_add(nnz64).ok_or_else(|| {
            StoreError::Malformed {
                what: format!("nnz total overflows at {label}[{b}]"),
            }
        })?;
    }
    Ok((offset, total_nnz))
}

/// mmap-free reader over a `.blco` container: all metadata (dims, spec,
/// per-block index — base and delta segments, rebuilt batches) lives in
/// memory from the header alone; block payloads load and decode on demand
/// through the bounded [`BlockCache`].
pub struct BlcoStoreReader {
    path: PathBuf,
    file: Mutex<File>,
    version: u32,
    default_codec: Codec,
    spec: BlcoSpec,
    config: BlcoConfig,
    nnz: usize,
    norm: f64,
    metas: Vec<BlockMeta>,
    /// blocks in the base payload region; `metas[base_blocks..]` are
    /// delta-segment blocks
    base_blocks: usize,
    /// pending delta segments
    segments: usize,
    batches: Vec<Batch>,
    cache: BlockCache,
}

impl BlcoStoreReader {
    /// Open with the default cache budget ([`DEFAULT_CACHE_BYTES`]).
    pub fn open(path: &Path) -> Result<Self, StoreError> {
        Self::open_with_budget(path, DEFAULT_CACHE_BYTES)
    }

    /// Open, validating magic/version/checksums/sizes — both container
    /// versions, and any appended delta segments — with an explicit
    /// [`BlockCache`] budget in bytes (engines pass
    /// `Profile::host_mem_bytes`).
    pub fn open_with_budget(
        path: &Path,
        cache_budget: usize,
    ) -> Result<Self, StoreError> {
        Self::open_pinned(path, cache_budget, None)
    }

    /// Open a **snapshot view** of the container pinned to its first
    /// `max_segments` delta segments: blocks, nnz and norm beyond the pin
    /// are excluded from every derived structure (batch maps, `nnz()`,
    /// `norm()`, `to_tensor()`), so the view is bit-for-bit the container
    /// as it stood before the later appends landed. Appends only ever
    /// grow the file past the pinned frames, so a pinned reader stays
    /// valid while writers append behind it — this is how the serving
    /// layer keeps in-flight jobs on the pre-append segment set while new
    /// jobs see the appended view. Segments past the pin are still fully
    /// validated (magic, checksums, sizes): a corrupt tail fails the open
    /// even when the snapshot would not read it. `max_segments` larger
    /// than the pending count simply keeps every segment;
    /// `None` is the unpinned [`Self::open_with_budget`] view.
    pub fn open_pinned(
        path: &Path,
        cache_budget: usize,
        max_segments: Option<usize>,
    ) -> Result<Self, StoreError> {
        let mut file = File::open(path)
            .map_err(io_err(format!("open {}", path.display())))?;
        let file_len = file
            .metadata()
            .map_err(io_err(format!("stat {}", path.display())))?
            .len();

        // ---- fixed preamble (zero-copy overlay)
        let mut pre = [0u8; 20];
        if file_len < 20 {
            return Err(StoreError::Truncated {
                what: "magic + version + header length".into(),
                needed: 20,
                available: file_len,
            });
        }
        file.read_exact(&mut pre)
            .map_err(io_err(format!("read preamble of {}", path.display())))?;
        let prefix = RawPrefix::overlay(&pre);
        if prefix.magic != STORE_MAGIC {
            return Err(StoreError::BadMagic { found: prefix.magic });
        }
        let version = prefix.version();
        if version == 0 || version > STORE_VERSION {
            return Err(StoreError::UnsupportedVersion {
                found: version,
                supported: STORE_VERSION,
            });
        }
        let header_len = prefix.header_len();
        if header_len > file_len.saturating_sub(24) {
            return Err(StoreError::Truncated {
                what: "header blob + checksum".into(),
                needed: 24 + header_len,
                available: file_len,
            });
        }

        // ---- header blob + its checksum
        let mut header = vec![0u8; header_len as usize];
        file.read_exact(&mut header)
            .map_err(io_err(format!("read header of {}", path.display())))?;
        let mut crc_buf = [0u8; 4];
        file.read_exact(&mut crc_buf)
            .map_err(io_err(format!("read header crc of {}", path.display())))?;
        let stored_crc = u32::from_le_bytes(crc_buf);
        let computed = crc32(&header);
        if stored_crc != computed {
            return Err(StoreError::ChecksumMismatch {
                what: "header".into(),
                expected: stored_crc,
                found: computed,
            });
        }

        // ---- parse
        let mut c = Cursor::new(&header);
        let order = c.u32("order")? as usize;
        if order == 0 || order > 16 {
            return Err(StoreError::Malformed {
                what: format!("order {order} outside 1..=16"),
            });
        }
        let mut dims = Vec::with_capacity(order);
        for n in 0..order {
            let d = c.u64(&format!("dims[{n}]"))?;
            if d == 0 {
                return Err(StoreError::Malformed {
                    what: format!("dims[{n}] is zero"),
                });
            }
            dims.push(d);
        }
        let nnz = c.u64("nnz")? as usize;
        let norm = c.f64("norm")?;
        let max_block_nnz = c.u64("max_block_nnz")? as usize;
        let workgroup = c.u32("workgroup")? as usize;
        let inblock_budget = c.u32("inblock_budget")?;
        if max_block_nnz == 0 || workgroup == 0 {
            return Err(StoreError::Malformed {
                what: "max_block_nnz and workgroup must be > 0".into(),
            });
        }
        let default_codec = if version >= 2 {
            let tag = c.u32("default codec")?;
            u8::try_from(tag)
                .ok()
                .and_then(Codec::from_tag)
                .ok_or_else(|| StoreError::Malformed {
                    what: format!("unknown default codec tag {tag}"),
                })?
        } else {
            Codec::None
        };
        let nblocks = c.u64("block count")? as usize;
        // each index entry takes 20 (v1) or 29 (v2) header bytes; a count
        // the header cannot physically hold is malformed (and must not
        // drive a pre-allocation)
        let entry_bytes = if version >= 2 { V2_ENTRY_BYTES } else { V1_ENTRY_BYTES };
        if nblocks > header.len() / entry_bytes {
            return Err(StoreError::Malformed {
                what: format!(
                    "block count {nblocks} exceeds what a {}-byte header can hold",
                    header.len()
                ),
            });
        }
        let payload_base = 24 + header_len;
        let mut metas = Vec::with_capacity(nblocks);
        let (offset, total_nnz) = if version >= 2 {
            let region = c.take(nblocks * V2_ENTRY_BYTES, "block index")?;
            parse_v2_entries(region, nblocks, "block", payload_base, &mut metas)?
        } else {
            // hard ceiling for any single v1 block: the payload region
            // that actually exists on disk. Without it, a crafted header
            // (the crc is attacker-computable) could declare a huge nnz
            // whose `* 16` wraps in release builds and whose decode loop
            // then aborts or indexes out of bounds — open must reject it
            // instead. (v2 bounds each block against its stored length.)
            let max_block_nnz_on_disk = file_len.saturating_sub(payload_base) / 16;
            let mut offset = payload_base;
            let mut total_nnz = 0u64;
            for b in 0..nblocks {
                let key = c.u64(&format!("block[{b}].key"))?;
                let bnnz64 = c.u64(&format!("block[{b}].nnz"))?;
                if bnnz64 == 0 {
                    return Err(StoreError::Malformed {
                        what: format!("block[{b}] has zero non-zeros"),
                    });
                }
                if bnnz64 > max_block_nnz_on_disk {
                    return Err(StoreError::Malformed {
                        what: format!(
                            "block[{b}] claims {bnnz64} non-zeros but the payload \
                             region holds at most {max_block_nnz_on_disk}"
                        ),
                    });
                }
                let bnnz = bnnz64 as usize;
                let crc = c.u32(&format!("block[{b}].crc"))?;
                let bytes = bnnz * 16; // cannot wrap: bnnz bounded by file size
                metas.push(BlockMeta {
                    key,
                    nnz: bnnz,
                    offset,
                    bytes,
                    stored_len: bytes,
                    codec: Codec::None,
                    crc,
                });
                offset = offset.checked_add(bytes as u64).ok_or_else(|| {
                    StoreError::Malformed {
                        what: format!("payload offsets overflow at block {b}"),
                    }
                })?;
                total_nnz = total_nnz.checked_add(bnnz64).ok_or_else(|| {
                    StoreError::Malformed {
                        what: format!("nnz total overflows at block {b}"),
                    }
                })?;
            }
            (offset, total_nnz)
        };
        if c.pos != header.len() {
            return Err(StoreError::Malformed {
                what: format!(
                    "{} trailing header bytes after the block index",
                    header.len() - c.pos
                ),
            });
        }
        if total_nnz as usize != nnz {
            return Err(StoreError::Malformed {
                what: format!(
                    "block nnz sum {total_nnz} != header nnz {nnz}"
                ),
            });
        }
        if offset > file_len {
            return Err(StoreError::Truncated {
                what: "block payload region".into(),
                needed: offset,
                available: file_len,
            });
        }
        let base_blocks = metas.len();

        // ---- delta segments (v2): parse every appended segment in file
        // order; v1 files must end exactly at the payload region. A
        // snapshot pin (`max_segments`) keeps the first N segments in the
        // view and validates-but-discards the rest.
        let mut offset = offset;
        let mut parsed_segments = 0usize;
        let mut segments = 0usize;
        let mut seg_nnz_total = 0usize;
        let mut seg_sumsq_total = 0.0f64;
        if version >= 2 {
            while offset < file_len {
                let i = parsed_segments;
                if file_len - offset < 20 {
                    return Err(StoreError::Malformed {
                        what: format!(
                            "{} trailing bytes after the payload region",
                            file_len - offset
                        ),
                    });
                }
                let mut seg_pre = [0u8; 16];
                file.seek(SeekFrom::Start(offset)).map_err(io_err(format!(
                    "seek to delta segment {i} of {}",
                    path.display()
                )))?;
                file.read_exact(&mut seg_pre).map_err(io_err(format!(
                    "read delta segment {i} preamble of {}",
                    path.display()
                )))?;
                let magic: [u8; 8] = seg_pre[0..8].try_into().unwrap();
                if magic != SEGMENT_MAGIC {
                    return Err(StoreError::Malformed {
                        what: format!(
                            "delta segment {i} has bad magic {magic:02x?}"
                        ),
                    });
                }
                let blob_len = u64::from_le_bytes(seg_pre[8..16].try_into().unwrap());
                let frame_end = offset
                    .checked_add(20)
                    .and_then(|v| v.checked_add(blob_len))
                    .ok_or_else(|| StoreError::Malformed {
                        what: format!("delta segment {i} blob length overflows"),
                    })?;
                if frame_end > file_len {
                    return Err(StoreError::Truncated {
                        what: format!("delta segment {i} header"),
                        needed: frame_end,
                        available: file_len,
                    });
                }
                let mut blob = vec![0u8; blob_len as usize];
                file.read_exact(&mut blob).map_err(io_err(format!(
                    "read delta segment {i} blob of {}",
                    path.display()
                )))?;
                let mut crc_buf = [0u8; 4];
                file.read_exact(&mut crc_buf).map_err(io_err(format!(
                    "read delta segment {i} crc of {}",
                    path.display()
                )))?;
                let stored_crc = u32::from_le_bytes(crc_buf);
                let computed = crc32(&blob);
                if stored_crc != computed {
                    return Err(StoreError::ChecksumMismatch {
                        what: format!("delta segment {i} header"),
                        expected: stored_crc,
                        found: computed,
                    });
                }
                let mut sc = Cursor::new(&blob);
                let seg_nnz = sc.u64("segment nnz")? as usize;
                let seg_sumsq = sc.f64("segment sumsq")?;
                let seg_nblocks = sc.u64("segment block count")? as usize;
                if seg_nnz == 0 {
                    return Err(StoreError::Malformed {
                        what: format!("delta segment {i} has zero non-zeros"),
                    });
                }
                if seg_nblocks > blob.len() / V2_ENTRY_BYTES {
                    return Err(StoreError::Malformed {
                        what: format!(
                            "delta segment {i} block count {seg_nblocks} exceeds \
                             what a {}-byte blob can hold",
                            blob.len()
                        ),
                    });
                }
                let region =
                    sc.take(seg_nblocks * V2_ENTRY_BYTES, "segment block index")?;
                let label = format!("delta segment {i} block");
                let kept = max_segments.map_or(true, |pin| i < pin);
                // a segment past the snapshot pin is validated in full
                // but its blocks never join the view
                let mut discard: Vec<BlockMeta> = Vec::new();
                let sink = if kept { &mut metas } else { &mut discard };
                let (end, total) =
                    parse_v2_entries(region, seg_nblocks, &label, frame_end, sink)?;
                if sc.pos != blob.len() {
                    return Err(StoreError::Malformed {
                        what: format!(
                            "{} trailing bytes in delta segment {i} blob",
                            blob.len() - sc.pos
                        ),
                    });
                }
                if total as usize != seg_nnz {
                    return Err(StoreError::Malformed {
                        what: format!(
                            "delta segment {i} block nnz sum {total} != segment \
                             nnz {seg_nnz}"
                        ),
                    });
                }
                if end > file_len {
                    return Err(StoreError::Truncated {
                        what: format!("delta segment {i} payload region"),
                        needed: end,
                        available: file_len,
                    });
                }
                offset = end;
                parsed_segments += 1;
                if kept {
                    segments += 1;
                    seg_nnz_total += seg_nnz;
                    seg_sumsq_total += seg_sumsq;
                }
            }
        } else if offset < file_len {
            return Err(StoreError::Malformed {
                what: format!(
                    "{} trailing bytes after the payload region",
                    file_len - offset
                ),
            });
        }

        // ---- rebuild the derived structures: the bit layout is a pure
        // function of (dims, budget), the batch maps of (block nnz list,
        // config) — both bit-identical to the resident tensor's. Delta
        // blocks join the batch maps after the base blocks, in segment
        // order; a base/delta duplicate coordinate simply accumulates in
        // MTTKRP, which is the semantics of appending.
        let spec = BlcoSpec::with_budget(&dims, inblock_budget);
        let config = BlcoConfig {
            max_block_nnz,
            workgroup,
            inblock_budget,
            ..BlcoConfig::default()
        };
        let nnzs: Vec<usize> = metas.iter().map(|m| m.nnz).collect();
        let batches = build_batches_from_nnz(&nnzs, &config);
        // the base norm is passed through untouched when no segments are
        // pending — sqrt(norm²) is not bit-exact, and pristine containers
        // must keep the exact header norm the parity tests pin
        let norm = if segments > 0 {
            (norm * norm + seg_sumsq_total).sqrt()
        } else {
            norm
        };

        Ok(BlcoStoreReader {
            path: path.to_path_buf(),
            file: Mutex::new(file),
            version,
            default_codec,
            spec,
            config,
            nnz: nnz + seg_nnz_total,
            norm,
            metas,
            base_blocks,
            segments,
            batches,
            cache: BlockCache::new(cache_budget),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Container version on disk (1 or 2).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Default codec recorded in the header (what the container was
    /// written with; individual blocks may have fallen back to raw).
    pub fn default_codec(&self) -> Codec {
        self.default_codec
    }

    /// Pending delta segments **in this view** (0 on a pristine or
    /// freshly compacted container; a snapshot opened with
    /// [`Self::open_pinned`] reports its kept count, not the file's).
    pub fn segments(&self) -> usize {
        self.segments
    }

    /// Blocks in the base payload region; `block_meta(i)` for
    /// `i >= base_blocks()` are delta-segment blocks.
    pub fn base_blocks(&self) -> usize {
        self.base_blocks
    }

    pub fn spec(&self) -> &BlcoSpec {
        &self.spec
    }

    pub fn config(&self) -> &BlcoConfig {
        &self.config
    }

    pub fn dims(&self) -> &[u64] {
        &self.spec.dims
    }

    pub fn order(&self) -> usize {
        self.spec.order()
    }

    /// Total nonzeros: base plus every pending delta segment.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Frobenius norm of the stored values (header field at write time,
    /// folded with each segment's recorded sum of squares when deltas are
    /// pending) — CP-ALS needs it without a payload scan.
    pub fn norm(&self) -> f64 {
        self.norm
    }

    pub fn num_blocks(&self) -> usize {
        self.metas.len()
    }

    pub fn block_meta(&self, i: usize) -> &BlockMeta {
        &self.metas[i]
    }

    /// Batch metadata rebuilt from the header (bit-identical to the
    /// resident tensor's batching).
    pub fn batches(&self) -> &[Batch] {
        &self.batches
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Stored (encoded) payload bytes across base and delta blocks — the
    /// denominator of [`compression_ratio`](Self::compression_ratio).
    pub fn stored_payload_bytes(&self) -> u64 {
        self.metas.iter().map(|m| m.stored_len as u64).sum()
    }

    /// Logical (decompressed) payload bytes (`nnz * 16` per block).
    pub fn raw_payload_bytes(&self) -> u64 {
        self.metas.iter().map(|m| m.bytes as u64).sum()
    }

    /// Logical over stored payload bytes (≥ 1.0 — codecs fall back to raw
    /// rather than expand; exactly 1.0 for an all-raw container).
    pub fn compression_ratio(&self) -> f64 {
        let stored = self.stored_payload_bytes();
        if stored == 0 {
            return 1.0;
        }
        self.raw_payload_bytes() as f64 / stored as f64
    }

    /// LSM read amplification: a lookup consults the base plus every
    /// pending delta segment, so `1 + segments` — 1.0 on a pristine or
    /// freshly compacted container, and the number `compact` exists to
    /// drive back down.
    pub fn read_amplification(&self) -> f64 {
        (1 + self.segments) as f64
    }

    /// Total on-device payload + metadata bytes, same accounting as
    /// [`BlcoTensor::footprint_bytes`] so routing decisions are identical
    /// across tiers (decompressed bytes — that is what moves to the
    /// device).
    pub fn footprint_bytes(&self) -> usize {
        let payload: usize = self.metas.iter().map(|m| m.bytes).sum();
        let keys = self.metas.len() * 8;
        let maps: usize = self.batches.iter().map(|b| b.wg_block.len() * 8).sum();
        payload + keys + maps
    }

    /// Read, checksum-verify and decode block `i` straight from disk — no
    /// cache interaction. The crc covers the **stored** bytes, so a
    /// corrupted compressed payload is a [`StoreError::ChecksumMismatch`]
    /// before any decode runs; a decode failure after a clean crc means
    /// the writer produced garbage and is [`StoreError::Malformed`].
    pub fn load_block(&self, i: usize) -> Result<Block, StoreError> {
        let m = self.metas[i];
        let mut raw = vec![0u8; m.stored_len];
        {
            let mut f = self.file.lock().expect("store file poisoned");
            f.seek(SeekFrom::Start(m.offset)).map_err(io_err(format!(
                "seek to block {i} of {}",
                self.path.display()
            )))?;
            f.read_exact(&mut raw).map_err(io_err(format!(
                "read block {i} of {}",
                self.path.display()
            )))?;
        }
        let found = crc32(&raw);
        if found != m.crc {
            return Err(StoreError::ChecksumMismatch {
                what: format!("block {i} payload"),
                expected: m.crc,
                found,
            });
        }
        let (lidx, vals) =
            decode_block_payload(&raw, m.nnz, m.codec, &format!("block {i}"))?;
        Ok(Block { key: m.key, lidx, vals })
    }

    /// Load block `i`, through the cache. Cache hit/miss/eviction counts
    /// and disk-read bytes are charged to `counters` (the host tier of
    /// the traffic model); `bytes_disk` charges the **stored** length —
    /// what actually crossed the disk link — while residency stays in
    /// decompressed bytes. Payload integrity is verified against the
    /// header checksum on every disk read.
    pub fn block(&self, i: usize, counters: &Counters) -> Result<Arc<Block>, StoreError> {
        if let Some(b) = self.cache.get(i) {
            counters.add(&Snapshot { host_hits: 1, ..Default::default() });
            return Ok(b);
        }
        let m = self.metas[i];
        let block = Arc::new(self.load_block(i)?);
        let evicted = self.cache.insert(i, Arc::clone(&block), false);
        self.cache.add_disk_bytes(m.stored_len as u64);
        counters.add(&Snapshot {
            host_misses: 1,
            host_evictions: evicted as u64,
            bytes_disk: m.stored_len as u64,
            ..Default::default()
        });
        Ok(block)
    }

    /// Advisory load of block `i` into the cache ahead of demand. A block
    /// already resident is left untouched (no recency or stat
    /// perturbation); a fresh load is charged exactly like a demand miss
    /// (it is the same disk I/O, just earlier) and flagged so
    /// [`CacheStats::prefetch_hits`] / [`CacheStats::prefetch_wasted`]
    /// attribute its fate.
    pub fn prefetch_block(&self, i: usize, counters: &Counters) -> Result<(), StoreError> {
        if self.cache.contains(i) {
            return Ok(());
        }
        let m = self.metas[i];
        let block = Arc::new(self.load_block(i)?);
        let evicted = self.cache.stage_prefetched(i, block);
        self.cache.add_disk_bytes(m.stored_len as u64);
        counters.add(&Snapshot {
            host_misses: 1,
            host_evictions: evicted as u64,
            bytes_disk: m.stored_len as u64,
            ..Default::default()
        });
        Ok(())
    }

    /// Prefetch every block of batch `b`. Errors are advisory — the
    /// demand path will retry the same block and surface the failure as
    /// fatal there — so a prefetch fault only warns and stops early.
    pub fn prefetch_batch(&self, b: usize, counters: &Counters) {
        for i in self.batches[b].blocks.clone() {
            if let Err(e) = self.prefetch_block(i, counters) {
                eprintln!(
                    "warning: prefetch of block {i} from {} failed: {e}",
                    self.path.display()
                );
                return;
            }
        }
    }

    /// Verify every block payload (base and delta) against its stored
    /// checksum without touching the cache (CLI `inspect --verify`).
    /// Returns the stored payload bytes scanned.
    pub fn verify_payloads(&self) -> Result<usize, StoreError> {
        let mut scanned = 0usize;
        for i in 0..self.metas.len() {
            self.load_block(i)?;
            scanned += self.metas[i].stored_len;
        }
        Ok(scanned)
    }

    /// Materialize the whole container (base plus pending deltas) as a
    /// resident [`BlcoTensor`] (cache-bypassing full scan) — the resident
    /// twin the CLI's `stream --from-store --check` compares bit-for-bit
    /// against, and an escape hatch for callers that decide a tensor fits
    /// after all.
    pub fn to_tensor(&self) -> Result<BlcoTensor, StoreError> {
        let mut blocks = Vec::with_capacity(self.metas.len());
        for i in 0..self.metas.len() {
            blocks.push(Arc::new(self.load_block(i)?));
        }
        Ok(BlcoTensor {
            spec: self.spec.clone(),
            blocks,
            batches: self.batches.clone(),
            config: self.config,
            nnz: self.nnz,
            stages: Arc::new(crate::util::timer::Stages::new()),
        })
    }
}

impl std::fmt::Debug for BlcoStoreReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BlcoStoreReader")
            .field("path", &self.path)
            .field("version", &self.version)
            .field("dims", &self.spec.dims)
            .field("nnz", &self.nnz)
            .field("blocks", &self.metas.len())
            .field("segments", &self.segments)
            .field("batches", &self.batches.len())
            .finish()
    }
}

// ------------------------------------------------------ the batch source

/// The blocks backing one batch, borrowed from a resident tensor or
/// freshly loaded from disk. Derefs to `[Arc<Block>]` indexed by
/// `global_block_id - batch.blocks.start`.
pub enum BatchBlocks<'a> {
    Borrowed(&'a [Arc<Block>]),
    Loaded(Vec<Arc<Block>>),
}

impl std::ops::Deref for BatchBlocks<'_> {
    type Target = [Arc<Block>];

    fn deref(&self) -> &[Arc<Block>] {
        match self {
            BatchBlocks::Borrowed(s) => s,
            BatchBlocks::Loaded(v) => v,
        }
    }
}

/// Where a BLCO engine's block payload lives. Every streaming executor
/// and kernel consumes batches through this interface, so nothing above
/// it assumes the tensor is in host RAM:
///
/// * [`BatchSource::Resident`] — the whole [`BlcoTensor`] is resident
///   (the original in-memory path); fetches borrow, zero copies;
/// * [`BatchSource::OnDisk`] — only header metadata is resident; fetches
///   load payloads through the reader's bounded [`BlockCache`], making
///   host memory a budget rather than a requirement.
// one value per engine, moved once at construction — the inline-size
// asymmetry between the Arc and the reader (spec + index + cache) is
// irrelevant, and boxing the reader would only add a pointer chase to
// every batch fetch
#[allow(clippy::large_enum_variant)]
pub enum BatchSource {
    Resident(Arc<BlcoTensor>),
    OnDisk(BlcoStoreReader),
}

impl BatchSource {
    pub fn spec(&self) -> &BlcoSpec {
        match self {
            BatchSource::Resident(t) => &t.spec,
            BatchSource::OnDisk(r) => r.spec(),
        }
    }

    pub fn dims(&self) -> &[u64] {
        match self {
            BatchSource::Resident(t) => t.dims(),
            BatchSource::OnDisk(r) => r.dims(),
        }
    }

    pub fn order(&self) -> usize {
        self.dims().len()
    }

    pub fn nnz(&self) -> usize {
        match self {
            BatchSource::Resident(t) => t.nnz,
            BatchSource::OnDisk(r) => r.nnz(),
        }
    }

    /// Work-group size the batch maps were built with.
    pub fn workgroup(&self) -> usize {
        match self {
            BatchSource::Resident(t) => t.config.workgroup,
            BatchSource::OnDisk(r) => r.config().workgroup,
        }
    }

    pub fn batches(&self) -> &[Batch] {
        match self {
            BatchSource::Resident(t) => &t.batches,
            BatchSource::OnDisk(r) => r.batches(),
        }
    }

    pub fn num_batches(&self) -> usize {
        self.batches().len()
    }

    /// Host→device wire bytes of batch `b` (decompressed payload +
    /// work-group maps) — identical across tiers, so schedules planned
    /// against either source are interchangeable (pinned per batch by the
    /// tier-parity tests). Compression changes what crosses the *disk*
    /// link, never what crosses the host→device link.
    pub fn batch_bytes(&self, b: usize) -> usize {
        match self {
            BatchSource::Resident(t) => t.batch_wire_bytes(b),
            BatchSource::OnDisk(r) => {
                let batch = &r.batches()[b];
                batch
                    .blocks
                    .clone()
                    .map(|i| r.block_meta(i).bytes)
                    .sum::<usize>()
                    + batch.wg_block.len() * 8
            }
        }
    }

    /// Total on-device bytes (payload + key + map metadata), the same
    /// number for both tiers of the same tensor.
    pub fn footprint_bytes(&self) -> usize {
        match self {
            BatchSource::Resident(t) => t.footprint_bytes(),
            BatchSource::OnDisk(r) => r.footprint_bytes(),
        }
    }

    /// Frobenius norm of the stored values (header field on disk).
    pub fn norm(&self) -> f64 {
        match self {
            BatchSource::Resident(t) => t.norm(),
            BatchSource::OnDisk(r) => r.norm(),
        }
    }

    pub fn is_on_disk(&self) -> bool {
        matches!(self, BatchSource::OnDisk(_))
    }

    /// The resident payload, when there is one.
    pub fn resident(&self) -> Option<&Arc<BlcoTensor>> {
        match self {
            BatchSource::Resident(t) => Some(t),
            BatchSource::OnDisk(_) => None,
        }
    }

    /// The disk reader, when the payload is out of core.
    pub fn reader(&self) -> Option<&BlcoStoreReader> {
        match self {
            BatchSource::Resident(_) => None,
            BatchSource::OnDisk(r) => Some(r),
        }
    }

    /// The blocks of batch `b`: borrowed when resident, cache-loaded when
    /// on disk. Disk corruption discovered here (crc mismatch, IO fault)
    /// is fatal — a half-streamed MTTKRP has no useful partial result —
    /// and panics with the path and block id.
    pub fn fetch_batch(&self, b: usize, counters: &Counters) -> BatchBlocks<'_> {
        match self {
            BatchSource::Resident(t) => {
                BatchBlocks::Borrowed(&t.blocks[t.batches[b].blocks.clone()])
            }
            BatchSource::OnDisk(r) => {
                let range = r.batches()[b].blocks.clone();
                let mut v = Vec::with_capacity(range.len());
                for i in range {
                    v.push(r.block(i, counters).unwrap_or_else(|e| {
                        panic!(
                            "loading BLCO block {i} from {}: {e}",
                            r.path().display()
                        )
                    }));
                }
                BatchBlocks::Loaded(v)
            }
        }
    }
}

// ------------------------------------------------- prefetch orchestration

/// Run a batch-ordered compute loop with a background thread pulling the
/// *next* batch's blocks off disk while the current one computes.
///
/// `body` receives a `notify` callback and must call `notify(b)` when it
/// starts computing batch `b`; the prefetcher stays at most **one batch
/// ahead** of the notified cursor, so lookahead residency is bounded by
/// one batch of payload on top of the demand working set (the
/// [`BlockCache`] budget still caps everything that is actually kept).
///
/// Batch 0 is prefetched synchronously before the background thread
/// starts: the first compute batch always finds its blocks staged when
/// the budget can hold them at all, which makes `prefetch_hits > 0`
/// deterministic rather than a race.
///
/// For a resident source, a zero-batch tensor, or `enabled == false`,
/// this degenerates to calling `body` with a no-op callback — callers
/// wrap their loop unconditionally and the resident path pays nothing.
/// If `body` panics, a drop guard parks the cursor so the prefetcher
/// exits instead of spinning, and the panic propagates.
pub fn run_with_prefetch<R>(
    src: &BatchSource,
    enabled: bool,
    counters: &Counters,
    body: impl FnOnce(&dyn Fn(usize)) -> R,
) -> R {
    let reader = match src.reader() {
        Some(r) if enabled && src.num_batches() > 0 => r,
        _ => return body(&|_| {}),
    };
    let nbatches = src.num_batches();
    reader.prefetch_batch(0, counters);
    if nbatches == 1 {
        return body(&|_| {});
    }
    // index of the batch the compute loop is currently on; usize::MAX
    // parks the prefetcher (set on completion or panic of `body`)
    let cursor = AtomicUsize::new(0);
    struct Park<'a>(&'a AtomicUsize);
    impl Drop for Park<'_> {
        fn drop(&mut self) {
            self.0.store(usize::MAX, Ordering::Release);
        }
    }
    std::thread::scope(|s| {
        let cursor = &cursor;
        s.spawn(move || {
            for b in 1..nbatches {
                loop {
                    let cur = cursor.load(Ordering::Acquire);
                    if cur == usize::MAX {
                        return;
                    }
                    if b <= cur + 1 {
                        break;
                    }
                    std::thread::yield_now();
                }
                reader.prefetch_batch(b, counters);
            }
        });
        let _park = Park(cursor);
        body(&|b| cursor.store(b, Ordering::Release))
    })
}

impl std::fmt::Debug for BatchSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchSource::Resident(t) => f
                .debug_struct("BatchSource::Resident")
                .field("dims", &t.dims())
                .field("nnz", &t.nnz)
                .finish(),
            BatchSource::OnDisk(r) => f
                .debug_struct("BatchSource::OnDisk")
                .field("path", &r.path)
                .field("nnz", &r.nnz)
                .finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::blco::BlcoConfig;
    use crate::tensor::synth;

    fn tmpfile(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_store_{}_{}", std::process::id(), name));
        p
    }

    fn sample_tensor() -> BlcoTensor {
        let t = synth::uniform(&[60, 50, 40], 6_000, 3);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        BlcoTensor::from_coo_with(&t, cfg)
    }

    /// Hand-write `t` in the version-1 layout (raw payloads, 20-byte
    /// index entries, no codec field) — the compat corpus for the
    /// v1→v2 read tests, since this build only writes version 2.
    fn write_v1(t: &BlcoTensor, path: &Path) {
        let mut buf: Vec<u8> = Vec::new();
        let mut header: Vec<u8> = Vec::new();
        put_u32(&mut header, t.dims().len() as u32);
        for &d in t.dims() {
            put_u64(&mut header, d);
        }
        put_u64(&mut header, t.nnz as u64);
        put_f64(&mut header, t.norm());
        put_u64(&mut header, t.config.max_block_nnz as u64);
        put_u32(&mut header, t.config.workgroup as u32);
        put_u32(&mut header, t.config.inblock_budget);
        put_u64(&mut header, t.blocks.len() as u64);
        for blk in &t.blocks {
            serialize_block_payload(&mut buf, &blk.lidx, &blk.vals);
            put_u64(&mut header, blk.key);
            put_u64(&mut header, blk.nnz() as u64);
            put_u32(&mut header, crc32(&buf));
        }
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(&STORE_MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(&header);
        out.extend_from_slice(&crc32(&header).to_le_bytes());
        for blk in &t.blocks {
            serialize_block_payload(&mut buf, &blk.lidx, &blk.vals);
            out.extend_from_slice(&buf);
        }
        std::fs::write(path, &out).unwrap();
    }

    #[test]
    fn incremental_writer_matches_batch_writer_bitwise() {
        // feeding the in-memory tensor's blocks through BlcoStoreWriter
        // must produce the exact file BlcoStore::write does — the shared
        // header/payload serializers are what the out-of-core build's
        // bit-parity guarantee stands on. Checked per codec: the encoders
        // are deterministic, so both writers store identical bytes.
        let b = sample_tensor();
        for codec in [Codec::None, Codec::DeltaVarint, Codec::Shuffled] {
            let p1 = tmpfile(&format!("batch_{}.blco", codec.tag()));
            let p2 = tmpfile(&format!("incremental_{}.blco", codec.tag()));
            let s1 = BlcoStore::write_with(&b, &p1, codec).unwrap();
            let mut w =
                BlcoStoreWriter::create_with_codec(&p2, b.dims(), b.config, codec)
                    .unwrap();
            for blk in &b.blocks {
                w.add_block(blk.key, &blk.lidx, &blk.vals).unwrap();
            }
            let s2 = w.finish().unwrap();
            assert_eq!(s1.file_bytes, s2.file_bytes);
            assert_eq!(s1.stored_bytes, s2.stored_bytes);
            assert_eq!(s1.blocks, s2.blocks);
            assert_eq!(s1.batches, s2.batches);
            assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
            // the payload stage must be gone after finish
            assert!(!PathBuf::from(format!("{}.payload.tmp", p2.display())).exists());
            std::fs::remove_file(&p1).ok();
            std::fs::remove_file(&p2).ok();
        }
    }

    #[test]
    fn incremental_writer_drop_cleans_stage_and_leaves_target_alone() {
        let p = tmpfile("aborted.blco");
        std::fs::write(&p, b"pre-existing").unwrap();
        let stage = PathBuf::from(format!("{}.payload.tmp", p.display()));
        {
            let mut w =
                BlcoStoreWriter::create(&p, &[8, 8], BlcoConfig::default())
                    .unwrap();
            w.add_block(0, &[1, 2], &[1.0, 2.0]).unwrap();
            assert!(stage.exists());
            // dropped without finish
        }
        assert!(!stage.exists(), "aborted writer leaked its payload stage");
        assert_eq!(std::fs::read(&p).unwrap(), b"pre-existing");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_rejects_bad_config_as_error() {
        // config mistakes arrive from CLI flags — they must surface as
        // structured errors, not asserts (the BlcoError satellite)
        let p = tmpfile("badcfg.blco");
        let bad_wg = BlcoConfig { workgroup: 0, ..Default::default() };
        assert!(matches!(
            BlcoStoreWriter::create(&p, &[8, 8], bad_wg),
            Err(StoreError::Malformed { .. })
        ));
        let bad_blk = BlcoConfig { max_block_nnz: 0, ..Default::default() };
        assert!(matches!(
            BlcoStoreWriter::create(&p, &[8, 8], bad_blk),
            Err(StoreError::Malformed { .. })
        ));
        assert!(matches!(
            BlcoStoreWriter::create(&p, &[8, 0], BlcoConfig::default()),
            Err(StoreError::Malformed { .. })
        ));
        assert!(matches!(
            BlcoStoreWriter::create(&p, &[], BlcoConfig::default()),
            Err(StoreError::Malformed { .. })
        ));
        assert!(!p.exists(), "rejected create must not touch the target");
    }

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn varint_zigzag_round_trip() {
        for v in [0i64, 1, -1, 63, -64, 64, i64::MAX, i64::MIN, 0x7FFF_FFFF] {
            assert_eq!(unzigzag(zigzag(v)), v, "zigzag({v})");
        }
        // small magnitudes of either sign stay small on the wire
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        let mut buf = Vec::new();
        let cases = [0u64, 1, 127, 128, 16_383, 16_384, u64::MAX, 1 << 63];
        for &v in &cases {
            buf.clear();
            put_varint(&mut buf, v);
            assert!(buf.len() <= 10);
            let mut pos = 0;
            assert_eq!(take_varint(&buf, &mut pos), Some(v));
            assert_eq!(pos, buf.len(), "varint({v}) must consume exactly");
        }
        // single-byte boundary
        buf.clear();
        put_varint(&mut buf, 127);
        assert_eq!(buf.len(), 1);
        buf.clear();
        put_varint(&mut buf, 128);
        assert_eq!(buf.len(), 2);
        // a stream that ends mid-varint is an error, not a wrap
        let mut pos = 0;
        assert_eq!(take_varint(&[0x80, 0x80], &mut pos), None);
    }

    #[test]
    fn block_payload_codecs_round_trip() {
        // sorted lidx + repetitive value planes: both codecs engage
        let lidx: Vec<u64> = (0..400u64).map(|i| i * 3 + (i % 7)).collect();
        let vals: Vec<f64> = (0..400).map(|i| (i % 5) as f64 * 0.25 + 1.0).collect();
        let mut buf = Vec::new();
        for codec in [Codec::None, Codec::DeltaVarint, Codec::Shuffled] {
            let stored = encode_block_payload(&mut buf, &lidx, &vals, codec);
            assert_eq!(stored, codec, "compressible payload must not fall back");
            if codec != Codec::None {
                assert!(buf.len() < lidx.len() * 16, "{codec:?} must shrink");
            }
            let (l2, v2) =
                decode_block_payload(&buf, lidx.len(), stored, "test block").unwrap();
            assert_eq!(l2, lidx, "{codec:?} lidx");
            let b1: Vec<u64> = vals.iter().map(|v| v.to_bits()).collect();
            let b2: Vec<u64> = v2.iter().map(|v| v.to_bits()).collect();
            assert_eq!(b1, b2, "{codec:?} value bits");
        }
        // incompressible payload: full-width pseudo-random deltas cost
        // ~10 varint bytes each, so the encoder must fall back to raw —
        // stored payloads never exceed nnz * 16
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        let rand: Vec<u64> = (0..64)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                x
            })
            .collect();
        let rvals: Vec<f64> = rand
            .iter()
            .map(|&r| f64::from_bits(r >> 12 | 0x3FF0_0000_0000_0000))
            .collect();
        let stored = encode_block_payload(&mut buf, &rand, &rvals, Codec::DeltaVarint);
        assert_eq!(stored, Codec::None, "expanding encode must fall back");
        assert_eq!(buf.len(), rand.len() * 16);
        let (l2, v2) = decode_block_payload(&buf, rand.len(), stored, "fallback").unwrap();
        assert_eq!(l2, rand);
        assert_eq!(
            v2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            rvals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn header_metadata_round_trips() {
        let b = sample_tensor();
        let p = tmpfile("header.blco");
        let summary = BlcoStore::write(&b, &p).unwrap();
        assert_eq!(summary.blocks, b.blocks.len());
        assert_eq!(summary.batches, b.batches.len());
        assert_eq!(summary.stored_bytes, summary.payload_bytes, "codec none is raw");
        let r = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(r.version(), STORE_VERSION);
        assert_eq!(r.default_codec(), Codec::None);
        assert_eq!(r.segments(), 0);
        assert_eq!(r.base_blocks(), b.blocks.len());
        assert_eq!(r.read_amplification(), 1.0);
        assert_eq!(r.compression_ratio(), 1.0);
        assert_eq!(r.dims(), b.dims());
        assert_eq!(r.order(), b.order());
        assert_eq!(r.nnz(), b.nnz);
        assert!((r.norm() - b.norm()).abs() < 1e-12);
        assert_eq!(r.num_blocks(), b.blocks.len());
        assert_eq!(r.footprint_bytes(), b.footprint_bytes());
        // batches rebuilt bit-identically
        assert_eq!(r.batches().len(), b.batches.len());
        for (a, e) in r.batches().iter().zip(&b.batches) {
            assert_eq!(a, e);
        }
        for (i, blk) in b.blocks.iter().enumerate() {
            assert_eq!(r.block_meta(i).key, blk.key);
            assert_eq!(r.block_meta(i).nnz, blk.nnz());
            assert_eq!(r.block_meta(i).codec, Codec::None);
            assert_eq!(r.block_meta(i).stored_len, r.block_meta(i).bytes);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn blocks_round_trip_bit_for_bit() {
        let b = sample_tensor();
        let p = tmpfile("payload.blco");
        BlcoStore::write(&b, &p).unwrap();
        let r = BlcoStoreReader::open(&p).unwrap();
        let c = Counters::new();
        for (i, expect) in b.blocks.iter().enumerate() {
            let got = r.block(i, &c).unwrap();
            assert_eq!(got.key, expect.key);
            assert_eq!(got.lidx, expect.lidx);
            let gb: Vec<u64> = got.vals.iter().map(|v| v.to_bits()).collect();
            let eb: Vec<u64> = expect.vals.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, eb, "block {i} values must be bit-identical");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn compressed_containers_round_trip_bit_for_bit() {
        // every codec must hand back the exact u64 lidx and f64 bit
        // patterns — compression changes the disk bytes, never the math
        let b = sample_tensor();
        for codec in [Codec::DeltaVarint, Codec::Shuffled] {
            let p = tmpfile(&format!("codec_{}.blco", codec.tag()));
            let summary = BlcoStore::write_with(&b, &p, codec).unwrap();
            assert!(
                summary.stored_bytes < summary.payload_bytes,
                "{codec:?} should compress sorted lidx streams: {} vs {}",
                summary.stored_bytes,
                summary.payload_bytes
            );
            let r = BlcoStoreReader::open(&p).unwrap();
            assert_eq!(r.default_codec(), codec);
            assert!(r.compression_ratio() > 1.0, "{codec:?}");
            assert_eq!(r.stored_payload_bytes() as usize, summary.stored_bytes);
            assert_eq!(r.raw_payload_bytes() as usize, summary.payload_bytes);
            assert_eq!(r.nnz(), b.nnz);
            assert_eq!(r.norm().to_bits(), b.norm().to_bits());
            // footprint and batch accounting stay in decompressed bytes:
            // cross-tier plans must not depend on the codec
            assert_eq!(r.footprint_bytes(), b.footprint_bytes());
            let c = Counters::new();
            for (i, expect) in b.blocks.iter().enumerate() {
                let got = r.block(i, &c).unwrap();
                assert_eq!(got.key, expect.key);
                assert_eq!(got.lidx, expect.lidx, "{codec:?} block {i}");
                let gb: Vec<u64> = got.vals.iter().map(|v| v.to_bits()).collect();
                let eb: Vec<u64> = expect.vals.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, eb, "{codec:?} block {i} values");
            }
            // bytes_disk charged the stored (compressed) lengths
            let snap = c.snapshot();
            assert_eq!(snap.bytes_disk as usize, summary.stored_bytes);
            assert_eq!(r.cache_stats().disk_bytes as usize, summary.stored_bytes);
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn v1_container_reads_back() {
        let b = sample_tensor();
        let p = tmpfile("v1compat.blco");
        write_v1(&b, &p);
        let r = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(r.version(), 1);
        assert_eq!(r.default_codec(), Codec::None);
        assert_eq!(r.segments(), 0);
        assert_eq!(r.nnz(), b.nnz);
        assert_eq!(r.norm().to_bits(), b.norm().to_bits());
        assert_eq!(r.footprint_bytes(), b.footprint_bytes());
        assert_eq!(r.batches().len(), b.batches.len());
        let c = Counters::new();
        for (i, expect) in b.blocks.iter().enumerate() {
            let got = r.block(i, &c).unwrap();
            assert_eq!(got.key, expect.key);
            assert_eq!(got.lidx, expect.lidx);
            let gb: Vec<u64> = got.vals.iter().map(|v| v.to_bits()).collect();
            let eb: Vec<u64> = expect.vals.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, eb, "v1 block {i}");
        }
        // v1 has no segments: trailing bytes stay malformed
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            BlcoStoreReader::open(&p),
            Err(StoreError::Malformed { .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn append_creates_delta_segment_readable() {
        let base_coo = synth::uniform(&[60, 50, 40], 4_000, 3);
        let delta_coo = synth::uniform(&[60, 50, 40], 1_500, 9);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let base = BlcoTensor::from_coo_with(&base_coo, cfg);
        let p = tmpfile("append.blco");
        BlcoStore::write_with(&base, &p, Codec::DeltaVarint).unwrap();
        let before = std::fs::metadata(&p).unwrap().len();

        let s = BlcoStoreWriter::append(&p, &delta_coo, None).unwrap();
        assert_eq!(s.appended_nnz, delta_coo.nnz());
        assert_eq!(s.segments, 1);
        assert!(s.blocks > 0);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), before + s.segment_bytes);

        let r = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(r.segments(), 1);
        assert_eq!(r.read_amplification(), 2.0);
        assert_eq!(r.nnz(), base.nnz + delta_coo.nnz());
        assert_eq!(r.num_blocks(), r.base_blocks() + s.blocks);
        // norm folds the segment's recorded sum of squares
        let delta_sumsq: f64 = delta_coo.vals.iter().map(|v| v * v).sum();
        let expect_norm = (base.norm() * base.norm() + delta_sumsq).sqrt();
        assert!((r.norm() - expect_norm).abs() < 1e-9);
        // base blocks are untouched bit-for-bit; delta blocks decode,
        // carry ALTO-sorted keys, and hold exactly the appended values
        let c = Counters::new();
        for (i, expect) in base.blocks.iter().enumerate() {
            let got = r.block(i, &c).unwrap();
            assert_eq!(got.key, expect.key);
            assert_eq!(got.lidx, expect.lidx);
        }
        let mut delta_nnz = 0usize;
        let mut delta_sum = 0.0f64;
        let mut prev_key = 0u64;
        for i in r.base_blocks()..r.num_blocks() {
            let blk = r.block(i, &c).unwrap();
            assert!(blk.key >= prev_key, "segment keys must be non-decreasing");
            prev_key = blk.key;
            assert!(blk.nnz() <= r.config().max_block_nnz);
            delta_nnz += blk.nnz();
            delta_sum += blk.vals.iter().sum::<f64>();
        }
        assert_eq!(delta_nnz, delta_coo.nnz());
        let expect_sum: f64 = delta_coo.vals.iter().sum();
        assert!((delta_sum - expect_sum).abs() < 1e-9);
        // appending again stacks a second segment
        let s2 = BlcoStoreWriter::append(&p, &delta_coo, Some(Codec::Shuffled)).unwrap();
        assert_eq!(s2.segments, 2);
        let r2 = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(r2.segments(), 2);
        assert_eq!(r2.read_amplification(), 3.0);
        assert_eq!(r2.nnz(), base.nnz + 2 * delta_coo.nnz());
        // the full container (base + deltas) still verifies
        r2.verify_payloads().unwrap();
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn append_rejects_v1_dim_mismatch_and_empty() {
        let b = sample_tensor();
        let delta = synth::uniform(&[60, 50, 40], 100, 5);

        // v1 containers must be rewritten before appending
        let p1 = tmpfile("append_v1.blco");
        write_v1(&b, &p1);
        match BlcoStoreWriter::append(&p1, &delta, None) {
            Err(StoreError::Malformed { what }) => {
                assert!(what.contains("version-2"), "{what}");
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        std::fs::remove_file(&p1).ok();

        let p2 = tmpfile("append_dims.blco");
        BlcoStore::write(&b, &p2).unwrap();
        let wrong = synth::uniform(&[60, 50, 41], 100, 5);
        match BlcoStoreWriter::append(&p2, &wrong, None) {
            Err(StoreError::Malformed { what }) => {
                assert!(what.contains("dims"), "{what}");
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        let empty = CooTensor {
            dims: vec![60, 50, 40],
            coords: vec![Vec::new(), Vec::new(), Vec::new()],
            vals: Vec::new(),
        };
        assert!(matches!(
            BlcoStoreWriter::append(&p2, &empty, None),
            Err(StoreError::Malformed { .. })
        ));
        // the rejected appends must not have grown the file
        let r = BlcoStoreReader::open(&p2).unwrap();
        assert_eq!(r.segments(), 0);
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn cache_bounds_residency_and_counts() {
        let b = sample_tensor();
        assert!(b.blocks.len() >= 8, "need enough blocks to thrash");
        let p = tmpfile("cache.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget of ~3 blocks forces eviction on a full scan
        let budget = 3 * 512 * 16;
        let r = BlcoStoreReader::open_with_budget(&p, budget).unwrap();
        let c = Counters::new();
        for i in 0..b.blocks.len() {
            r.block(i, &c).unwrap();
        }
        // second pass over the first blocks: they were evicted
        for i in 0..3 {
            r.block(i, &c).unwrap();
        }
        let s = r.cache_stats();
        assert!(
            s.peak_resident_bytes <= budget,
            "peak {} > budget {budget}",
            s.peak_resident_bytes
        );
        assert!(s.resident_bytes <= budget);
        assert!(s.evictions > 0, "scan over budget must evict");
        assert_eq!(s.misses as usize, b.blocks.len() + 3);
        assert_eq!(s.disk_bytes, {
            // every miss charges the block's *stored* length
            let mut total = 0u64;
            for i in 0..b.blocks.len() {
                total += (r.block_meta(i).stored_len) as u64;
            }
            for i in 0..3 {
                total += (r.block_meta(i).stored_len) as u64;
            }
            total
        });
        // hot re-read of a just-inserted block hits
        let before = r.cache_stats().hits;
        r.block(2, &c).unwrap();
        assert_eq!(r.cache_stats().hits, before + 1);
        // counters carry the same story
        let snap = c.snapshot();
        assert_eq!(snap.host_hits, r.cache_stats().hits);
        assert_eq!(snap.host_misses, r.cache_stats().misses);
        assert_eq!(snap.bytes_disk, r.cache_stats().disk_bytes);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn compressed_cache_budgets_decompressed_bytes() {
        // a compressed container must evict by what the blocks cost in
        // RAM (decompressed), while disk_bytes reports the smaller stored
        // traffic — the accounting split the codec exists for
        let b = sample_tensor();
        let p = tmpfile("cache_codec.blco");
        let summary = BlcoStore::write_with(&b, &p, Codec::DeltaVarint).unwrap();
        let budget = 3 * 512 * 16;
        let r = BlcoStoreReader::open_with_budget(&p, budget).unwrap();
        let c = Counters::new();
        for i in 0..b.blocks.len() {
            r.block(i, &c).unwrap();
        }
        let s = r.cache_stats();
        assert!(s.peak_resident_bytes <= budget);
        assert!(s.evictions > 0, "decompressed residency must thrash the budget");
        assert_eq!(s.disk_bytes as usize, summary.stored_bytes);
        assert!(
            (s.disk_bytes as usize) < summary.payload_bytes,
            "stored traffic must be below the raw bytes"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn prefetch_stages_blocks_and_counts_hits() {
        let b = sample_tensor();
        let p = tmpfile("prefetch_hits.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget big enough that nothing prefetched is ever evicted
        let r = BlcoStoreReader::open(&p).unwrap();
        let c = Counters::new();
        let nblocks = r.batches()[0].blocks.len();
        r.prefetch_batch(0, &c);
        let staged = r.cache_stats();
        assert_eq!(staged.misses as usize, nblocks, "each staged block is a miss");
        assert_eq!(staged.hits, 0);
        assert_eq!(staged.prefetch_hits, 0, "no demand touch yet");
        // re-prefetching resident blocks must not perturb any stat
        r.prefetch_batch(0, &c);
        assert_eq!(r.cache_stats(), staged);
        // first demand pass: every lookup is a hit, and a prefetch hit
        for i in r.batches()[0].blocks.clone() {
            r.block(i, &c).unwrap();
        }
        let after = r.cache_stats();
        assert_eq!(after.misses as usize, nblocks);
        assert_eq!(after.hits as usize, nblocks);
        assert_eq!(after.prefetch_hits as usize, nblocks);
        assert_eq!(after.prefetch_wasted, 0);
        // second demand pass: plain hits, prefetch_hits stays flat
        for i in r.batches()[0].blocks.clone() {
            r.block(i, &c).unwrap();
        }
        assert_eq!(r.cache_stats().prefetch_hits as usize, nblocks);
        // counters saw the prefetch I/O as host misses + disk bytes
        let snap = c.snapshot();
        assert_eq!(snap.host_misses as usize, nblocks);
        assert_eq!(snap.bytes_disk, after.disk_bytes);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn prefetch_evicted_before_use_counts_as_wasted() {
        let b = sample_tensor();
        assert!(b.blocks.len() >= 8, "need enough blocks to thrash");
        let p = tmpfile("prefetch_waste.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget of ~3 blocks: prefetch 3, then demand the rest so every
        // staged block is evicted before any demand touch
        let budget = 3 * 512 * 16;
        let r = BlcoStoreReader::open_with_budget(&p, budget).unwrap();
        let c = Counters::new();
        for i in 0..3 {
            r.prefetch_block(i, &c).unwrap();
        }
        for i in 3..b.blocks.len() {
            r.block(i, &c).unwrap();
        }
        let s = r.cache_stats();
        assert_eq!(s.prefetch_wasted, 3, "all staged blocks evicted unused");
        assert_eq!(s.prefetch_hits, 0);
        assert!(s.peak_resident_bytes <= budget);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn run_with_prefetch_overlaps_and_stays_in_budget() {
        let b = sample_tensor();
        let p = tmpfile("prefetch_run.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget of two max-size batches: lookahead never forces the
        // current batch out, so prefetch hits are deterministic
        let probe = BatchSource::OnDisk(BlcoStoreReader::open(&p).unwrap());
        let max_batch: usize = (0..probe.num_batches())
            .map(|bi| probe.batch_bytes(bi))
            .max()
            .unwrap();
        let src =
            BatchSource::OnDisk(BlcoStoreReader::open_with_budget(&p, 2 * max_batch).unwrap());
        let c = Counters::new();
        let fetched = run_with_prefetch(&src, true, &c, |notify| {
            let mut n = 0usize;
            for bi in 0..src.num_batches() {
                notify(bi);
                n += src.fetch_batch(bi, &c).len();
            }
            n
        });
        assert_eq!(fetched, b.blocks.len());
        let s = src.reader().unwrap().cache_stats();
        assert!(s.prefetch_hits > 0, "overlap must produce prefetch hits: {s:?}");
        assert!(
            s.peak_resident_bytes <= s.budget_bytes,
            "peak {} > budget {}",
            s.peak_resident_bytes,
            s.budget_bytes
        );
        // the resident tier is a strict no-op: body runs, nothing else
        let resident = BatchSource::Resident(Arc::new(b));
        let out = run_with_prefetch(&resident, true, &c, |notify| {
            notify(0);
            42usize
        });
        assert_eq!(out, 42);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn batch_source_parity_between_tiers() {
        let b = sample_tensor();
        let p = tmpfile("source.blco");
        BlcoStore::write(&b, &p).unwrap();
        let resident = BatchSource::Resident(Arc::new(b));
        let disk = BatchSource::OnDisk(BlcoStoreReader::open(&p).unwrap());
        assert_eq!(resident.dims(), disk.dims());
        assert_eq!(resident.nnz(), disk.nnz());
        assert_eq!(resident.num_batches(), disk.num_batches());
        assert_eq!(resident.footprint_bytes(), disk.footprint_bytes());
        assert_eq!(resident.workgroup(), disk.workgroup());
        assert!((resident.norm() - disk.norm()).abs() < 1e-12);
        let c = Counters::new();
        for bi in 0..resident.num_batches() {
            assert_eq!(resident.batch_bytes(bi), disk.batch_bytes(bi), "batch {bi}");
            let a = resident.fetch_batch(bi, &c);
            let d = disk.fetch_batch(bi, &c);
            assert_eq!(a.len(), d.len());
            for (x, y) in a.iter().zip(d.iter()) {
                assert_eq!(x.key, y.key);
                assert_eq!(x.lidx, y.lidx);
            }
        }
        assert!(resident.resident().is_some());
        assert!(disk.reader().is_some());
        assert!(disk.is_on_disk() && !resident.is_on_disk());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_magic_is_structured() {
        let b = sample_tensor();
        let p = tmpfile("magic.blco");
        BlcoStore::write(&b, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::BadMagic { .. }) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wrong_version_is_structured() {
        let b = sample_tensor();
        let p = tmpfile("version.blco");
        BlcoStore::write(&b, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::UnsupportedVersion { found: 99, supported }) => {
                assert_eq!(supported, STORE_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
        // version 0 is equally unreadable (versions start at 1)
        bytes[8..12].copy_from_slice(&0u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            BlcoStoreReader::open(&p),
            Err(StoreError::UnsupportedVersion { found: 0, .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_file_is_structured() {
        let b = sample_tensor();
        let p = tmpfile("trunc.blco");
        BlcoStore::write(&b, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // cut the payload region short
        std::fs::write(&p, &bytes[..bytes.len() - 64]).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // cut into the header
        std::fs::write(&p, &bytes[..12]).unwrap();
        assert!(matches!(
            BlcoStoreReader::open(&p),
            Err(StoreError::Truncated { .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_header_and_payload_checksums() {
        let b = sample_tensor();
        let p = tmpfile("crc.blco");
        BlcoStore::write(&b, &p).unwrap();
        let good = std::fs::read(&p).unwrap();

        // flip a dims byte inside the header
        let mut bad = good.clone();
        bad[24] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::ChecksumMismatch { what, .. }) => {
                assert_eq!(what, "header");
            }
            other => panic!("expected header ChecksumMismatch, got {other:?}"),
        }

        // flip a byte in the last block's payload: open succeeds (header
        // intact), the lazy load fails with a structured error
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        let r = BlcoStoreReader::open(&p).unwrap();
        let last = r.num_blocks() - 1;
        match r.block(last, &Counters::new()) {
            Err(StoreError::ChecksumMismatch { what, .. }) => {
                assert!(what.contains("block"), "{what}");
            }
            other => panic!("expected payload ChecksumMismatch, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_compressed_payload_is_checksum_mismatch() {
        // the crc covers the *stored* bytes, so flipping a compressed bit
        // is caught before any varint/plane decode can misbehave
        let b = sample_tensor();
        for codec in [Codec::DeltaVarint, Codec::Shuffled] {
            let p = tmpfile(&format!("crc_codec_{}.blco", codec.tag()));
            BlcoStore::write_with(&b, &p, codec).unwrap();
            let mut bad = std::fs::read(&p).unwrap();
            let n = bad.len();
            bad[n - 1] ^= 0x01;
            std::fs::write(&p, &bad).unwrap();
            let r = BlcoStoreReader::open(&p).unwrap();
            let last = r.num_blocks() - 1;
            match r.block(last, &Counters::new()) {
                Err(StoreError::ChecksumMismatch { what, .. }) => {
                    assert!(what.contains("block"), "{what}");
                }
                other => panic!("{codec:?}: expected ChecksumMismatch, got {other:?}"),
            }
            // and verify_payloads reports the same fault
            assert!(matches!(
                r.verify_payloads(),
                Err(StoreError::ChecksumMismatch { .. })
            ));
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn errors_render_readably() {
        let e = StoreError::UnsupportedVersion { found: 7, supported: 2 };
        assert!(e.to_string().contains("version 7"));
        let e = StoreError::Truncated {
            what: "payload".into(),
            needed: 100,
            available: 50,
        };
        assert!(e.to_string().contains("100"));
    }
}
