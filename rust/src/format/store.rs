//! The on-disk `.blco` container and the host-out-of-core batch source.
//!
//! The paper's out-of-memory streaming makes *device* memory a non-issue;
//! this module removes the remaining binding constraint — host RAM — by
//! persisting a constructed [`BlcoTensor`] into a checksummed, versioned,
//! little-endian container that the streaming coordinator can read back
//! **block by block**. A [`BlcoStoreReader`] exposes every piece of
//! metadata (dims, order, nnz, per-block keys/sizes, batch maps) from the
//! header alone, and loads block payloads on demand through a
//! bounded-memory LRU [`BlockCache`], so the resident working set is the
//! cache budget — not the tensor size.
//!
//! # Container layout (version 1, everything little-endian)
//!
//! ```text
//! [0..8)    magic  "BLCOSTOR"
//! [8..12)   u32    version (currently 1)
//! [12..20)  u64    header length H (bytes of the header blob)
//! [20..20+H)       header blob:
//!                    u32        order
//!                    u64 × ord  dims
//!                    u64        nnz
//!                    f64        Frobenius norm of the values
//!                    u64        max_block_nnz   (BlcoConfig)
//!                    u32        workgroup       (BlcoConfig)
//!                    u32        inblock_budget  (BlcoConfig)
//!                    u64        number of blocks B
//!                    B × { u64 key, u64 nnz, u32 payload crc32 }
//! [20+H..24+H) u32  crc32 of the header blob
//! [24+H..)         block payloads, in block order, back to back:
//!                    nnz × u64  in-block indices (lidx)
//!                    nnz × u64  value bits (f64::to_bits)
//! ```
//!
//! Per-block payload offsets/lengths are derived (`nnz * 16` each, packed
//! in order), so a truncated file is detected by a single size check at
//! open. The [`BlcoSpec`] bit layout and the batch → work-group maps are
//! pure functions of `(dims, inblock_budget)` and the per-block nnz list
//! respectively, so both are rebuilt at open instead of being stored —
//! the reader's batches are bit-identical to the resident tensor's.
//!
//! Every open-time failure is a structured [`StoreError`]; payload
//! corruption discovered later (a crc mismatch on a lazily loaded block)
//! surfaces as an error from [`BlcoStoreReader::block`]. The streaming
//! executors treat that as fatal (they panic with the path and block id):
//! a half-streamed MTTKRP has no useful partial answer.

use std::collections::HashMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::device::counters::{Counters, Snapshot};
use crate::format::blco::{build_batches_from_nnz, Batch, BlcoConfig, Block, BlcoTensor};
use crate::linear::encode::BlcoSpec;

/// First 8 bytes of every `.blco` container.
pub const STORE_MAGIC: [u8; 8] = *b"BLCOSTOR";

/// Container version this build writes and reads.
pub const STORE_VERSION: u32 = 1;

/// Default [`BlockCache`] budget when the caller does not pass one
/// (CLI `inspect`, ad-hoc opens). Engines pass `Profile::host_mem_bytes`.
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

/// Why a container could not be written, opened or read. Open-time
/// variants carry the numbers needed to diagnose the file; all of them
/// are values, never panics.
#[derive(Debug)]
pub enum StoreError {
    /// underlying IO failure, with what we were doing at the time
    Io { context: String, source: std::io::Error },
    /// the first 8 bytes are not [`STORE_MAGIC`]
    BadMagic { found: [u8; 8] },
    /// a container written by an incompatible version of this layout
    UnsupportedVersion { found: u32, supported: u32 },
    /// the file ends before the region the header promises
    Truncated { what: String, needed: u64, available: u64 },
    /// stored checksum does not match the bytes on disk
    ChecksumMismatch { what: String, expected: u32, found: u32 },
    /// internally inconsistent metadata (bad counts, trailing bytes, ...)
    Malformed { what: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io { context, source } => {
                write!(f, "{context}: {source}")
            }
            StoreError::BadMagic { found } => write!(
                f,
                "not a .blco container: magic {found:02x?} != {:02x?}",
                STORE_MAGIC
            ),
            StoreError::UnsupportedVersion { found, supported } => write!(
                f,
                "unsupported container version {found} (this build reads \
                 version {supported})"
            ),
            StoreError::Truncated { what, needed, available } => write!(
                f,
                "truncated container: {what} needs {needed} bytes, file has \
                 {available}"
            ),
            StoreError::ChecksumMismatch { what, expected, found } => write!(
                f,
                "checksum mismatch in {what}: stored {expected:#010x}, \
                 computed {found:#010x}"
            ),
            StoreError::Malformed { what } => {
                write!(f, "malformed container: {what}")
            }
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

fn io_err(context: impl Into<String>) -> impl FnOnce(std::io::Error) -> StoreError {
    let context = context.into();
    move |source| StoreError::Io { context, source }
}

// ---------------------------------------------------------------- crc32

/// CRC-32 (IEEE 802.3, the zlib polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ------------------------------------------------- little-endian helpers

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Sequential little-endian reader over a byte slice with
/// truncation-checked takes.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], StoreError> {
        if self.pos + n > self.buf.len() {
            return Err(StoreError::Truncated {
                what: format!("header field {what}"),
                needed: (self.pos + n) as u64,
                available: self.buf.len() as u64,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64, StoreError> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

// ------------------------------------------------------------ the writer

/// Summary of a written container (what `blco convert` prints).
#[derive(Clone, Debug)]
pub struct StoreSummary {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub header_bytes: usize,
    pub payload_bytes: usize,
    pub blocks: usize,
    pub batches: usize,
    pub nnz: usize,
}

/// Per-block header-index entry: `(key, nnz, payload crc32)`. The single
/// currency both writers ([`BlcoStore::write`] and [`BlcoStoreWriter`])
/// serialize the block index from, so their headers are byte-identical by
/// construction.
pub type BlockMeta = (u64, u64, u32);

/// Serialize one block's payload — `nnz × u64` in-block indices then
/// `nnz × u64` value bits, all little-endian — into the reusable `buf`.
fn serialize_block_payload(buf: &mut Vec<u8>, lidx: &[u64], vals: &[f64]) {
    debug_assert_eq!(lidx.len(), vals.len());
    buf.clear();
    buf.reserve(lidx.len() * 16);
    for &l in lidx {
        buf.extend_from_slice(&l.to_le_bytes());
    }
    for &v in vals {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Build the version-1 header blob from streamed metadata alone. Both
/// writers call this, which is what guarantees the out-of-core path's
/// container is bit-for-bit the in-memory one (given equal blocks).
fn build_header_blob(
    dims: &[u64],
    nnz: u64,
    norm: f64,
    config: &BlcoConfig,
    metas: &[BlockMeta],
) -> Vec<u8> {
    let mut header = Vec::with_capacity(64 + metas.len() * 20);
    put_u32(&mut header, dims.len() as u32);
    for &d in dims {
        put_u64(&mut header, d);
    }
    put_u64(&mut header, nnz);
    put_f64(&mut header, norm);
    put_u64(&mut header, config.max_block_nnz as u64);
    put_u32(&mut header, config.workgroup as u32);
    put_u32(&mut header, config.inblock_budget);
    put_u64(&mut header, metas.len() as u64);
    for &(key, bnnz, crc) in metas {
        put_u64(&mut header, key);
        put_u64(&mut header, bnnz);
        put_u32(&mut header, crc);
    }
    header
}

/// Writer namespace for the `.blco` container.
pub struct BlcoStore;

impl BlcoStore {
    /// Serialize a constructed BLCO tensor into the container at `path`
    /// (overwriting any existing file). The written payload is the exact
    /// block content — `u64` indices and `f64` bit patterns — so a
    /// read-back MTTKRP is bit-for-bit the resident one.
    pub fn write(t: &BlcoTensor, path: &Path) -> Result<StoreSummary, StoreError> {
        // one reusable serialization buffer: each block is serialized
        // twice (pass 1 for the header checksums, pass 2 to stream the
        // payload region out), so peak extra memory is O(one block), not
        // O(tensor) — writing must not halve the size `convert` handles
        let mut buf: Vec<u8> = Vec::new();

        // ---- header blob (pass 1 over the blocks)
        let metas: Vec<BlockMeta> = t
            .blocks
            .iter()
            .map(|blk| {
                serialize_block_payload(&mut buf, &blk.lidx, &blk.vals);
                (blk.key, blk.nnz() as u64, crc32(&buf))
            })
            .collect();
        let header =
            build_header_blob(t.dims(), t.nnz as u64, t.norm(), &t.config, &metas);

        // ---- file (pass 2 streams the payloads)
        let file = File::create(path)
            .map_err(io_err(format!("create {}", path.display())))?;
        let mut w = std::io::BufWriter::new(file);
        let ctx = || format!("write {}", path.display());
        w.write_all(&STORE_MAGIC).map_err(io_err(ctx()))?;
        w.write_all(&STORE_VERSION.to_le_bytes()).map_err(io_err(ctx()))?;
        w.write_all(&(header.len() as u64).to_le_bytes()).map_err(io_err(ctx()))?;
        w.write_all(&header).map_err(io_err(ctx()))?;
        w.write_all(&crc32(&header).to_le_bytes()).map_err(io_err(ctx()))?;
        let mut payload_bytes = 0usize;
        for blk in &t.blocks {
            serialize_block_payload(&mut buf, &blk.lidx, &blk.vals);
            w.write_all(&buf).map_err(io_err(ctx()))?;
            payload_bytes += buf.len();
        }
        w.flush().map_err(io_err(ctx()))?;

        Ok(StoreSummary {
            path: path.to_path_buf(),
            file_bytes: (24 + header.len() + payload_bytes) as u64,
            header_bytes: header.len(),
            payload_bytes,
            blocks: t.blocks.len(),
            batches: t.batches.len(),
            nnz: t.nnz,
        })
    }
}

// -------------------------------------------------- the incremental writer

/// Incremental `.blco` writer for block streams whose header (nnz, norm,
/// block index) is unknown until the last block: the out-of-core builder
/// ([`crate::tensor::ooc`]) emits merged blocks one at a time and never
/// holds the tensor.
///
/// The container's header *precedes* the payload region, so payloads are
/// staged in a sibling temp file (`<path>.payload.tmp`, same directory ⇒
/// same filesystem) and copied behind the finished header at
/// [`finish`](Self::finish). Peak memory is one serialized block; the
/// transient disk cost is one extra copy of the payload region. Dropping
/// the writer without `finish` removes the temp file and never touches
/// `path`.
///
/// Norm accounting mirrors [`BlcoTensor::norm`] bit for bit: values are
/// squared and summed in block-emission order, then rooted once at
/// finish, so a streamed build writes the exact header bytes the
/// in-memory `from_coo` → [`BlcoStore::write`] path would.
pub struct BlcoStoreWriter {
    path: PathBuf,
    tmp_path: PathBuf,
    payload: Option<std::io::BufWriter<File>>,
    dims: Vec<u64>,
    config: BlcoConfig,
    metas: Vec<BlockMeta>,
    nnz: u64,
    sumsq: f64,
    buf: Vec<u8>,
    payload_bytes: usize,
}

impl BlcoStoreWriter {
    /// Start a container at `path` for a tensor over `dims`. Asserts the
    /// same config invariants as `BlcoTensor::from_coo_with`.
    pub fn create(
        path: &Path,
        dims: &[u64],
        config: BlcoConfig,
    ) -> Result<Self, StoreError> {
        assert!(config.workgroup > 0, "BlcoConfig.workgroup must be > 0");
        assert!(config.max_block_nnz > 0, "BlcoConfig.max_block_nnz must be > 0");
        assert!(!dims.is_empty() && dims.iter().all(|&d| d > 0), "bad dims");
        let tmp_path = PathBuf::from(format!("{}.payload.tmp", path.display()));
        let file = File::create(&tmp_path)
            .map_err(io_err(format!("create {}", tmp_path.display())))?;
        Ok(BlcoStoreWriter {
            path: path.to_path_buf(),
            tmp_path,
            payload: Some(std::io::BufWriter::new(file)),
            dims: dims.to_vec(),
            config,
            metas: Vec::new(),
            nnz: 0,
            sumsq: 0.0,
            buf: Vec::new(),
            payload_bytes: 0,
        })
    }

    /// Append one finished block (non-empty, `≤ max_block_nnz`, keys
    /// non-decreasing across calls — the merge emits them in ALTO order).
    pub fn add_block(
        &mut self,
        key: u64,
        lidx: &[u64],
        vals: &[f64],
    ) -> Result<(), StoreError> {
        assert_eq!(lidx.len(), vals.len(), "ragged block");
        assert!(!vals.is_empty(), "empty block");
        assert!(vals.len() <= self.config.max_block_nnz, "block over budget");
        serialize_block_payload(&mut self.buf, lidx, vals);
        self.metas.push((key, vals.len() as u64, crc32(&self.buf)));
        self.nnz += vals.len() as u64;
        for &v in vals {
            self.sumsq += v * v;
        }
        self.payload_bytes += self.buf.len();
        let w = self.payload.as_mut().expect("writer already finished");
        w.write_all(&self.buf)
            .map_err(io_err(format!("write {}", self.tmp_path.display())))
    }

    /// Blocks written so far.
    pub fn blocks(&self) -> usize {
        self.metas.len()
    }

    /// Bytes of writer-held state (block index + serialization buffer) —
    /// feeds the out-of-core builder's peak-memory accounting.
    pub fn held_bytes(&self) -> usize {
        self.metas.capacity() * std::mem::size_of::<BlockMeta>()
            + self.buf.capacity()
    }

    /// Write the header in front of the staged payloads and produce the
    /// final container. Consumes the writer; the temp file is removed.
    pub fn finish(mut self) -> Result<StoreSummary, StoreError> {
        // flush + close the payload stage before reading it back
        let mut w = self.payload.take().expect("writer already finished");
        w.flush()
            .map_err(io_err(format!("flush {}", self.tmp_path.display())))?;
        drop(w);

        let norm = self.sumsq.sqrt();
        let header = build_header_blob(
            &self.dims,
            self.nnz,
            norm,
            &self.config,
            &self.metas,
        );
        let batches = build_batches_from_nnz(
            &self.metas.iter().map(|&(_, n, _)| n as usize).collect::<Vec<_>>(),
            &self.config,
        );

        let file = File::create(&self.path)
            .map_err(io_err(format!("create {}", self.path.display())))?;
        let mut out = std::io::BufWriter::new(file);
        let ctx = || format!("write {}", self.path.display());
        out.write_all(&STORE_MAGIC).map_err(io_err(ctx()))?;
        out.write_all(&STORE_VERSION.to_le_bytes()).map_err(io_err(ctx()))?;
        out.write_all(&(header.len() as u64).to_le_bytes())
            .map_err(io_err(ctx()))?;
        out.write_all(&header).map_err(io_err(ctx()))?;
        out.write_all(&crc32(&header).to_le_bytes()).map_err(io_err(ctx()))?;
        let mut stage = File::open(&self.tmp_path)
            .map_err(io_err(format!("open {}", self.tmp_path.display())))?;
        let copied = std::io::copy(&mut stage, &mut out).map_err(io_err(
            format!(
                "copy {} -> {}",
                self.tmp_path.display(),
                self.path.display()
            ),
        ))?;
        if copied != self.payload_bytes as u64 {
            return Err(StoreError::Malformed {
                what: format!(
                    "payload stage holds {copied} bytes, wrote {}",
                    self.payload_bytes
                ),
            });
        }
        out.flush().map_err(io_err(ctx()))?;
        drop(stage);

        Ok(StoreSummary {
            path: self.path.clone(),
            file_bytes: (24 + header.len() + self.payload_bytes) as u64,
            header_bytes: header.len(),
            payload_bytes: self.payload_bytes,
            blocks: self.metas.len(),
            batches: batches.len(),
            nnz: self.nnz as usize,
        })
        // Drop::drop removes the temp file
    }
}

impl Drop for BlcoStoreWriter {
    fn drop(&mut self) {
        // close the stage handle first (no-op if finish already took it),
        // then clean up; an aborted build must not leak temp payloads
        self.payload.take();
        std::fs::remove_file(&self.tmp_path).ok();
    }
}

// ------------------------------------------------------------- the cache

/// Point-in-time statistics of a [`BlockCache`]. `peak_resident_bytes`
/// never exceeding `budget_bytes` is the host-out-of-core acceptance
/// observable the round-trip tests assert.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// demand lookups that found a block staged by the prefetcher — the
    /// direct measure of I/O successfully hidden behind compute
    pub prefetch_hits: u64,
    /// prefetched blocks evicted before any demand touch (prefetch I/O
    /// that bought nothing; a high count means the budget is too small
    /// to hold the working set plus one batch of lookahead)
    pub prefetch_wasted: u64,
    /// bytes read from disk (payloads of every miss)
    pub disk_bytes: u64,
    /// block payload bytes currently held
    pub resident_bytes: usize,
    /// high-water mark of host payload residency, *including* any single
    /// over-budget block handed out uncached — so the invariant
    /// `peak_resident_bytes <= budget_bytes` fails honestly when the
    /// budget cannot bound residency, rather than passing vacuously
    pub peak_resident_bytes: usize,
    pub budget_bytes: usize,
}

struct CacheEntry {
    block: Arc<Block>,
    /// last-touch tick (LRU recency)
    last: u64,
    /// staged by the prefetcher and not yet demanded: the first demand
    /// `get` clears this and counts a prefetch hit; eviction while still
    /// set counts a wasted prefetch
    prefetched: bool,
}

struct CacheInner {
    /// block id → cache entry
    map: HashMap<usize, CacheEntry>,
    resident_bytes: usize,
    tick: u64,
}

/// Bounded-memory LRU over loaded blocks: at most `budget` payload bytes
/// stay resident; least-recently-used blocks are evicted to make room. A
/// single block larger than the whole budget is returned to the caller
/// but never inserted — the cache map stays under budget, and the
/// over-budget hand-out is charged to `peak_resident_bytes` so the
/// violation is observable.
pub struct BlockCache {
    budget: usize,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    prefetch_hits: AtomicU64,
    prefetch_wasted: AtomicU64,
    disk_bytes: AtomicU64,
    peak: AtomicUsize,
}

impl BlockCache {
    pub fn new(budget: usize) -> Self {
        BlockCache {
            budget,
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                resident_bytes: 0,
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            prefetch_wasted: AtomicU64::new(0),
            disk_bytes: AtomicU64::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Whether block `i` is resident, without touching recency or stats —
    /// the prefetcher's peek must not perturb what it is measuring.
    fn contains(&self, i: usize) -> bool {
        self.inner.lock().expect("block cache poisoned").map.contains_key(&i)
    }

    /// Look up block `i`, refreshing its recency on a hit.
    fn get(&self, i: usize) -> Option<Arc<Block>> {
        let mut inner = self.inner.lock().expect("block cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&i) {
            Some(e) => {
                e.last = tick;
                if e.prefetched {
                    e.prefetched = false;
                    self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.block))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly loaded block, evicting LRU entries until it fits.
    /// Returns how many blocks were evicted.
    fn insert(&self, i: usize, block: Arc<Block>, prefetched: bool) -> usize {
        let bytes = block.bytes();
        self.disk_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        if bytes > self.budget {
            // over-budget single block: hand it out uncached — but charge
            // it to the high-water mark, so `peak <= budget` assertions
            // honestly FAIL when the budget cannot bound residency at all
            // (raise the budget or shrink max_block_nnz), instead of
            // passing vacuously while the caller holds the payload anyway
            let inner = self.inner.lock().expect("block cache poisoned");
            self.peak.fetch_max(inner.resident_bytes + bytes, Ordering::Relaxed);
            return 0;
        }
        let mut inner = self.inner.lock().expect("block cache poisoned");
        let mut evicted = 0usize;
        while inner.resident_bytes + bytes > self.budget {
            let lru = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last)
                .map(|(&k, _)| k)
                .expect("resident_bytes > 0 implies a resident block");
            let gone = inner.map.remove(&lru).expect("lru key present");
            inner.resident_bytes -= gone.block.bytes();
            if gone.prefetched {
                self.prefetch_wasted.fetch_add(1, Ordering::Relaxed);
            }
            evicted += 1;
        }
        inner.tick += 1;
        let tick = inner.tick;
        // two threads can race a miss on the same block; replacing must
        // not double-count the payload
        if let Some(old) = inner.map.insert(i, CacheEntry { block, last: tick, prefetched }) {
            inner.resident_bytes -= old.block.bytes();
        }
        inner.resident_bytes += bytes;
        self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        self.peak.fetch_max(inner.resident_bytes, Ordering::Relaxed);
        evicted
    }

    /// Stage a block loaded by the prefetcher: counted as a miss (the
    /// payload did come off disk) and flagged so the first demand `get`
    /// reports a prefetch hit, and an eviction-before-use reports waste.
    fn stage_prefetched(&self, i: usize, block: Arc<Block>) -> usize {
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.insert(i, block, true)
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().expect("block cache poisoned");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_wasted: self.prefetch_wasted.load(Ordering::Relaxed),
            disk_bytes: self.disk_bytes.load(Ordering::Relaxed),
            resident_bytes: inner.resident_bytes,
            peak_resident_bytes: self.peak.load(Ordering::Relaxed),
            budget_bytes: self.budget,
        }
    }
}

// ------------------------------------------------------------ the reader

/// Header-resident metadata of one stored block.
#[derive(Clone, Copy, Debug)]
pub struct BlockMeta {
    pub key: u64,
    pub nnz: usize,
    /// absolute payload offset in the file
    pub offset: u64,
    /// payload length (`nnz * 16`)
    pub bytes: usize,
    pub crc: u32,
}

/// mmap-free reader over a `.blco` container: all metadata (dims, spec,
/// per-block index, rebuilt batches) lives in memory from the header
/// alone; block payloads load on demand through the bounded
/// [`BlockCache`].
pub struct BlcoStoreReader {
    path: PathBuf,
    file: Mutex<File>,
    spec: BlcoSpec,
    config: BlcoConfig,
    nnz: usize,
    norm: f64,
    metas: Vec<BlockMeta>,
    batches: Vec<Batch>,
    cache: BlockCache,
}

impl BlcoStoreReader {
    /// Open with the default cache budget ([`DEFAULT_CACHE_BYTES`]).
    pub fn open(path: &Path) -> Result<Self, StoreError> {
        Self::open_with_budget(path, DEFAULT_CACHE_BYTES)
    }

    /// Open, validating magic/version/header checksum/size, with an
    /// explicit [`BlockCache`] budget in bytes (engines pass
    /// `Profile::host_mem_bytes`).
    pub fn open_with_budget(
        path: &Path,
        cache_budget: usize,
    ) -> Result<Self, StoreError> {
        let mut file = File::open(path)
            .map_err(io_err(format!("open {}", path.display())))?;
        let file_len = file
            .metadata()
            .map_err(io_err(format!("stat {}", path.display())))?
            .len();

        // ---- fixed preamble
        let mut pre = [0u8; 20];
        if file_len < 20 {
            return Err(StoreError::Truncated {
                what: "magic + version + header length".into(),
                needed: 20,
                available: file_len,
            });
        }
        file.read_exact(&mut pre)
            .map_err(io_err(format!("read preamble of {}", path.display())))?;
        let magic: [u8; 8] = pre[0..8].try_into().unwrap();
        if magic != STORE_MAGIC {
            return Err(StoreError::BadMagic { found: magic });
        }
        let version = u32::from_le_bytes(pre[8..12].try_into().unwrap());
        if version != STORE_VERSION {
            return Err(StoreError::UnsupportedVersion {
                found: version,
                supported: STORE_VERSION,
            });
        }
        let header_len = u64::from_le_bytes(pre[12..20].try_into().unwrap());
        if header_len > file_len.saturating_sub(24) {
            return Err(StoreError::Truncated {
                what: "header blob + checksum".into(),
                needed: 24 + header_len,
                available: file_len,
            });
        }

        // ---- header blob + its checksum
        let mut header = vec![0u8; header_len as usize];
        file.read_exact(&mut header)
            .map_err(io_err(format!("read header of {}", path.display())))?;
        let mut crc_buf = [0u8; 4];
        file.read_exact(&mut crc_buf)
            .map_err(io_err(format!("read header crc of {}", path.display())))?;
        let stored_crc = u32::from_le_bytes(crc_buf);
        let computed = crc32(&header);
        if stored_crc != computed {
            return Err(StoreError::ChecksumMismatch {
                what: "header".into(),
                expected: stored_crc,
                found: computed,
            });
        }

        // ---- parse
        let mut c = Cursor::new(&header);
        let order = c.u32("order")? as usize;
        if order == 0 || order > 16 {
            return Err(StoreError::Malformed {
                what: format!("order {order} outside 1..=16"),
            });
        }
        let mut dims = Vec::with_capacity(order);
        for n in 0..order {
            let d = c.u64(&format!("dims[{n}]"))?;
            if d == 0 {
                return Err(StoreError::Malformed {
                    what: format!("dims[{n}] is zero"),
                });
            }
            dims.push(d);
        }
        let nnz = c.u64("nnz")? as usize;
        let norm = c.f64("norm")?;
        let max_block_nnz = c.u64("max_block_nnz")? as usize;
        let workgroup = c.u32("workgroup")? as usize;
        let inblock_budget = c.u32("inblock_budget")?;
        if max_block_nnz == 0 || workgroup == 0 {
            return Err(StoreError::Malformed {
                what: "max_block_nnz and workgroup must be > 0".into(),
            });
        }
        let nblocks = c.u64("block count")? as usize;
        // each index entry takes 20 header bytes; a count the header
        // cannot physically hold is malformed (and must not drive a
        // pre-allocation)
        if nblocks > header.len() / 20 {
            return Err(StoreError::Malformed {
                what: format!(
                    "block count {nblocks} exceeds what a {}-byte header can hold",
                    header.len()
                ),
            });
        }
        let payload_base = 24 + header_len;
        // hard ceiling for any single block: the payload region that
        // actually exists on disk. Without it, a crafted header (the crc
        // is attacker-computable) could declare a huge nnz whose
        // `* 16` wraps in release builds and whose decode loop then
        // aborts or indexes out of bounds — open must reject it instead.
        let max_block_nnz_on_disk = file_len.saturating_sub(payload_base) / 16;
        let mut metas = Vec::with_capacity(nblocks);
        let mut offset = payload_base;
        let mut total_nnz = 0usize;
        for b in 0..nblocks {
            let key = c.u64(&format!("block[{b}].key"))?;
            let bnnz64 = c.u64(&format!("block[{b}].nnz"))?;
            if bnnz64 == 0 {
                return Err(StoreError::Malformed {
                    what: format!("block[{b}] has zero non-zeros"),
                });
            }
            if bnnz64 > max_block_nnz_on_disk {
                return Err(StoreError::Malformed {
                    what: format!(
                        "block[{b}] claims {bnnz64} non-zeros but the payload \
                         region holds at most {max_block_nnz_on_disk}"
                    ),
                });
            }
            let bnnz = bnnz64 as usize;
            let crc = c.u32(&format!("block[{b}].crc"))?;
            let bytes = bnnz * 16; // cannot wrap: bnnz bounded by file size
            metas.push(BlockMeta { key, nnz: bnnz, offset, bytes, crc });
            offset = offset.checked_add(bytes as u64).ok_or_else(|| {
                StoreError::Malformed {
                    what: format!("payload offsets overflow at block {b}"),
                }
            })?;
            total_nnz = total_nnz.checked_add(bnnz).ok_or_else(|| {
                StoreError::Malformed {
                    what: format!("nnz total overflows at block {b}"),
                }
            })?;
        }
        if c.pos != header.len() {
            return Err(StoreError::Malformed {
                what: format!(
                    "{} trailing header bytes after the block index",
                    header.len() - c.pos
                ),
            });
        }
        if total_nnz != nnz {
            return Err(StoreError::Malformed {
                what: format!(
                    "block nnz sum {total_nnz} != header nnz {nnz}"
                ),
            });
        }
        if offset > file_len {
            return Err(StoreError::Truncated {
                what: "block payload region".into(),
                needed: offset,
                available: file_len,
            });
        }
        if offset < file_len {
            return Err(StoreError::Malformed {
                what: format!("{} trailing bytes after the payload region", file_len - offset),
            });
        }

        // ---- rebuild the derived structures: the bit layout is a pure
        // function of (dims, budget), the batch maps of (block nnz list,
        // config) — both bit-identical to the resident tensor's
        let spec = BlcoSpec::with_budget(&dims, inblock_budget);
        let config = BlcoConfig {
            max_block_nnz,
            workgroup,
            inblock_budget,
            ..BlcoConfig::default()
        };
        let nnzs: Vec<usize> = metas.iter().map(|m| m.nnz).collect();
        let batches = build_batches_from_nnz(&nnzs, &config);

        Ok(BlcoStoreReader {
            path: path.to_path_buf(),
            file: Mutex::new(file),
            spec,
            config,
            nnz,
            norm,
            metas,
            batches,
            cache: BlockCache::new(cache_budget),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn spec(&self) -> &BlcoSpec {
        &self.spec
    }

    pub fn config(&self) -> &BlcoConfig {
        &self.config
    }

    pub fn dims(&self) -> &[u64] {
        &self.spec.dims
    }

    pub fn order(&self) -> usize {
        self.spec.order()
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Frobenius norm recorded at write time (CP-ALS needs it without a
    /// payload scan).
    pub fn norm(&self) -> f64 {
        self.norm
    }

    pub fn num_blocks(&self) -> usize {
        self.metas.len()
    }

    pub fn block_meta(&self, i: usize) -> &BlockMeta {
        &self.metas[i]
    }

    /// Batch metadata rebuilt from the header (bit-identical to the
    /// resident tensor's batching).
    pub fn batches(&self) -> &[Batch] {
        &self.batches
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Total on-device payload + metadata bytes, same accounting as
    /// [`BlcoTensor::footprint_bytes`] so routing decisions are identical
    /// across tiers.
    pub fn footprint_bytes(&self) -> usize {
        let payload: usize = self.metas.iter().map(|m| m.bytes).sum();
        let keys = self.metas.len() * 8;
        let maps: usize = self.batches.iter().map(|b| b.wg_block.len() * 8).sum();
        payload + keys + maps
    }

    /// Read and decode block `i` straight from disk, verifying its
    /// checksum — no cache interaction.
    fn read_block(&self, i: usize) -> Result<Block, StoreError> {
        let m = self.metas[i];
        let mut raw = vec![0u8; m.bytes];
        {
            let mut f = self.file.lock().expect("store file poisoned");
            f.seek(SeekFrom::Start(m.offset)).map_err(io_err(format!(
                "seek to block {i} of {}",
                self.path.display()
            )))?;
            f.read_exact(&mut raw).map_err(io_err(format!(
                "read block {i} of {}",
                self.path.display()
            )))?;
        }
        let found = crc32(&raw);
        if found != m.crc {
            return Err(StoreError::ChecksumMismatch {
                what: format!("block {i} payload"),
                expected: m.crc,
                found,
            });
        }
        let mut lidx = Vec::with_capacity(m.nnz);
        for w in 0..m.nnz {
            lidx.push(u64::from_le_bytes(raw[w * 8..w * 8 + 8].try_into().unwrap()));
        }
        let vbase = m.nnz * 8;
        let mut vals = Vec::with_capacity(m.nnz);
        for w in 0..m.nnz {
            vals.push(f64::from_bits(u64::from_le_bytes(
                raw[vbase + w * 8..vbase + w * 8 + 8].try_into().unwrap(),
            )));
        }
        Ok(Block { key: m.key, lidx, vals })
    }

    /// Load block `i`, through the cache. Cache hit/miss/eviction counts
    /// and disk-read bytes are charged to `counters` (the host tier of
    /// the traffic model); payload integrity is verified against the
    /// header checksum on every disk read.
    pub fn block(&self, i: usize, counters: &Counters) -> Result<Arc<Block>, StoreError> {
        if let Some(b) = self.cache.get(i) {
            counters.add(&Snapshot { host_hits: 1, ..Default::default() });
            return Ok(b);
        }
        let m = self.metas[i];
        let block = Arc::new(self.read_block(i)?);
        let evicted = self.cache.insert(i, Arc::clone(&block), false);
        counters.add(&Snapshot {
            host_misses: 1,
            host_evictions: evicted as u64,
            bytes_disk: m.bytes as u64,
            ..Default::default()
        });
        Ok(block)
    }

    /// Advisory load of block `i` into the cache ahead of demand. A block
    /// already resident is left untouched (no recency or stat
    /// perturbation); a fresh load is charged exactly like a demand miss
    /// (it is the same disk I/O, just earlier) and flagged so
    /// [`CacheStats::prefetch_hits`] / [`CacheStats::prefetch_wasted`]
    /// attribute its fate.
    pub fn prefetch_block(&self, i: usize, counters: &Counters) -> Result<(), StoreError> {
        if self.cache.contains(i) {
            return Ok(());
        }
        let m = self.metas[i];
        let block = Arc::new(self.read_block(i)?);
        let evicted = self.cache.stage_prefetched(i, block);
        counters.add(&Snapshot {
            host_misses: 1,
            host_evictions: evicted as u64,
            bytes_disk: m.bytes as u64,
            ..Default::default()
        });
        Ok(())
    }

    /// Prefetch every block of batch `b`. Errors are advisory — the
    /// demand path will retry the same block and surface the failure as
    /// fatal there — so a prefetch fault only warns and stops early.
    pub fn prefetch_batch(&self, b: usize, counters: &Counters) {
        for i in self.batches[b].blocks.clone() {
            if let Err(e) = self.prefetch_block(i, counters) {
                eprintln!(
                    "warning: prefetch of block {i} from {} failed: {e}",
                    self.path.display()
                );
                return;
            }
        }
    }

    /// Verify every block payload against its stored checksum without
    /// touching the cache (CLI `inspect --verify`). Returns the payload
    /// bytes scanned.
    pub fn verify_payloads(&self) -> Result<usize, StoreError> {
        let mut scanned = 0usize;
        for i in 0..self.metas.len() {
            self.read_block(i)?;
            scanned += self.metas[i].bytes;
        }
        Ok(scanned)
    }

    /// Materialize the whole container as a resident [`BlcoTensor`]
    /// (cache-bypassing full scan) — the resident twin the CLI's
    /// `stream --from-store --check` compares bit-for-bit against, and an
    /// escape hatch for callers that decide a tensor fits after all.
    pub fn to_tensor(&self) -> Result<BlcoTensor, StoreError> {
        let mut blocks = Vec::with_capacity(self.metas.len());
        for i in 0..self.metas.len() {
            blocks.push(Arc::new(self.read_block(i)?));
        }
        Ok(BlcoTensor {
            spec: self.spec.clone(),
            blocks,
            batches: self.batches.clone(),
            config: self.config,
            nnz: self.nnz,
            stages: Arc::new(crate::util::timer::Stages::new()),
        })
    }
}

impl std::fmt::Debug for BlcoStoreReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BlcoStoreReader")
            .field("path", &self.path)
            .field("dims", &self.spec.dims)
            .field("nnz", &self.nnz)
            .field("blocks", &self.metas.len())
            .field("batches", &self.batches.len())
            .finish()
    }
}

// ------------------------------------------------------ the batch source

/// The blocks backing one batch, borrowed from a resident tensor or
/// freshly loaded from disk. Derefs to `[Arc<Block>]` indexed by
/// `global_block_id - batch.blocks.start`.
pub enum BatchBlocks<'a> {
    Borrowed(&'a [Arc<Block>]),
    Loaded(Vec<Arc<Block>>),
}

impl std::ops::Deref for BatchBlocks<'_> {
    type Target = [Arc<Block>];

    fn deref(&self) -> &[Arc<Block>] {
        match self {
            BatchBlocks::Borrowed(s) => s,
            BatchBlocks::Loaded(v) => v,
        }
    }
}

/// Where a BLCO engine's block payload lives. Every streaming executor
/// and kernel consumes batches through this interface, so nothing above
/// it assumes the tensor is in host RAM:
///
/// * [`BatchSource::Resident`] — the whole [`BlcoTensor`] is resident
///   (the original in-memory path); fetches borrow, zero copies;
/// * [`BatchSource::OnDisk`] — only header metadata is resident; fetches
///   load payloads through the reader's bounded [`BlockCache`], making
///   host memory a budget rather than a requirement.
// one value per engine, moved once at construction — the inline-size
// asymmetry between the Arc and the reader (spec + index + cache) is
// irrelevant, and boxing the reader would only add a pointer chase to
// every batch fetch
#[allow(clippy::large_enum_variant)]
pub enum BatchSource {
    Resident(Arc<BlcoTensor>),
    OnDisk(BlcoStoreReader),
}

impl BatchSource {
    pub fn spec(&self) -> &BlcoSpec {
        match self {
            BatchSource::Resident(t) => &t.spec,
            BatchSource::OnDisk(r) => r.spec(),
        }
    }

    pub fn dims(&self) -> &[u64] {
        match self {
            BatchSource::Resident(t) => t.dims(),
            BatchSource::OnDisk(r) => r.dims(),
        }
    }

    pub fn order(&self) -> usize {
        self.dims().len()
    }

    pub fn nnz(&self) -> usize {
        match self {
            BatchSource::Resident(t) => t.nnz,
            BatchSource::OnDisk(r) => r.nnz(),
        }
    }

    /// Work-group size the batch maps were built with.
    pub fn workgroup(&self) -> usize {
        match self {
            BatchSource::Resident(t) => t.config.workgroup,
            BatchSource::OnDisk(r) => r.config().workgroup,
        }
    }

    pub fn batches(&self) -> &[Batch] {
        match self {
            BatchSource::Resident(t) => &t.batches,
            BatchSource::OnDisk(r) => r.batches(),
        }
    }

    pub fn num_batches(&self) -> usize {
        self.batches().len()
    }

    /// Host→device wire bytes of batch `b` (payload + work-group maps) —
    /// identical across tiers, so schedules planned against either source
    /// are interchangeable (pinned per batch by the tier-parity tests).
    pub fn batch_bytes(&self, b: usize) -> usize {
        match self {
            BatchSource::Resident(t) => t.batch_wire_bytes(b),
            BatchSource::OnDisk(r) => {
                let batch = &r.batches()[b];
                batch
                    .blocks
                    .clone()
                    .map(|i| r.block_meta(i).bytes)
                    .sum::<usize>()
                    + batch.wg_block.len() * 8
            }
        }
    }

    /// Total on-device bytes (payload + key + map metadata), the same
    /// number for both tiers of the same tensor.
    pub fn footprint_bytes(&self) -> usize {
        match self {
            BatchSource::Resident(t) => t.footprint_bytes(),
            BatchSource::OnDisk(r) => r.footprint_bytes(),
        }
    }

    /// Frobenius norm of the stored values (header field on disk).
    pub fn norm(&self) -> f64 {
        match self {
            BatchSource::Resident(t) => t.norm(),
            BatchSource::OnDisk(r) => r.norm(),
        }
    }

    pub fn is_on_disk(&self) -> bool {
        matches!(self, BatchSource::OnDisk(_))
    }

    /// The resident payload, when there is one.
    pub fn resident(&self) -> Option<&Arc<BlcoTensor>> {
        match self {
            BatchSource::Resident(t) => Some(t),
            BatchSource::OnDisk(_) => None,
        }
    }

    /// The disk reader, when the payload is out of core.
    pub fn reader(&self) -> Option<&BlcoStoreReader> {
        match self {
            BatchSource::Resident(_) => None,
            BatchSource::OnDisk(r) => Some(r),
        }
    }

    /// The blocks of batch `b`: borrowed when resident, cache-loaded when
    /// on disk. Disk corruption discovered here (crc mismatch, IO fault)
    /// is fatal — a half-streamed MTTKRP has no useful partial result —
    /// and panics with the path and block id.
    pub fn fetch_batch(&self, b: usize, counters: &Counters) -> BatchBlocks<'_> {
        match self {
            BatchSource::Resident(t) => {
                BatchBlocks::Borrowed(&t.blocks[t.batches[b].blocks.clone()])
            }
            BatchSource::OnDisk(r) => {
                let range = r.batches()[b].blocks.clone();
                let mut v = Vec::with_capacity(range.len());
                for i in range {
                    v.push(r.block(i, counters).unwrap_or_else(|e| {
                        panic!(
                            "loading BLCO block {i} from {}: {e}",
                            r.path().display()
                        )
                    }));
                }
                BatchBlocks::Loaded(v)
            }
        }
    }
}

// ------------------------------------------------- prefetch orchestration

/// Run a batch-ordered compute loop with a background thread pulling the
/// *next* batch's blocks off disk while the current one computes.
///
/// `body` receives a `notify` callback and must call `notify(b)` when it
/// starts computing batch `b`; the prefetcher stays at most **one batch
/// ahead** of the notified cursor, so lookahead residency is bounded by
/// one batch of payload on top of the demand working set (the
/// [`BlockCache`] budget still caps everything that is actually kept).
///
/// Batch 0 is prefetched synchronously before the background thread
/// starts: the first compute batch always finds its blocks staged when
/// the budget can hold them at all, which makes `prefetch_hits > 0`
/// deterministic rather than a race.
///
/// For a resident source, a zero-batch tensor, or `enabled == false`,
/// this degenerates to calling `body` with a no-op callback — callers
/// wrap their loop unconditionally and the resident path pays nothing.
/// If `body` panics, a drop guard parks the cursor so the prefetcher
/// exits instead of spinning, and the panic propagates.
pub fn run_with_prefetch<R>(
    src: &BatchSource,
    enabled: bool,
    counters: &Counters,
    body: impl FnOnce(&dyn Fn(usize)) -> R,
) -> R {
    let reader = match src.reader() {
        Some(r) if enabled && src.num_batches() > 0 => r,
        _ => return body(&|_| {}),
    };
    let nbatches = src.num_batches();
    reader.prefetch_batch(0, counters);
    if nbatches == 1 {
        return body(&|_| {});
    }
    // index of the batch the compute loop is currently on; usize::MAX
    // parks the prefetcher (set on completion or panic of `body`)
    let cursor = AtomicUsize::new(0);
    struct Park<'a>(&'a AtomicUsize);
    impl Drop for Park<'_> {
        fn drop(&mut self) {
            self.0.store(usize::MAX, Ordering::Release);
        }
    }
    std::thread::scope(|s| {
        let cursor = &cursor;
        s.spawn(move || {
            for b in 1..nbatches {
                loop {
                    let cur = cursor.load(Ordering::Acquire);
                    if cur == usize::MAX {
                        return;
                    }
                    if b <= cur + 1 {
                        break;
                    }
                    std::thread::yield_now();
                }
                reader.prefetch_batch(b, counters);
            }
        });
        let _park = Park(cursor);
        body(&|b| cursor.store(b, Ordering::Release))
    })
}

impl std::fmt::Debug for BatchSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchSource::Resident(t) => f
                .debug_struct("BatchSource::Resident")
                .field("dims", &t.dims())
                .field("nnz", &t.nnz)
                .finish(),
            BatchSource::OnDisk(r) => f
                .debug_struct("BatchSource::OnDisk")
                .field("path", &r.path)
                .field("nnz", &r.nnz)
                .finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::blco::BlcoConfig;
    use crate::tensor::synth;

    fn tmpfile(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_store_{}_{}", std::process::id(), name));
        p
    }

    fn sample_tensor() -> BlcoTensor {
        let t = synth::uniform(&[60, 50, 40], 6_000, 3);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        BlcoTensor::from_coo_with(&t, cfg)
    }

    #[test]
    fn incremental_writer_matches_batch_writer_bitwise() {
        // feeding the in-memory tensor's blocks through BlcoStoreWriter
        // must produce the exact file BlcoStore::write does — the shared
        // header/payload serializers are what the out-of-core build's
        // bit-parity guarantee stands on
        let b = sample_tensor();
        let p1 = tmpfile("batch.blco");
        let p2 = tmpfile("incremental.blco");
        let s1 = BlcoStore::write(&b, &p1).unwrap();
        let mut w = BlcoStoreWriter::create(&p2, b.dims(), b.config).unwrap();
        for blk in &b.blocks {
            w.add_block(blk.key, &blk.lidx, &blk.vals).unwrap();
        }
        let s2 = w.finish().unwrap();
        assert_eq!(s1.file_bytes, s2.file_bytes);
        assert_eq!(s1.blocks, s2.blocks);
        assert_eq!(s1.batches, s2.batches);
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        // the payload stage must be gone after finish
        assert!(!PathBuf::from(format!("{}.payload.tmp", p2.display())).exists());
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn incremental_writer_drop_cleans_stage_and_leaves_target_alone() {
        let p = tmpfile("aborted.blco");
        std::fs::write(&p, b"pre-existing").unwrap();
        let stage = PathBuf::from(format!("{}.payload.tmp", p.display()));
        {
            let mut w =
                BlcoStoreWriter::create(&p, &[8, 8], BlcoConfig::default())
                    .unwrap();
            w.add_block(0, &[1, 2], &[1.0, 2.0]).unwrap();
            assert!(stage.exists());
            // dropped without finish
        }
        assert!(!stage.exists(), "aborted writer leaked its payload stage");
        assert_eq!(std::fs::read(&p).unwrap(), b"pre-existing");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn header_metadata_round_trips() {
        let b = sample_tensor();
        let p = tmpfile("header.blco");
        let summary = BlcoStore::write(&b, &p).unwrap();
        assert_eq!(summary.blocks, b.blocks.len());
        assert_eq!(summary.batches, b.batches.len());
        let r = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(r.dims(), b.dims());
        assert_eq!(r.order(), b.order());
        assert_eq!(r.nnz(), b.nnz);
        assert!((r.norm() - b.norm()).abs() < 1e-12);
        assert_eq!(r.num_blocks(), b.blocks.len());
        assert_eq!(r.footprint_bytes(), b.footprint_bytes());
        // batches rebuilt bit-identically
        assert_eq!(r.batches().len(), b.batches.len());
        for (a, e) in r.batches().iter().zip(&b.batches) {
            assert_eq!(a, e);
        }
        for (i, blk) in b.blocks.iter().enumerate() {
            assert_eq!(r.block_meta(i).key, blk.key);
            assert_eq!(r.block_meta(i).nnz, blk.nnz());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn blocks_round_trip_bit_for_bit() {
        let b = sample_tensor();
        let p = tmpfile("payload.blco");
        BlcoStore::write(&b, &p).unwrap();
        let r = BlcoStoreReader::open(&p).unwrap();
        let c = Counters::new();
        for (i, expect) in b.blocks.iter().enumerate() {
            let got = r.block(i, &c).unwrap();
            assert_eq!(got.key, expect.key);
            assert_eq!(got.lidx, expect.lidx);
            let gb: Vec<u64> = got.vals.iter().map(|v| v.to_bits()).collect();
            let eb: Vec<u64> = expect.vals.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, eb, "block {i} values must be bit-identical");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cache_bounds_residency_and_counts() {
        let b = sample_tensor();
        assert!(b.blocks.len() >= 8, "need enough blocks to thrash");
        let p = tmpfile("cache.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget of ~3 blocks forces eviction on a full scan
        let budget = 3 * 512 * 16;
        let r = BlcoStoreReader::open_with_budget(&p, budget).unwrap();
        let c = Counters::new();
        for i in 0..b.blocks.len() {
            r.block(i, &c).unwrap();
        }
        // second pass over the first blocks: they were evicted
        for i in 0..3 {
            r.block(i, &c).unwrap();
        }
        let s = r.cache_stats();
        assert!(s.peak_resident_bytes <= budget, "peak {} > budget {budget}", s.peak_resident_bytes);
        assert!(s.resident_bytes <= budget);
        assert!(s.evictions > 0, "scan over budget must evict");
        assert_eq!(s.misses as usize, b.blocks.len() + 3);
        assert_eq!(s.disk_bytes, {
            let mut total = 0u64;
            for i in 0..b.blocks.len() {
                total += (r.block_meta(i).bytes) as u64;
            }
            for i in 0..3 {
                total += (r.block_meta(i).bytes) as u64;
            }
            total
        });
        // hot re-read of a just-inserted block hits
        let before = r.cache_stats().hits;
        r.block(2, &c).unwrap();
        assert_eq!(r.cache_stats().hits, before + 1);
        // counters carry the same story
        let snap = c.snapshot();
        assert_eq!(snap.host_hits, r.cache_stats().hits);
        assert_eq!(snap.host_misses, r.cache_stats().misses);
        assert_eq!(snap.bytes_disk, r.cache_stats().disk_bytes);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn prefetch_stages_blocks_and_counts_hits() {
        let b = sample_tensor();
        let p = tmpfile("prefetch_hits.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget big enough that nothing prefetched is ever evicted
        let r = BlcoStoreReader::open(&p).unwrap();
        let c = Counters::new();
        let nblocks = r.batches()[0].blocks.len();
        r.prefetch_batch(0, &c);
        let staged = r.cache_stats();
        assert_eq!(staged.misses as usize, nblocks, "each staged block is a miss");
        assert_eq!(staged.hits, 0);
        assert_eq!(staged.prefetch_hits, 0, "no demand touch yet");
        // re-prefetching resident blocks must not perturb any stat
        r.prefetch_batch(0, &c);
        assert_eq!(r.cache_stats(), staged);
        // first demand pass: every lookup is a hit, and a prefetch hit
        for i in r.batches()[0].blocks.clone() {
            r.block(i, &c).unwrap();
        }
        let after = r.cache_stats();
        assert_eq!(after.misses as usize, nblocks);
        assert_eq!(after.hits as usize, nblocks);
        assert_eq!(after.prefetch_hits as usize, nblocks);
        assert_eq!(after.prefetch_wasted, 0);
        // second demand pass: plain hits, prefetch_hits stays flat
        for i in r.batches()[0].blocks.clone() {
            r.block(i, &c).unwrap();
        }
        assert_eq!(r.cache_stats().prefetch_hits as usize, nblocks);
        // counters saw the prefetch I/O as host misses + disk bytes
        let snap = c.snapshot();
        assert_eq!(snap.host_misses as usize, nblocks);
        assert_eq!(snap.bytes_disk, after.disk_bytes);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn prefetch_evicted_before_use_counts_as_wasted() {
        let b = sample_tensor();
        assert!(b.blocks.len() >= 8, "need enough blocks to thrash");
        let p = tmpfile("prefetch_waste.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget of ~3 blocks: prefetch 3, then demand the rest so every
        // staged block is evicted before any demand touch
        let budget = 3 * 512 * 16;
        let r = BlcoStoreReader::open_with_budget(&p, budget).unwrap();
        let c = Counters::new();
        for i in 0..3 {
            r.prefetch_block(i, &c).unwrap();
        }
        for i in 3..b.blocks.len() {
            r.block(i, &c).unwrap();
        }
        let s = r.cache_stats();
        assert_eq!(s.prefetch_wasted, 3, "all staged blocks evicted unused");
        assert_eq!(s.prefetch_hits, 0);
        assert!(s.peak_resident_bytes <= budget);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn run_with_prefetch_overlaps_and_stays_in_budget() {
        let b = sample_tensor();
        let p = tmpfile("prefetch_run.blco");
        BlcoStore::write(&b, &p).unwrap();
        // budget of two max-size batches: lookahead never forces the
        // current batch out, so prefetch hits are deterministic
        let probe = BatchSource::OnDisk(BlcoStoreReader::open(&p).unwrap());
        let max_batch: usize = (0..probe.num_batches())
            .map(|bi| probe.batch_bytes(bi))
            .max()
            .unwrap();
        let src =
            BatchSource::OnDisk(BlcoStoreReader::open_with_budget(&p, 2 * max_batch).unwrap());
        let c = Counters::new();
        let fetched = run_with_prefetch(&src, true, &c, |notify| {
            let mut n = 0usize;
            for bi in 0..src.num_batches() {
                notify(bi);
                n += src.fetch_batch(bi, &c).len();
            }
            n
        });
        assert_eq!(fetched, b.blocks.len());
        let s = src.reader().unwrap().cache_stats();
        assert!(s.prefetch_hits > 0, "overlap must produce prefetch hits: {s:?}");
        assert!(
            s.peak_resident_bytes <= s.budget_bytes,
            "peak {} > budget {}",
            s.peak_resident_bytes,
            s.budget_bytes
        );
        // the resident tier is a strict no-op: body runs, nothing else
        let resident = BatchSource::Resident(Arc::new(b));
        let out = run_with_prefetch(&resident, true, &c, |notify| {
            notify(0);
            42usize
        });
        assert_eq!(out, 42);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn batch_source_parity_between_tiers() {
        let b = sample_tensor();
        let p = tmpfile("source.blco");
        BlcoStore::write(&b, &p).unwrap();
        let resident = BatchSource::Resident(Arc::new(b));
        let disk = BatchSource::OnDisk(BlcoStoreReader::open(&p).unwrap());
        assert_eq!(resident.dims(), disk.dims());
        assert_eq!(resident.nnz(), disk.nnz());
        assert_eq!(resident.num_batches(), disk.num_batches());
        assert_eq!(resident.footprint_bytes(), disk.footprint_bytes());
        assert_eq!(resident.workgroup(), disk.workgroup());
        assert!((resident.norm() - disk.norm()).abs() < 1e-12);
        let c = Counters::new();
        for bi in 0..resident.num_batches() {
            assert_eq!(resident.batch_bytes(bi), disk.batch_bytes(bi), "batch {bi}");
            let a = resident.fetch_batch(bi, &c);
            let d = disk.fetch_batch(bi, &c);
            assert_eq!(a.len(), d.len());
            for (x, y) in a.iter().zip(d.iter()) {
                assert_eq!(x.key, y.key);
                assert_eq!(x.lidx, y.lidx);
            }
        }
        assert!(resident.resident().is_some());
        assert!(disk.reader().is_some());
        assert!(disk.is_on_disk() && !resident.is_on_disk());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_magic_is_structured() {
        let b = sample_tensor();
        let p = tmpfile("magic.blco");
        BlcoStore::write(&b, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::BadMagic { .. }) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wrong_version_is_structured() {
        let b = sample_tensor();
        let p = tmpfile("version.blco");
        BlcoStore::write(&b, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::UnsupportedVersion { found: 99, supported }) => {
                assert_eq!(supported, STORE_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_file_is_structured() {
        let b = sample_tensor();
        let p = tmpfile("trunc.blco");
        BlcoStore::write(&b, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // cut the payload region short
        std::fs::write(&p, &bytes[..bytes.len() - 64]).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // cut into the header
        std::fs::write(&p, &bytes[..12]).unwrap();
        assert!(matches!(
            BlcoStoreReader::open(&p),
            Err(StoreError::Truncated { .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_header_and_payload_checksums() {
        let b = sample_tensor();
        let p = tmpfile("crc.blco");
        BlcoStore::write(&b, &p).unwrap();
        let good = std::fs::read(&p).unwrap();

        // flip a dims byte inside the header
        let mut bad = good.clone();
        bad[24] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        match BlcoStoreReader::open(&p) {
            Err(StoreError::ChecksumMismatch { what, .. }) => {
                assert_eq!(what, "header");
            }
            other => panic!("expected header ChecksumMismatch, got {other:?}"),
        }

        // flip a byte in the last block's payload: open succeeds (header
        // intact), the lazy load fails with a structured error
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        let r = BlcoStoreReader::open(&p).unwrap();
        let last = r.num_blocks() - 1;
        match r.block(last, &Counters::new()) {
            Err(StoreError::ChecksumMismatch { what, .. }) => {
                assert!(what.contains("block"), "{what}");
            }
            other => panic!("expected payload ChecksumMismatch, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn errors_render_readably() {
        let e = StoreError::UnsupportedVersion { found: 7, supported: 1 };
        assert!(e.to_string().contains("version 7"));
        let e = StoreError::Truncated {
            what: "payload".into(),
            needed: 100,
            available: 50,
        };
        assert!(e.to_string().contains("100"));
    }
}
