//! HiCOO (Li et al., SC '18) — the block-based format the paper contrasts
//! BLCO against (Section 7): non-zeros are grouped into fixed-size
//! multi-dimensional blocks (side `2^block_bits`), each storing compact
//! per-mode *element* offsets (u8) against the block's base coordinates.
//! Compression is good when blocks are dense, but hypersparse tensors
//! degenerate to one-element blocks with *more* metadata than COO — the
//! load-imbalance/overhead pathology the paper cites for why HiCOO has no
//! GPU implementation.

use std::collections::HashMap;

use crate::tensor::coo::CooTensor;

/// One HiCOO block: base coordinates (block index per mode) plus compact
/// element offsets.
#[derive(Clone, Debug)]
pub struct HicooBlock {
    /// per-mode block coordinates (global coordinate >> block_bits)
    pub base: Vec<u32>,
    /// per-mode element offsets within the block (mode-major planes)
    pub eidx: Vec<Vec<u8>>,
    pub vals: Vec<f64>,
}

impl HicooBlock {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

/// The HiCOO tensor: blocks sorted by block coordinate (Z-like row-major).
#[derive(Clone, Debug)]
pub struct HicooTensor {
    pub dims: Vec<u64>,
    pub block_bits: u32,
    pub blocks: Vec<HicooBlock>,
    pub nnz: usize,
}

impl HicooTensor {
    /// Build with blocks of side `2^block_bits` (HiCOO's default is 7,
    /// i.e. 128, matching its u8 element offsets).
    pub fn from_coo(t: &CooTensor, block_bits: u32) -> Self {
        assert!(block_bits <= 8, "u8 element offsets cap block side at 256");
        let order = t.order();
        let mut groups: HashMap<Vec<u32>, Vec<usize>> = HashMap::new();
        for e in 0..t.nnz() {
            let key: Vec<u32> =
                (0..order).map(|n| t.coords[n][e] >> block_bits).collect();
            groups.entry(key).or_default().push(e);
        }
        let mut keys: Vec<Vec<u32>> = groups.keys().cloned().collect();
        keys.sort_unstable();
        let blocks = keys
            .into_iter()
            .map(|key| {
                let elems = &groups[&key];
                let mask = (1u32 << block_bits) - 1;
                HicooBlock {
                    eidx: (0..order)
                        .map(|n| {
                            elems
                                .iter()
                                .map(|&e| (t.coords[n][e] & mask) as u8)
                                .collect()
                        })
                        .collect(),
                    vals: elems.iter().map(|&e| t.vals[e]).collect(),
                    base: key,
                }
            })
            .collect();
        HicooTensor { dims: t.dims.clone(), block_bits, blocks, nnz: t.nnz() }
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Bytes: per block, base coords (4B/mode) + per nnz (1B/mode + 8B val).
    pub fn footprint_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.base.len() * 4 + b.nnz() * (b.base.len() + 8))
            .sum()
    }

    /// Mean non-zeros per block (the density HiCOO's compression relies on).
    pub fn avg_block_nnz(&self) -> f64 {
        if self.blocks.is_empty() {
            0.0
        } else {
            self.nnz as f64 / self.blocks.len() as f64
        }
    }

    /// Round-trip reconstruction (tests).
    pub fn to_coo(&self) -> CooTensor {
        let mut t = CooTensor::with_capacity(&self.dims, self.nnz);
        let order = self.order();
        let mut coord = vec![0u32; order];
        for b in &self.blocks {
            for i in 0..b.nnz() {
                for n in 0..order {
                    coord[n] = (b.base[n] << self.block_bits) | b.eidx[n][i] as u32;
                }
                t.push(&coord, b.vals[i]);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;
    use std::collections::HashMap as Map;

    fn key_count(t: &CooTensor) -> Map<(Vec<u32>, u64), u32> {
        let mut m = Map::new();
        for e in 0..t.nnz() {
            *m.entry((t.coord(e), t.vals[e].to_bits())).or_insert(0u32) += 1;
        }
        m
    }

    #[test]
    fn roundtrip() {
        let t = synth::uniform(&[300, 200, 100], 5_000, 1);
        let h = HicooTensor::from_coo(&t, 7);
        assert_eq!(h.nnz, t.nnz());
        assert_eq!(key_count(&h.to_coo()), key_count(&t));
    }

    #[test]
    fn blocks_partition_nnz() {
        let t = synth::fiber_clustered(&[256, 256, 256], 8_000, 2, 1.0, 2);
        let h = HicooTensor::from_coo(&t, 6);
        let total: usize = h.blocks.iter().map(|b| b.nnz()).sum();
        assert_eq!(total, t.nnz());
        // element offsets must fit the block side
        for b in &h.blocks {
            for plane in &b.eidx {
                assert!(plane.iter().all(|&x| (x as u32) < (1 << 6)));
            }
        }
    }

    #[test]
    fn dense_blocks_compress_hypersparse_bloats() {
        // clustered tensor in a small space → dense blocks → smaller than COO
        let dense = synth::fiber_clustered(&[128, 128, 128], 40_000, 2, 1.2, 3);
        let hd = HicooTensor::from_coo(&dense, 7);
        assert!(hd.avg_block_nnz() > 8.0, "avg {}", hd.avg_block_nnz());
        assert!(hd.footprint_bytes() < dense.footprint_bytes());

        // hypersparse tensor → singleton blocks → more bytes than COO
        // (the paper's §7 criticism, quantified)
        let hyper = synth::uniform(&[1 << 20, 1 << 20, 1 << 20], 5_000, 4);
        let hh = HicooTensor::from_coo(&hyper, 7);
        assert!(hh.avg_block_nnz() < 1.5, "avg {}", hh.avg_block_nnz());
        assert!(hh.footprint_bytes() > hyper.footprint_bytes() * 3 / 4);
    }

    #[test]
    fn block_sorted_order() {
        let t = synth::uniform(&[512, 512, 512], 3_000, 5);
        let h = HicooTensor::from_coo(&t, 7);
        for w in h.blocks.windows(2) {
            assert!(w[0].base <= w[1].base);
        }
    }
}
