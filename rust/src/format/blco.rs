//! The Blocked Linearized CoOrdinate (BLCO) tensor (Section 4).
//!
//! Construction pipeline (stage-timed for Figure 12):
//! 1. **linearize** — ALTO-encode every non-zero (up to 128-bit line);
//! 2. **sort** — order non-zeros along the space-filling curve;
//! 3. **reencode** — rewrite each index as (block key, shift/mask-decodable
//!    in-block index), Figure 6b;
//! 4. **block** — split at key changes and at the device nnz budget
//!    (adaptive blocking, Section 4.2);
//! 5. **batch** — group small blocks into single launches with explicit
//!    work-group → (block, offset) mappings (the hypersparse batching
//!    optimization at the end of Section 4.2).

use crate::linear::encode::{BlcoSpec, MAX_INBLOCK_BITS};
use crate::tensor::coo::CooTensor;
use crate::util::pool::{default_threads, parallel_chunks};
use crate::util::timer::Stages;

/// Construction knobs. Defaults follow the paper scaled to the simulated
/// devices: the paper uses 2^27 non-zeros per block on 40 GB GPUs; the
/// simulated profiles are ~256x smaller, so the default block budget is
/// 2^19.
#[derive(Clone, Copy, Debug)]
pub struct BlcoConfig {
    /// max non-zeros per block (further split of key blocks)
    pub max_block_nnz: usize,
    /// work-group (thread-block) size used for batching metadata
    pub workgroup: usize,
    /// threads used during construction
    pub threads: usize,
    /// in-block index bit budget; [`MAX_INBLOCK_BITS`] outside tests —
    /// lowering it forces the adaptive-blocking key path on small shapes
    pub inblock_budget: u32,
}

impl Default for BlcoConfig {
    fn default() -> Self {
        BlcoConfig {
            max_block_nnz: 1 << 19,
            workgroup: 256,
            threads: default_threads(),
            inblock_budget: MAX_INBLOCK_BITS,
        }
    }
}

/// One coarse-grained BLCO block: all non-zeros sharing `key`, split to the
/// nnz budget, ALTO-ordered, with shift/mask-decodable in-block indices.
#[derive(Clone, Debug)]
pub struct Block {
    pub key: u64,
    pub lidx: Vec<u64>,
    pub vals: Vec<f64>,
}

impl Block {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Bytes this block occupies on device (indices + values).
    pub fn bytes(&self) -> usize {
        self.nnz() * (8 + 8)
    }
}

/// A batched launch: consecutive blocks submitted as one kernel, with the
/// per-work-group block id and element offset precomputed at construction.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    /// range of block indices covered
    pub blocks: std::ops::Range<usize>,
    /// per work-group: which block it works on
    pub wg_block: Vec<u32>,
    /// per work-group: first element within that block
    pub wg_offset: Vec<u32>,
    /// total non-zeros in the batch
    pub nnz: usize,
}

/// The BLCO tensor (Figure 6b). Blocks are individually `Arc`ed so the
/// batch-fetch interface ([`crate::format::store::BatchSource`]) can hand
/// out resident and disk-loaded blocks through one type without copying.
#[derive(Clone, Debug)]
pub struct BlcoTensor {
    pub spec: BlcoSpec,
    pub blocks: Vec<std::sync::Arc<Block>>,
    pub batches: Vec<Batch>,
    pub config: BlcoConfig,
    pub nnz: usize,
    /// construction stage durations (Figure 12)
    pub stages: std::sync::Arc<Stages>,
}

impl BlcoTensor {
    /// Construct from COO with default config.
    pub fn from_coo(t: &CooTensor) -> Self {
        Self::from_coo_with(t, BlcoConfig::default())
    }

    /// [`try_from_coo_with`](Self::try_from_coo_with) for callers that
    /// prefer to crash on a bad config (the historical behavior).
    pub fn from_coo_with(t: &CooTensor, config: BlcoConfig) -> Self {
        Self::try_from_coo_with(t, config).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Construct from COO, rejecting degenerate configs as a structured
    /// [`BlcoError::InvalidConfig`] instead of a panic: a zero work-group
    /// would make the batching maps loop forever, and a zero block budget
    /// degenerates the adaptive blocking.
    pub fn try_from_coo_with(
        t: &CooTensor,
        config: BlcoConfig,
    ) -> Result<Self, crate::error::BlcoError> {
        if config.workgroup == 0 {
            return Err(crate::error::BlcoError::InvalidConfig {
                what: "BlcoConfig.workgroup must be > 0 (the per-launch \
                       work-group size tiles each block; 0 would never \
                       advance)"
                    .into(),
            });
        }
        if config.max_block_nnz == 0 {
            return Err(crate::error::BlcoError::InvalidConfig {
                what: "BlcoConfig.max_block_nnz must be > 0 (the \
                       adaptive-blocking nnz budget; 0 would split every \
                       non-zero into its own block)"
                    .into(),
            });
        }
        let mut stages = Stages::new();
        let spec = BlcoSpec::with_budget(&t.dims, config.inblock_budget);
        let nnz = t.nnz();
        let nt = config.threads;

        // 1. linearize: ALTO-encode every non-zero into (line, source-id)
        // pairs (parallel over nnz; threads write disjoint ranges). Keeping
        // the id next to the key makes the sort and all later passes
        // sequential — no permutation-indirect reads on the hot path
        // (§Perf: ~2.5x over the sort-a-permutation formulation).
        let mut pairs: Vec<(u128, u32)> = vec![(0, 0); nnz];
        {
            let planes = &t.coords;
            let spec_ref = &spec;
            let base = pairs.as_mut_ptr() as usize;
            parallel_chunks(nt, nnz, |_, lo, hi| {
                let ptr = base as *mut (u128, u32);
                let mut coord = vec![0u32; planes.len()];
                for e in lo..hi {
                    for (n, p) in planes.iter().enumerate() {
                        coord[n] = p[e];
                    }
                    // SAFETY: each e is written by exactly one thread
                    unsafe { *ptr.add(e) = (spec_ref.alto.encode(&coord), e as u32) };
                }
            });
        }
        stages.mark("linearize");

        // 2. sort along the space-filling curve (parallel bucket sort)
        crate::util::psort::par_sort_pairs(&mut pairs, nt, spec.alto.total_bits);
        stages.mark("sort");

        // 3. re-encode: block key + shift/mask in-block index, ALTO order
        // (table-driven, sequential reads)
        let mut keys = vec![0u64; nnz];
        let mut lidx = vec![0u64; nnz];
        {
            let kb = keys.as_mut_ptr() as usize;
            let lb = lidx.as_mut_ptr() as usize;
            let (spec_ref, pairs_ref) = (&spec, &pairs);
            parallel_chunks(nt, nnz, |_, lo, hi| {
                let kp = kb as *mut u64;
                let lp = lb as *mut u64;
                for (i, pair) in pairs_ref[lo..hi].iter().enumerate() {
                    let (k, l) = spec_ref.reencode_alto(pair.0);
                    // SAFETY: disjoint ranges per thread
                    unsafe {
                        *kp.add(lo + i) = k;
                        *lp.add(lo + i) = l;
                    }
                }
            });
        }
        stages.mark("reencode");

        // 4. adaptive blocking: split at key boundaries and the nnz budget
        let mut blocks: Vec<std::sync::Arc<Block>> = Vec::new();
        let mut start = 0usize;
        for i in 0..=nnz {
            let boundary = i == nnz
                || keys[i] != keys[start]
                || i - start >= config.max_block_nnz;
            if boundary && i > start {
                blocks.push(std::sync::Arc::new(Block {
                    key: keys[start],
                    lidx: lidx[start..i].to_vec(),
                    vals: pairs[start..i]
                        .iter()
                        .map(|&(_, e)| t.vals[e as usize])
                        .collect(),
                }));
                start = i;
            }
        }
        stages.mark("block");

        // 5. batching: group consecutive blocks into launches of at most
        // `max_block_nnz` total elements, with explicit work-group mappings
        let batches = Self::build_batches(&blocks, &config);
        stages.mark("batch");

        Ok(BlcoTensor {
            spec,
            blocks,
            batches,
            config,
            nnz,
            stages: std::sync::Arc::new(stages),
        })
    }

    fn build_batches(
        blocks: &[std::sync::Arc<Block>],
        config: &BlcoConfig,
    ) -> Vec<Batch> {
        let nnzs: Vec<usize> = blocks.iter().map(|b| b.nnz()).collect();
        build_batches_from_nnz(&nnzs, config)
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.spec.order()
    }

    #[inline]
    pub fn dims(&self) -> &[u64] {
        &self.spec.dims
    }

    /// Frobenius norm of the stored values. Construction preserves values
    /// exactly (reordering only), so this equals the source
    /// [`CooTensor::norm`] — which lets callers that hold only the
    /// `Arc<BlcoTensor>` (the serving registry) drive CP-ALS without
    /// keeping the COO form alive.
    pub fn norm(&self) -> f64 {
        self.blocks
            .iter()
            .flat_map(|b| &b.vals)
            .map(|v| v * v)
            .sum::<f64>()
            .sqrt()
    }

    /// Host→device wire bytes of batch `b`: its blocks' payload plus the
    /// work-group maps that ride along. The single source of truth for
    /// this accounting — the streamer's free function and the resident
    /// [`BatchSource`](crate::format::store::BatchSource) arm both
    /// delegate here (the on-disk arm computes the identical number from
    /// header metadata, pinned by the tier-parity tests).
    pub fn batch_wire_bytes(&self, b: usize) -> usize {
        let batch = &self.batches[b];
        batch
            .blocks
            .clone()
            .map(|i| self.blocks[i].bytes())
            .sum::<usize>()
            + batch.wg_block.len() * 8
    }

    /// Total bytes of the on-device representation: per-nnz payload plus
    /// per-block key metadata and batching maps.
    pub fn footprint_bytes(&self) -> usize {
        let payload: usize = self.blocks.iter().map(|b| b.bytes()).sum();
        let keys = self.blocks.len() * 8;
        let maps: usize =
            self.batches.iter().map(|b| b.wg_block.len() * 8).sum();
        payload + keys + maps
    }

    /// Reconstruct COO (tests / round-trip validation). Order follows the
    /// ALTO curve, not the original input order.
    pub fn to_coo(&self) -> CooTensor {
        let mut t = CooTensor::with_capacity(self.dims(), self.nnz);
        let mut coord = vec![0u32; self.order()];
        for blk in &self.blocks {
            for (i, &l) in blk.lidx.iter().enumerate() {
                self.spec.decode(blk.key, l, &mut coord);
                t.push(&coord, blk.vals[i]);
            }
        }
        t
    }
}

/// Stage 5 as a pure function of the per-block nnz list: group consecutive
/// blocks into launches of at most `max_block_nnz` total elements with
/// explicit work-group → (block, offset) maps. The maps depend only on the
/// block sizes and the config, which is why the on-disk container
/// ([`crate::format::store`]) stores neither — the reader rebuilds batches
/// bit-identical to the resident tensor's from the header's block index.
pub fn build_batches_from_nnz(nnzs: &[usize], config: &BlcoConfig) -> Vec<Batch> {
    assert!(config.workgroup > 0, "workgroup must be > 0");
    let mut batches = Vec::new();
    let mut b = 0usize;
    while b < nnzs.len() {
        let start = b;
        let mut total = 0usize;
        while b < nnzs.len() && total + nnzs[b] <= config.max_block_nnz {
            total += nnzs[b];
            b += 1;
        }
        if b == start {
            // a single block larger than the budget cannot happen
            // (stage 4 splits at the budget) but guard anyway
            total = nnzs[b];
            b += 1;
        }
        let mut wg_block = Vec::new();
        let mut wg_offset = Vec::new();
        for (bi, &nnz) in nnzs[start..b].iter().enumerate() {
            let mut off = 0usize;
            while off < nnz {
                wg_block.push((start + bi) as u32);
                wg_offset.push(off as u32);
                off += config.workgroup;
            }
        }
        batches.push(Batch { blocks: start..b, wg_block, wg_offset, nnz: total });
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;
    use crate::util::prop::{check, Config};
    use std::collections::HashMap;

    fn key_count(t: &CooTensor) -> HashMap<(Vec<u32>, u64), u32> {
        let mut m = HashMap::new();
        for e in 0..t.nnz() {
            *m.entry((t.coord(e), t.vals[e].to_bits())).or_insert(0u32) += 1;
        }
        m
    }

    #[test]
    fn roundtrip_small() {
        let t = synth::uniform(&[40, 30, 20], 2_000, 1);
        let b = BlcoTensor::from_coo(&t);
        assert_eq!(b.nnz, t.nnz());
        let back = b.to_coo();
        assert_eq!(key_count(&back), key_count(&t));
    }

    #[test]
    fn roundtrip_with_blocking_keys() {
        // 66-bit line forces real block keys
        let dims = [1u64 << 23, 1 << 21, 1 << 22];
        let t = synth::uniform(&dims, 5_000, 2);
        let b = BlcoTensor::from_coo(&t);
        assert!(b.spec.needs_blocking());
        assert!(b.blocks.len() > 1, "expected multiple key blocks");
        let back = b.to_coo();
        assert_eq!(key_count(&back), key_count(&t));
    }

    #[test]
    fn capacity_split_respected() {
        let t = synth::uniform(&[64, 64, 64], 10_000, 3);
        let cfg = BlcoConfig {
            max_block_nnz: 1_000,
            workgroup: 128,
            threads: 2,
            ..Default::default()
        };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        assert!(b.blocks.len() >= 10);
        for blk in &b.blocks {
            assert!(blk.nnz() <= 1_000);
        }
        // blocks partition the nnz set
        let total: usize = b.blocks.iter().map(|x| x.nnz()).sum();
        assert_eq!(total, t.nnz());
    }

    #[test]
    fn blocks_sorted_along_curve() {
        let t = synth::uniform(&[256, 256, 256], 4_000, 4);
        let b = BlcoTensor::from_coo(&t);
        // the concatenated blocks must preserve ALTO (curve) order
        let mut coord = vec![0u32; 3];
        let mut prev: Option<u128> = None;
        for blk in &b.blocks {
            for &l in &blk.lidx {
                b.spec.decode(blk.key, l, &mut coord);
                let a = b.spec.alto.encode(&coord);
                if let Some(p) = prev {
                    assert!(a >= p, "curve order violated");
                }
                prev = Some(a);
            }
        }
    }

    #[test]
    fn batches_cover_all_blocks_once() {
        check("batch_cover", Config { cases: 32, max_size: 4000, ..Default::default() }, |ctx| {
            let nnz = 100 + ctx.rng.below(ctx.size as u64) as usize;
            let t = synth::uniform(&[128, 64, 32], nnz, ctx.rng.next_u64());
            let cfg = BlcoConfig {
                max_block_nnz: 64 + ctx.rng.below(512) as usize,
                workgroup: 32,
                threads: 2,
                ..Default::default()
            };
            let b = BlcoTensor::from_coo_with(&t, cfg);
            let mut covered = vec![false; b.blocks.len()];
            for batch in &b.batches {
                let mut nnz_check = 0usize;
                for bi in batch.blocks.clone() {
                    if covered[bi] {
                        return Err(format!("block {bi} in two batches"));
                    }
                    covered[bi] = true;
                    nnz_check += b.blocks[bi].nnz();
                }
                if nnz_check != batch.nnz {
                    return Err("batch nnz mismatch".into());
                }
                // work-group maps must tile each block exactly
                let mut per_block: HashMap<u32, Vec<u32>> = HashMap::new();
                for (w, &blk) in batch.wg_block.iter().enumerate() {
                    per_block.entry(blk).or_default().push(batch.wg_offset[w]);
                }
                for (blk, offs) in per_block {
                    let expect: Vec<u32> = (0..b.blocks[blk as usize].nnz())
                        .step_by(cfg.workgroup)
                        .map(|x| x as u32)
                        .collect();
                    if offs != expect {
                        return Err(format!("wg offsets wrong for block {blk}"));
                    }
                }
            }
            if !covered.iter().all(|&c| c) {
                return Err("some block not batched".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stage_timers_recorded() {
        let t = synth::uniform(&[64, 64, 64], 1_000, 5);
        let b = BlcoTensor::from_coo(&t);
        for name in ["linearize", "sort", "reencode", "block", "batch"] {
            assert!(b.stages.get(name).is_some(), "missing stage {name}");
        }
    }

    #[test]
    fn footprint_accounts_payload() {
        let t = synth::uniform(&[64, 64, 64], 1_000, 6);
        let b = BlcoTensor::from_coo(&t);
        assert!(b.footprint_bytes() >= t.nnz() * 16);
    }

    #[test]
    fn norm_matches_coo() {
        let t = synth::uniform(&[64, 48, 32], 2_000, 8);
        let b = BlcoTensor::from_coo(&t);
        assert!((b.norm() - t.norm()).abs() < 1e-9);
        assert_eq!(BlcoTensor::from_coo(&CooTensor::new(&[4, 4, 4])).norm(), 0.0);
    }

    #[test]
    fn empty_tensor() {
        let t = CooTensor::new(&[8, 8, 8]);
        let b = BlcoTensor::from_coo(&t);
        assert_eq!(b.blocks.len(), 0);
        assert_eq!(b.batches.len(), 0);
        assert_eq!(b.nnz, 0);
    }

    #[test]
    fn zero_workgroup_is_rejected() {
        // regression: workgroup 0 used to infinite-loop build_batches;
        // now a structured error (panic only through the legacy wrapper)
        let t = synth::uniform(&[16, 16, 16], 200, 7);
        let cfg = BlcoConfig { workgroup: 0, ..Default::default() };
        match BlcoTensor::try_from_coo_with(&t, cfg) {
            Err(crate::error::BlcoError::InvalidConfig { what }) => {
                assert!(what.contains("workgroup"), "{what}");
            }
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn zero_block_budget_is_rejected() {
        let t = synth::uniform(&[16, 16, 16], 200, 7);
        let cfg = BlcoConfig { max_block_nnz: 0, ..Default::default() };
        match BlcoTensor::try_from_coo_with(&t, cfg) {
            Err(crate::error::BlcoError::InvalidConfig { what }) => {
                assert!(what.contains("max_block_nnz"), "{what}");
            }
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }
}
