//! The Flagged COOrdinate (F-COO) format (Liu et al., CLUSTER '17;
//! Section 3.1 / Figure 4b of the paper).
//!
//! One *mode-specific* copy per target mode: non-zeros sorted by the target
//! index, the target index replaced by a bit flag `bf` (1 while the next
//! non-zero continues the same segment, 0 at the last element of a segment)
//! plus per-chunk start flags `sf` used by the GPU-style segmented scan.
//! The N copies are the format's memory-footprint tradeoff the paper
//! criticizes.

use crate::tensor::coo::CooTensor;

/// The mode-`target` copy of an F-COO tensor.
#[derive(Clone, Debug)]
pub struct FCooMode {
    pub target: usize,
    /// modes stored explicitly (all but `target`)
    pub other_modes: Vec<usize>,
    /// index planes for `other_modes`, parallel to `vals`
    pub other_idx: Vec<Vec<u32>>,
    pub vals: Vec<f64>,
    /// `bf[i]` = the non-zero after `i` has the same target index
    pub bf: Vec<bool>,
    /// target row of each segment, in segment order
    pub seg_rows: Vec<u32>,
    /// processing chunk (thread group) size
    pub chunk: usize,
    /// `sf[c]` = a new segment starts inside chunk `c`
    pub sf: Vec<bool>,
}

/// F-COO: one sorted, flagged copy per mode.
#[derive(Clone, Debug)]
pub struct FCoo {
    pub dims: Vec<u64>,
    pub modes: Vec<FCooMode>,
}

impl FCooMode {
    pub fn from_coo(t: &CooTensor, target: usize, chunk: usize) -> Self {
        assert!(target < t.order());
        assert!(chunk > 0);
        let nnz = t.nnz();
        // stable sort by target index groups segments without disturbing
        // intra-segment order
        let mut perm: Vec<u32> = (0..nnz as u32).collect();
        perm.sort_by_key(|&e| t.coords[target][e as usize]);

        let other_modes: Vec<usize> =
            (0..t.order()).filter(|&n| n != target).collect();
        let other_idx: Vec<Vec<u32>> = other_modes
            .iter()
            .map(|&n| perm.iter().map(|&e| t.coords[n][e as usize]).collect())
            .collect();
        let vals: Vec<f64> =
            perm.iter().map(|&e| t.vals[e as usize]).collect();

        let tgt = |i: usize| t.coords[target][perm[i] as usize];
        let mut bf = vec![false; nnz];
        let mut seg_rows = Vec::new();
        for i in 0..nnz {
            if i == 0 || tgt(i) != tgt(i - 1) {
                seg_rows.push(tgt(i));
            }
            bf[i] = i + 1 < nnz && tgt(i + 1) == tgt(i);
        }
        let nchunks = nnz.div_ceil(chunk);
        let mut sf = vec![false; nchunks];
        for c in 0..nchunks {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(nnz);
            sf[c] = (lo..hi).any(|i| i == 0 || tgt(i) != tgt(i - 1));
        }
        FCooMode { target, other_modes, other_idx, vals, bf, seg_rows, chunk, sf }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Bytes of this copy: explicit indices + values + flags (flags modeled
    /// at 1 bit each, as stored on device).
    pub fn footprint_bytes(&self) -> usize {
        let idx: usize = self.other_idx.iter().map(|p| p.len() * 4).sum();
        let flags = (self.nnz() + self.sf.len() + 7) / 8;
        idx + self.vals.len() * 8 + self.seg_rows.len() * 4 + flags
    }
}

impl FCoo {
    pub fn from_coo(t: &CooTensor, chunk: usize) -> Self {
        let modes = (0..t.order())
            .map(|m| FCooMode::from_coo(t, m, chunk))
            .collect();
        FCoo { dims: t.dims.clone(), modes }
    }

    pub fn footprint_bytes(&self) -> usize {
        self.modes.iter().map(|m| m.footprint_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;

    #[test]
    fn paper_figure4b() {
        // Figure 4a tensor, mode-1 copy: segments per i1 = [3, 2, 2, 5]
        let mut t = CooTensor::new(&[4, 4, 4]);
        for (c, v) in [
            ([0u32, 0, 0], 1.0),
            ([0, 0, 1], 2.0),
            ([0, 2, 2], 3.0),
            ([1, 0, 1], 4.0),
            ([1, 0, 2], 5.0),
            ([2, 0, 1], 6.0),
            ([2, 3, 3], 7.0),
            ([3, 1, 0], 8.0),
            ([3, 1, 1], 9.0),
            ([3, 2, 2], 10.0),
            ([3, 2, 3], 11.0),
            ([3, 3, 3], 12.0),
        ] {
            t.push(&c, v);
        }
        let f = FCooMode::from_coo(&t, 0, 6);
        assert_eq!(f.seg_rows, vec![0, 1, 2, 3]);
        // bf per Figure 4b: 1,1,0 | 1,0 | 1,0 | 1,1,1,1,0
        let expect = [true, true, false, true, false, true, false, true, true, true, true, false];
        assert_eq!(f.bf, expect);
        // chunks of 6: both contain segment starts
        assert_eq!(f.sf, vec![true, true]);
    }

    #[test]
    fn segments_count_matches_distinct_rows() {
        let t = synth::uniform(&[50, 40, 30], 3_000, 1);
        for m in 0..3 {
            let f = FCooMode::from_coo(&t, m, 128);
            let mut rows: Vec<u32> = t.coords[m].clone();
            rows.sort_unstable();
            rows.dedup();
            assert_eq!(f.seg_rows.len(), rows.len(), "mode {m}");
            assert_eq!(f.seg_rows, rows, "mode {m} (sorted order)");
            // number of bf=0 entries equals number of segments
            let ends = f.bf.iter().filter(|&&b| !b).count();
            assert_eq!(ends, rows.len());
        }
    }

    #[test]
    fn values_preserved_per_segment() {
        let t = synth::uniform(&[10, 10, 10], 400, 2);
        let f = FCooMode::from_coo(&t, 1, 64);
        // total value mass per target row must match COO
        let mut per_row_coo = vec![0.0f64; 10];
        for e in 0..t.nnz() {
            per_row_coo[t.coords[1][e] as usize] += t.vals[e];
        }
        let mut per_row_f = vec![0.0f64; 10];
        let mut seg = 0usize;
        for i in 0..f.nnz() {
            per_row_f[f.seg_rows[seg] as usize] += f.vals[i];
            if !f.bf[i] {
                seg += 1;
            }
        }
        for r in 0..10 {
            assert!((per_row_coo[r] - per_row_f[r]).abs() < 1e-9, "row {r}");
        }
    }

    #[test]
    fn full_fcoo_keeps_n_copies() {
        let t = synth::uniform(&[30, 30, 30, 30], 1_000, 3);
        let f = FCoo::from_coo(&t, 256);
        assert_eq!(f.modes.len(), 4);
        // N copies: footprint far exceeds one COO copy
        assert!(f.footprint_bytes() > t.footprint_bytes() * 2);
    }

    #[test]
    fn sf_flags_empty_and_dense_chunks() {
        // all nnz share one target row: only chunk 0 sees a segment start
        let mut t = CooTensor::new(&[4, 64, 4]);
        for j in 0..64u32 {
            t.push(&[2, j, 1], 1.0);
        }
        let f = FCooMode::from_coo(&t, 0, 16);
        assert_eq!(f.sf, vec![true, false, false, false]);
        assert_eq!(f.seg_rows, vec![2]);
    }
}
