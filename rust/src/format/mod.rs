//! Sparse tensor formats: the paper's BLCO format plus every baseline its
//! evaluation compares against, implemented from scratch — list-based
//! (COO is [`crate::tensor::coo`], F-COO) and tree-based (CSF, B-CSF,
//! MM-CSF).

pub mod blco;
pub mod csf;
pub mod fcoo;
pub mod hicoo;
pub mod mmcsf;
