//! Sparse tensor formats: the paper's BLCO format plus every baseline its
//! evaluation compares against, implemented from scratch — list-based
//! (COO is [`crate::tensor::coo`], F-COO) and tree-based (CSF, B-CSF,
//! MM-CSF) — and the on-disk `.blco` container + host-out-of-core batch
//! source ([`store`]).

pub mod blco;
pub mod store;
pub mod csf;
pub mod fcoo;
pub mod hicoo;
pub mod mmcsf;
