//! Row-major dense matrices for factor matrices and MTTKRP outputs.

use crate::util::prng::Rng;

/// A row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Uniform(0,1) entries — the CP-ALS random initialization convention
    /// (non-negative init keeps early gram matrices well-conditioned).
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.f64()).collect();
        Matrix { rows, cols, data }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn fill(&mut self, v: f64) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, o: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        self.data
            .iter()
            .zip(&o.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Gram matrix AᵀA (cols × cols).
    pub fn gram(&self) -> Matrix {
        let c = self.cols;
        let mut g = Matrix::zeros(c, c);
        for i in 0..self.rows {
            let r = self.row(i);
            for a in 0..c {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = g.row_mut(a);
                for b in 0..c {
                    grow[b] += ra * r[b];
                }
            }
        }
        g
    }

    /// Elementwise (Hadamard) product, in place.
    pub fn hadamard_assign(&mut self, o: &Matrix) {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        for (a, b) in self.data.iter_mut().zip(&o.data) {
            *a *= b;
        }
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Inner product ⟨self, o⟩ (elementwise).
    pub fn dot(&self, o: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        self.data.iter().zip(&o.data).map(|(a, b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_norms() {
        let m = Matrix::from_rows(vec![vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert_eq!(m.row(0), &[3.0, 0.0]);
        assert_eq!(m.row(1), &[0.0, 4.0]);
        assert!((m.norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn gram_small() {
        let m = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let g = m.gram();
        // AᵀA = [[10, 14], [14, 20]]
        assert_eq!(g.data, vec![10.0, 14.0, 14.0, 20.0]);
    }

    #[test]
    fn hadamard_and_dot() {
        let mut a = Matrix::from_rows(vec![vec![1.0, 2.0]]);
        let b = Matrix::from_rows(vec![vec![3.0, 5.0]]);
        assert_eq!(a.dot(&b), 13.0);
        a.hadamard_assign(&b);
        assert_eq!(a.data, vec![3.0, 10.0]);
        assert_eq!(a.sum(), 13.0);
    }

    #[test]
    fn random_in_unit_interval() {
        let mut rng = Rng::new(1);
        let m = Matrix::random(10, 10, &mut rng);
        assert!(m.data.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn max_abs_diff() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0]]);
        let b = Matrix::from_rows(vec![vec![1.5, 2.0]]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-12);
    }
}
