//! The paper's unified, mode-agnostic MTTKRP over BLCO blocks (Section 5).
//!
//! Execution follows the two-phase structure of Figure 7. Each *work-group*
//! (one `wg_block`/`wg_offset` entry of a batch — a tile of at most
//! `workgroup` non-zeros of one block) runs:
//!
//! * **processing phase** — coalesced load of the linearized tile,
//!   on-the-fly de-linearization (shift/mask + block base), reorder of the
//!   tile by target index (the warp histogram/prefix-sum of §5.1.1 becomes
//!   a small in-tile sort on the CPU) and segmented-scan flag generation;
//! * **computing phase** — rank-wise accumulation in a register while the
//!   target index is unchanged, then at each segment boundary either
//!   - **register-based** (§5.2): atomic add straight into the output, or
//!   - **hierarchical** (§5.1.2): write into one of `slices` shadow copies
//!     of the output (the "multiple factor matrix copies"), merged at the
//!     end. The per-tile sort already plays the role of the local-memory
//!     stash: each row flushes at most once per work-group.
//!
//! The §5.3 heuristic picks hierarchical when the target mode is shorter
//! than the device's SM/subslice count, register-based otherwise.
//!
//! # Parallel execution
//!
//! Every kernel consumes an [`ExecBackend`] (derived from the caller's
//! thread count). With a [`ConflictCertificate`] attached, the register
//! path executes each batch under its certified wave schedule
//! ([`BlcoEngine::run_batch_certified`] — the production promotion of the
//! race checker's `run_waved` scaffold): work-groups within a wave are
//! row-disjoint by construction, so flushes are *plain stores* at any
//! thread count, and the order-preserving coloring replays each row's
//! flushes in submission order — the threaded result is **bit-for-bit**
//! the sequential one. The hierarchical path stays deterministic by
//! *copy ownership*: the worker handling shadow copy `c` processes
//! exactly the work-groups `w ≡ c (mod slices)` in ascending order, so
//! every shadow slot has a single writer and a fixed flush order, and the
//! final merge walks copies in fixed order per row. Uncertified threaded
//! register runs fall back to CAS atomics (correct, but with
//! thread-count-dependent low-order bits) — attaching certificates is
//! what buys determinism. [`BatchStrategy`] exposes the per-batch
//! NoSync/Privatize/Atomic choices individually for the measured
//! ablation in `benches/ablation_conflict_resolution.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::atomicf::{as_atomic, atomic_add_row, serial_add_row};
use super::dense::Matrix;
use super::{check_shapes, Mttkrp, MAX_RANK};
use crate::analysis::conflict::{BatchCert, CertificateSet, ConflictCertificate};
use crate::analysis::racecheck::WriteLog;
use crate::device::counters::{Counters, ShardedCounters, Snapshot};
use crate::device::profile::Profile;
use crate::format::blco::{BlcoTensor, Block};
use crate::format::store::{BatchSource, BlcoStoreReader};
use crate::linear::encode::BlcoSpec;
use crate::util::pool::ExecBackend;

/// Conflict-resolution strategy (Sections 5.1, 5.2, 5.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolution {
    /// pick per the §5.3 heuristic
    Auto,
    /// §5.2: registers + global atomics at segment boundaries
    Register,
    /// §5.1: registers + shadow output copies + final merge
    Hierarchical,
}

/// The §5.3 adaptation heuristic.
pub fn choose_resolution(target_len: u64, p: &Profile) -> Resolution {
    if target_len < p.sms as u64 {
        Resolution::Hierarchical
    } else {
        Resolution::Register
    }
}

/// One concrete synchronization strategy, forced for *every* batch — the
/// axes of the measured conflict-resolution ablation
/// (`benches/ablation_conflict_resolution.rs`). Production dispatch never
/// forces a strategy: a certified engine executes its wave schedule
/// (plain stores, bit-deterministic), an uncertified one uses CAS
/// atomics, and `Privatize`-dominant certificates route `Auto` to the
/// hierarchical engine. [`BlcoEngine::mttkrp_forced`] exists so each
/// strategy's real wall-clock cost can be measured in isolation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchStrategy {
    /// certified wave schedule, plain stores (requires attached
    /// certificates); bit-for-bit the sequential result
    NoSync,
    /// per-thread private output copies merged by a pairwise tree
    /// reduction; oracle-equal but not bit-stable (the dynamic
    /// work-group→thread assignment reassociates float adds)
    Privatize,
    /// CAS loop ([`super::atomicf::atomic_add`]) on every flush, even
    /// single-threaded; oracle-equal, order-nondeterministic when threaded
    Atomic,
}

pub struct BlcoEngine {
    /// where the block payload lives: resident in host RAM
    /// ([`BatchSource::Resident`]) or on disk behind a bounded
    /// [`BlockCache`](crate::format::store::BlockCache)
    /// ([`BatchSource::OnDisk`]). Every kernel fetches batches through
    /// this, so the engine never assumes the tensor is in memory.
    pub src: BatchSource,
    pub profile: Profile,
    pub resolution: Resolution,
    /// per-mode conflict certificates ([`crate::analysis::conflict`]).
    /// When present, `Resolution::Auto` routes through
    /// [`ConflictCertificate::resolution`] instead of the §5.3
    /// `target_len` heuristic, and the streaming planner reads per-batch
    /// [`SyncClass`](crate::analysis::conflict::SyncClass) marks from it.
    pub certs: Option<Arc<CertificateSet>>,
}

impl BlcoEngine {
    /// Panics when the profile's modelled rates are degenerate (zero/NaN
    /// bandwidths would poison every downstream cost model — see
    /// [`Profile::validate`]).
    pub fn new(t: BlcoTensor, profile: Profile) -> Self {
        Self::from_arc(Arc::new(t), profile)
    }

    /// Construct over an *already shared* tensor payload — the serving
    /// registry's entry point: many engines (and therefore many concurrent
    /// jobs) reference one resident BLCO copy through the same `Arc`.
    /// Panics on an invalid profile like [`BlcoEngine::new`].
    pub fn from_arc(t: Arc<BlcoTensor>, profile: Profile) -> Self {
        Self::from_source(BatchSource::Resident(t), profile)
    }

    /// Construct over a disk-resident container: only header metadata is
    /// in memory, payloads load through the reader's bounded block cache.
    pub fn from_store_reader(reader: BlcoStoreReader, profile: Profile) -> Self {
        Self::from_source(BatchSource::OnDisk(reader), profile)
    }

    /// Construct over any [`BatchSource`]. Panics on an invalid profile
    /// like [`BlcoEngine::new`].
    pub fn from_source(src: BatchSource, profile: Profile) -> Self {
        Self::try_from_source(src, profile).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`from_source`](Self::from_source), reporting an invalid profile
    /// as [`BlcoError::InvalidProfile`](crate::error::BlcoError) instead
    /// of panicking.
    pub fn try_from_source(
        src: BatchSource,
        profile: Profile,
    ) -> Result<Self, crate::error::BlcoError> {
        if let Err(reason) = profile.validate() {
            return Err(crate::error::BlcoError::InvalidProfile {
                profile: profile.name.to_string(),
                reason,
            });
        }
        Ok(BlcoEngine { src, profile, resolution: Resolution::Auto, certs: None })
    }

    pub fn with_resolution(mut self, r: Resolution) -> Self {
        self.resolution = r;
        self
    }

    /// Attach statically computed conflict certificates (usually via
    /// [`CertificateSet::analyze`]). Panics when the certificates'
    /// fingerprint does not describe this engine's tensor — a stale
    /// certificate must never certify the wrong structure.
    pub fn with_certificates(mut self, certs: Arc<CertificateSet>) -> Self {
        assert!(
            certs.matches(&self.src),
            "certificate fingerprint mismatch: {:?} vs tensor dims {:?} / \
             nnz {} / {} batches",
            certs.fingerprint,
            self.src.dims(),
            self.src.nnz(),
            self.src.num_batches(),
        );
        self.certs = Some(certs);
        self
    }

    /// The attached certificate for `target`, if analysis ran.
    pub fn certificate_for(&self, target: usize) -> Option<&ConflictCertificate> {
        self.certs.as_deref().map(|c| c.mode(target))
    }

    /// The resident tensor payload, when there is one (`None` for a
    /// disk-backed engine).
    pub fn resident(&self) -> Option<&Arc<BlcoTensor>> {
        self.src.resident()
    }

    pub fn dims(&self) -> &[u64] {
        self.src.dims()
    }

    pub fn order(&self) -> usize {
        self.src.order()
    }

    pub fn nnz(&self) -> usize {
        self.src.nnz()
    }

    pub fn num_batches(&self) -> usize {
        self.src.num_batches()
    }

    /// The same tensor on a different (e.g. cluster) profile, sharing the
    /// payload through its `Arc` — no copy. Used by the device-count
    /// sweeps in the benches/examples. Requires a resident payload (a
    /// disk reader owns a file handle and a cache that cannot be shared);
    /// panics on an invalid profile like [`BlcoEngine::new`].
    pub fn share_with_profile(&self, profile: Profile) -> Self {
        if let Err(e) = profile.validate() {
            panic!("invalid profile {:?}: {e}", profile.name);
        }
        let t = self.src.resident().unwrap_or_else(|| {
            panic!("share_with_profile: engine is disk-backed; open a second reader instead")
        });
        BlcoEngine {
            src: BatchSource::Resident(Arc::clone(t)),
            profile,
            resolution: self.resolution,
            // certificates are structural, not profile-dependent: the
            // shared payload has the same blocks and batches
            certs: self.certs.clone(),
        }
    }

    /// The strategy that will run for `target`: explicit settings win;
    /// `Auto` consults the attached [`ConflictCertificate`] when analysis
    /// ran, falling back to the §5.3 `target_len` heuristic.
    pub fn effective_resolution(&self, target: usize) -> Resolution {
        match self.resolution {
            Resolution::Auto => match self.certificate_for(target) {
                Some(cert) => cert.resolution(),
                None => choose_resolution(self.src.dims()[target], &self.profile),
            },
            r => r,
        }
    }

    pub fn footprint_bytes(&self) -> usize {
        self.src.footprint_bytes()
    }
}

/// Per-work-group scratch, reused across the tiles a thread processes.
pub(crate) struct Scratch {
    /// decoded global coordinates, mode-major: coords[n][i]
    coords: Vec<Vec<u32>>,
    /// tile-local permutation (the §5.1.1 reorder)
    order: Vec<u32>,
    /// scratch for the cold/hot gather split (clobbered by sorting)
    rows: Vec<u32>,
}

impl Scratch {
    pub(crate) fn new(order_n: usize, wg: usize) -> Self {
        Scratch {
            coords: vec![vec![0u32; wg]; order_n],
            order: vec![0u32; wg],
            rows: vec![0u32; wg],
        }
    }
}

/// Process one work-group tile. The block arrives as a plain reference —
/// borrowed from a resident tensor or freshly cache-loaded from disk —
/// so the hot loop is identical across tiers (the bit-for-bit parity
/// anchor of the container round-trip tests).
///
/// `writes` is the race checker's instrumentation point
/// ([`crate::analysis::racecheck`]): when present, every flushed output
/// row is pushed in flush order. The tile is sorted by target row, so a
/// row appears at most once per tile. `None` compiles down to the
/// uninstrumented hot loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn process_tile(
    spec: &BlcoSpec,
    workgroup: usize,
    blk: &Block,
    offset: usize,
    target: usize,
    factors: &[Matrix],
    rank: usize,
    dest: &[AtomicU64],
    dest_rank_stride: usize,
    serial: bool,
    scratch: &mut Scratch,
    tally: &mut Snapshot,
    mut writes: Option<&mut Vec<u32>>,
) {
    let order_n = spec.order();
    let wg = workgroup;
    let len = (blk.nnz() - offset).min(wg);
    let lidx = &blk.lidx[offset..offset + len];
    let vals = &blk.vals[offset..offset + len];
    let bases = spec.bases(blk.key);

    // ---- processing phase: coalesced load + on-the-fly de-linearization.
    // Every mode decodes independently (ILP), one shift + mask each.
    for n in 0..order_n {
        let off = spec.offsets[n];
        let mask = crate::util::bitops::mask64(spec.inblock_bits[n]);
        let base = bases[n];
        let out = &mut scratch.coords[n][..len];
        for (i, &l) in lidx.iter().enumerate() {
            out[i] = base + ((l >> off) & mask) as u32;
        }
    }
    tally.bytes_streamed += len as u64 * 16; // lidx + vals

    // measured gather locality: distinct rows per non-target mode within
    // the tile fetch from HBM, repeats hit cache (ALTO order clusters every
    // mode at once — the paper's data-locality claim, quantified)
    for n in 0..order_n {
        if n == target {
            continue;
        }
        scratch.rows[..len].copy_from_slice(&scratch.coords[n][..len]);
        let (cold, hot) = crate::mttkrp::split_cold_hot(&mut scratch.rows[..len]);
        tally.bytes_gathered += cold * rank as u64 * 8;
        tally.bytes_local += hot * rank as u64 * 8;
    }

    // reorder the tile by target index + segmented-scan flags (implicit in
    // the sorted runs). Small tiles: insertion-friendly unstable sort.
    let ord = &mut scratch.order[..len];
    for (i, o) in ord.iter_mut().enumerate() {
        *o = i as u32;
    }
    let tcoords = &scratch.coords[target][..len];
    ord.sort_unstable_by_key(|&i| tcoords[i as usize]);

    // ---- computing phase: rank-wise register accumulation over segments
    let mut reg = [0.0f64; MAX_RANK];
    let mut cur_row = u32::MAX;
    let mut open = false;
    for &i in ord.iter() {
        let i = i as usize;
        let row = tcoords[i];
        if open && row != cur_row {
            // segment boundary: flush the register
            if serial {
                serial_add_row(dest, cur_row as usize * dest_rank_stride, &reg[..rank]);
            } else {
                atomic_add_row(dest, cur_row as usize * dest_rank_stride, &reg[..rank]);
            }
            if let Some(w) = writes.as_deref_mut() {
                w.push(cur_row);
            }
            tally.atomics += rank as u64;
            tally.bytes_written += rank as u64 * 8;
            tally.segments += 1;
            reg[..rank].iter_mut().for_each(|x| *x = 0.0);
        } else if open {
            tally.stash_hits += 1; // absorbed in the register
        }
        cur_row = row;
        open = true;
        // product of non-target factor rows, scaled by the value
        // (slice-to-rank bindings let LLVM elide bounds checks + vectorize)
        let mut row_acc = [0.0f64; MAX_RANK];
        let ra = &mut row_acc[..rank];
        ra.iter_mut().for_each(|x| *x = vals[i]);
        for n in 0..order_n {
            if n == target {
                continue;
            }
            let f = &factors[n].row(scratch.coords[n][i] as usize)[..rank];
            for (a, &b) in ra.iter_mut().zip(f) {
                *a *= b;
            }
        }
        for (r, &a) in reg[..rank].iter_mut().zip(ra.iter()) {
            *r += a;
        }
    }
    if open {
        if serial {
            serial_add_row(dest, cur_row as usize * dest_rank_stride, &reg[..rank]);
        } else {
            atomic_add_row(dest, cur_row as usize * dest_rank_stride, &reg[..rank]);
        }
        if let Some(w) = writes.as_deref_mut() {
            w.push(cur_row);
        }
        tally.atomics += rank as u64;
        tally.bytes_written += rank as u64 * 8;
        tally.segments += 1;
    }
}

impl Mttkrp for BlcoEngine {
    fn name(&self) -> String {
        match self.resolution {
            Resolution::Auto => "blco".into(),
            Resolution::Register => "blco-reg".into(),
            Resolution::Hierarchical => "blco-hier".into(),
        }
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(self.src.dims(), target, factors, out);
        let rows = self.src.dims()[target] as usize;
        out.fill(0.0);
        let resolution = self.effective_resolution(target);

        match resolution {
            // `effective_resolution` always resolves `Auto` to a concrete
            // strategy; a silent `Auto` arm here could mask a future
            // dispatch bug, so it is a hard error instead.
            Resolution::Auto => {
                unreachable!("effective_resolution returned Auto")
            }
            Resolution::Register => {
                let out_at = as_atomic(&mut out.data);
                // a certified engine executes the wave schedule: plain
                // stores at any thread count, bit-for-bit the sequential
                // register path (the certificate's guarantee, cashed in)
                match self.certificate_for(target) {
                    Some(cert) => {
                        let backend = ExecBackend::from_threads(threads);
                        self.run_certified(
                            cert, target, factors, rank, out_at, rank, backend,
                            counters, None,
                        );
                    }
                    None => {
                        self.run(
                            target, factors, rank, out_at, rank, threads, counters,
                            None,
                        );
                    }
                }
                counters.add(&Snapshot {
                    atomic_fanout: (rows * rank) as u64,
                    ..Default::default()
                });
            }
            Resolution::Hierarchical => {
                self.hier_full(target, factors, rank, out, threads, counters, None);
            }
        }
    }
}

impl BlcoEngine {
    /// Run a single batch (one "kernel launch") of the register path,
    /// *accumulating* into `out` — the streaming coordinator's entry point:
    /// each batch is processed as its blocks arrive on a device queue, so
    /// the output must not be zeroed here. The blocks come through
    /// [`BatchSource::fetch_batch`]: borrowed when resident, loaded via
    /// the bounded block cache when the payload lives on disk.
    pub fn mttkrp_batch(
        &self,
        batch_idx: usize,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(self.src.dims(), target, factors, out);
        let out_at = as_atomic(&mut out.data);
        let backend = ExecBackend::from_threads(threads);
        // certified streaming: this batch runs its wave schedule with
        // plain stores — the streamed threaded result stays bit-for-bit
        // the sequential (and resident) one
        if let Some(cert) = self.certificate_for(target) {
            self.run_batch_certified(
                batch_idx,
                &cert.batches[batch_idx],
                target,
                factors,
                rank,
                out_at,
                rank,
                backend,
                counters,
                None,
            );
            counters.add(&Snapshot {
                atomic_fanout: self.src.dims()[target] * rank as u64,
                ..Default::default()
            });
            return;
        }
        let spec = self.src.spec();
        let wg = self.src.workgroup();
        let batch = &self.src.batches()[batch_idx];
        let fetched = self.src.fetch_batch(batch_idx, counters);
        let blocks: &[Arc<Block>] = &fetched;
        let base = batch.blocks.start;
        let wgs = batch.wg_block.len();
        let shards = ShardedCounters::new(backend.threads());
        backend.dynamic(wgs, 4, |t, lo, hi| {
            let mut scratch = Scratch::new(spec.order(), wg);
            let mut tally = Snapshot::default();
            for w in lo..hi {
                process_tile(
                    spec,
                    wg,
                    &blocks[batch.wg_block[w] as usize - base],
                    batch.wg_offset[w] as usize,
                    target,
                    factors,
                    rank,
                    out_at,
                    rank,
                    backend.is_sequential(),
                    &mut scratch,
                    &mut tally,
                    None,
                );
            }
            shards.shard(t).add(&tally);
        });
        shards.merge_into(counters);
        counters.add(&Snapshot {
            launches: 1,
            atomic_fanout: self.src.dims()[target] * rank as u64,
            ..Default::default()
        });
    }

    /// The register path with every flush logged ([`WriteLog`]) — the race
    /// checker's observation run. Semantics are otherwise exactly
    /// [`Mttkrp::mttkrp`] under `Resolution::Register`: the output is
    /// overwritten, not accumulated.
    pub fn mttkrp_logged(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
        log: &WriteLog,
    ) {
        let rank = check_shapes(self.src.dims(), target, factors, out);
        let rows = self.src.dims()[target] as usize;
        out.fill(0.0);
        let out_at = as_atomic(&mut out.data);
        self.run(target, factors, rank, out_at, rank, threads, counters, Some(log));
        counters.add(&Snapshot {
            atomic_fanout: (rows * rank) as u64,
            ..Default::default()
        });
    }

    /// The hierarchical path with every shadow flush logged, each record's
    /// ordering class being the shadow-copy index (independent
    /// destinations never conflict across copies).
    pub fn mttkrp_logged_hier(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
        log: &WriteLog,
    ) {
        let rank = check_shapes(self.src.dims(), target, factors, out);
        out.fill(0.0);
        self.hier_full(target, factors, rank, out, threads, counters, Some(log));
    }

    /// Full hierarchical execution (§5.1.2 steps 6–7): shadow copies, the
    /// `run_hier` sweep, and the final parallel merge — shared by the
    /// plain `Mttkrp` dispatch (`log = None`) and [`mttkrp_logged_hier`].
    /// Accumulates into `out` (callers zero-fill).
    #[allow(clippy::too_many_arguments)]
    fn hier_full(
        &self,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
        log: Option<&WriteLog>,
    ) {
        let rows = self.src.dims()[target] as usize;
        // shadow output copies, one per device slice (§5.1.2 step 6)
        let slices = self.profile.slices.max(1);
        let mut shadows = vec![0.0f64; slices * rows * rank];
        {
            let sh_at = as_atomic(&mut shadows);
            // destination of a work-group = shadow (wg % slices);
            // encode by offsetting the row stride region
            self.run_hier(target, factors, rank, sh_at, rows, threads, counters, log);
        }
        // final merge (§5.1.2 step 7): parallel over rows, plain
        // adds. The merge *accumulates* into `out` (matching
        // `mttkrp_batch` semantics) rather than storing, so prior
        // contents are never silently dropped if a caller ever
        // reuses this path without the zero-fill above.
        let out_data = as_atomic(&mut out.data);
        let backend = ExecBackend::from_threads(threads);
        backend.dynamic(rows, 256, |_, lo, hi| {
            let mut written = 0u64;
            for r in lo..hi {
                for k in 0..rank {
                    let mut acc = 0.0;
                    for s in 0..slices {
                        acc += shadows[(s * rows + r) * rank + k];
                    }
                    // rows are owned by one chunk: a plain
                    // load+store through the atomic view is sound
                    let slot = &out_data[r * rank + k];
                    let prev = f64::from_bits(slot.load(Ordering::Relaxed));
                    slot.store((prev + acc).to_bits(), Ordering::Relaxed);
                    written += 8;
                }
            }
            counters.add(&Snapshot {
                // reads: `slices` shadow values + the prior output
                // value the accumulate folds in
                bytes_streamed: written * (slices as u64 + 1),
                bytes_written: written,
                ..Default::default()
            });
        });
        counters.add(&Snapshot {
            atomic_fanout: (rows * rank * slices) as u64,
            ..Default::default()
        });
    }

    /// Register path: every work-group flushes straight into `dest`. With
    /// `log`, each tile's flushed rows are recorded under ordering class 0
    /// (a register run has no barrier structure beyond batch order).
    #[allow(clippy::too_many_arguments)]
    fn run(
        &self,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        dest: &[AtomicU64],
        stride: usize,
        threads: usize,
        counters: &Counters,
        log: Option<&WriteLog>,
    ) {
        let backend = ExecBackend::from_threads(threads);
        let spec = self.src.spec();
        let wg = self.src.workgroup();
        for (bi, batch) in self.src.batches().iter().enumerate() {
            let fetched = self.src.fetch_batch(bi, counters);
            let blocks: &[Arc<Block>] = &fetched;
            let base = batch.blocks.start;
            let wgs = batch.wg_block.len();
            let shards = ShardedCounters::new(backend.threads());
            backend.dynamic(wgs, 4, |t, lo, hi| {
                let mut scratch = Scratch::new(spec.order(), wg);
                let mut tally = Snapshot::default();
                let mut rows = Vec::new();
                for w in lo..hi {
                    rows.clear();
                    process_tile(
                        spec,
                        wg,
                        &blocks[batch.wg_block[w] as usize - base],
                        batch.wg_offset[w] as usize,
                        target,
                        factors,
                        rank,
                        dest,
                        stride,
                        backend.is_sequential(),
                        &mut scratch,
                        &mut tally,
                        log.map(|_| &mut rows),
                    );
                    if let Some(lg) = log {
                        lg.append_tile(t as u32, bi as u32, 0, w as u32, &rows);
                    }
                }
                shards.shard(t).add(&tally);
            });
            shards.merge_into(counters);
            counters.add(&Snapshot { launches: 1, ..Default::default() });
        }
    }

    /// Execute one batch under its certified wave schedule — the
    /// production promotion of the race checker's waved scaffold
    /// ([`crate::analysis::racecheck::run_waved`] is now a thin wrapper
    /// over this). Waves run in order with a barrier between them; within
    /// a wave every work-group owns its output rows outright (the
    /// certificate's row-overlap graph has no intra-wave edge), so
    /// flushes are plain stores at any thread count and the
    /// order-preserving coloring replays each row's flush sequence in
    /// submission order: the result is bit-for-bit the sequential one.
    /// Flush work is charged to `nosync_flushes` instead of `atomics`,
    /// each barrier bumps `waves`, and the batch counts one launch.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_batch_certified(
        &self,
        batch_idx: usize,
        bc: &BatchCert,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        dest: &[AtomicU64],
        stride: usize,
        backend: ExecBackend,
        counters: &Counters,
        log: Option<&WriteLog>,
    ) {
        let spec = self.src.spec();
        let wg_size = self.src.workgroup();
        let batch = &self.src.batches()[batch_idx];
        let fetched = self.src.fetch_batch(batch_idx, counters);
        let base = batch.blocks.start;
        let shards = ShardedCounters::new(backend.threads());
        for (wave, members) in bc.wave_members().iter().enumerate() {
            backend.dynamic(members.len(), 1, |t, lo, hi| {
                let mut scratch = Scratch::new(spec.order(), wg_size);
                let mut tally = Snapshot::default();
                let mut rows = Vec::new();
                for k in lo..hi {
                    let w = members[k] as usize;
                    rows.clear();
                    process_tile(
                        spec,
                        wg_size,
                        &fetched[batch.wg_block[w] as usize - base],
                        batch.wg_offset[w] as usize,
                        target,
                        factors,
                        rank,
                        dest,
                        stride,
                        true, // wave members are row-disjoint: plain stores
                        &mut scratch,
                        &mut tally,
                        log.map(|_| &mut rows),
                    );
                    if let Some(lg) = log {
                        lg.append_tile(
                            t as u32,
                            batch_idx as u32,
                            wave as u32,
                            w as u32,
                            &rows,
                        );
                    }
                }
                // certified waves issue no atomics: reclassify the flush
                // tally as synchronization-free stores
                tally.nosync_flushes = tally.atomics;
                tally.atomics = 0;
                shards.shard(t).add(&tally);
            });
            counters.add(&Snapshot { waves: 1, ..Default::default() });
        }
        shards.merge_into(counters);
        counters.add(&Snapshot { launches: 1, ..Default::default() });
    }

    /// The full certified register path: every batch through
    /// [`run_batch_certified`](Self::run_batch_certified), batches in
    /// order (kernel launches serialize).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_certified(
        &self,
        cert: &ConflictCertificate,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        dest: &[AtomicU64],
        stride: usize,
        backend: ExecBackend,
        counters: &Counters,
        log: Option<&WriteLog>,
    ) {
        debug_assert_eq!(cert.target, target, "certificate is for another mode");
        for bi in 0..self.src.num_batches() {
            self.run_batch_certified(
                bi,
                &cert.batches[bi],
                target,
                factors,
                rank,
                dest,
                stride,
                backend,
                counters,
                log,
            );
        }
    }

    /// Hierarchical path: work-group w flushes into shadow copy (w % slices).
    /// With `log`, the shadow-copy index is the record's ordering class.
    ///
    /// Threading is by *copy ownership*: the worker holding copy `c`
    /// processes the work-groups `w ≡ c (mod slices)` in ascending order
    /// with plain stores. One writer per shadow copy means no
    /// synchronization, and the per-(copy, row) flush order equals the
    /// sequential sweep's — the threaded hierarchical result is
    /// bit-for-bit the sequential one at any thread count (parallelism
    /// is bounded by `slices`, the paper's shadow-copy count).
    #[allow(clippy::too_many_arguments)]
    fn run_hier(
        &self,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        shadows: &[AtomicU64],
        rows: usize,
        threads: usize,
        counters: &Counters,
        log: Option<&WriteLog>,
    ) {
        let backend = ExecBackend::from_threads(threads);
        let slices = self.profile.slices.max(1);
        let spec = self.src.spec();
        let wg = self.src.workgroup();
        for (bi, batch) in self.src.batches().iter().enumerate() {
            let fetched = self.src.fetch_batch(bi, counters);
            let blocks: &[Arc<Block>] = &fetched;
            let base = batch.blocks.start;
            let wgs = batch.wg_block.len();
            let shards = ShardedCounters::new(backend.threads());
            backend.dynamic(slices, 1, |t, lo, hi| {
                let mut scratch = Scratch::new(spec.order(), wg);
                let mut tally = Snapshot::default();
                let mut wrows = Vec::new();
                for copy in lo..hi {
                    let dest = &shadows[copy * rows * rank..(copy + 1) * rows * rank];
                    let mut w = copy;
                    while w < wgs {
                        wrows.clear();
                        process_tile(
                            spec,
                            wg,
                            &blocks[batch.wg_block[w] as usize - base],
                            batch.wg_offset[w] as usize,
                            target,
                            factors,
                            rank,
                            dest,
                            rank,
                            true, // single owner per copy: plain stores
                            &mut scratch,
                            &mut tally,
                            log.map(|_| &mut wrows),
                        );
                        if let Some(lg) = log {
                            lg.append_tile(
                                t as u32,
                                bi as u32,
                                copy as u32,
                                w as u32,
                                &wrows,
                            );
                        }
                        w += slices;
                    }
                }
                shards.shard(t).add(&tally);
            });
            shards.merge_into(counters);
            counters.add(&Snapshot { launches: 1, ..Default::default() });
        }
    }

    /// Run with one [`BatchStrategy`] forced for every batch — the
    /// measured conflict-resolution ablation's entry point. Overwrites
    /// `out` like [`Mttkrp::mttkrp`]. `NoSync` panics without attached
    /// certificates (there is nothing to prove the schedule safe);
    /// `Privatize` and `Atomic` run on any engine.
    pub fn mttkrp_forced(
        &self,
        strategy: BatchStrategy,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(self.src.dims(), target, factors, out);
        let rows = self.src.dims()[target] as usize;
        out.fill(0.0);
        let backend = ExecBackend::from_threads(threads);
        match strategy {
            BatchStrategy::NoSync => {
                let cert = self.certificate_for(target).unwrap_or_else(|| {
                    panic!("BatchStrategy::NoSync requires attached certificates")
                });
                let out_at = as_atomic(&mut out.data);
                self.run_certified(
                    cert, target, factors, rank, out_at, rank, backend, counters,
                    None,
                );
                counters.add(&Snapshot {
                    atomic_fanout: (rows * rank) as u64,
                    ..Default::default()
                });
            }
            BatchStrategy::Atomic => {
                let out_at = as_atomic(&mut out.data);
                self.run_forced_atomic(
                    target, factors, rank, out_at, backend, counters,
                );
                counters.add(&Snapshot {
                    atomic_fanout: (rows * rank) as u64,
                    ..Default::default()
                });
            }
            BatchStrategy::Privatize => {
                self.run_forced_privatize(
                    target, factors, rank, out, backend, counters,
                );
            }
        }
    }

    /// Forced-`Atomic` ablation leg: every flush takes the CAS loop, even
    /// sequentially — what the register path costs with no certificate
    /// and no luck.
    fn run_forced_atomic(
        &self,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        dest: &[AtomicU64],
        backend: ExecBackend,
        counters: &Counters,
    ) {
        let spec = self.src.spec();
        let wg = self.src.workgroup();
        for (bi, batch) in self.src.batches().iter().enumerate() {
            let fetched = self.src.fetch_batch(bi, counters);
            let blocks: &[Arc<Block>] = &fetched;
            let base = batch.blocks.start;
            let wgs = batch.wg_block.len();
            let shards = ShardedCounters::new(backend.threads());
            backend.dynamic(wgs, 4, |t, lo, hi| {
                let mut scratch = Scratch::new(spec.order(), wg);
                let mut tally = Snapshot::default();
                for w in lo..hi {
                    process_tile(
                        spec,
                        wg,
                        &blocks[batch.wg_block[w] as usize - base],
                        batch.wg_offset[w] as usize,
                        target,
                        factors,
                        rank,
                        dest,
                        rank,
                        false, // forced: CAS on every flush
                        &mut scratch,
                        &mut tally,
                        None,
                    );
                }
                shards.shard(t).add(&tally);
            });
            shards.merge_into(counters);
            counters.add(&Snapshot { launches: 1, ..Default::default() });
        }
    }

    /// Forced-`Privatize` ablation leg: one private output copy per
    /// worker thread (plain stores, no contention), then a pairwise tree
    /// reduction merges the copies and accumulates into `out`. Pays
    /// `threads × rows × rank` of buffer traffic whether or not the
    /// batches conflicted — the cost the certificate lets NoSync batches
    /// skip.
    fn run_forced_privatize(
        &self,
        target: usize,
        factors: &[Matrix],
        rank: usize,
        out: &mut Matrix,
        backend: ExecBackend,
        counters: &Counters,
    ) {
        let rows = self.src.dims()[target] as usize;
        let nt = backend.threads();
        let copy_len = rows * rank;
        let mut partials = vec![0.0f64; nt * copy_len];
        let spec = self.src.spec();
        let wg = self.src.workgroup();
        let at = as_atomic(&mut partials);
        for (bi, batch) in self.src.batches().iter().enumerate() {
            let fetched = self.src.fetch_batch(bi, counters);
            let blocks: &[Arc<Block>] = &fetched;
            let base = batch.blocks.start;
            let wgs = batch.wg_block.len();
            let shards = ShardedCounters::new(nt);
            backend.dynamic(wgs, 4, |t, lo, hi| {
                // worker t owns private copy t: plain stores
                let dest = &at[(t % nt) * copy_len..(t % nt + 1) * copy_len];
                let mut scratch = Scratch::new(spec.order(), wg);
                let mut tally = Snapshot::default();
                for w in lo..hi {
                    process_tile(
                        spec,
                        wg,
                        &blocks[batch.wg_block[w] as usize - base],
                        batch.wg_offset[w] as usize,
                        target,
                        factors,
                        rank,
                        dest,
                        rank,
                        true,
                        &mut scratch,
                        &mut tally,
                        None,
                    );
                }
                shards.shard(t).add(&tally);
            });
            shards.merge_into(counters);
            counters.add(&Snapshot { launches: 1, ..Default::default() });
        }
        // pairwise tree reduction: copy (b + stride) folds into copy b,
        // stride doubling. Element destinations are owned by exactly one
        // chunk, so plain loads/stores through the atomic view are sound.
        let mut pairs = 0u64;
        let mut stride = 1usize;
        while stride < nt {
            for b0 in (0..nt).step_by(2 * stride) {
                let peer = b0 + stride;
                if peer >= nt {
                    continue;
                }
                pairs += 1;
                backend.dynamic(copy_len, 1024, |_, lo, hi| {
                    for i in lo..hi {
                        let src = f64::from_bits(
                            at[peer * copy_len + i].load(Ordering::Relaxed),
                        );
                        let d = &at[b0 * copy_len + i];
                        let cur = f64::from_bits(d.load(Ordering::Relaxed));
                        d.store((cur + src).to_bits(), Ordering::Relaxed);
                    }
                });
            }
            stride *= 2;
        }
        // accumulate the reduced copy into the (zero-filled) output
        let out_at = as_atomic(&mut out.data);
        backend.dynamic(copy_len, 1024, |_, lo, hi| {
            for i in lo..hi {
                let src = f64::from_bits(at[i].load(Ordering::Relaxed));
                let d = &out_at[i];
                let cur = f64::from_bits(d.load(Ordering::Relaxed));
                d.store((cur + src).to_bits(), Ordering::Relaxed);
            }
        });
        counters.add(&Snapshot {
            // tree rounds read two copies and write one, the final
            // accumulate reads copy 0 + the prior output and writes out
            bytes_streamed: (pairs * 2 + 2) * copy_len as u64 * 8,
            bytes_written: (pairs + 1) * copy_len as u64 * 8,
            launches: pairs + 1,
            atomic_fanout: (nt * copy_len) as u64,
            ..Default::default()
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::blco::BlcoConfig;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    fn engine(t: &crate::tensor::coo::CooTensor, r: Resolution) -> BlcoEngine {
        BlcoEngine::new(BlcoTensor::from_coo(t), Profile::a100()).with_resolution(r)
    }

    #[test]
    fn register_matches_oracle_all_modes() {
        let dims = [50u64, 40, 30];
        let t = synth::uniform(&dims, 5_000, 1);
        let factors = random_factors(&dims, 8, 2);
        let eng = engine(&t, Resolution::Register);
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            eng.mttkrp(target, &factors, &mut out, 4, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn hierarchical_matches_oracle_all_modes() {
        let dims = [20u64, 40, 60];
        let t = synth::uniform(&dims, 4_000, 3);
        let factors = random_factors(&dims, 16, 5);
        let eng = engine(&t, Resolution::Hierarchical);
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 16);
            eng.mttkrp(target, &factors, &mut out, 8, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn auto_heuristic_follows_553() {
        let p = Profile::a100(); // 108 SMs
        assert_eq!(choose_resolution(24, &p), Resolution::Hierarchical);
        assert_eq!(choose_resolution(107, &p), Resolution::Hierarchical);
        assert_eq!(choose_resolution(108, &p), Resolution::Register);
        assert_eq!(choose_resolution(1 << 20, &p), Resolution::Register);

        let dims = [24u64, 2000, 2000]; // mode 0 short, others long
        let t = synth::uniform(&dims, 2_000, 7);
        let eng = engine(&t, Resolution::Auto);
        assert_eq!(eng.effective_resolution(0), Resolution::Hierarchical);
        assert_eq!(eng.effective_resolution(1), Resolution::Register);
    }

    #[test]
    fn auto_matches_oracle() {
        let dims = [24u64, 500, 300];
        let t = synth::uniform(&dims, 6_000, 9);
        let factors = random_factors(&dims, 8, 11);
        let eng = engine(&t, Resolution::Auto);
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            eng.mttkrp(target, &factors, &mut out, 8, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn works_with_blocking_keys() {
        // force the adaptive-blocking key path on a small shape by lowering
        // the in-block bit budget: 18-bit line squeezed into 10 bits → 8-bit
        // keys, many blocks with non-zero per-mode bases
        let dims = [64u64, 64, 64];
        let t = synth::uniform(&dims, 4_000, 13);
        let cfg = BlcoConfig {
            max_block_nnz: 4096,
            workgroup: 64,
            threads: 2,
            inblock_budget: 10,
        };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        assert!(b.spec.needs_blocking());
        assert!(b.blocks.len() > 4, "blocks {}", b.blocks.len());
        let eng = BlcoEngine::new(b, Profile::a100());
        let factors = random_factors(&dims, 8, 15);
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(64, 8);
            eng.mttkrp(target, &factors, &mut out, 4, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn capacity_one_tile_per_workgroup() {
        // workgroup smaller than block: many tiles per block
        let dims = [30u64, 30, 30];
        let t = synth::uniform(&dims, 3_000, 17);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        let eng = BlcoEngine::new(b, Profile::v100());
        let factors = random_factors(&dims, 4, 19);
        let expect = mttkrp_oracle(&t, 1, &factors);
        let mut out = Matrix::zeros(30, 4);
        eng.mttkrp(1, &factors, &mut out, 4, &Counters::new());
        assert!(out.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn reorder_reduces_atomics_vs_coo() {
        // BLCO's in-tile reorder + registers must issue far fewer atomics
        // than nnz*rank (COO's count) on a clustered tensor
        let dims = [64u64, 400, 400];
        let t = synth::fiber_clustered(&dims, 20_000, 0, 1.2, 21);
        let factors = random_factors(&dims, 8, 23);
        let eng = engine(&t, Resolution::Register);
        let c = Counters::new();
        let mut out = Matrix::zeros(64, 8);
        eng.mttkrp(0, &factors, &mut out, 4, &c);
        let s = c.snapshot();
        assert!(s.atomics < t.nnz() as u64 * 8 / 2, "atomics {}", s.atomics);
        assert!(s.stash_hits > 0);
        // correctness too
        let expect = mttkrp_oracle(&t, 0, &factors);
        assert!(out.max_abs_diff(&expect) < 1e-8);
    }

    #[test]
    fn output_overwritten_regardless_of_prior_contents() {
        // Regression for the hierarchical final merge: `mttkrp` overwrites
        // `out` per the trait contract, and the merge step must neither
        // drop nor double prior contents no matter what the buffer held
        // before the call (it accumulates into a zero-filled output).
        let dims = [16u64, 120, 90];
        let t = synth::uniform(&dims, 3_000, 29);
        let factors = random_factors(&dims, 8, 31);
        for res in [Resolution::Register, Resolution::Hierarchical] {
            let eng = engine(&t, res);
            let expect = mttkrp_oracle(&t, 0, &factors);
            let mut out = Matrix::zeros(16, 8);
            out.fill(1e30); // poison
            eng.mttkrp(0, &factors, &mut out, 4, &Counters::new());
            assert!(
                out.max_abs_diff(&expect) < 1e-9,
                "{res:?}: poison leaked into the merge"
            );
            // second call on the dirty buffer must give the same answer
            eng.mttkrp(0, &factors, &mut out, 4, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "{res:?}: not idempotent");
        }
    }

    #[test]
    fn auto_never_leaks_past_resolution() {
        // the `unreachable!` Auto arm in `mttkrp` is guarded by this:
        // `effective_resolution` must return a concrete strategy for every
        // mode, certificates attached or not
        let dims = [24u64, 500, 300];
        let t = synth::uniform(&dims, 4_000, 33);
        let eng = engine(&t, Resolution::Auto);
        for m in 0..3 {
            assert_ne!(eng.effective_resolution(m), Resolution::Auto, "mode {m}");
        }
        let set = Arc::new(crate::analysis::conflict::CertificateSet::analyze(&eng.src));
        let eng = eng.with_certificates(set);
        for m in 0..3 {
            assert_ne!(eng.effective_resolution(m), Resolution::Auto, "mode {m} (cert)");
        }
    }

    #[test]
    fn auto_routes_through_certificate_bit_for_bit() {
        // with certificates attached, Auto must dispatch to the certified
        // strategy and produce output bitwise identical to an engine pinned
        // to that same strategy explicitly — the certificate changes the
        // policy, never the kernel
        let dims = [150u64, 130, 170];
        let t = synth::uniform(&dims, 10_000, 35);
        let factors = random_factors(&dims, 8, 37);
        let plain = engine(&t, Resolution::Auto);
        let set = Arc::new(crate::analysis::conflict::CertificateSet::analyze(&plain.src));
        let certified = engine(&t, Resolution::Auto).with_certificates(set);
        for m in 0..3 {
            let res = certified.effective_resolution(m);
            assert_ne!(res, Resolution::Auto);
            let pinned = engine(&t, res);
            let rows = dims[m] as usize;
            let (mut a, mut b) = (Matrix::zeros(rows, 8), Matrix::zeros(rows, 8));
            // single-threaded: atomic-add order (and hence low-order bits)
            // is only deterministic when work-groups run in sequence
            certified.mttkrp(m, &factors, &mut a, 1, &Counters::new());
            pinned.mttkrp(m, &factors, &mut b, 1, &Counters::new());
            assert!(
                a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
                "mode {m}: certified Auto diverged from pinned {res:?}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "certificate fingerprint mismatch")]
    fn stale_certificates_are_rejected() {
        let t1 = synth::uniform(&[40u64, 40, 40], 3_000, 41);
        let t2 = synth::uniform(&[40u64, 40, 40], 4_000, 43);
        let e1 = engine(&t1, Resolution::Auto);
        let set = Arc::new(crate::analysis::conflict::CertificateSet::analyze(&e1.src));
        let _ = engine(&t2, Resolution::Auto).with_certificates(set);
    }

    fn bitwise_eq(a: &Matrix, b: &Matrix) -> bool {
        a.data.len() == b.data.len()
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn certified_register_is_bitwise_across_thread_counts() {
        // the tentpole invariant: with certificates attached, the waved
        // register path produces bit-identical output at every thread
        // count — the order-preserving coloring replays each row's flush
        // sequence in submission order no matter how waves are split
        let dims = [150u64, 130, 170];
        let t = synth::uniform(&dims, 10_000, 51);
        let factors = random_factors(&dims, 8, 53);
        let plain = engine(&t, Resolution::Register);
        let set = Arc::new(crate::analysis::conflict::CertificateSet::analyze(&plain.src));
        let eng = engine(&t, Resolution::Register).with_certificates(set);
        for m in 0..3 {
            let rows = dims[m] as usize;
            let mut reference = Matrix::zeros(rows, 8);
            eng.mttkrp(m, &factors, &mut reference, 1, &Counters::new());
            // the certified 1-thread run is bitwise the uncertified
            // sequential register path (same per-row flush order)
            let mut seq = Matrix::zeros(rows, 8);
            plain.mttkrp(m, &factors, &mut seq, 1, &Counters::new());
            assert!(bitwise_eq(&reference, &seq), "mode {m}: waved@1 != sequential");
            for threads in [2usize, 4, 8] {
                let mut out = Matrix::zeros(rows, 8);
                eng.mttkrp(m, &factors, &mut out, threads, &Counters::new());
                assert!(
                    bitwise_eq(&reference, &out),
                    "mode {m}: certified run diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn hierarchical_is_bitwise_across_thread_counts() {
        // copy ownership: one writer per shadow copy, fixed per-copy
        // sweep order, fixed merge order → deterministic at any thread
        // count, certificates or not
        let dims = [16u64, 200, 150];
        let t = synth::uniform(&dims, 8_000, 55);
        let factors = random_factors(&dims, 8, 57);
        let eng = engine(&t, Resolution::Hierarchical);
        for m in 0..3 {
            let rows = dims[m] as usize;
            let mut reference = Matrix::zeros(rows, 8);
            eng.mttkrp(m, &factors, &mut reference, 1, &Counters::new());
            for threads in [2usize, 4, 8] {
                let mut out = Matrix::zeros(rows, 8);
                eng.mttkrp(m, &factors, &mut out, threads, &Counters::new());
                assert!(
                    bitwise_eq(&reference, &out),
                    "mode {m}: hierarchical diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn certified_threaded_counts_waves_not_atomics() {
        let dims = [150u64, 130, 170];
        let t = synth::uniform(&dims, 8_000, 59);
        let factors = random_factors(&dims, 8, 61);
        let eng = engine(&t, Resolution::Register);
        let set = Arc::new(crate::analysis::conflict::CertificateSet::analyze(&eng.src));
        let eng = eng.with_certificates(set);
        let c = Counters::new();
        let mut out = Matrix::zeros(150, 8);
        eng.mttkrp(0, &factors, &mut out, 4, &c);
        let s = c.snapshot();
        assert_eq!(s.atomics, 0, "certified flushes are plain stores");
        assert!(s.nosync_flushes > 0);
        assert!(s.waves as usize >= eng.src.num_batches());
        assert_eq!(s.launches as usize, eng.src.num_batches());
    }

    #[test]
    fn forced_strategies_match_oracle() {
        let dims = [64u64, 90, 110];
        let t = synth::uniform(&dims, 6_000, 63);
        let factors = random_factors(&dims, 8, 65);
        let eng = engine(&t, Resolution::Register);
        let set = Arc::new(crate::analysis::conflict::CertificateSet::analyze(&eng.src));
        let eng = eng.with_certificates(set);
        let expect = mttkrp_oracle(&t, 0, &factors);
        for strategy in
            [BatchStrategy::NoSync, BatchStrategy::Privatize, BatchStrategy::Atomic]
        {
            for threads in [1usize, 4] {
                let mut out = Matrix::zeros(64, 8);
                out.fill(1e30); // forced paths must overwrite too
                eng.mttkrp_forced(strategy, 0, &factors, &mut out, threads, &Counters::new());
                assert!(
                    out.max_abs_diff(&expect) < 1e-9,
                    "{strategy:?} at {threads} threads"
                );
            }
        }
        // the forced NoSync leg is the certified production path itself
        let (mut a, mut b) = (Matrix::zeros(64, 8), Matrix::zeros(64, 8));
        eng.mttkrp_forced(BatchStrategy::NoSync, 0, &factors, &mut a, 4, &Counters::new());
        eng.mttkrp(0, &factors, &mut b, 4, &Counters::new());
        assert!(bitwise_eq(&a, &b));
    }

    #[test]
    #[should_panic(expected = "requires attached certificates")]
    fn forced_nosync_requires_certificates() {
        let dims = [30u64, 30, 30];
        let t = synth::uniform(&dims, 1_000, 67);
        let eng = engine(&t, Resolution::Register);
        let factors = random_factors(&dims, 4, 69);
        let mut out = Matrix::zeros(30, 4);
        eng.mttkrp_forced(
            BatchStrategy::NoSync, 0, &factors, &mut out, 2, &Counters::new(),
        );
    }

    #[test]
    fn hierarchical_reports_larger_fanout() {
        let dims = [16u64, 200, 200];
        let t = synth::uniform(&dims, 3_000, 25);
        let factors = random_factors(&dims, 4, 27);
        let (cr, ch) = (Counters::new(), Counters::new());
        let mut out = Matrix::zeros(16, 4);
        engine(&t, Resolution::Register).mttkrp(0, &factors, &mut out, 4, &cr);
        engine(&t, Resolution::Hierarchical).mttkrp(0, &factors, &mut out, 4, &ch);
        let (sr, sh) = (cr.snapshot(), ch.snapshot());
        assert!(sh.atomic_fanout > sr.atomic_fanout);
        // a100 has 7 slices (shadow copies)
        assert_eq!(sh.atomic_fanout, sr.atomic_fanout * 7);
    }
}
