//! Serial COO MTTKRP — the textbook formulation of Figure 3 and the
//! correctness anchor every parallel engine is tested against.

use super::dense::Matrix;
use crate::tensor::coo::CooTensor;

/// `out = X_(target) ⨀ (⊙_{n≠target} factors[n])`, serial, no tricks.
pub fn mttkrp_serial(
    t: &CooTensor,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
) {
    let rank = factors[0].cols;
    assert_eq!(out.rows as u64, t.dims[target]);
    assert_eq!(out.cols, rank);
    out.fill(0.0);
    let mut row = vec![0.0f64; rank];
    for e in 0..t.nnz() {
        row.iter_mut().for_each(|x| *x = t.vals[e]);
        for n in 0..t.order() {
            if n == target {
                continue;
            }
            let f = factors[n].row(t.coords[n][e] as usize);
            for k in 0..rank {
                row[k] *= f[k];
            }
        }
        let o = out.row_mut(t.coords[target][e] as usize);
        for k in 0..rank {
            o[k] += row[k];
        }
    }
}

/// Convenience: allocate the output and run the serial oracle.
pub fn mttkrp_oracle(t: &CooTensor, target: usize, factors: &[Matrix]) -> Matrix {
    let mut out = Matrix::zeros(t.dims[target] as usize, factors[0].cols);
    mttkrp_serial(t, target, factors, &mut out);
    out
}

/// Random factor matrices for a tensor (test/bench helper).
pub fn random_factors(dims: &[u64], rank: usize, seed: u64) -> Vec<Matrix> {
    let mut rng = crate::util::prng::Rng::new(seed);
    dims.iter().map(|&d| Matrix::random(d as usize, rank, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_case() {
        // X(0,0,0)=2, X(1,0,1)=3; A1 = [[1],[10]], A2 = [[5],[7]] (rank 1)
        let mut t = CooTensor::new(&[2, 2, 2]);
        t.push(&[0, 0, 0], 2.0);
        t.push(&[1, 0, 1], 3.0);
        let factors = vec![
            Matrix::from_rows(vec![vec![100.0], vec![200.0]]), // unused (target)
            Matrix::from_rows(vec![vec![1.0], vec![10.0]]),
            Matrix::from_rows(vec![vec![5.0], vec![7.0]]),
        ];
        let out = mttkrp_oracle(&t, 0, &factors);
        // row 0: 2 * A1[0] * A2[0] = 2*1*5 = 10
        // row 1: 3 * A1[0] * A2[1] = 3*1*7 = 21
        assert_eq!(out.data, vec![10.0, 21.0]);
    }

    #[test]
    fn mode1_of_paper_tensor() {
        // Figure 3's description: rows i2, i3 fetched, scaled, accumulated
        let mut t = CooTensor::new(&[2, 2, 2]);
        t.push(&[0, 1, 1], 1.0);
        t.push(&[1, 1, 1], 4.0);
        let ones = Matrix::from_rows(vec![vec![1.0, 1.0], vec![2.0, 3.0]]);
        let factors = vec![ones.clone(), ones.clone(), ones];
        let out = mttkrp_oracle(&t, 1, &factors);
        // target mode 1, row 1 receives both nnz:
        //   e0: 1.0 * A0[0] * A2[1] = [1*2, 1*3] = [2,3]
        //   e1: 4.0 * A0[1] * A2[1] = 4*[2*2, 3*3] = [16,36]
        assert_eq!(out.row(1), &[18.0, 39.0]);
        assert_eq!(out.row(0), &[0.0, 0.0]);
    }

    #[test]
    fn matches_python_style_dense_reference() {
        // cross-check against an explicit dense matricization × KRP,
        // mirroring python/compile/kernels/ref.py::mttkrp_dense_ref
        use crate::tensor::synth;
        let dims = [5u64, 4, 3];
        let t = synth::uniform(&dims, 25, 3);
        let rank = 4;
        let factors = random_factors(&dims, rank, 7);
        for target in 0..3 {
            let m = mttkrp_oracle(&t, target, &factors);
            // dense path
            let mut dense = vec![0.0f64; 5 * 4 * 3];
            for e in 0..t.nnz() {
                let c = t.coord(e);
                dense[(c[0] as usize * 4 + c[1] as usize) * 3 + c[2] as usize] =
                    t.vals[e];
            }
            let mut expect = Matrix::zeros(dims[target] as usize, rank);
            for i0 in 0..5usize {
                for i1 in 0..4usize {
                    for i2 in 0..3usize {
                        let v = dense[(i0 * 4 + i1) * 3 + i2];
                        if v == 0.0 {
                            continue;
                        }
                        let c = [i0, i1, i2];
                        for k in 0..rank {
                            let mut p = v;
                            for n in 0..3 {
                                if n != target {
                                    p *= factors[n].row(c[n])[k];
                                }
                            }
                            expect.row_mut(c[target])[k] += p;
                        }
                    }
                }
            }
            assert!(m.max_abs_diff(&expect) < 1e-10, "target {target}");
        }
    }
}
