//! F-COO segmented-scan MTTKRP (Liu et al., Section 3.1): non-zeros are
//! pre-sorted by target index with bit flags, so each processing chunk
//! accumulates locally in a register and only the segments that cross chunk
//! boundaries need global atomics.

use super::atomicf::{as_atomic, atomic_add_row};
use super::dense::Matrix;
use super::{check_shapes, Mttkrp, MAX_RANK};
use crate::device::counters::{Counters, Snapshot};
use crate::format::fcoo::FCoo;
use crate::util::pool::parallel_dynamic;

/// Rank elements per GPU pass: the F-COO kernel scans rank-wide partial
/// rows through local memory, whose capacity bounds the tile to ~8 lanes —
/// larger ranks re-read the whole tensor payload once per tile (a real
/// structural cost of the format's two-phase kernel).
pub const RANK_TILE: usize = 8;

pub struct FCooEngine {
    pub f: FCoo,
    /// cumulative segment count before each position (per mode), so a chunk
    /// knows which `seg_rows` entry it is in without scanning from 0
    seg_before: Vec<Vec<u32>>,
}

impl FCooEngine {
    pub fn new(f: FCoo) -> Self {
        let seg_before = f
            .modes
            .iter()
            .map(|m| {
                let mut acc = 0u32;
                let mut v = Vec::with_capacity(m.nnz());
                for i in 0..m.nnz() {
                    v.push(acc);
                    if !m.bf[i] {
                        acc += 1;
                    }
                }
                v
            })
            .collect();
        FCooEngine { f, seg_before }
    }
}

impl Mttkrp for FCooEngine {
    fn name(&self) -> String {
        "fcoo".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(&self.f.dims, target, factors, out);
        let m = &self.f.modes[target];
        let seg_before = &self.seg_before[target];
        out.fill(0.0);
        let out_at = as_atomic(&mut out.data);
        let nnz = m.nnz();
        let chunk = m.chunk;

        // each scheduling step takes one format chunk; segments interior to
        // a chunk write without atomics (sorted target ⇒ the row belongs to
        // this chunk alone), boundary segments use atomics
        parallel_dynamic(threads, nnz.div_ceil(chunk), 1, |_, clo, chi| {
            for c in clo..chi {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(nnz);
                let mut scratch = vec![0u32; hi - lo];
                let (mut cold, mut hot) = (0u64, 0u64);
                for plane in &m.other_idx {
                    scratch.copy_from_slice(&plane[lo..hi]);
                    let (cc, hh) = crate::mttkrp::split_cold_hot(&mut scratch);
                    cold += cc;
                    hot += hh;
                }
                let mut reg = [0.0f64; MAX_RANK];
                let mut seg = seg_before[lo] as usize;
                // the segment containing position lo is shared with the
                // previous chunk unless it starts exactly at lo
                let mut seg_started_inside = lo == 0 || !m.bf[lo - 1];
                let mut atomics = 0u64;
                let mut segments = 0u64;
                let mut writes = 0u64;
                for i in lo..hi {
                    // rank-wise product of non-target rows
                    let mut row = [0.0f64; MAX_RANK];
                    row[..rank].iter_mut().for_each(|x| *x = m.vals[i]);
                    for (j, &n) in m.other_modes.iter().enumerate() {
                        let fr = factors[n].row(m.other_idx[j][i] as usize);
                        for k in 0..rank {
                            row[k] *= fr[k];
                        }
                    }
                    for k in 0..rank {
                        reg[k] += row[k];
                    }
                    if !m.bf[i] {
                        // segment ends at i
                        let r = m.seg_rows[seg] as usize;
                        segments += 1;
                        if seg_started_inside {
                            // segment fully inside this chunk: the row is
                            // exclusively ours (sorted target ⇒ one segment
                            // per row), plain read-modify-write suffices
                            let o = r * rank;
                            for k in 0..rank {
                                let cur = f64::from_bits(
                                    out_at[o + k].load(std::sync::atomic::Ordering::Relaxed),
                                );
                                out_at[o + k].store(
                                    (cur + reg[k]).to_bits(),
                                    std::sync::atomic::Ordering::Relaxed,
                                );
                            }
                            writes += rank as u64;
                        } else {
                            // continuation from the previous chunk
                            atomic_add_row(out_at, r * rank, &reg[..rank]);
                            atomics += rank as u64;
                        }
                        reg[..rank].iter_mut().for_each(|x| *x = 0.0);
                        seg += 1;
                        seg_started_inside = true;
                    }
                }
                // trailing open segment: crosses the chunk boundary → atomic
                if hi > lo && m.bf[hi - 1] {
                    let r = m.seg_rows[seg] as usize;
                    atomic_add_row(out_at, r * rank, &reg[..rank]);
                    atomics += rank as u64;
                }
                let n = (hi - lo) as u64;
                // the GPU F-COO merges partial rows with a log-depth
                // segmented scan over the chunk in local memory (log2(chunk)
                // barrier-separated passes); local-memory capacity forces
                // rank tiling (payload re-read per tile); and the two-phase
                // product→scan pipeline stages the rank-wide partial rows
                // through GLOBAL memory between its kernels (one write +
                // one read per non-zero)
                let scan_passes = (chunk.max(2) as f64).log2().ceil() as u64;
                let rank_tiles = rank.div_ceil(RANK_TILE) as u64;
                counters.add(&Snapshot {
                    bytes_streamed: (n * ((m.other_modes.len() as u64) * 4 + 8)
                        + n / 8 // bit flags
                        + 4) // sf flag
                        * rank_tiles
                        + n * rank as u64 * 8 * 2, // staged partials
                    bytes_gathered: cold * rank as u64 * 8,
                    bytes_local: hot * rank as u64 * 8
                        + n * rank as u64 * 8 * scan_passes,
                    bytes_written: writes * 8 + atomics * 8,
                    atomics,
                    segments,
                    ..Default::default()
                });
            }
        });
        counters.add(&Snapshot {
            launches: rank.div_ceil(RANK_TILE) as u64,
            atomic_fanout: self.f.dims[target] * rank as u64,
            ..Default::default()
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    fn engine(t: &crate::tensor::coo::CooTensor, chunk: usize) -> FCooEngine {
        FCooEngine::new(FCoo::from_coo(t, chunk))
    }

    #[test]
    fn matches_oracle_all_modes() {
        let dims = [40u64, 30, 20];
        let t = synth::uniform(&dims, 4_000, 1);
        let factors = random_factors(&dims, 8, 2);
        let eng = engine(&t, 64);
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            let c = Counters::new();
            eng.mttkrp(target, &factors, &mut out, 4, &c);
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
            // segmented scan must use far fewer atomics than nnz*rank
            let s = c.snapshot();
            assert!(s.atomics < t.nnz() as u64 * 8 / 4, "atomics {}", s.atomics);
        }
    }

    #[test]
    fn chunk_boundary_segments_exact() {
        // tiny chunks force many boundary crossings
        let dims = [5u64, 50, 50];
        let t = synth::uniform(&dims, 3_000, 7);
        let factors = random_factors(&dims, 4, 3);
        let eng = engine(&t, 8);
        let expect = mttkrp_oracle(&t, 0, &factors);
        let mut out = Matrix::zeros(5, 4);
        eng.mttkrp(0, &factors, &mut out, 8, &Counters::new());
        assert!(out.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn four_mode() {
        let dims = [12u64, 10, 8, 6];
        let t = synth::uniform(&dims, 1_500, 5);
        let factors = random_factors(&dims, 8, 9);
        let eng = engine(&t, 32);
        for target in 0..4 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            eng.mttkrp(target, &factors, &mut out, 3, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn single_thread_matches() {
        let dims = [16u64, 16, 16];
        let t = synth::uniform(&dims, 800, 11);
        let factors = random_factors(&dims, 8, 13);
        let eng = engine(&t, 128);
        let expect = mttkrp_oracle(&t, 2, &factors);
        let mut out = Matrix::zeros(16, 8);
        eng.mttkrp(2, &factors, &mut out, 1, &Counters::new());
        assert!(out.max_abs_diff(&expect) < 1e-9);
    }
}
