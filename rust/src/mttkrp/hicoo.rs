//! HiCOO MTTKRP: parallel over blocks, register accumulation within a
//! block (non-zeros in a block share few distinct target rows when blocks
//! are dense), atomic flushes at block boundaries. The per-block workload
//! variance (singleton blocks in hypersparse data) is exactly the
//! imbalance the paper cites against block-based formats on GPUs.

use super::atomicf::{as_atomic, atomic_add_row};
use super::dense::Matrix;
use super::{check_shapes, Mttkrp, MAX_RANK};
use crate::device::counters::{Counters, Snapshot};
use crate::format::hicoo::HicooTensor;
use crate::util::pool::parallel_dynamic;

pub struct HicooEngine {
    pub t: HicooTensor,
}

impl HicooEngine {
    pub fn new(t: HicooTensor) -> Self {
        HicooEngine { t }
    }
}

impl Mttkrp for HicooEngine {
    fn name(&self) -> String {
        "hicoo".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let t = &self.t;
        let rank = check_shapes(&t.dims, target, factors, out);
        let order = t.order();
        let bb = t.block_bits;
        out.fill(0.0);
        let out_at = as_atomic(&mut out.data);

        parallel_dynamic(threads, t.blocks.len(), 4, |_, lo, hi| {
            let mut tally = Snapshot::default();
            let mut scratch: Vec<u32> = Vec::new();
            for bi in lo..hi {
                let blk = &t.blocks[bi];
                let n_nnz = blk.nnz();
                // measured gather locality within the block (dense blocks
                // reuse rows heavily — HiCOO's whole selling point)
                for n in 0..order {
                    if n == target {
                        continue;
                    }
                    scratch.clear();
                    scratch.extend(
                        blk.eidx[n]
                            .iter()
                            .map(|&e| (blk.base[n] << bb) | e as u32),
                    );
                    let (cold, hot) = crate::mttkrp::split_cold_hot(&mut scratch);
                    tally.bytes_gathered += cold * rank as u64 * 8;
                    tally.bytes_local += hot * rank as u64 * 8;
                }
                // compute with register accumulation over equal target rows
                let mut reg = [0.0f64; MAX_RANK];
                let mut cur_row = u32::MAX;
                let mut open = false;
                for i in 0..n_nnz {
                    let row = (blk.base[target] << bb) | blk.eidx[target][i] as u32;
                    if open && row != cur_row {
                        atomic_add_row(out_at, cur_row as usize * rank, &reg[..rank]);
                        tally.atomics += rank as u64;
                        tally.segments += 1;
                        tally.bytes_written += rank as u64 * 8;
                        reg[..rank].iter_mut().for_each(|x| *x = 0.0);
                    } else if open {
                        tally.stash_hits += 1;
                    }
                    cur_row = row;
                    open = true;
                    let mut prod = [0.0f64; MAX_RANK];
                    let p = &mut prod[..rank];
                    p.iter_mut().for_each(|x| *x = blk.vals[i]);
                    for n in 0..order {
                        if n == target {
                            continue;
                        }
                        let gi = (blk.base[n] << bb) | blk.eidx[n][i] as u32;
                        let f = &factors[n].row(gi as usize)[..rank];
                        for (a, &b) in p.iter_mut().zip(f) {
                            *a *= b;
                        }
                    }
                    for (r, &a) in reg[..rank].iter_mut().zip(p.iter()) {
                        *r += a;
                    }
                }
                if open {
                    atomic_add_row(out_at, cur_row as usize * rank, &reg[..rank]);
                    tally.atomics += rank as u64;
                    tally.segments += 1;
                    tally.bytes_written += rank as u64 * 8;
                }
                // compact payload streams: base (4B/mode) + eidx (1B/mode)
                // + value per non-zero
                tally.bytes_streamed +=
                    order as u64 * 4 + n_nnz as u64 * (order as u64 + 8);
            }
            counters.add(&tally);
        });
        counters.add(&Snapshot {
            launches: 1,
            atomic_fanout: t.dims[target] * rank as u64,
            ..Default::default()
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    #[test]
    fn matches_oracle_all_modes() {
        let dims = [200u64, 150, 100];
        let t = synth::fiber_clustered(&dims, 6_000, 2, 1.0, 1);
        let factors = random_factors(&dims, 8, 2);
        let eng = HicooEngine::new(crate::format::hicoo::HicooTensor::from_coo(&t, 6));
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            eng.mttkrp(target, &factors, &mut out, 4, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn four_mode() {
        let dims = [40u64, 32, 24, 16];
        let t = synth::uniform(&dims, 2_000, 3);
        let factors = random_factors(&dims, 4, 5);
        let eng = HicooEngine::new(crate::format::hicoo::HicooTensor::from_coo(&t, 5));
        for target in 0..4 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 4);
            eng.mttkrp(target, &factors, &mut out, 3, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn dense_blocks_yield_high_locality() {
        let dims = [128u64, 128, 128];
        let t = synth::fiber_clustered(&dims, 30_000, 2, 1.2, 7);
        let factors = random_factors(&dims, 8, 9);
        let eng = HicooEngine::new(crate::format::hicoo::HicooTensor::from_coo(&t, 7));
        let c = Counters::new();
        let mut out = Matrix::zeros(128, 8);
        eng.mttkrp(0, &factors, &mut out, 4, &c);
        let s = c.snapshot();
        // dense blocks: most row fetches hit cache
        assert!(s.bytes_local > s.bytes_gathered, "local {} gathered {}", s.bytes_local, s.bytes_gathered);
    }
}
