//! Atomic f64 accumulation — the CPU analogue of the GPU's global
//! `atomicAdd(double*)`, implemented as a compare-and-swap loop over the
//! bit representation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Reinterpret a mutable f64 slice as atomic u64 slots. Sound: `AtomicU64`
/// has the same size/alignment as `u64`/`f64`, and the borrow of `data`
/// is held for the returned lifetime, so no unsynchronized plain access
/// can coexist with the atomic view.
pub fn as_atomic(data: &mut [f64]) -> &[AtomicU64] {
    unsafe { &*(data as *mut [f64] as *const [AtomicU64]) }
}

/// `slot += v` with CAS retry.
#[inline]
pub fn atomic_add(slot: &AtomicU64, v: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + v).to_bits();
        match slot.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Add `row` into `out[row_start..row_start+row.len()]` atomically.
#[inline]
pub fn atomic_add_row(out: &[AtomicU64], row_start: usize, row: &[f64]) {
    for (k, &v) in row.iter().enumerate() {
        atomic_add(&out[row_start + k], v);
    }
}

/// Unsynchronized add through the atomic view — only sound when a single
/// thread owns the destination (the engines' `threads == 1` fast path: a
/// CAS is ~20 cycles even uncontended, which dominates single-core runs).
#[inline]
pub fn serial_add_row(out: &[AtomicU64], row_start: usize, row: &[f64]) {
    for (k, &v) in row.iter().enumerate() {
        let slot = &out[row_start + k];
        let cur = f64::from_bits(slot.load(Ordering::Relaxed));
        slot.store((cur + v).to_bits(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_adds_sum_exactly() {
        // 2^k increments are exactly representable: the sum must be exact
        let mut data = vec![0.0f64; 4];
        {
            let a = as_atomic(&mut data);
            std::thread::scope(|s| {
                for _ in 0..8 {
                    s.spawn(|| {
                        for i in 0..1024 {
                            atomic_add(&a[i % 4], 1.0);
                        }
                    });
                }
            });
        }
        assert_eq!(data, vec![2048.0; 4]);
    }

    #[test]
    fn add_row() {
        let mut data = vec![0.0f64; 6];
        {
            let a = as_atomic(&mut data);
            atomic_add_row(a, 2, &[1.0, 2.0, 3.0]);
            atomic_add_row(a, 2, &[0.5, 0.5, 0.5]);
        }
        assert_eq!(data, vec![0.0, 0.0, 1.5, 2.5, 3.5, 0.0]);
    }

    #[test]
    fn negative_and_fractional() {
        let mut data = vec![1.0f64];
        {
            let a = as_atomic(&mut data);
            atomic_add(&a[0], -0.25);
        }
        assert_eq!(data[0], 0.75);
    }
}
