//! Tree-walk MTTKRP engines for the CSF family (Section 3.2):
//!
//! * [`CsfEngine`] — CSF-N: one tree per root mode (N tensor copies), the
//!   target mode's tree is walked root-down, conflict-free at the root;
//! * [`BCsfEngine`] — B-CSF: same N copies with heavy roots split for
//!   balance, paying atomics on the (now repeated) root rows;
//! * [`MmCsfEngine`] — MM-CSF: a *single* copy partitioned by fiber
//!   density; the target mode lands at a different tree level per group,
//!   so each (group, target) pair needs a different traversal — the very
//!   mode-specificity that causes Figure 1's per-mode variance.

use super::atomicf::{as_atomic, atomic_add_row};
use super::dense::Matrix;
use super::{check_shapes, Mttkrp, MAX_RANK};
use crate::device::counters::{Counters, Snapshot};
use crate::format::csf::Csf;
use crate::format::mmcsf::MmCsf;
use crate::tensor::coo::CooTensor;
use crate::util::pool::parallel_dynamic;
use std::sync::atomic::AtomicU64;

/// Mode ordering with `root` first, remaining modes ascending.
pub fn mode_order_with_root(order: usize, root: usize) -> Vec<usize> {
    let mut mo = vec![root];
    mo.extend((0..order).filter(|&n| n != root));
    mo
}

/// Per-chunk traffic tally flushed once per scheduling step.
///
/// All tree-walk traffic — structure reads *and* the factor-row fetches and
/// partial accumulations inside the recursive traversal — sits on a
/// dependency chain, so it is classed as `serial` (device::model): this is
/// the latency-bound behaviour behind MM-CSF's low measured throughput in
/// the paper's Table 3.
#[derive(Default)]
struct Tally {
    serial: u64,
    written: u64,
    atomics: u64,
    segments: u64,
}

/// Walk the subtree under (`level`, `node`) accumulating into `out`.
///
/// `tpos` is the tree level holding the target mode. `prefix` carries the
/// Hadamard product of the factor rows of all levels above `level`
/// (target excluded by construction since `level <= tpos`).
#[allow(clippy::too_many_arguments)]
fn walk(
    csf: &Csf,
    level: usize,
    node: usize,
    tpos: usize,
    prefix: &[f64],
    factors: &[Matrix],
    out: &[AtomicU64],
    rank: usize,
    atomic_target: bool,
    tally: &mut Tally,
) {
    if level == tpos {
        // contribution = prefix ⊙ (subtree sum below, target row excluded)
        let mut down = [0.0f64; MAX_RANK];
        subtree_sum(csf, level, node, factors, rank, &mut down, tally);
        for k in 0..rank {
            down[k] *= prefix[k];
        }
        let row = csf.fids[level][node] as usize * rank;
        tally.segments += 1;
        if atomic_target {
            atomic_add_row(out, row, &down[..rank]);
            tally.atomics += rank as u64;
        } else {
            for k in 0..rank {
                let cur = f64::from_bits(out[row + k].load(std::sync::atomic::Ordering::Relaxed));
                out[row + k].store((cur + down[k]).to_bits(), std::sync::atomic::Ordering::Relaxed);
            }
        }
        tally.written += rank as u64 * 8;
        return;
    }
    // multiply in this level's factor row and recurse
    let mode = csf.mode_order[level];
    let frow = factors[mode].row(csf.fids[level][node] as usize);
    tally.serial += rank as u64 * 8;
    let mut p = [0.0f64; MAX_RANK];
    for k in 0..rank {
        p[k] = prefix[k] * frow[k];
    }
    let (lo, hi) = (csf.fptr[level][node] as usize, csf.fptr[level][node + 1] as usize);
    tally.serial += 8; // fptr pointer chase
    for c in lo..hi {
        walk(csf, level + 1, c, tpos, &p[..rank], factors, out, rank, atomic_target, tally);
    }
}

/// Σ over the subtree below (`level`, `node`) of val ⊙ rows of all levels
/// strictly *below* `level` (the node's own row excluded).
fn subtree_sum(
    csf: &Csf,
    level: usize,
    node: usize,
    factors: &[Matrix],
    rank: usize,
    acc: &mut [f64; MAX_RANK],
    tally: &mut Tally,
) {
    let order = csf.order();
    acc[..rank].iter_mut().for_each(|x| *x = 0.0);
    if level == order - 1 {
        // leaf: just the value
        let v = csf.vals[node];
        tally.serial += 8 + 4;
        acc[..rank].iter_mut().for_each(|x| *x = v);
        return;
    }
    let (lo, hi) = (csf.fptr[level][node] as usize, csf.fptr[level][node + 1] as usize);
    tally.serial += 8;
    let mut child = [0.0f64; MAX_RANK];
    for c in lo..hi {
        subtree_sum(csf, level + 1, c, factors, rank, &mut child, tally);
        let mode = csf.mode_order[level + 1];
        let frow = factors[mode].row(csf.fids[level + 1][c] as usize);
        tally.serial += rank as u64 * 8 + 4;
        for k in 0..rank {
            acc[k] += frow[k] * child[k];
        }
    }
}

/// Run mode-`target` MTTKRP over one CSF tree, parallel over roots.
fn csf_mttkrp(
    csf: &Csf,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
    atomic_roots: bool,
) {
    let rank = factors[0].cols;
    let tpos = csf
        .mode_order
        .iter()
        .position(|&m| m == target)
        .expect("target not in mode order");
    let out_at = as_atomic(&mut out.data);
    // target at root level is conflict-free iff root ids are unique
    let atomic_target = tpos > 0 || atomic_roots;
    let ones = vec![1.0f64; rank];
    parallel_dynamic(threads, csf.roots(), 8, |_, lo, hi| {
        let mut tally = Tally::default();
        for r in lo..hi {
            walk(csf, 0, r, tpos, &ones, factors, out_at, rank, atomic_target, &mut tally);
        }
        counters.add(&Snapshot {
            bytes_serial: tally.serial,
            bytes_written: tally.written,
            atomics: tally.atomics,
            segments: tally.segments,
            ..Default::default()
        });
    });
}

/// CSF-N: one tree per root mode.
pub struct CsfEngine {
    pub trees: Vec<Csf>,
    pub dims: Vec<u64>,
}

impl CsfEngine {
    pub fn new(t: &CooTensor) -> Self {
        let trees = (0..t.order())
            .map(|m| Csf::from_coo(t, &mode_order_with_root(t.order(), m)))
            .collect();
        CsfEngine { trees, dims: t.dims.clone() }
    }

    pub fn footprint_bytes(&self) -> usize {
        self.trees.iter().map(|c| c.footprint_bytes()).sum()
    }
}

impl Mttkrp for CsfEngine {
    fn name(&self) -> String {
        "csf-n".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(&self.dims, target, factors, out);
        out.fill(0.0);
        csf_mttkrp(&self.trees[target], target, factors, out, threads, counters, false);
        counters.add(&Snapshot {
            launches: 1,
            atomic_fanout: self.dims[target] * rank as u64,
            ..Default::default()
        });
    }
}

/// B-CSF: CSF-N with heavy roots split for balance (root rows repeat →
/// atomics at the root level).
pub struct BCsfEngine {
    pub trees: Vec<Csf>,
    pub dims: Vec<u64>,
}

impl BCsfEngine {
    pub fn new(t: &CooTensor, max_root_nnz: usize) -> Self {
        let trees = (0..t.order())
            .map(|m| {
                Csf::from_coo(t, &mode_order_with_root(t.order(), m))
                    .split_roots(max_root_nnz)
            })
            .collect();
        BCsfEngine { trees, dims: t.dims.clone() }
    }

    pub fn footprint_bytes(&self) -> usize {
        self.trees.iter().map(|c| c.footprint_bytes()).sum()
    }
}

impl Mttkrp for BCsfEngine {
    fn name(&self) -> String {
        "b-csf".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(&self.dims, target, factors, out);
        out.fill(0.0);
        csf_mttkrp(&self.trees[target], target, factors, out, threads, counters, true);
        counters.add(&Snapshot {
            launches: 1,
            atomic_fanout: self.dims[target] * rank as u64,
            ..Default::default()
        });
    }
}

/// MM-CSF: single mixed-mode copy; every group is traversed with the target
/// at whatever level the group's orientation puts it.
pub struct MmCsfEngine {
    pub mm: MmCsf,
}

impl MmCsfEngine {
    pub fn new(t: &CooTensor) -> Self {
        MmCsfEngine { mm: MmCsf::from_coo(t) }
    }

    pub fn footprint_bytes(&self) -> usize {
        self.mm.footprint_bytes()
    }
}

impl Mttkrp for MmCsfEngine {
    fn name(&self) -> String {
        "mm-csf".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = check_shapes(&self.mm.dims, target, factors, out);
        out.fill(0.0);
        for g in &self.mm.groups {
            // roots repeat across groups → always atomic at the root too
            csf_mttkrp(&g.csf, target, factors, out, threads, counters, true);
            counters.add(&Snapshot {
                launches: 1,
                atomic_fanout: self.mm.dims[target] * rank as u64,
                ..Default::default()
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    fn assert_engine_matches<E: Mttkrp>(
        eng: &E,
        t: &CooTensor,
        rank: usize,
        threads: usize,
    ) {
        let factors = random_factors(&t.dims, rank, 42);
        for target in 0..t.order() {
            let expect = mttkrp_oracle(t, target, &factors);
            let mut out = Matrix::zeros(t.dims[target] as usize, rank);
            eng.mttkrp(target, &factors, &mut out, threads, &Counters::new());
            let d = out.max_abs_diff(&expect);
            assert!(d < 1e-8, "{} target {target}: diff {d}", eng.name());
        }
    }

    #[test]
    fn csf_matches_oracle() {
        let t = synth::uniform(&[40, 30, 20], 3_000, 1);
        assert_engine_matches(&CsfEngine::new(&t), &t, 8, 4);
    }

    #[test]
    fn csf_4mode() {
        let t = synth::uniform(&[14, 12, 10, 8], 2_000, 2);
        assert_engine_matches(&CsfEngine::new(&t), &t, 8, 3);
    }

    #[test]
    fn bcsf_matches_oracle_with_splits() {
        let t = synth::fiber_clustered(&[8, 80, 80], 6_000, 2, 1.0, 3);
        let eng = BCsfEngine::new(&t, 200);
        // splits actually happened
        assert!(eng.trees[0].roots() > 8);
        assert_engine_matches(&eng, &t, 8, 8);
    }

    #[test]
    fn mmcsf_matches_oracle() {
        let t = synth::fiber_clustered(&[50, 40, 30], 4_000, 2, 0.9, 5);
        assert_engine_matches(&MmCsfEngine::new(&t), &t, 8, 4);
    }

    #[test]
    fn mmcsf_4mode() {
        let t = synth::uniform(&[12, 10, 8, 6], 1_500, 7);
        assert_engine_matches(&MmCsfEngine::new(&t), &t, 4, 4);
    }

    #[test]
    fn mmcsf_moves_less_volume_on_dense_fibers() {
        // tree compression: shared fiber prefixes fetch the upper-level
        // factor rows once per fiber instead of once per nnz, so the total
        // volume is lower than COO's — Table 3's "Vol" relationship
        let t = synth::fiber_clustered(&[60, 60, 60], 20_000, 2, 1.3, 9);
        let factors = random_factors(&t.dims, 16, 1);
        let mm = MmCsfEngine::new(&t);
        let cm = Counters::new();
        let mut out = Matrix::zeros(60, 16);
        mm.mttkrp(0, &factors, &mut out, 4, &cm);
        // upper bound without any structural reuse: every non-zero fetches
        // both non-target rows + reads its payload
        let no_reuse = t.nnz() as u64 * (2 * 16 * 8 + 20);
        assert!(
            cm.snapshot().volume_bytes() < no_reuse,
            "mm {} vs no-reuse bound {no_reuse}",
            cm.snapshot().volume_bytes(),
        );
        // ... and the traversal traffic is dependency-chained (serial class)
        assert!(cm.snapshot().bytes_serial > 0);
    }

    #[test]
    fn mode_order_with_root_layout() {
        assert_eq!(mode_order_with_root(3, 0), vec![0, 1, 2]);
        assert_eq!(mode_order_with_root(3, 1), vec![1, 0, 2]);
        assert_eq!(mode_order_with_root(4, 2), vec![2, 0, 1, 3]);
    }

    #[test]
    fn csf_counters_populated() {
        let t = synth::uniform(&[30, 30, 30], 2_000, 13);
        let factors = random_factors(&t.dims, 8, 17);
        let eng = CsfEngine::new(&t);
        let c = Counters::new();
        let mut out = Matrix::zeros(30, 8);
        eng.mttkrp(1, &factors, &mut out, 2, &c);
        let s = c.snapshot();
        assert!(s.bytes_serial > 0);
        assert_eq!(s.launches, 1);
        // root-mode MTTKRP on a unique-root tree: no atomics
        assert_eq!(s.atomics, 0);
    }
}
