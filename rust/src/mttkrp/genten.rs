//! GenTen-style MTTKRP (Phipps & Kolda, SISC '19): COO kept in place, plus
//! one *permutation array* per mode sorting non-zeros by that mode's index.
//! Threads walk the permutation, accumulate in registers while the target
//! index repeats, and atomically add at segment boundaries. Compared to
//! F-COO this avoids N full tensor copies and the local-memory scan, but
//! every payload access is *indirect through the permutation* — a gather
//! instead of a stream.

use super::atomicf::{as_atomic, atomic_add_row};
use super::dense::Matrix;
use super::{check_shapes, Mttkrp, MAX_RANK};
use crate::device::counters::{Counters, Snapshot};
use crate::tensor::coo::CooTensor;
use crate::util::pool::parallel_dynamic;

/// Non-zeros per scheduling chunk.
const CHUNK: usize = 1024;

pub struct GenTenEngine {
    pub t: CooTensor,
    /// per-mode permutation sorting non-zeros by that mode's index
    pub perms: Vec<Vec<u32>>,
}

impl GenTenEngine {
    pub fn new(t: CooTensor) -> Self {
        let perms = (0..t.order())
            .map(|m| {
                let mut p: Vec<u32> = (0..t.nnz() as u32).collect();
                p.sort_by_key(|&e| t.coords[m][e as usize]);
                p
            })
            .collect();
        GenTenEngine { t, perms }
    }

    /// COO payload + N permutation arrays.
    pub fn footprint_bytes(&self) -> usize {
        self.t.footprint_bytes() + self.perms.len() * self.t.nnz() * 4
    }
}

impl Mttkrp for GenTenEngine {
    fn name(&self) -> String {
        "genten".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let t = &self.t;
        let rank = check_shapes(&t.dims, target, factors, out);
        let order = t.order();
        let perm = &self.perms[target];
        out.fill(0.0);
        let out_at = as_atomic(&mut out.data);
        let nnz = t.nnz();

        parallel_dynamic(threads, nnz.div_ceil(CHUNK), 1, |_, clo, chi| {
            for c in clo..chi {
                let lo = c * CHUNK;
                let hi = ((c + 1) * CHUNK).min(nnz);
                let mut scratch = vec![0u32; hi - lo];
                let (mut cold, mut hot) = (0u64, 0u64);
                for n in 0..order {
                    if n == target {
                        continue;
                    }
                    for (j, i) in (lo..hi).enumerate() {
                        scratch[j] = t.coords[n][perm[i] as usize];
                    }
                    let (cc, hh) = crate::mttkrp::split_cold_hot(&mut scratch);
                    cold += cc;
                    hot += hh;
                }
                let mut reg = [0.0f64; MAX_RANK];
                let mut cur_row = u32::MAX;
                let mut open = false;
                let mut atomics = 0u64;
                let mut segments = 0u64;
                for i in lo..hi {
                    let e = perm[i] as usize;
                    let row = t.coords[target][e];
                    if open && row != cur_row {
                        atomic_add_row(out_at, cur_row as usize * rank, &reg[..rank]);
                        atomics += rank as u64;
                        segments += 1;
                        reg[..rank].iter_mut().for_each(|x| *x = 0.0);
                    }
                    cur_row = row;
                    open = true;
                    let mut prod = [0.0f64; MAX_RANK];
                    prod[..rank].iter_mut().for_each(|x| *x = t.vals[e]);
                    for n in 0..order {
                        if n == target {
                            continue;
                        }
                        let f = factors[n].row(t.coords[n][e] as usize);
                        for k in 0..rank {
                            prod[k] *= f[k];
                        }
                    }
                    for k in 0..rank {
                        reg[k] += prod[k];
                    }
                }
                if open {
                    atomic_add_row(out_at, cur_row as usize * rank, &reg[..rank]);
                    atomics += rank as u64;
                    segments += 1;
                }
                let n = (hi - lo) as u64;
                counters.add(&Snapshot {
                    // permutation reads stream; the payload is reached
                    // *through* the permutation → word-granular scatters;
                    // factor rows are ordinary row gathers
                    bytes_streamed: n * 4,
                    bytes_scattered: n * (order as u64 * 4 + 8),
                    bytes_gathered: cold * rank as u64 * 8,
                    bytes_local: hot * rank as u64 * 8,
                    bytes_written: atomics * 8,
                    atomics,
                    segments,
                    ..Default::default()
                });
            }
        });
        counters.add(&Snapshot {
            launches: 1,
            atomic_fanout: t.dims[target] * rank as u64,
            ..Default::default()
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    #[test]
    fn matches_oracle_all_modes() {
        let dims = [40u64, 30, 20];
        let t = synth::uniform(&dims, 4_000, 1);
        let factors = random_factors(&dims, 8, 2);
        let eng = GenTenEngine::new(t.clone());
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            let c = Counters::new();
            eng.mttkrp(target, &factors, &mut out, 4, &c);
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
            // register accumulation: far fewer atomics than COO's nnz*rank
            assert!(c.snapshot().atomics < t.nnz() as u64 * 8);
        }
    }

    #[test]
    fn four_mode() {
        let dims = [14u64, 12, 10, 8];
        let t = synth::uniform(&dims, 1_500, 3);
        let factors = random_factors(&dims, 4, 5);
        let eng = GenTenEngine::new(t.clone());
        for target in 0..4 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 4);
            eng.mttkrp(target, &factors, &mut out, 6, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn footprint_one_copy_plus_perms() {
        let t = synth::uniform(&[30, 30, 30], 2_000, 7);
        let eng = GenTenEngine::new(t.clone());
        // much cheaper than F-COO's N copies
        let fcoo = crate::format::fcoo::FCoo::from_coo(&t, 256);
        assert!(eng.footprint_bytes() < fcoo.footprint_bytes());
    }

    #[test]
    fn permutations_sort_by_mode() {
        let t = synth::uniform(&[20, 20, 20], 500, 9);
        let eng = GenTenEngine::new(t.clone());
        for m in 0..3 {
            for w in eng.perms[m].windows(2) {
                assert!(
                    t.coords[m][w[0] as usize] <= t.coords[m][w[1] as usize]
                );
            }
        }
    }
}
