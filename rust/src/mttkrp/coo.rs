//! COO + global atomics: the naive massively-parallel baseline. Every
//! non-zero issues `rank` atomic adds to the output row — the RAW-hazard
//! storm of Section 3.1 that all the smarter formats try to avoid.

use super::atomicf::{as_atomic, atomic_add};
use super::dense::Matrix;
use super::{check_shapes, Mttkrp, MAX_RANK};
use crate::device::counters::{Counters, Snapshot};
use crate::tensor::coo::CooTensor;
use crate::util::pool::parallel_dynamic;

/// Chunk of non-zeros grabbed per scheduling step.
const CHUNK: usize = 4096;

pub struct CooAtomicEngine {
    pub t: CooTensor,
}

impl CooAtomicEngine {
    pub fn new(t: CooTensor) -> Self {
        CooAtomicEngine { t }
    }
}

impl Mttkrp for CooAtomicEngine {
    fn name(&self) -> String {
        "coo-atomic".into()
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let t = &self.t;
        let rank = check_shapes(&t.dims, target, factors, out);
        let order = t.order();
        out.fill(0.0);
        let out_at = as_atomic(&mut out.data);

        parallel_dynamic(threads, t.nnz(), CHUNK, |_, lo, hi| {
            let mut row = [0.0f64; MAX_RANK];
            let mut scratch = vec![0u32; hi - lo];
            let (mut cold, mut hot) = (0u64, 0u64);
            for n in 0..order {
                if n == target {
                    continue;
                }
                scratch.copy_from_slice(&t.coords[n][lo..hi]);
                let (c, h) = crate::mttkrp::split_cold_hot(&mut scratch);
                cold += c;
                hot += h;
            }
            for e in lo..hi {
                row[..rank].iter_mut().for_each(|x| *x = t.vals[e]);
                for n in 0..order {
                    if n == target {
                        continue;
                    }
                    let f = factors[n].row(t.coords[n][e] as usize);
                    for k in 0..rank {
                        row[k] *= f[k];
                    }
                }
                let base = t.coords[target][e] as usize * rank;
                for k in 0..rank {
                    atomic_add(&out_at[base + k], row[k]);
                }
            }
            let n = (hi - lo) as u64;
            counters.add(&Snapshot {
                // index planes + values stream linearly
                bytes_streamed: n * (order as u64 * 4 + 8),
                // factor rows: cold rows gather from HBM, repeats hit cache
                bytes_gathered: cold * rank as u64 * 8,
                bytes_local: hot * rank as u64 * 8,
                bytes_written: n * rank as u64 * 8,
                atomics: n * rank as u64,
                segments: n, // every non-zero is its own segment
                ..Default::default()
            });
        });
        counters.add(&Snapshot {
            launches: 1,
            atomic_fanout: t.dims[target] * rank as u64,
            ..Default::default()
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    #[test]
    fn matches_oracle_all_modes() {
        let dims = [60u64, 50, 40];
        let t = synth::uniform(&dims, 5_000, 1);
        let factors = random_factors(&dims, 8, 2);
        let eng = CooAtomicEngine::new(t.clone());
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 8);
            let c = Counters::new();
            eng.mttkrp(target, &factors, &mut out, 4, &c);
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
            let s = c.snapshot();
            assert_eq!(s.atomics, t.nnz() as u64 * 8);
            assert!(s.volume_bytes() > 0);
        }
    }

    #[test]
    fn four_mode() {
        let dims = [20u64, 16, 12, 8];
        let t = synth::uniform(&dims, 2_000, 3);
        let factors = random_factors(&dims, 4, 5);
        let eng = CooAtomicEngine::new(t.clone());
        for target in 0..4 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(dims[target] as usize, 4);
            eng.mttkrp(target, &factors, &mut out, 8, &Counters::new());
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
        }
    }

    #[test]
    fn contended_short_mode_is_exact() {
        // dims[0] = 2: all threads hammer two rows; CAS must not lose updates
        let dims = [2u64, 100, 100];
        let t = synth::uniform(&dims, 8_000, 9);
        let factors = random_factors(&dims, 16, 1);
        let eng = CooAtomicEngine::new(t.clone());
        let expect = mttkrp_oracle(&t, 0, &factors);
        let mut out = Matrix::zeros(2, 16);
        eng.mttkrp(0, &factors, &mut out, 16, &Counters::new());
        assert!(out.max_abs_diff(&expect) < 1e-8);
    }
}
