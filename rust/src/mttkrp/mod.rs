//! Parallel sparse MTTKRP engines — one per format — all implementing
//! [`Mttkrp`] against the same dense [`Matrix`](dense::Matrix) factors and
//! reporting exact traffic into [`Counters`](crate::device::Counters):
//!
//! * [`oracle`] — serial COO reference (the correctness anchor);
//! * [`coo`] — COO + global atomics (the naive massively-parallel baseline);
//! * [`genten`] — GenTen-style permutation + register accumulation;
//! * [`hicoo`] — HiCOO block-based engine (Li et al.);
//! * [`fcoo`] — F-COO segmented scan (Liu et al.);
//! * [`csf`] — CSF-N / B-CSF tree walks and the MM-CSF mixed-mode engine
//!   (Smith & Karypis; Nisa et al.);
//! * [`blco`] — the paper's unified mode-agnostic algorithm with
//!   register-based and hierarchical conflict resolution (Section 5).
//!
//! The BLCO engine's `Resolution::Auto` dispatch can additionally consult
//! statically computed conflict certificates ([`crate::analysis`]), and
//! its kernels expose a write-logging mode the race checker
//! ([`crate::analysis::racecheck`]) uses to verify those certificates
//! against real executions.

pub mod atomicf;
pub mod blco;
pub mod coo;
pub mod csf;
pub mod dense;
pub mod fcoo;
pub mod genten;
pub mod hicoo;
pub mod oracle;

use crate::device::Counters;
use dense::Matrix;

/// Reuse window for the measured gather-locality split: row fetches that
/// repeat within this many consecutive non-zeros are charged as
/// cache-resident. One size for every engine so layouts compete fairly;
/// 256 ≈ the footprint a warp's tile keeps live in L1/L2.
pub const LOCALITY_WINDOW: usize = 256;

/// Split a chunk's factor-row fetches into cold (distinct rows → HBM
/// gathers) and cache-resident repeats (→ local-class traffic), counted in
/// [`LOCALITY_WINDOW`]-sized windows.
///
/// This is *measured*, per chunk, per mode: `rows` is scratch space whose
/// first `len` entries hold the chunk's row ids for one mode (clobbered by
/// per-window sorting). Returns `(distinct, repeats)`. The space-filling
/// BLCO order clusters coordinates in every mode at once, so its tiles see
/// far more repeats than target-sorted or unsorted layouts — the
/// data-locality mechanism the paper credits for BLCO's throughput edge.
#[inline]
pub(crate) fn split_cold_hot(rows: &mut [u32]) -> (u64, u64) {
    let len = rows.len();
    let (mut distinct, mut repeats) = (0u64, 0u64);
    let mut lo = 0usize;
    while lo < len {
        let hi = (lo + LOCALITY_WINDOW).min(len);
        let w = &mut rows[lo..hi];
        w.sort_unstable();
        let mut d = 1u64;
        for i in 1..w.len() {
            if w[i] != w[i - 1] {
                d += 1;
            }
        }
        distinct += d;
        repeats += w.len() as u64 - d;
        lo = hi;
    }
    (distinct, repeats)
}

#[cfg(test)]
mod tests {
    use super::split_cold_hot;

    #[test]
    fn all_distinct() {
        let mut v: Vec<u32> = (0..100).collect();
        assert_eq!(split_cold_hot(&mut v), (100, 0));
    }

    #[test]
    fn all_same() {
        let mut v = vec![7u32; 50];
        assert_eq!(split_cold_hot(&mut v), (1, 49));
    }

    #[test]
    fn windowed_counting() {
        // the same row in two different windows is cold twice
        let mut v = vec![3u32; 512];
        assert_eq!(split_cold_hot(&mut v), (2, 510));
    }

    #[test]
    fn empty() {
        let mut v: Vec<u32> = vec![];
        assert_eq!(split_cold_hot(&mut v), (0, 0));
    }
}

/// Maximum decomposition rank supported by the stack-allocated register
/// accumulators in the hot loops.
pub const MAX_RANK: usize = 64;

/// A parallel mode-`target` MTTKRP engine over some tensor format.
pub trait Mttkrp {
    /// Engine name for reports (e.g. `"blco-reg"`).
    fn name(&self) -> String;

    /// Compute `out = X_(target) ⨀ (⊙ factors[n != target])`, overwriting
    /// `out` (shape `dims[target] × rank`). Traffic is accumulated into
    /// `counters`.
    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    );

    /// Like [`Mttkrp::mttkrp`], additionally reporting which execution
    /// path served the call. Single-path engines keep this default (run
    /// and report nothing); the routing facade
    /// ([`MttkrpEngine`](crate::coordinator::engine::MttkrpEngine))
    /// overrides it so drivers like CP-ALS can trace per-mode paths.
    fn mttkrp_traced(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) -> Option<crate::coordinator::engine::ExecPath> {
        self.mttkrp(target, factors, out, threads, counters);
        None
    }

    /// Streaming-schedule cache statistics (out-of-memory plans built vs
    /// reused). Engines without a schedule cache report zeros.
    fn schedule_stats(&self) -> crate::coordinator::schedule::ScheduleStats {
        crate::coordinator::schedule::ScheduleStats::default()
    }
}

/// Validate common preconditions shared by all engines. Panics (with a
/// message naming the violated contract) on: target out of range, missing
/// or extra factor matrices, rank over [`MAX_RANK`], per-factor row/column
/// mismatches, and wrongly shaped outputs — the negative paths are pinned
/// by `shape_contract` tests below so they cannot silently regress.
pub(crate) fn check_shapes(
    dims: &[u64],
    target: usize,
    factors: &[Matrix],
    out: &Matrix,
) -> usize {
    assert!(target < dims.len(), "target {target} out of range");
    assert_eq!(factors.len(), dims.len(), "one factor per mode");
    let rank = factors[0].cols;
    assert!(rank <= MAX_RANK, "rank {rank} > MAX_RANK {MAX_RANK}");
    for (n, f) in factors.iter().enumerate() {
        assert_eq!(f.rows as u64, dims[n], "factor {n} rows");
        assert_eq!(f.cols, rank, "factor {n} cols");
    }
    assert_eq!(out.rows as u64, dims[target], "out rows");
    assert_eq!(out.cols, rank, "out cols");
    rank
}

#[cfg(test)]
mod shape_contract {
    use super::*;

    const DIMS: [u64; 3] = [4, 3, 2];

    fn factors(rank: usize) -> Vec<Matrix> {
        DIMS.iter().map(|&d| Matrix::zeros(d as usize, rank)).collect()
    }

    #[test]
    fn well_formed_inputs_pass_and_return_rank() {
        let out = Matrix::zeros(3, 8);
        assert_eq!(check_shapes(&DIMS, 1, &factors(8), &out), 8);
        // the register-budget boundary itself is legal
        let out = Matrix::zeros(4, MAX_RANK);
        assert_eq!(check_shapes(&DIMS, 0, &factors(MAX_RANK), &out), MAX_RANK);
    }

    #[test]
    #[should_panic(expected = "target 3 out of range")]
    fn target_out_of_range() {
        let out = Matrix::zeros(2, 4);
        check_shapes(&DIMS, 3, &factors(4), &out);
    }

    #[test]
    #[should_panic(expected = "one factor per mode")]
    fn missing_factor() {
        let out = Matrix::zeros(4, 4);
        let two = factors(4)[..2].to_vec();
        check_shapes(&DIMS, 0, &two, &out);
    }

    #[test]
    #[should_panic(expected = "> MAX_RANK")]
    fn rank_over_register_budget() {
        let out = Matrix::zeros(4, MAX_RANK + 1);
        check_shapes(&DIMS, 0, &factors(MAX_RANK + 1), &out);
    }

    #[test]
    #[should_panic(expected = "factor 1 rows")]
    fn wrong_factor_rows() {
        let out = Matrix::zeros(4, 4);
        let mut f = factors(4);
        f[1] = Matrix::zeros(99, 4);
        check_shapes(&DIMS, 0, &f, &out);
    }

    #[test]
    #[should_panic(expected = "factor 2 cols")]
    fn mismatched_factor_cols() {
        let out = Matrix::zeros(4, 4);
        let mut f = factors(4);
        f[2] = Matrix::zeros(2, 5);
        check_shapes(&DIMS, 0, &f, &out);
    }

    #[test]
    #[should_panic(expected = "out rows")]
    fn wrong_output_rows() {
        let out = Matrix::zeros(1, 4);
        check_shapes(&DIMS, 0, &factors(4), &out);
    }

    #[test]
    #[should_panic(expected = "out cols")]
    fn wrong_output_cols() {
        let out = Matrix::zeros(4, 5);
        check_shapes(&DIMS, 0, &factors(4), &out);
    }

    #[test]
    #[should_panic(expected = "target 0 out of range")]
    fn engines_surface_the_contract() {
        // the panic reaches callers through a real engine entry point
        use crate::device::Counters;
        use crate::mttkrp::coo::CooAtomicEngine;
        use crate::tensor::coo::CooTensor;
        let t = CooTensor::new(&[]);
        let eng = CooAtomicEngine::new(t);
        let mut out = Matrix::zeros(0, 1);
        eng.mttkrp(0, &[], &mut out, 1, &Counters::new());
    }
}
