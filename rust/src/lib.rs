//! # BLCO — Blocked Linearized COOrdinate sparse tensors, out of memory
//!
//! A reproduction of *"Efficient, Out-of-Memory Sparse MTTKRP on Massively
//! Parallel Architectures"* (Nguyen et al., ICS '22) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: the BLCO format
//!   ([`format::blco`]), the unified mode-agnostic MTTKRP with hierarchical /
//!   register conflict resolution ([`mttkrp`]), the out-of-memory streaming
//!   orchestrator and its multi-device sharded generalization
//!   ([`coordinator`]), simulated accelerator profiles
//!   ([`device`]), a full CP-ALS driver ([`cpals`]) and a static conflict
//!   analyzer + instrumented race checker certifying synchronization-free
//!   schedules ([`analysis`]). Baseline formats the
//!   paper compares against (COO, F-COO, CSF, B-CSF, MM-CSF) are implemented
//!   from scratch in [`format`].
//! * **L2/L1 (build time, `python/`)** — the per-block MTTKRP compute graph
//!   and its Pallas kernel, AOT-lowered to HLO text and executed from the
//!   request path through the PJRT bridge in [`runtime`].
//! * **Serving ([`service`])** — a multi-tenant decomposition front end
//!   over shared tensor payloads: admission control on the engine's exact
//!   memory accounting, weighted-round-robin fair scheduling, and fused
//!   streaming of compatible jobs over one tensor copy.
//!
//! See `DESIGN.md` for the complete system inventory and the experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

// The kernels are written in the explicit index-loop style of the GPU code
// they model; these style lints fight that idiom (CI runs clippy with
// `-D warnings`, which keeps all correctness lints fatal).
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod cpals;
pub mod device;
pub mod error;
pub mod format;
pub mod linear;
pub mod mttkrp;
pub mod ops;
pub mod runtime;
pub mod service;
pub mod tensor;
pub mod util;

pub use analysis::conflict::{CertificateSet, ConflictCertificate, SyncClass};
pub use coordinator::engine::MttkrpEngine;
pub use coordinator::request::{StreamOutcome, StreamRequest};
pub use error::BlcoError;
pub use format::blco::BlcoTensor;
pub use format::store::{
    AppendSummary, BatchSource, BlcoStore, BlcoStoreReader, BlcoStoreWriter, Codec,
};
pub use tensor::coo::CooTensor;
pub use tensor::ooc::{BuildOptions, BuildStats};
