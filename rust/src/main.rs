//! `blco` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   convert   — build a BLCO tensor from a .tns file or preset, print stats
//!   mttkrp    — run mode-n (or all-mode) MTTKRP on a preset/file
//!   cpals     — run CP-ALS end to end, print the fit trace
//!   stream    — force the out-of-memory streaming path and report overlap
//!   datasets  — list the built-in scaled dataset presets
//!   runtime   — run the AOT/PJRT path on the demo preset (needs artifacts)
//!
//! Examples:
//!   blco mttkrp --tensor nell2 --rank 32 --device a100
//!   blco cpals --tensor uber --rank 16 --iters 10
//!   blco stream --tensor amazon --rank 32 --device a100

use anyhow::{bail, Context, Result};

use blco::bench::Table;
use blco::coordinator::cluster::cluster_mttkrp;
use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::cpals::CpAlsOptions;
use blco::device::model::throughput_tbps;
use blco::device::{LinkTopology, Profile};
use blco::format::blco::BlcoConfig;
use blco::mttkrp::oracle::random_factors;
use blco::tensor::{coo::CooTensor, datasets, io, stats};
use blco::util::cli::Args;
use blco::util::pool::default_threads;
use blco::util::timer::fmt_duration;

fn load_tensor(args: &Args) -> Result<CooTensor> {
    if let Some(path) = args.get("input") {
        return io::read_tns(std::path::Path::new(path), None);
    }
    let name = args.get_or("tensor", "demo3");
    let preset = datasets::by_name(name)
        .with_context(|| format!("unknown preset {name:?} (see `blco datasets`)"))?;
    eprintln!("building preset {name} ({} nnz requested)...", preset.nnz);
    Ok(preset.build())
}

fn profile(args: &Args) -> Result<Profile> {
    let name = args.get_or("device", "a100");
    let mut p = Profile::by_name(name)
        .with_context(|| format!("unknown device {name:?}"))?;
    p.devices = args.parse_or::<usize>("devices", 1).max(1);
    match args.get("links") {
        None => {}
        Some("shared") => p.links = LinkTopology::Shared,
        Some("dedicated") => p.links = LinkTopology::Dedicated,
        Some(other) => match other.parse::<usize>() {
            Ok(n) if n >= 1 => p.links = LinkTopology::Ports(n),
            _ => bail!("unknown link topology {other:?} (shared|dedicated|<n>)"),
        },
    }
    Ok(p)
}

fn cmd_datasets() -> Result<()> {
    let tbl = Table::new(&[10, 30, 12, 8, 6]);
    tbl.header(&["name", "dims", "nnz", "theta", "oom"]);
    for p in datasets::all() {
        tbl.row(&[
            p.name.to_string(),
            format!("{:?}", p.dims),
            p.nnz.to_string(),
            format!("{:.2}", p.theta),
            if p.oom { "yes" } else { "no" }.to_string(),
        ]);
    }
    println!("\nplus demo presets: demo3, demo4 (match the AOT artifact dims)");
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let b = blco::format::blco::BlcoTensor::from_coo(&t);
    println!("dims            {:?}", t.dims);
    println!("nnz             {}", t.nnz());
    println!("density         {:.3e}", t.density());
    println!("encoding bits   {}", b.spec.alto.total_bits);
    println!("in-block bits   {}", b.spec.total_inblock_bits);
    println!("key bits        {}", b.spec.total_key_bits);
    println!("blocks          {}", b.blocks.len());
    println!("batches         {}", b.batches.len());
    println!("footprint       {:.1} MiB", b.footprint_bytes() as f64 / (1 << 20) as f64);
    println!("construction:");
    for (name, d) in &b.stages.stages {
        println!("  {name:<10} {}", fmt_duration(*d));
    }
    for m in 0..t.order() {
        let fs = stats::fiber_stats(&t, m);
        println!(
            "mode {m}: len {}  fibers {} (avg {:.2} nnz, max {})",
            t.dims[m], fs.fibers, fs.avg_len, fs.max_len
        );
    }
    Ok(())
}

fn cmd_mttkrp(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let rank: usize = args.parse_or("rank", 32);
    let threads: usize = args.parse_or("threads", default_threads());
    let engine = MttkrpEngine::from_coo(&t, profile(args)?).with_threads(threads);
    let factors = random_factors(&t.dims, rank, 7);
    let modes: Vec<usize> = match args.get("mode") {
        Some(m) => vec![m.parse()?],
        None => (0..t.order()).collect(),
    };
    let tbl = Table::new(&[6, 14, 12, 12, 14, 12]);
    tbl.header(&["mode", "path", "wall", "model", "volume(GB)", "TP(TB/s)"]);
    for target in modes {
        engine.counters.reset();
        let w0 = std::time::Instant::now();
        let (_m, path) = engine.mttkrp(target, &factors);
        let wall = w0.elapsed();
        let snap = engine.counters.snapshot();
        let model =
            blco::device::model::device_time(&snap, &engine.eng.profile).total();
        let (path_s, model_s) = match &path {
            ExecPath::InMemory(r) => (format!("{r:?}"), model),
            ExecPath::Streamed(rep) => ("streamed".to_string(), rep.overall_s),
            ExecPath::Clustered(rep) => {
                (format!("cluster×{}", rep.devices), rep.overall_s)
            }
        };
        tbl.row(&[
            target.to_string(),
            path_s,
            fmt_duration(wall),
            format!("{:.3} ms", model_s * 1e3),
            format!("{:.3}", snap.volume_bytes() as f64 / 1e9),
            format!("{:.2}", throughput_tbps(snap.volume_bytes(), model_s)),
        ]);
    }
    Ok(())
}

fn cmd_cpals(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let opts = CpAlsOptions {
        rank: args.parse_or("rank", 16),
        max_iters: args.parse_or("iters", 20),
        tol: args.parse_or("tol", 1e-5),
        threads: args.parse_or("threads", default_threads()),
        seed: args.parse_or("seed", 0xCA1),
    };
    let engine = MttkrpEngine::from_coo(&t, profile(args)?).with_threads(opts.threads);
    let rep = engine.cp_als(opts);
    println!("iterations      {}", rep.iterations);
    println!("mttkrp time     {:.3} s", rep.mttkrp_seconds);
    println!("total time      {:.3} s", rep.total_seconds);
    println!("lambda          {:?}", &rep.lambda[..rep.lambda.len().min(8)]);
    for (i, f) in rep.fits.iter().enumerate() {
        println!("iter {:>3}: fit = {f:.6}", i + 1);
    }
    // ---- decompose report: per-mode routing + schedule-cache activity
    println!("\ndecompose:");
    println!(
        "  plans built     {} (reused {}x across {} iterations)",
        rep.schedule.built, rep.schedule.hits, rep.iterations
    );
    for (n, tr) in rep.mode_traces.iter().enumerate() {
        let last = tr.last.as_ref().map(ExecPath::summary).unwrap_or_else(|| "-".into());
        println!(
            "  mode {n}: in-memory {:>3} | streamed {:>3} | clustered {:>3} | last {last}",
            tr.in_memory, tr.streamed, tr.clustered
        );
    }
    if rep.stream.streamed_calls + rep.stream.clustered_calls > 0 {
        println!(
            "  OOM traffic     {:.1} MiB shipped (+{:.1} MiB merge), \
             transfer {:.3} s, overall(model) {:.3} s",
            rep.stream.bytes as f64 / (1 << 20) as f64,
            rep.stream.merge_bytes as f64 / (1 << 20) as f64,
            rep.stream.transfer_s,
            rep.stream.overall_s,
        );
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let rank: usize = args.parse_or("rank", 32);
    let threads: usize = args.parse_or("threads", default_threads());
    let p = profile(args)?;
    let engine = MttkrpEngine::from_coo_with(&t, p, BlcoConfig::default())
        .with_threads(threads);
    println!(
        "working set {:.1} MiB vs device {:.1} MiB → {}",
        engine.working_set_bytes(rank) as f64 / (1 << 20) as f64,
        engine.eng.profile.dev_mem_bytes as f64 / (1 << 20) as f64,
        if engine.is_oom(rank) { "OUT-OF-MEMORY" } else { "in-memory" }
    );
    // routing is mode-aware: short modes of an OOM tensor may still fit
    for mode in 0..t.order() {
        println!(
            "  mode {mode}: working set {:.1} MiB → {}",
            engine.working_set_bytes_for(mode, rank) as f64 / (1 << 20) as f64,
            if engine.is_oom_for(mode, rank) { "streams" } else { "in-memory" }
        );
    }
    let factors = random_factors(&t.dims, rank, 7);
    if engine.eng.profile.devices > 1 {
        println!(
            "cluster: {} devices, {} host link(s), peer {} GB/s",
            engine.eng.profile.devices,
            engine.eng.profile.host_links(),
            engine.eng.profile.peer_gbps,
        );
        for target in 0..t.order() {
            engine.counters.reset();
            let mut out =
                blco::mttkrp::dense::Matrix::zeros(t.dims[target] as usize, rank);
            let rep = cluster_mttkrp(
                &engine.eng,
                target,
                &factors,
                &mut out,
                threads,
                &engine.counters,
            );
            let vol = engine.counters.snapshot().volume_bytes();
            println!(
                "mode {target}: batches {:>4}  overall(model) {:.3} s  \
                 (stream {:.3} s + merge {:.3} s)  imbalance {:.3}  \
                 link busy {:.0}%  TP overall {:.2} TB/s",
                rep.batches.len(),
                rep.overall_s,
                rep.stream_s,
                rep.merge_s,
                rep.imbalance(),
                rep.link_occupancy(&engine.eng.profile) * 100.0,
                throughput_tbps(vol, rep.overall_s),
            );
            for (d, tl) in rep.per_device.iter().enumerate() {
                println!(
                    "    dev {d}: {:>4} batches  {:>7.1} MiB  busy {:.3} s  \
                     finish {:.3} s",
                    tl.batches.len(),
                    tl.bytes as f64 / (1 << 20) as f64,
                    tl.busy_s(),
                    tl.finish_s,
                );
            }
        }
        return Ok(());
    }
    for target in 0..t.order() {
        engine.counters.reset();
        let mut out =
            blco::mttkrp::dense::Matrix::zeros(t.dims[target] as usize, rank);
        let rep = blco::coordinator::streamer::stream_mttkrp(
            &engine.eng,
            target,
            &factors,
            &mut out,
            threads,
            &engine.counters,
        );
        let vol = engine.counters.snapshot().volume_bytes();
        println!(
            "mode {target}: batches {:>4}  wall {:>9}  overall(model) {:.3} s  \
             compute(model) {:.3} s  transfer {:.3} s  overlap-eff {:.2}  \
             TP overall {:.2} / in-mem {:.2} TB/s",
            rep.batches.len(),
            fmt_duration(std::time::Duration::from_secs_f64(rep.wall_s)),
            rep.overall_s,
            rep.compute_s,
            rep.transfer_s,
            rep.overlap_efficiency(),
            throughput_tbps(vol, rep.overall_s),
            throughput_tbps(vol, rep.compute_s),
        );
    }
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let rank: usize = args.parse_or("rank", 32);
    let dir = blco::runtime::artifacts::default_dir();
    let rt = blco::runtime::PjrtRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let b = blco::format::blco::BlcoTensor::from_coo(&t);
    let factors = random_factors(&t.dims, rank, 7);
    let counters = blco::device::Counters::new();
    let mut out = blco::mttkrp::dense::Matrix::zeros(t.dims[0] as usize, rank);
    let w0 = std::time::Instant::now();
    rt.mttkrp_fused(&b, 0, &factors, &mut out, &counters)?;
    println!(
        "mode-0 MTTKRP through AOT/PJRT: {} ({} launches)",
        fmt_duration(w0.elapsed()),
        counters.snapshot().launches
    );
    // verify against the rust oracle
    let expect = blco::mttkrp::oracle::mttkrp_oracle(&t, 0, &factors);
    let diff = out.max_abs_diff(&expect);
    println!("max |pjrt - oracle| = {diff:.3e} (f32 kernel vs f64 oracle)");
    if diff > 1e-2 {
        bail!("PJRT result diverges from oracle");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("datasets") => cmd_datasets(),
        Some("convert") => cmd_convert(&args),
        Some("mttkrp") => cmd_mttkrp(&args),
        Some("cpals") => cmd_cpals(&args),
        Some("stream") => cmd_stream(&args),
        Some("runtime") => cmd_runtime(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "usage: blco <datasets|convert|mttkrp|cpals|stream|runtime> \
                 [--tensor NAME | --input FILE] [--rank R] [--mode N] \
                 [--device a100|v100|intel_d1] [--devices D] \
                 [--links shared|dedicated|<n>] [--threads T]"
            );
            std::process::exit(2);
        }
    }
}
