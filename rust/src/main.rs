//! `blco` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   convert   — build a BLCO tensor from a .tns file, preset or synthetic
//!               shape; print stats, optionally write a `.blco` container
//!               (`--out`) and/or a `.tns` copy (`--tns-out`)
//!   inspect   — dump a `.blco` container's header (dims, blocks, batches,
//!               codecs, compression ratio, pending delta segments and
//!               read amplification); `--verify` scans every stored
//!               payload checksum
//!   append    — push new non-zeros onto an existing container as an
//!               LSM-style delta segment (no base rewrite)
//!   compact   — fold pending delta segments (and an optional `--codec`
//!               change) back into a single-base container, bit-for-bit
//!               what a from-scratch rebuild writes
//!   mttkrp    — run mode-n (or all-mode) MTTKRP on a preset/file
//!   cpals     — run CP-ALS end to end, print the fit trace
//!   stream    — force the out-of-memory streaming path and report overlap
//!   serve     — replay a synthetic mixed-tenant trace through the
//!               multi-tenant serving layer (admission, WRR fairness,
//!               fused streaming) and compare against the naive baseline
//!   analyze   — static conflict analysis per (mode, batch): row-overlap
//!               graphs, conflict-free wave partitions, NoSync/Privatize/
//!               Atomic certificates; `--check` verifies every certificate
//!               with the instrumented race checker and asserts
//!               `Resolution::Auto` routes through it bit-for-bit
//!   datasets  — list the built-in scaled dataset presets
//!   runtime   — run the AOT/PJRT path on the demo preset (needs artifacts)
//!
//! `stream`, `cpals` and `serve` accept `--from-store FILE.blco` to run
//! host-out-of-core: block payloads stay on disk and stream through a
//! cache bounded by the profile's host-memory budget (`--host-kib`
//! overrides it). `stream --from-store --check` hard-asserts the disk
//! path is bit-for-bit the resident path, that plans are reused, and
//! that cache residency never exceeded the budget.
//!
//! Examples:
//!   blco mttkrp --tensor nell2 --rank 32 --device a100
//!   blco cpals --tensor uber --rank 16 --iters 10
//!   blco stream --tensor amazon --rank 32 --device a100
//!   blco convert --dims 60x50x40 --nnz 6000 --seed 7 --codec delta-varint \
//!        --out /tmp/t.blco
//!   blco inspect --store /tmp/t.blco --verify
//!   blco append --store /tmp/t.blco --dims 60x50x40 --nnz 500 --seed 9
//!   blco compact --store /tmp/t.blco --codec shuffled
//!   blco stream --from-store /tmp/t.blco --rank 16 --host-kib 64 --check
//!   blco analyze --dims 150x130x170 --nnz 40000 --workgroup 64 --check

use anyhow::{bail, Context, Result};

use blco::bench::Table;
use blco::coordinator::engine::{ExecPath, MttkrpEngine};
use blco::cpals::CpAlsOptions;
use blco::device::model::throughput_tbps;
use blco::device::{LinkTopology, Profile};
use blco::format::blco::BlcoConfig;
use blco::mttkrp::oracle::random_factors;
use blco::service::{
    synthetic_trace, ArrivalProcess, JobKind, JobRequest, JobStatus, SchedPolicy,
    ServeRequest, ServiceReport, ShedPolicy, Tenant, TensorRegistry, TraceConfig,
};
use blco::tensor::{coo::CooTensor, datasets, io, stats, synth};
use blco::util::cli::Args;
use blco::util::pool::default_threads;
use blco::util::timer::fmt_duration;

fn load_tensor(args: &Args) -> Result<CooTensor> {
    if let Some(path) = args.get("input") {
        return io::read_tns(std::path::Path::new(path), None);
    }
    if let Some(spec) = args.get("dims") {
        // synthetic tensor: --dims 60x50x40 --nnz N [--seed S] [--theta θ]
        let dims: Vec<u64> = spec
            .split('x')
            .map(|d| d.parse().with_context(|| format!("bad --dims {spec:?}")))
            .collect::<Result<_>>()?;
        if dims.is_empty() || dims.iter().any(|&d| d == 0) {
            bail!("--dims needs non-zero extents like 60x50x40");
        }
        let nnz: usize = args.parse_or("nnz", 10_000);
        let seed: u64 = args.parse_or("seed", 7);
        let theta: f64 = args.parse_or("theta", 0.0);
        return Ok(if theta > 0.0 {
            synth::fiber_clustered(&dims, nnz, dims.len() - 1, theta, seed)
        } else {
            synth::uniform(&dims, nnz, seed)
        });
    }
    let name = args.get_or("tensor", "demo3");
    let preset = datasets::by_name(name)
        .with_context(|| format!("unknown preset {name:?} (see `blco datasets`)"))?;
    eprintln!("building preset {name} ({} nnz requested)...", preset.nnz);
    Ok(preset.build())
}

fn profile(args: &Args) -> Result<Profile> {
    let name = args.get_or("device", "a100");
    let mut p = Profile::by_name(name)
        .with_context(|| format!("unknown device {name:?}"))?;
    p.devices = args.parse_or::<usize>("devices", 1).max(1);
    if let Some(m) = args.get("mem-kib") {
        let kib: usize = m.parse().with_context(|| format!("bad --mem-kib {m:?}"))?;
        if kib == 0 {
            bail!("--mem-kib must be > 0");
        }
        p.dev_mem_bytes = kib << 10;
    }
    if let Some(h) = args.get("host-kib") {
        let kib: usize = h.parse().with_context(|| format!("bad --host-kib {h:?}"))?;
        if kib == 0 {
            bail!("--host-kib must be > 0");
        }
        p.host_mem_bytes = kib << 10;
    }
    match args.get("links") {
        None => {}
        Some("shared") => p.links = LinkTopology::Shared,
        Some("dedicated") => p.links = LinkTopology::Dedicated,
        Some(other) => match other.parse::<usize>() {
            Ok(n) if n >= 1 => p.links = LinkTopology::Ports(n),
            _ => bail!("unknown link topology {other:?} (shared|dedicated|<n>)"),
        },
    }
    Ok(p)
}

/// `--codec none|delta-varint|shuffled`; `None` when the flag is absent so
/// callers can distinguish "keep the container's codec" from an explicit
/// choice. Every codec round-trips exact bits — this only trades disk
/// bytes for encode/decode time.
fn parse_codec(args: &Args) -> Result<Option<blco::Codec>> {
    args.get("codec")
        .map(|s| {
            blco::Codec::parse(s).with_context(|| {
                format!("unknown --codec {s:?} (none|delta-varint|shuffled)")
            })
        })
        .transpose()
}

fn cmd_datasets() -> Result<()> {
    let tbl = Table::new(&[10, 30, 12, 8, 6]);
    tbl.header(&["name", "dims", "nnz", "theta", "oom"]);
    for p in datasets::all() {
        tbl.row(&[
            p.name.to_string(),
            format!("{:?}", p.dims),
            p.nnz.to_string(),
            format!("{:.2}", p.theta),
            if p.oom { "yes" } else { "no" }.to_string(),
        ]);
    }
    println!("\nplus demo presets: demo3, demo4 (match the AOT artifact dims)");
    Ok(())
}

/// `convert --stream`: external-memory construction. The tensor goes
/// chunk → sorted run → k-way merge → `.blco` without ever being resident,
/// and the build's peak accounted memory is asserted against
/// `--build-mem-kib` when given. The container is bit-for-bit what the
/// in-memory path writes.
fn cmd_convert_stream(args: &Args) -> Result<()> {
    use blco::tensor::ooc;
    use blco::util::pool::ExecBackend;

    let out = args
        .get("out")
        .with_context(|| "convert --stream needs --out FILE.blco")?;
    if args.parse_or::<f64>("theta", 0.0) > 0.0 {
        bail!(
            "--stream only supports uniform synthetic tensors (the \
             fiber-clustered generator has no streaming form); drop --theta \
             or drop --stream"
        );
    }
    let defaults = BlcoConfig::default();
    let threads: usize = args.parse_or("threads", default_threads());
    let opts = ooc::BuildOptions {
        config: BlcoConfig {
            max_block_nnz: args.parse_or("max-block-nnz", defaults.max_block_nnz),
            workgroup: args.parse_or("workgroup", defaults.workgroup),
            threads,
            ..defaults
        },
        backend: ExecBackend::from_threads(threads),
        mem_budget_bytes: args
            .get("build-mem-kib")
            .map(|k| -> Result<usize> {
                let kib: usize =
                    k.parse().with_context(|| format!("bad --build-mem-kib {k:?}"))?;
                if kib == 0 {
                    bail!("--build-mem-kib must be > 0");
                }
                Ok(kib << 10)
            })
            .transpose()?,
        chunk_nnz: args
            .get("chunk-nnz")
            .map(|c| c.parse().with_context(|| format!("bad --chunk-nnz {c:?}")))
            .transpose()?,
        tmp_dir: None,
        codec: parse_codec(args)?.unwrap_or_default(),
    };
    let path = std::path::Path::new(out);
    let (summary, stats) = if let Some(input) = args.get("input") {
        let dims: Option<Vec<u64>> = args
            .get("dims")
            .map(|spec| {
                spec.split('x')
                    .map(|d| d.parse().with_context(|| format!("bad --dims {spec:?}")))
                    .collect::<Result<Vec<u64>>>()
            })
            .transpose()?;
        ooc::build_from_tns(std::path::Path::new(input), dims.as_deref(), path, &opts)?
    } else if let Some(spec) = args.get("dims") {
        let dims: Vec<u64> = spec
            .split('x')
            .map(|d| d.parse().with_context(|| format!("bad --dims {spec:?}")))
            .collect::<Result<_>>()?;
        if dims.is_empty() || dims.iter().any(|&d| d == 0) {
            bail!("--dims needs non-zero extents like 60x50x40");
        }
        let nnz: usize = args.parse_or("nnz", 10_000);
        let seed: u64 = args.parse_or("seed", 7);
        ooc::build_uniform(&dims, nnz, seed, path, &opts)?
    } else {
        bail!("convert --stream needs --input FILE.tns or --dims AxBxC --nnz N");
    };

    println!("streamed build   {out}");
    println!("entries          {}", stats.entries);
    println!(
        "chunks/runs      {} x {} nnz (spilled {:.1} MiB)",
        stats.runs,
        stats.chunk_nnz,
        stats.spill_bytes as f64 / (1 << 20) as f64
    );
    println!(
        "merge window     {:.1} KiB per run, {} blocks out",
        stats.run_buf_bytes as f64 / 1024.0,
        stats.blocks
    );
    println!(
        "peak memory      {:.1} KiB of {:.1} KiB budget",
        stats.peak_bytes as f64 / 1024.0,
        stats.budget_bytes as f64 / 1024.0
    );
    if stats.infer_s > 0.0 {
        println!("  infer          {:.3} s (dims pre-pass)", stats.infer_s);
    }
    println!("  spill          {:.3} s", stats.spill_s);
    println!("  merge          {:.3} s", stats.merge_s);
    println!("throughput       {:.2} Mnnz/s", stats.mnnz_per_s());
    println!(
        "wrote container  {} ({:.1} MiB: {} B header + {:.1} MiB stored payload, \
         {} blocks / {} batches)",
        out,
        summary.file_bytes as f64 / (1 << 20) as f64,
        summary.header_bytes,
        summary.stored_bytes as f64 / (1 << 20) as f64,
        summary.blocks,
        summary.batches,
    );
    println!(
        "codec            {} ({:.1} MiB raw -> {:.2}x compression)",
        summary.codec.name(),
        summary.payload_bytes as f64 / (1 << 20) as f64,
        summary.payload_bytes as f64 / summary.stored_bytes.max(1) as f64,
    );
    if stats.peak_bytes > stats.budget_bytes {
        bail!(
            "peak construction memory {} B exceeded the {} B budget",
            stats.peak_bytes,
            stats.budget_bytes
        );
    }
    // prove the header round-trips before anyone depends on the file
    let r = blco::BlcoStoreReader::open(path)?;
    if r.nnz() as u64 != stats.entries || r.num_blocks() != summary.blocks {
        bail!("container re-open disagrees with the streamed build");
    }
    println!("reopen check     OK (nnz/blocks match)");
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    if args.flag("stream") {
        return cmd_convert_stream(args);
    }
    let t = load_tensor(args)?;
    let defaults = BlcoConfig::default();
    let cfg = BlcoConfig {
        max_block_nnz: args.parse_or("max-block-nnz", defaults.max_block_nnz),
        workgroup: args.parse_or("workgroup", defaults.workgroup),
        ..defaults
    };
    let b = blco::format::blco::BlcoTensor::from_coo_with(&t, cfg);
    println!("dims            {:?}", t.dims);
    println!("nnz             {}", t.nnz());
    println!("density         {:.3e}", t.density());
    println!("encoding bits   {}", b.spec.alto.total_bits);
    println!("in-block bits   {}", b.spec.total_inblock_bits);
    println!("key bits        {}", b.spec.total_key_bits);
    println!("blocks          {}", b.blocks.len());
    println!("batches         {}", b.batches.len());
    println!("footprint       {:.1} MiB", b.footprint_bytes() as f64 / (1 << 20) as f64);
    println!("construction:");
    for (name, d) in &b.stages.stages {
        println!("  {name:<10} {}", fmt_duration(*d));
    }
    for m in 0..t.order() {
        let fs = stats::fiber_stats(&t, m);
        println!(
            "mode {m}: len {}  fibers {} (avg {:.2} nnz, max {})",
            t.dims[m], fs.fibers, fs.avg_len, fs.max_len
        );
    }
    if let Some(tns) = args.get("tns-out") {
        io::write_tns(std::path::Path::new(tns), &t)?;
        println!("wrote .tns       {tns}");
    }
    if let Some(out) = args.get("out") {
        let path = std::path::Path::new(out);
        let codec = parse_codec(args)?.unwrap_or_default();
        let summary = blco::BlcoStore::write_with(&b, path, codec)?;
        println!(
            "wrote container  {} ({:.1} MiB: {} B header + {:.1} MiB stored payload, \
             {} blocks / {} batches)",
            out,
            summary.file_bytes as f64 / (1 << 20) as f64,
            summary.header_bytes,
            summary.stored_bytes as f64 / (1 << 20) as f64,
            summary.blocks,
            summary.batches,
        );
        println!(
            "codec            {} ({:.1} MiB raw -> {:.2}x compression)",
            summary.codec.name(),
            summary.payload_bytes as f64 / (1 << 20) as f64,
            summary.payload_bytes as f64 / summary.stored_bytes.max(1) as f64,
        );
        // prove the header round-trips before anyone depends on the file
        let r = blco::BlcoStoreReader::open(path)?;
        if r.dims() != b.dims() || r.nnz() != b.nnz {
            bail!("container re-open disagrees with the written tensor");
        }
        println!("reopen check     OK (dims/nnz/batches match)");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .get("store")
        .or_else(|| args.positionals.first().map(|s| s.as_str()))
        .with_context(|| "inspect needs --store FILE.blco (or a positional path)")?;
    let r = blco::BlcoStoreReader::open(std::path::Path::new(path))?;
    println!("container       {path}");
    println!(
        "version         {}{}",
        r.version(),
        if r.version() < blco::format::store::STORE_VERSION {
            " (legacy, readable; convert rewrites as v2)"
        } else {
            ""
        }
    );
    println!("codec           {} (container default)", r.default_codec().name());
    println!("dims            {:?}", r.dims());
    println!("order           {}", r.order());
    println!("nnz             {}", r.nnz());
    println!("norm            {:.6e}", r.norm());
    println!(
        "blocks          {} ({} base + {} appended)",
        r.num_blocks(),
        r.base_blocks(),
        r.num_blocks() - r.base_blocks()
    );
    println!("batches         {}", r.batches().len());
    println!(
        "payload         {:.1} MiB raw -> {:.1} MiB stored ({:.2}x compression)",
        r.raw_payload_bytes() as f64 / (1 << 20) as f64,
        r.stored_payload_bytes() as f64 / (1 << 20) as f64,
        r.compression_ratio()
    );
    println!(
        "segments        {} pending delta segment(s), read amplification {:.1}",
        r.segments(),
        r.read_amplification()
    );
    println!(
        "footprint       {:.1} MiB (streamed on-device bytes)",
        r.footprint_bytes() as f64 / (1 << 20) as f64
    );
    let cfg = r.config();
    println!(
        "config          max_block_nnz {}  workgroup {}  in-block bits {}",
        cfg.max_block_nnz, cfg.workgroup, cfg.inblock_budget
    );
    let show: usize = args.parse_or("blocks", 8);
    if show > 0 {
        let tbl = Table::new(&[8, 18, 10, 12, 14, 12, 12]);
        tbl.header(&["block", "key", "nnz", "bytes", "codec", "stored", "crc32"]);
        for i in 0..r.num_blocks().min(show) {
            let m = r.block_meta(i);
            tbl.row(&[
                i.to_string(),
                format!("{:#x}", m.key),
                m.nnz.to_string(),
                m.bytes.to_string(),
                m.codec.name().to_string(),
                m.stored_len.to_string(),
                format!("{:#010x}", m.crc),
            ]);
        }
        if r.num_blocks() > show {
            println!("  ... {} more (pass --blocks N)", r.num_blocks() - show);
        }
    }
    if args.flag("verify") {
        let scanned = r.verify_payloads()?;
        println!(
            "verify          OK ({:.1} MiB of stored payload checksums)",
            scanned as f64 / (1 << 20) as f64
        );
    }
    Ok(())
}

/// `append --store FILE.blco [tensor spec]`: push new non-zeros onto an
/// existing container as an LSM-style delta segment — the base is never
/// rewritten. Reads immediately answer over base + deltas; `compact`
/// folds them back into a single base when read amplification matters.
fn cmd_append(args: &Args) -> Result<()> {
    let path = args
        .get("store")
        .or_else(|| args.positionals.first().map(|s| s.as_str()))
        .with_context(|| "append needs --store FILE.blco (or a positional path)")?;
    let t = load_tensor(args)?;
    let codec = parse_codec(args)?;
    let sum =
        blco::BlcoStoreWriter::append(std::path::Path::new(path), &t, codec)?;
    println!("appended         {} nnz -> {}", sum.appended_nnz, path);
    println!(
        "segment          {} blocks, {:.1} KiB",
        sum.blocks,
        sum.segment_bytes as f64 / 1024.0
    );
    let r = blco::BlcoStoreReader::open(std::path::Path::new(path))?;
    println!(
        "pending          {} delta segment(s), read amplification {:.1} \
         (`blco compact` folds them)",
        r.segments(),
        r.read_amplification()
    );
    println!("total nnz        {}", r.nnz());
    Ok(())
}

/// `compact --store FILE.blco [--codec NAME]`: fold pending delta
/// segments (and an optional codec change) into a fresh single-base
/// container through the external-memory build pipeline, atomically
/// renamed over the original — byte-identical to a from-scratch rebuild
/// over the concatenated non-zeros.
fn cmd_compact(args: &Args) -> Result<()> {
    use blco::tensor::ooc;
    use blco::util::pool::ExecBackend;

    let path = args
        .get("store")
        .or_else(|| args.positionals.first().map(|s| s.as_str()))
        .with_context(|| "compact needs --store FILE.blco (or a positional path)")?;
    let path = std::path::Path::new(path);
    let (segments_before, ratio_before) = {
        let r = blco::BlcoStoreReader::open(path)?;
        (r.segments(), r.compression_ratio())
    };
    let threads: usize = args.parse_or("threads", default_threads());
    let budget = args
        .get("build-mem-kib")
        .map(|k| -> Result<usize> {
            let kib: usize =
                k.parse().with_context(|| format!("bad --build-mem-kib {k:?}"))?;
            if kib == 0 {
                bail!("--build-mem-kib must be > 0");
            }
            Ok(kib << 10)
        })
        .transpose()?;
    let (summary, stats) = ooc::compact(
        path,
        parse_codec(args)?,
        ExecBackend::from_threads(threads),
        budget,
    )?;
    println!(
        "compacted        {} ({} segment(s) folded into the base)",
        path.display(),
        segments_before
    );
    println!(
        "replayed         {} nnz through {} chunk(s), peak {:.1} KiB of \
         {:.1} KiB budget",
        stats.entries,
        stats.runs,
        stats.peak_bytes as f64 / 1024.0,
        stats.budget_bytes as f64 / 1024.0
    );
    let r = blco::BlcoStoreReader::open(path)?;
    println!(
        "container        {:.1} MiB stored, {} codec, {:.2}x -> {:.2}x \
         compression, read amplification {:.1}",
        summary.stored_bytes as f64 / (1 << 20) as f64,
        summary.codec.name(),
        ratio_before,
        r.compression_ratio(),
        r.read_amplification()
    );
    Ok(())
}

fn cmd_mttkrp(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let rank: usize = args.parse_or("rank", 32);
    let threads: usize = args.parse_or("threads", default_threads());
    let engine = MttkrpEngine::from_coo(&t, profile(args)?).with_threads(threads);
    let factors = random_factors(&t.dims, rank, 7);
    let modes: Vec<usize> = match args.get("mode") {
        Some(m) => vec![m.parse()?],
        None => (0..t.order()).collect(),
    };
    let tbl = Table::new(&[6, 14, 12, 12, 14, 12]);
    tbl.header(&["mode", "path", "wall", "model", "volume(GB)", "TP(TB/s)"]);
    for target in modes {
        engine.counters.reset();
        let w0 = std::time::Instant::now();
        let (_m, path) = engine.mttkrp(target, &factors);
        let wall = w0.elapsed();
        let snap = engine.counters.snapshot();
        let model =
            blco::device::model::device_time(&snap, &engine.eng.profile).total();
        let (path_s, model_s) = match &path {
            ExecPath::InMemory(r) => (format!("{r:?}"), model),
            ExecPath::Streamed(rep) => ("streamed".to_string(), rep.overall_s),
            ExecPath::Clustered(rep) => {
                (format!("cluster×{}", rep.devices), rep.overall_s)
            }
        };
        tbl.row(&[
            target.to_string(),
            path_s,
            fmt_duration(wall),
            format!("{:.3} ms", model_s * 1e3),
            format!("{:.3}", snap.volume_bytes() as f64 / 1e9),
            format!("{:.2}", throughput_tbps(snap.volume_bytes(), model_s)),
        ]);
    }
    Ok(())
}

fn cmd_cpals(args: &Args) -> Result<()> {
    let opts = CpAlsOptions {
        rank: args.parse_or("rank", 16),
        max_iters: args.parse_or("iters", 20),
        tol: args.parse_or("tol", 1e-5),
        threads: args.parse_or("threads", default_threads()),
        seed: args.parse_or("seed", 0xCA1),
    };
    let engine = if let Some(store) = args.get("from-store") {
        // host-out-of-core decomposition: the tensor streams from disk on
        // every iteration, bounded by the block cache
        MttkrpEngine::from_store(std::path::Path::new(store), profile(args)?)?
            .with_threads(opts.threads)
    } else {
        let t = load_tensor(args)?;
        MttkrpEngine::from_coo(&t, profile(args)?).with_threads(opts.threads)
    };
    let rep = engine.cp_als(opts);
    println!("iterations      {}", rep.iterations);
    println!("mttkrp time     {:.3} s", rep.mttkrp_seconds);
    println!("total time      {:.3} s", rep.total_seconds);
    println!("lambda          {:?}", &rep.lambda[..rep.lambda.len().min(8)]);
    for (i, f) in rep.fits.iter().enumerate() {
        println!("iter {:>3}: fit = {f:.6}", i + 1);
    }
    // ---- decompose report: per-mode routing + schedule-cache activity
    println!("\ndecompose:");
    println!(
        "  plans built     {} (reused {}x across {} iterations)",
        rep.schedule.built, rep.schedule.hits, rep.iterations
    );
    for (n, tr) in rep.mode_traces.iter().enumerate() {
        let last = tr.last.as_ref().map(ExecPath::summary).unwrap_or_else(|| "-".into());
        println!(
            "  mode {n}: in-memory {:>3} | streamed {:>3} | clustered {:>3} | last {last}",
            tr.in_memory, tr.streamed, tr.clustered
        );
    }
    if rep.stream.streamed_calls + rep.stream.clustered_calls > 0 {
        println!(
            "  OOM traffic     {:.1} MiB shipped (+{:.1} MiB merge), \
             transfer {:.3} s, overall(model) {:.3} s",
            rep.stream.bytes as f64 / (1 << 20) as f64,
            rep.stream.merge_bytes as f64 / (1 << 20) as f64,
            rep.stream.transfer_s,
            rep.stream.overall_s,
        );
    }
    if let Some(cache) = engine.host_cache_stats() {
        println!(
            "  host cache      {} hits / {} misses / {} evictions \
             (prefetch: {} hits, {} wasted), {:.1} MiB from disk, \
             peak {:.1} KiB of {:.1} KiB budget",
            cache.hits,
            cache.misses,
            cache.evictions,
            cache.prefetch_hits,
            cache.prefetch_wasted,
            cache.disk_bytes as f64 / (1 << 20) as f64,
            cache.peak_resident_bytes as f64 / 1024.0,
            cache.budget_bytes as f64 / 1024.0,
        );
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let rank: usize = args.parse_or("rank", 32);
    let threads: usize = args.parse_or("threads", default_threads());
    let p = profile(args)?;
    let engine = if let Some(store) = args.get("from-store") {
        let path = std::path::Path::new(store);
        let e = MttkrpEngine::from_store(path, p.clone())?.with_threads(threads);
        println!(
            "payload tier: DISK ({store}), block cache bounded at {:.1} KiB \
             of host memory",
            p.host_mem_bytes as f64 / 1024.0
        );
        e
    } else {
        let t = load_tensor(args)?;
        MttkrpEngine::from_coo_with(&t, p, BlcoConfig::default()).with_threads(threads)
    };
    let dims = engine.dims.clone();
    println!(
        "working set {:.1} MiB vs device {:.1} MiB → {}",
        engine.working_set_bytes(rank) as f64 / (1 << 20) as f64,
        engine.eng.profile.dev_mem_bytes as f64 / (1 << 20) as f64,
        if engine.is_oom(rank) { "OUT-OF-MEMORY" } else { "in-memory" }
    );
    // routing is mode-aware: short modes of an OOM tensor may still fit
    for mode in 0..dims.len() {
        println!(
            "  mode {mode}: working set {:.1} MiB → {}",
            engine.working_set_bytes_for(mode, rank) as f64 / (1 << 20) as f64,
            if engine.is_oom_for(mode, rank) { "streams" } else { "in-memory" }
        );
    }
    let factors = random_factors(&dims, rank, 7);
    if engine.eng.profile.devices > 1 {
        println!(
            "cluster: {} devices, {} host link(s), peer {} GB/s",
            engine.eng.profile.devices,
            engine.eng.profile.host_links(),
            engine.eng.profile.peer_gbps,
        );
        for target in 0..dims.len() {
            engine.counters.reset();
            let mut out =
                blco::mttkrp::dense::Matrix::zeros(dims[target] as usize, rank);
            let rep = blco::StreamRequest::new(&engine.eng, target)
                .job(&factors)
                .threads(threads)
                .counters(&engine.counters)
                .run(std::slice::from_mut(&mut out))?
                .into_clustered()
                .expect("multi-device profile shards");
            let vol = engine.counters.snapshot().volume_bytes();
            println!(
                "mode {target}: batches {:>4}  overall(model) {:.3} s  \
                 (stream {:.3} s + merge {:.3} s)  imbalance {:.3}  \
                 link busy {:.0}%  TP overall {:.2} TB/s",
                rep.batches.len(),
                rep.overall_s,
                rep.stream_s,
                rep.merge_s,
                rep.imbalance(),
                rep.link_occupancy(&engine.eng.profile) * 100.0,
                throughput_tbps(vol, rep.overall_s),
            );
            for (d, tl) in rep.per_device.iter().enumerate() {
                println!(
                    "    dev {d}: {:>4} batches  {:>7.1} MiB  busy {:.3} s  \
                     finish {:.3} s",
                    tl.batches.len(),
                    tl.bytes as f64 / (1 << 20) as f64,
                    tl.busy_s(),
                    tl.finish_s,
                );
            }
        }
        if args.flag("check") {
            check_store_parity(&engine, rank)?;
        }
        return Ok(());
    }
    for target in 0..dims.len() {
        engine.counters.reset();
        let mut out =
            blco::mttkrp::dense::Matrix::zeros(dims[target] as usize, rank);
        let rep = blco::StreamRequest::new(&engine.eng, target)
            .job(&factors)
            .devices(1)
            .threads(threads)
            .counters(&engine.counters)
            .run(std::slice::from_mut(&mut out))?
            .into_streamed()
            .expect("one device streams");
        let vol = engine.counters.snapshot().volume_bytes();
        println!(
            "mode {target}: batches {:>4}  wall {:>9}  overall(model) {:.3} s  \
             compute(model) {:.3} s  transfer {:.3} s  overlap-eff {:.2}  \
             TP overall {:.2} / in-mem {:.2} TB/s",
            rep.batches.len(),
            fmt_duration(std::time::Duration::from_secs_f64(rep.wall_s)),
            rep.overall_s,
            rep.compute_s,
            rep.transfer_s,
            rep.overlap_efficiency(),
            throughput_tbps(vol, rep.overall_s),
            throughput_tbps(vol, rep.compute_s),
        );
    }
    if args.flag("check") {
        check_store_parity(&engine, rank)?;
    }
    Ok(())
}

/// `stream --from-store --check`: hard CI assertions of the
/// host-out-of-core tier — the disk-streamed result must be bit-for-bit
/// the resident-path result on every mode (and, through the conflict
/// certificates, at every thread count), repeated streamed modes must
/// reuse their cached plan instead of replanning, the block cache must
/// never have held more than its host budget, and when the budget can
/// hold a batch of lookahead the prefetcher must have hidden disk I/O
/// behind compute.
fn check_store_parity(engine: &MttkrpEngine, rank: usize) -> Result<()> {
    use blco::mttkrp::Mttkrp;
    let reader = engine
        .source()
        .reader()
        .with_context(|| "--check needs --from-store (nothing to verify)")?;
    let store_path = reader.path().to_path_buf();
    // resident twin materialized from the very same container (a
    // cache-bypassing full read, so cache stats stay honest)
    let twin = MttkrpEngine::from_blco(
        std::sync::Arc::new(reader.to_tensor()?),
        engine.eng.profile.clone(),
    );
    let factors = random_factors(&engine.dims, rank, 7);
    let mut streamed = Vec::new();
    for mode in 0..engine.dims.len() {
        let rows = engine.dims[mode] as usize;
        let mut a = blco::mttkrp::dense::Matrix::zeros(rows, rank);
        let mut b = blco::mttkrp::dense::Matrix::zeros(rows, rank);
        // one thread on both tiers: a fully deterministic float-op order,
        // so the two executions must agree to the BIT, not a tolerance
        Mttkrp::mttkrp(engine, mode, &factors, &mut a, 1, &engine.counters);
        Mttkrp::mttkrp(&twin, mode, &factors, &mut b, 1, &twin.counters);
        let diverged =
            a.data.iter().zip(&b.data).any(|(x, y)| x.to_bits() != y.to_bits());
        if a.data.len() != b.data.len() || diverged {
            bail!("mode {mode}: disk-streamed result diverges from the resident path");
        }
        if engine.is_oom_for(mode, rank) {
            streamed.push(mode);
        }
    }
    // certified tier: with conflict certificates attached, BOTH tiers must
    // reproduce the sequential bits at every thread count — the waved /
    // copy-ownership schedules replay each row's flushes in a fixed order,
    // so parallelism cannot perturb even the last ulp
    let certified_disk =
        MttkrpEngine::from_store(&store_path, engine.eng.profile.clone())?
            .with_conflict_analysis();
    let certified_res =
        MttkrpEngine::from_blco(twin.tensor(), engine.eng.profile.clone())
            .with_conflict_analysis();
    let scratch = blco::device::Counters::new();
    for mode in 0..engine.dims.len() {
        let rows = engine.dims[mode] as usize;
        let res = certified_disk.eng.effective_resolution(mode);
        // reference: the pre-analyzer kernel pinned to the certified
        // strategy, one thread (the sequential float-op order)
        let pinned = MttkrpEngine::from_blco(twin.tensor(), engine.eng.profile.clone())
            .with_resolution(res);
        let mut want = blco::mttkrp::dense::Matrix::zeros(rows, rank);
        Mttkrp::mttkrp(&pinned, mode, &factors, &mut want, 1, &scratch);
        for nt in [1usize, 2, 4, 8] {
            let mut d = blco::mttkrp::dense::Matrix::zeros(rows, rank);
            let mut r = blco::mttkrp::dense::Matrix::zeros(rows, rank);
            Mttkrp::mttkrp(&certified_disk, mode, &factors, &mut d, nt, &scratch);
            Mttkrp::mttkrp(&certified_res, mode, &factors, &mut r, nt, &scratch);
            for (tier, got) in [("disk", &d), ("resident", &r)] {
                let ok = got.data.len() == want.data.len()
                    && got
                        .data
                        .iter()
                        .zip(&want.data)
                        .all(|(x, y)| x.to_bits() == y.to_bits());
                if !ok {
                    bail!(
                        "mode {mode}: certified {tier} run at {nt} threads \
                         diverges from the sequential {res:?} bits"
                    );
                }
            }
        }
    }
    if let Some(&mode) = streamed.first() {
        // a second pass over a streamed mode must hit the plan cache
        let before = engine.schedule_stats();
        let _ = engine.mttkrp(mode, &factors);
        let after = engine.schedule_stats();
        if after.hits <= before.hits || after.built != before.built {
            bail!(
                "expected schedule reuse on repeated mode {mode}: built \
                 {}->{}, hits {}->{}",
                before.built,
                after.built,
                before.hits,
                after.hits
            );
        }
    }
    let cache = engine.host_cache_stats().expect("disk-backed engine has a cache");
    if cache.peak_resident_bytes > cache.budget_bytes {
        bail!(
            "block cache exceeded its host budget: peak {} B > {} B",
            cache.peak_resident_bytes,
            cache.budget_bytes
        );
    }
    if cache.misses == 0 {
        bail!("expected disk reads through the block cache, saw none");
    }
    // prefetch observable: when the budget can hold the current batch plus
    // one batch of lookahead and something actually streamed, the prefetch
    // thread must have staged blocks that demand fetches then hit (a
    // tighter budget makes hits a race with eviction, so only the peak
    // bound is asserted there)
    let max_batch = (0..engine.source().num_batches())
        .map(|b| engine.source().batch_bytes(b))
        .max()
        .unwrap_or(0);
    if !streamed.is_empty() && cache.budget_bytes >= 2 * max_batch
        && cache.prefetch_hits == 0
    {
        bail!(
            "expected prefetch hits with budget {} B >= 2 x max batch {} B, \
             saw none",
            cache.budget_bytes,
            max_batch
        );
    }
    println!(
        "check: OK (bit-for-bit vs resident on {} modes + certified parity \
         at 1/2/4/8 threads, {} streamed, plan reuse, cache peak {:.1} KiB \
         <= budget {:.1} KiB, {} evictions, prefetch {} hits / {} wasted)",
        engine.dims.len(),
        streamed.len(),
        cache.peak_resident_bytes as f64 / 1024.0,
        cache.budget_bytes as f64 / 1024.0,
        cache.evictions,
        cache.prefetch_hits,
        cache.prefetch_wasted,
    );
    Ok(())
}

fn print_service_report(label: &str, tenants: &[Tenant], rep: &ServiceReport) {
    println!("\n[{label}] per-tenant:");
    let tbl = Table::new(&[10, 7, 5, 5, 5, 6, 5, 5, 11, 11, 11, 6]);
    tbl.header(&[
        "tenant", "weight", "jobs", "done", "rej", "fused", "shed", "miss", "mean lat",
        "p99 lat", "max lat", "maxQ",
    ]);
    for t in tenants {
        if let Some(s) = rep.per_tenant.get(&t.name) {
            tbl.row(&[
                t.name.clone(),
                s.weight.to_string(),
                s.submitted.to_string(),
                s.completed.to_string(),
                s.rejected.to_string(),
                s.fused.to_string(),
                s.shed.to_string(),
                format!("{}/{}", s.deadline_misses, s.deadline_jobs),
                format!("{:.2} ms", s.mean_latency_s * 1e3),
                format!("{:.2} ms", s.latency.p99 * 1e3),
                format!("{:.2} ms", s.max_latency_s * 1e3),
                s.max_queue_depth.to_string(),
            ]);
        }
    }
    println!(
        "[{label}] makespan {:.3} ms | {} devices | {} fused group(s) covering {} job(s) \
         | plans built {} reused {} (hit rate {:.0}%) | {:.1} MiB shipped | wall {:.0} ms",
        rep.makespan_s * 1e3,
        rep.devices,
        rep.fused_groups,
        rep.fused_jobs,
        rep.schedule.built,
        rep.schedule.hits,
        rep.cache_hit_rate() * 100.0,
        rep.bytes_shipped as f64 / (1 << 20) as f64,
        rep.wall_s * 1e3,
    );
    println!(
        "[{label}] latency p50/p95/p99 {:.2}/{:.2}/{:.2} ms | queue depth p50/p99/max \
         {:.0}/{:.0}/{:.0} | deadline misses {}/{} ({:.0}%) | {} shed",
        rep.latency.p50 * 1e3,
        rep.latency.p95 * 1e3,
        rep.latency.p99 * 1e3,
        rep.queue_depth.p50,
        rep.queue_depth.p99,
        rep.queue_depth.max,
        rep.deadline_misses,
        rep.deadline_jobs,
        rep.deadline_miss_rate() * 100.0,
        rep.shed_jobs,
    );
}

fn cmd_serve(args: &Args) -> Result<()> {
    let base = profile(args)?;
    let fleet = base.devices.max(1);
    let threads: usize = args.parse_or("threads", default_threads());
    // shrink device memory so the demo mixes one in-memory and one
    // streamed tensor without building multi-GB payloads
    let mem_kib: usize = args.parse_or("mem-kib", 4096);
    let reg_profile = base.with_memory(mem_kib << 10);

    eprintln!("building tensors ...");
    let hot = synth::uniform(&[200, 150, 100], 30_000, 11);
    let cold = synth::fiber_clustered(&[2_000, 1_200, 900], 400_000, 2, 0.7, 13);
    let mut reg = TensorRegistry::new(reg_profile.clone());
    reg.register("hot", &hot, BlcoConfig::default());
    reg.register(
        "cold",
        &cold,
        BlcoConfig { max_block_nnz: 1 << 15, ..Default::default() },
    );
    if let Some(store) = args.get("from-store") {
        // third tenant target living on disk: jobs against it stream
        // through the block cache instead of a resident payload
        reg.register_store("disk", std::path::Path::new(store))?;
        eprintln!("registered disk tensor from {store}");
    }
    println!(
        "registry: {} tensors, {:.1} MiB resident vs {:.1} MiB device memory",
        reg.len(),
        reg.resident_bytes() as f64 / (1 << 20) as f64,
        reg.profile().dev_mem_bytes as f64 / (1 << 20) as f64,
    );
    for name in reg.names() {
        let eng = &reg.get(&name).unwrap().engine;
        let rank = 16;
        let routes: Vec<String> = (0..eng.dims.len())
            .map(|m| {
                if eng.is_oom_for(m, rank) { "streamed".into() } else { "in-memory".into() }
            })
            .collect();
        println!("  {name}: dims {:?}, rank-{rank} routes {routes:?}", eng.dims);
    }

    let policy = match args.get_or("policy", "wrr") {
        "wrr" => SchedPolicy::Wrr,
        "edf" => SchedPolicy::Edf,
        "fifo" => SchedPolicy::Fifo,
        other => bail!("unknown --policy {other:?} (expected wrr|edf|fifo)"),
    };
    // open loop when an offered rate is given, legacy bursty replay
    // otherwise; --mmpp-burst adds calm/burst phase modulation on top
    let arrival = match args.get("rate-qps") {
        None => ArrivalProcess::Bursty,
        Some(r) => {
            let rate_qps: f64 =
                r.parse().map_err(|_| anyhow::anyhow!("bad --rate-qps {r:?}"))?;
            match args.get("mmpp-burst") {
                None => ArrivalProcess::Poisson { rate_qps },
                Some(b) => ArrivalProcess::Mmpp {
                    rate_qps,
                    burst: b.parse().map_err(|_| anyhow::anyhow!("bad --mmpp-burst {b:?}"))?,
                    mean_dwell_s: args.parse_or::<f64>("mmpp-dwell-ms", 1.0) * 1e-3,
                },
            }
        }
    };
    let deadline_s = match args.get("deadline-ms") {
        None => None,
        Some(v) => Some(
            v.parse::<f64>().map_err(|_| anyhow::anyhow!("bad --deadline-ms {v:?}"))? * 1e-3,
        ),
    };
    let shed = if args.flag("shed") {
        Some(ShedPolicy {
            wait_frac: args.parse_or("shed-wait-frac", 0.5),
            min_rank: args.parse_or("shed-min-rank", 4),
        })
    } else {
        None
    };
    let cfg = TraceConfig {
        tenants: args.parse_or("tenants", 3),
        jobs: args.parse_or("jobs", 30),
        mean_gap_s: args.parse_or::<f64>("gap-us", 50.0) * 1e-6,
        ranks: vec![16],
        cpals_every: args.parse_or("cpals-every", 12),
        arrival,
        deadline_s,
        seed: args.parse_or("seed", 0x5EB0),
    };
    let (tenants, jobs) = synthetic_trace(&reg, &cfg);
    println!(
        "\nreplaying {} jobs from {} tenants over a {}-device fleet ({} threads, \
         {policy:?} policy)",
        jobs.len(),
        tenants.len(),
        fleet,
        threads,
    );

    // full policy: chosen scheduler + fused streaming
    let mut req = ServeRequest::new(&reg)
        .trace(&tenants, &jobs)
        .policy(policy)
        .devices(fleet)
        .threads(threads);
    if let Some(s) = shed {
        req = req.shed(s);
    }
    let rep_b = req.run()?.into_report();
    print_service_report("batched", &tenants, &rep_b);

    // ablation baseline: one job at a time, global FIFO, on a fresh
    // registry sharing the same payload Arcs (fresh schedule caches)
    let mut reg_naive = TensorRegistry::new(reg_profile);
    for name in reg.names() {
        let engine = &reg.get(&name).unwrap().engine;
        match engine.try_tensor() {
            Some(t) => {
                reg_naive.register_shared(&name, t);
            }
            None => {
                let path = engine.source().reader().expect("disk entry").path();
                reg_naive.register_store(&name, path)?;
            }
        }
    }
    let rep_n = ServeRequest::new(&reg_naive)
        .trace(&tenants, &jobs)
        .policy(SchedPolicy::Fifo)
        .batching(false)
        .devices(fleet)
        .threads(threads)
        .run()?
        .into_report();
    print_service_report("naive FIFO", &tenants, &rep_n);

    println!(
        "\nbatched+fair vs naive: makespan {:.3} ms vs {:.3} ms ({:.2}x), \
         shipped {:.1} vs {:.1} MiB",
        rep_b.makespan_s * 1e3,
        rep_n.makespan_s * 1e3,
        rep_n.makespan_s / rep_b.makespan_s.max(1e-12),
        rep_b.bytes_shipped as f64 / (1 << 20) as f64,
        rep_n.bytes_shipped as f64 / (1 << 20) as f64,
    );

    if args.flag("check") {
        // the acceptance-criteria observables, hard-asserted for CI
        if rep_b.rejected() != 0 {
            bail!("expected zero rejections, got {}", rep_b.rejected());
        }
        if rep_b.schedule.hits == 0 {
            bail!("expected schedule-cache hits for repeated (tensor, mode, rank) jobs");
        }
        if rep_b.fused_groups == 0 {
            bail!("expected at least one fused streamed group");
        }
        if rep_b.makespan_s >= rep_n.makespan_s {
            bail!(
                "batched scheduling must beat the one-job-at-a-time baseline: \
                 {} vs {}",
                rep_b.makespan_s,
                rep_n.makespan_s
            );
        }

        // ---- open-loop SLO observables. Probe the modelled service time
        // of one streamed rank-16 job, then express every rate and
        // deadline in that unit so the checks are profile-independent.
        let probe_jobs = vec![JobRequest::new(
            0,
            "probe",
            "cold",
            JobKind::Mttkrp { target: 0, rank: 16, seed: 0xD0 },
            0.0,
        )];
        let probe = ServeRequest::new(&reg)
            .trace(&[], &probe_jobs)
            .threads(threads)
            .run()?
            .into_report();
        let d = probe.outcomes[0].duration_s;
        if !(d > 0.0 && d.is_finite()) {
            bail!("probe job has no modelled duration");
        }

        // sub-knee open loop: Poisson at 60% of one device's service rate
        // must keep the tail finite (above the knee it grows without bound)
        let slo_cfg = TraceConfig {
            tenants: 3,
            jobs: 24,
            ranks: vec![16],
            cpals_every: 0,
            arrival: ArrivalProcess::Poisson { rate_qps: 0.6 / d },
            deadline_s: Some(8.0 * d),
            seed: 0x510,
            ..Default::default()
        };
        let (slo_tenants, slo_jobs) = synthetic_trace(&reg, &slo_cfg);
        let sub_knee = ServeRequest::new(&reg)
            .trace(&slo_tenants, &slo_jobs)
            .devices(1)
            .threads(threads)
            .batching(false)
            .run()?
            .into_report();
        let p99 = sub_knee.p99_latency_s();
        if !(p99 > 0.0 && p99.is_finite()) {
            bail!("sub-knee p99 must be finite and positive, got {p99}");
        }

        // EDF vs WRR at equal throughput: 3 loose then 3 tight deadlines,
        // all at t=0 on one tenant and one device. FIFO-order WRR blows
        // every tight deadline; EDF serves them first and misses none.
        let edf_wrr_jobs: Vec<JobRequest> = (0..6)
            .map(|i| {
                JobRequest::new(
                    i,
                    "t0",
                    "cold",
                    JobKind::Mttkrp { target: 0, rank: 16, seed: 0xE0 + i as u64 },
                    0.0,
                )
                .with_deadline(if i < 3 { 100.0 * d } else { 3.5 * d })
            })
            .collect();
        let run_policy = |policy: SchedPolicy| -> Result<ServiceReport> {
            Ok(ServeRequest::new(&reg)
                .trace(&[], &edf_wrr_jobs)
                .policy(policy)
                .devices(1)
                .threads(threads)
                .batching(false)
                .run()?
                .into_report())
        };
        let wrr = run_policy(SchedPolicy::Wrr)?;
        let edf = run_policy(SchedPolicy::Edf)?;
        if edf.completed() != wrr.completed()
            || (edf.makespan_s - wrr.makespan_s).abs() > 1e-9
        {
            bail!("EDF and WRR must serve the same load at equal throughput");
        }
        if edf.deadline_miss_rate() > wrr.deadline_miss_rate() {
            bail!(
                "EDF deadline-miss rate {} must not exceed WRR's {}",
                edf.deadline_miss_rate(),
                wrr.deadline_miss_rate()
            );
        }
        if wrr.deadline_misses == 0 {
            bail!("scenario miscalibrated: WRR should miss the tight deadlines");
        }

        // overload + shedding: a t=0 backlog with tight SLOs sheds at
        // least one job to a coarser rank and still completes it
        let overload_jobs: Vec<JobRequest> = (0..6)
            .map(|i| {
                JobRequest::new(
                    i,
                    "t0",
                    "cold",
                    JobKind::Mttkrp { target: i % 3, rank: 16, seed: 0xF0 + i as u64 },
                    0.0,
                )
                .with_deadline(2.0 * d)
            })
            .collect();
        let overload = ServeRequest::new(&reg)
            .trace(&[], &overload_jobs)
            .devices(1)
            .threads(threads)
            .batching(false)
            .shed(ShedPolicy::default())
            .run()?
            .into_report();
        let shed_completed = overload
            .outcomes
            .iter()
            .filter(|o| o.shed && matches!(o.status, JobStatus::Completed))
            .count();
        if shed_completed == 0 {
            bail!("expected at least one job shed to a coarser rank at overload");
        }
        if overload.rejected() != 0 {
            bail!("shedding must degrade, not reject: {} rejections", overload.rejected());
        }

        println!(
            "check: OK (no rejections, cache hits, fusion, makespan win, finite \
             sub-knee p99, EDF misses {} <= WRR misses {}, {} shed-and-completed \
             at overload)",
            edf.deadline_misses, wrr.deadline_misses, shed_completed,
        );
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    use blco::analysis::racecheck::racecheck;
    use blco::mttkrp::blco::choose_resolution;
    use blco::mttkrp::Mttkrp;

    let rank: usize = args.parse_or("rank", 16);
    let threads: usize = args.parse_or("threads", default_threads());
    let p = profile(args)?;
    let engine = if let Some(store) = args.get("from-store") {
        println!("payload tier: DISK ({store})");
        MttkrpEngine::from_store(std::path::Path::new(store), p.clone())?
    } else {
        let t = load_tensor(args)?;
        let defaults = BlcoConfig::default();
        let cfg = BlcoConfig {
            max_block_nnz: args.parse_or("max-block-nnz", defaults.max_block_nnz),
            workgroup: args.parse_or("workgroup", defaults.workgroup),
            ..defaults
        };
        MttkrpEngine::from_coo_with(&t, p.clone(), cfg)
    };
    let a0 = std::time::Instant::now();
    let engine = engine.with_conflict_analysis().with_threads(threads);
    let certs = std::sync::Arc::clone(engine.certificates().expect("analysis ran"));
    println!(
        "analyzed {} modes: dims {:?}, {} nnz, {} blocks, {} batches, \
         workgroup {} ({})",
        certs.num_modes(),
        engine.dims,
        engine.eng.nnz(),
        certs.fingerprint.blocks,
        engine.eng.num_batches(),
        certs.fingerprint.workgroup,
        fmt_duration(a0.elapsed()),
    );

    let tbl = Table::new(&[6, 8, 7, 8, 9, 8, 7, 7, 18, 14, 14]);
    tbl.header(&[
        "mode", "batches", "wgs", "pairs", "density", "sharers", "fiber",
        "waves", "nosync/priv/atomic", "certified", "heuristic",
    ]);
    for m in 0..certs.num_modes() {
        let cert = certs.mode(m);
        let wgs: usize = cert.batches.iter().map(|b| b.wgs).sum();
        let max_density =
            cert.batches.iter().map(|b| b.density).fold(0.0f64, f64::max);
        let max_fiber =
            cert.blocks.iter().map(|b| b.max_fiber_degree).max().unwrap_or(0);
        let (ns, pv, at) = cert.sync_counts();
        tbl.row(&[
            m.to_string(),
            cert.batches.len().to_string(),
            wgs.to_string(),
            cert.conflict_pairs().to_string(),
            format!("{max_density:.3}"),
            cert.max_row_sharers().to_string(),
            max_fiber.to_string(),
            cert.max_waves().to_string(),
            format!("{ns}/{pv}/{at}"),
            format!("{:?}", cert.resolution()),
            format!("{:?}", choose_resolution(engine.dims[m], &engine.eng.profile)),
        ]);
    }

    if !args.flag("check") {
        return Ok(());
    }

    // --check: every certificate must survive the instrumented race
    // checker, at least one batch must be certified NoSync, and Auto must
    // route through the certificate bit-for-bit
    let factors = random_factors(&engine.dims, rank, 7);
    let mut records = 0usize;
    for m in 0..certs.num_modes() {
        let rep = racecheck(&engine.eng, certs.mode(m), &factors, threads);
        if !rep.races.is_empty() {
            bail!("mode {m}: {} unordered conflicting writes, e.g. {:?}",
                rep.races.len(), rep.races[0]);
        }
        if !rep.missed_static.is_empty() {
            bail!("mode {m}: analysis missed {} observed overlaps (unsound), \
                   e.g. {:?}", rep.missed_static.len(), rep.missed_static[0]);
        }
        if !rep.stale_static.is_empty() {
            bail!("mode {m}: {} certified edges never observed (imprecise), \
                   e.g. {:?}", rep.stale_static.len(), rep.stale_static[0]);
        }
        if !rep.bit_identical {
            bail!("mode {m}: waved run diverges from the sequential result");
        }
        records += rep.records;
    }
    let total_nosync: usize =
        (0..certs.num_modes()).map(|m| certs.mode(m).no_sync_batches()).sum();
    if total_nosync == 0 {
        bail!("no batch certified NoSync on any mode — the analyzer found \
               nothing synchronization-free to prove");
    }
    // Auto-through-certificate parity: the certified engine's Auto output
    // is bitwise the pre-analyzer kernel pinned to the certified strategy
    // (one thread on both: deterministic float-op order)
    let scratch = blco::device::Counters::new();
    for m in 0..certs.num_modes() {
        let res = engine.eng.effective_resolution(m);
        let twin = if engine.eng.resident().is_some() {
            engine.eng.share_with_profile(engine.eng.profile.clone())
        } else {
            let store = args.get("from-store").expect("disk engine came from a store");
            MttkrpEngine::from_store(std::path::Path::new(store), engine.eng.profile.clone())?
                .eng
        }
        .with_resolution(res);
        let rows = engine.dims[m] as usize;
        let mut a = blco::mttkrp::dense::Matrix::zeros(rows, rank);
        let mut b = blco::mttkrp::dense::Matrix::zeros(rows, rank);
        Mttkrp::mttkrp(&engine.eng, m, &factors, &mut a, 1, &scratch);
        Mttkrp::mttkrp(&twin, m, &factors, &mut b, 1, &scratch);
        let diverged =
            a.data.iter().zip(&b.data).any(|(x, y)| x.to_bits() != y.to_bits());
        if a.data.len() != b.data.len() || diverged {
            bail!("mode {m}: Auto-through-certificate diverges from the \
                   pre-analyzer path pinned to {res:?}");
        }
    }
    println!(
        "check: OK ({} modes race-checked, {} flushes logged, {} NoSync \
         batches confirmed, Auto routes bit-for-bit)",
        certs.num_modes(),
        records,
        total_nosync,
    );
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let t = load_tensor(args)?;
    let rank: usize = args.parse_or("rank", 32);
    let dir = blco::runtime::artifacts::default_dir();
    let rt = blco::runtime::PjrtRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let b = blco::format::blco::BlcoTensor::from_coo(&t);
    let factors = random_factors(&t.dims, rank, 7);
    let counters = blco::device::Counters::new();
    let mut out = blco::mttkrp::dense::Matrix::zeros(t.dims[0] as usize, rank);
    let w0 = std::time::Instant::now();
    rt.mttkrp_fused(&b, 0, &factors, &mut out, &counters)?;
    println!(
        "mode-0 MTTKRP through AOT/PJRT: {} ({} launches)",
        fmt_duration(w0.elapsed()),
        counters.snapshot().launches
    );
    // verify against the rust oracle
    let expect = blco::mttkrp::oracle::mttkrp_oracle(&t, 0, &factors);
    let diff = out.max_abs_diff(&expect);
    println!("max |pjrt - oracle| = {diff:.3e} (f32 kernel vs f64 oracle)");
    if diff > 1e-2 {
        bail!("PJRT result diverges from oracle");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("datasets") => cmd_datasets(),
        Some("convert") => cmd_convert(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("append") => cmd_append(&args),
        Some("compact") => cmd_compact(&args),
        Some("mttkrp") => cmd_mttkrp(&args),
        Some("cpals") => cmd_cpals(&args),
        Some("stream") => cmd_stream(&args),
        Some("serve") => cmd_serve(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("runtime") => cmd_runtime(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "usage: blco <datasets|convert|inspect|append|compact|mttkrp|cpals|stream|serve|analyze|runtime> \
                 [--tensor NAME | --input FILE | --dims AxBxC --nnz N] \
                 [--rank R] [--mode N] [--device a100|v100|intel_d1] \
                 [--devices D] [--links shared|dedicated|<n>] [--threads T]\n\
                 convert: [--out FILE.blco] [--tns-out FILE.tns] \
                 [--codec none|delta-varint|shuffled] \
                 [--max-block-nnz B] [--workgroup W] \
                 [--stream [--build-mem-kib K] [--chunk-nnz C]]\n\
                 inspect: --store FILE.blco [--blocks N] [--verify]\n\
                 append: --store FILE.blco [tensor spec] [--codec NAME]\n\
                 compact: --store FILE.blco [--codec NAME] [--build-mem-kib K]\n\
                 stream/cpals/serve/analyze: [--from-store FILE.blco] [--host-kib H]\n\
                 stream: [--check]   analyze: [--max-block-nnz B] [--workgroup W] [--check]\n\
                 serve: [--tenants N] [--jobs J] \
                 [--gap-us G] [--mem-kib M] [--cpals-every K] [--seed S] \
                 [--policy wrr|edf|fifo] [--rate-qps Q [--mmpp-burst B \
                 [--mmpp-dwell-ms MS]]] [--deadline-ms MS] \
                 [--shed [--shed-wait-frac F] [--shed-min-rank R]] [--check]"
            );
            std::process::exit(2);
        }
    }
}
