//! Structural statistics of sparse tensors: fiber densities (the quantity
//! MM-CSF partitions by) and per-mode slice histograms (the contention
//! predictor behind the paper's §5.3 adaptation heuristic).

use std::collections::HashMap;

use super::coo::CooTensor;

/// Statistics of the mode-`leaf` fibers (vectors obtained by fixing every
/// index except `leaf`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FiberStats {
    /// number of distinct non-empty fibers
    pub fibers: usize,
    /// max non-zeros in one fiber
    pub max_len: usize,
    /// mean non-zeros per non-empty fiber
    pub avg_len: f64,
}

/// Hash key of the fiber containing non-zero `e` for the given leaf mode.
pub fn fiber_key(t: &CooTensor, e: usize, leaf: usize) -> u128 {
    let mut key: u128 = 0;
    for n in 0..t.order() {
        if n == leaf {
            continue;
        }
        key = key
            .wrapping_mul(t.dims[n] as u128)
            .wrapping_add(t.coords[n][e] as u128);
    }
    key
}

/// Count non-zeros per mode-`leaf` fiber.
pub fn fiber_histogram(t: &CooTensor, leaf: usize) -> HashMap<u128, u32> {
    let mut h = HashMap::with_capacity(t.nnz());
    for e in 0..t.nnz() {
        *h.entry(fiber_key(t, e, leaf)).or_insert(0u32) += 1;
    }
    h
}

pub fn fiber_stats(t: &CooTensor, leaf: usize) -> FiberStats {
    let h = fiber_histogram(t, leaf);
    let fibers = h.len();
    let max_len = h.values().copied().max().unwrap_or(0) as usize;
    let avg_len = if fibers == 0 {
        0.0
    } else {
        t.nnz() as f64 / fibers as f64
    };
    FiberStats { fibers, max_len, avg_len }
}

/// Non-zeros per index along `mode` (slice histogram). `hist[i]` is the
/// number of updates row `i` of the mode-`mode` factor matrix receives
/// during mode-`mode` MTTKRP — i.e. the atomic-contention profile.
pub fn slice_histogram(t: &CooTensor, mode: usize) -> Vec<u64> {
    let mut hist = vec![0u64; t.dims[mode] as usize];
    for &c in &t.coords[mode] {
        hist[c as usize] += 1;
    }
    hist
}

/// Imbalance factor of a histogram: max/mean over non-empty entries.
pub fn imbalance(hist: &[u64]) -> f64 {
    let nz: Vec<u64> = hist.iter().copied().filter(|&x| x > 0).collect();
    if nz.is_empty() {
        return 0.0;
    }
    let max = *nz.iter().max().unwrap() as f64;
    let mean = nz.iter().sum::<u64>() as f64 / nz.len() as f64;
    max / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor() -> CooTensor {
        let mut t = CooTensor::new(&[3, 3, 3]);
        // two nnz share the mode-2 fiber (0,1,*); one separate
        t.push(&[0, 1, 0], 1.0);
        t.push(&[0, 1, 2], 2.0);
        t.push(&[2, 2, 2], 3.0);
        t
    }

    #[test]
    fn fiber_stats_counts_fibers() {
        let s = fiber_stats(&tensor(), 2);
        assert_eq!(s.fibers, 2);
        assert_eq!(s.max_len, 2);
        assert!((s.avg_len - 1.5).abs() < 1e-12);
    }

    #[test]
    fn fiber_stats_leaf_mode_matters() {
        let s0 = fiber_stats(&tensor(), 0);
        // fibers along mode 0: (1,0), (1,2), (2,2) — all distinct
        assert_eq!(s0.fibers, 3);
        assert_eq!(s0.max_len, 1);
    }

    #[test]
    fn slice_histogram_counts_updates() {
        let h = slice_histogram(&tensor(), 0);
        assert_eq!(h, vec![2, 0, 1]);
        let h1 = slice_histogram(&tensor(), 1);
        assert_eq!(h1, vec![0, 2, 1]);
    }

    #[test]
    fn imbalance_of_uniform_is_one() {
        assert!((imbalance(&[5, 5, 5]) - 1.0).abs() < 1e-12);
        assert!(imbalance(&[9, 1, 0, 2]) > 2.0);
        assert_eq!(imbalance(&[]), 0.0);
        assert_eq!(imbalance(&[0, 0]), 0.0);
    }

    #[test]
    fn empty_tensor_stats() {
        let t = CooTensor::new(&[4, 4]);
        let s = fiber_stats(&t, 0);
        assert_eq!(s.fibers, 0);
        assert_eq!(s.max_len, 0);
    }
}
