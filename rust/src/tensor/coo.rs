//! Coordinate (COO) sparse tensors — the raw interchange representation all
//! formats are constructed from (Figure 4a of the paper).

use anyhow::{bail, Result};

/// An N-order sparse tensor in coordinate form.
///
/// Indices are stored *mode-major* (`coords[n][e]` is the mode-`n` index of
/// non-zero `e`) so per-mode scans touch contiguous memory. Coordinates are
/// `u32` (every tensor in the paper's evaluation has mode lengths < 2^32);
/// mode lengths themselves are `u64` so encoding-line arithmetic never
/// overflows intermediate products.
#[derive(Clone, Debug, Default)]
pub struct CooTensor {
    pub dims: Vec<u64>,
    pub coords: Vec<Vec<u32>>,
    pub vals: Vec<f64>,
}

impl CooTensor {
    /// Empty tensor with the given mode lengths.
    pub fn new(dims: &[u64]) -> Self {
        CooTensor {
            dims: dims.to_vec(),
            coords: vec![Vec::new(); dims.len()],
            vals: Vec::new(),
        }
    }

    pub fn with_capacity(dims: &[u64], nnz: usize) -> Self {
        CooTensor {
            dims: dims.to_vec(),
            coords: vec![Vec::with_capacity(nnz); dims.len()],
            vals: Vec::with_capacity(nnz),
        }
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Fraction of occupied cells; 0 for degenerate shapes.
    pub fn density(&self) -> f64 {
        let cells: f64 = self.dims.iter().map(|&d| d as f64).product();
        if cells == 0.0 {
            0.0
        } else {
            self.nnz() as f64 / cells
        }
    }

    /// Append one non-zero. Debug-asserts bounds.
    #[inline]
    pub fn push(&mut self, coord: &[u32], val: f64) {
        debug_assert_eq!(coord.len(), self.order());
        for (n, &c) in coord.iter().enumerate() {
            debug_assert!((c as u64) < self.dims[n], "mode {n}: {c} >= {}", self.dims[n]);
            self.coords[n].push(c);
        }
        self.vals.push(val);
    }

    /// The coordinates of non-zero `e` as a fresh vector.
    pub fn coord(&self, e: usize) -> Vec<u32> {
        self.coords.iter().map(|m| m[e]).collect()
    }

    /// Full validation: plane lengths agree and all indices are in bounds.
    pub fn validate(&self) -> Result<()> {
        if self.coords.len() != self.dims.len() {
            bail!("{} coordinate planes for {} modes", self.coords.len(), self.dims.len());
        }
        for (n, plane) in self.coords.iter().enumerate() {
            if plane.len() != self.vals.len() {
                bail!("mode {n}: {} indices vs {} values", plane.len(), self.vals.len());
            }
            if let Some(&bad) = plane.iter().find(|&&c| c as u64 >= self.dims[n]) {
                bail!("mode {n}: index {bad} out of bounds {}", self.dims[n]);
            }
        }
        Ok(())
    }

    /// Reorder all non-zeros by `perm` (a permutation of `0..nnz`).
    pub fn permute(&mut self, perm: &[u32]) {
        debug_assert_eq!(perm.len(), self.nnz());
        for plane in &mut self.coords {
            let old = std::mem::take(plane);
            *plane = perm.iter().map(|&p| old[p as usize]).collect();
        }
        let old = std::mem::take(&mut self.vals);
        self.vals = perm.iter().map(|&p| old[p as usize]).collect();
    }

    /// Deduplicate identical coordinates by summing their values. Sorting is
    /// lexicographic over modes. Returns the number of merged duplicates.
    pub fn sum_duplicates(&mut self) -> usize {
        let nnz = self.nnz();
        if nnz == 0 {
            return 0;
        }
        let mut idx: Vec<u32> = (0..nnz as u32).collect();
        idx.sort_unstable_by(|&a, &b| {
            for plane in &self.coords {
                match plane[a as usize].cmp(&plane[b as usize]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        });
        let mut out = CooTensor::with_capacity(&self.dims, nnz);
        let mut merged = 0usize;
        for &e in &idx {
            let e = e as usize;
            let same = out.nnz() > 0
                && self
                    .coords
                    .iter()
                    .zip(&out.coords)
                    .all(|(p, q)| p[e] == *q.last().unwrap());
            if same {
                *out.vals.last_mut().unwrap() += self.vals[e];
                merged += 1;
            } else {
                let c = self.coord(e);
                out.push(&c, self.vals[e]);
            }
        }
        *self = out;
        merged
    }

    /// Bytes of a plain COO representation (paper accounting: one u64 value
    /// + N u32/u64 indices per non-zero). Uses u32 indices like this struct.
    pub fn footprint_bytes(&self) -> usize {
        self.nnz() * (8 + 4 * self.order())
    }

    /// Frobenius norm of the non-zero values.
    pub fn norm(&self) -> f64 {
        self.vals.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

/// A bounded slice of a COO stream: non-zeros `base .. base + len` of some
/// larger (possibly disk-resident) tensor, mode-major like [`CooTensor`].
/// This is the unit the chunked `.tns` parser
/// ([`crate::tensor::io::TnsChunks`]), the streamed synthetic generator
/// ([`crate::tensor::synth::UniformChunks`]) and the external-memory
/// builder ([`crate::tensor::ooc`]) exchange, so construction never holds
/// more than one chunk of coordinates at a time.
#[derive(Clone, Debug)]
pub struct CooChunk {
    /// global index of this chunk's first non-zero (source order)
    pub base: u64,
    /// mode-major coordinate planes, 0-based
    pub coords: Vec<Vec<u32>>,
    pub vals: Vec<f64>,
}

impl CooChunk {
    /// Empty chunk starting at global non-zero `base`, with capacity for
    /// `cap` entries per plane (pre-reserved so `push` never reallocates
    /// below the chunk budget — the builder's memory accounting relies on
    /// the capacity being fixed).
    pub fn with_capacity(order: usize, cap: usize, base: u64) -> Self {
        CooChunk {
            base,
            coords: vec![Vec::with_capacity(cap); order],
            vals: Vec::with_capacity(cap),
        }
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.coords.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Append one non-zero (coordinates already 0-based and validated).
    #[inline]
    pub fn push(&mut self, coord: &[u32], val: f64) {
        debug_assert_eq!(coord.len(), self.order());
        for (plane, &c) in self.coords.iter_mut().zip(coord) {
            plane.push(c);
        }
        self.vals.push(val);
    }

    /// Allocated bytes of the coordinate planes and values (by capacity,
    /// which is what actually sits in RAM).
    pub fn alloc_bytes(&self) -> usize {
        self.coords.iter().map(|p| p.capacity() * 4).sum::<usize>()
            + self.vals.capacity() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CooTensor {
        // the running example tensor of the paper (Figure 4a), 0-based
        let mut t = CooTensor::new(&[4, 4, 4]);
        let data: [([u32; 3], f64); 12] = [
            ([0, 0, 0], 1.0),
            ([0, 0, 1], 2.0),
            ([0, 2, 2], 3.0),
            ([1, 0, 1], 4.0),
            ([1, 0, 2], 5.0),
            ([2, 0, 1], 6.0),
            ([2, 3, 3], 7.0),
            ([3, 1, 0], 8.0),
            ([3, 1, 1], 9.0),
            ([3, 2, 2], 10.0),
            ([3, 2, 3], 11.0),
            ([3, 3, 3], 12.0),
        ];
        for (c, v) in data {
            t.push(&c, v);
        }
        t
    }

    #[test]
    fn basic_accessors() {
        let t = tiny();
        assert_eq!(t.order(), 3);
        assert_eq!(t.nnz(), 12);
        assert_eq!(t.coord(3), vec![1, 0, 1]);
        assert!((t.density() - 12.0 / 64.0).abs() < 1e-12);
        t.validate().unwrap();
    }

    #[test]
    fn validate_catches_out_of_bounds() {
        let mut t = tiny();
        t.coords[1][5] = 99;
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_ragged_planes() {
        let mut t = tiny();
        t.coords[0].pop();
        assert!(t.validate().is_err());
    }

    #[test]
    fn permute_roundtrip() {
        let mut t = tiny();
        let orig = t.clone();
        let perm: Vec<u32> = (0..t.nnz() as u32).rev().collect();
        t.permute(&perm);
        assert_eq!(t.vals[0], 12.0);
        t.permute(&perm);
        assert_eq!(t.vals, orig.vals);
        assert_eq!(t.coords, orig.coords);
    }

    #[test]
    fn sum_duplicates_merges() {
        let mut t = CooTensor::new(&[2, 2]);
        t.push(&[0, 1], 1.0);
        t.push(&[1, 1], 5.0);
        t.push(&[0, 1], 2.0);
        let merged = t.sum_duplicates();
        assert_eq!(merged, 1);
        assert_eq!(t.nnz(), 2);
        let e = (0..2).find(|&e| t.coord(e) == vec![0, 1]).unwrap();
        assert_eq!(t.vals[e], 3.0);
    }

    #[test]
    fn footprint_and_norm() {
        let t = tiny();
        assert_eq!(t.footprint_bytes(), 12 * (8 + 12));
        let expect: f64 = (1..=12).map(|v| (v * v) as f64).sum::<f64>().sqrt();
        assert!((t.norm() - expect).abs() < 1e-12);
    }
}
