//! Scaled synthetic stand-ins for the paper's 14 evaluation tensors
//! (Table 2), plus small demo presets matching the AOT artifact shapes.
//!
//! Mode-length ratios follow the paper; absolute sizes are scaled down
//! (~10–500×) so the full benchmark suite runs on one CPU in minutes. The
//! fiber-skew parameter θ encodes each dataset's character: high for
//! short-mode/dense-fiber tensors (Uber, Chicago, NELL-2), near zero for the
//! hypersparse low-fiber-density sets where the paper shows MM-CSF
//! degrading (DARPA, FB-M, Delicious). `oom` marks the three tensors the
//! paper can only process out-of-memory (Amazon, Patents, Reddit) — they
//! exceed the scaled device-memory budget of the simulated GPUs in
//! [`crate::device`].

use super::coo::CooTensor;
use super::synth;

/// A named synthetic dataset recipe.
#[derive(Clone, Debug)]
pub struct Preset {
    pub name: &'static str,
    pub dims: Vec<u64>,
    pub nnz: usize,
    /// leaf mode for fiber clustering
    pub leaf: usize,
    /// Zipf skew of fiber occupancy (0 = uniform)
    pub theta: f64,
    /// paper classifies this tensor as out-of-memory on the target GPUs
    pub oom: bool,
    pub seed: u64,
    /// bits the *original* (paper-scale) tensor's encoding line exceeds 64
    /// by — the scaled preset strips the same number of key bits so the
    /// adaptive-blocking path is exercised identically (DESIGN.md §3)
    pub orig_excess_bits: u32,
}

impl Preset {
    /// BLCO construction config for this preset: default, except that the
    /// in-block bit budget is tightened by `orig_excess_bits` so presets
    /// whose originals need >64-bit lines (Delicious, Flickr, NELL-1,
    /// Amazon, Reddit) still take the multi-key-block path.
    pub fn blco_config(&self) -> crate::format::blco::BlcoConfig {
        let total: u32 = self
            .dims
            .iter()
            .map(|&d| crate::util::bitops::mode_bits(d))
            .sum();
        let mut cfg = crate::format::blco::BlcoConfig::default();
        if self.orig_excess_bits > 0 {
            cfg.inblock_budget = cfg
                .inblock_budget
                .min(total.saturating_sub(self.orig_excess_bits).max(8));
        }
        cfg
    }

    pub fn build(&self) -> CooTensor {
        if self.theta <= 0.0 {
            synth::uniform(&self.dims, self.nnz, self.seed)
        } else {
            synth::fiber_clustered(&self.dims, self.nnz, self.leaf, self.theta, self.seed)
        }
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }
}

#[allow(clippy::too_many_arguments)]
fn p(
    name: &'static str,
    dims: &[u64],
    nnz: usize,
    leaf: usize,
    theta: f64,
    oom: bool,
    seed: u64,
) -> Preset {
    Preset { name, dims: dims.to_vec(), nnz, leaf, theta, oom, seed, orig_excess_bits: 0 }
}

fn px(mut pr: Preset, orig_excess_bits: u32) -> Preset {
    pr.orig_excess_bits = orig_excess_bits;
    pr
}

/// All presets, ordered by nnz like Table 2.
///
/// Sizing rules (DESIGN.md §3): every in-memory preset's rank-32 working
/// set (BLCO payload + factors + output) fits all three scaled device
/// profiles; every OOM preset exceeds all of them while its *factors* alone
/// still fit (the paper streams the tensor, never the factors).
pub fn all() -> Vec<Preset> {
    vec![
        // in-memory (Figure 8/9/11 suite)
        p("nips", &[625, 725, 3500, 17], 120_000, 2, 0.9, false, 101),
        p("uber", &[183, 24, 1100, 1700], 130_000, 3, 1.1, false, 102),
        p("chicago", &[6186, 24, 77, 32], 160_000, 0, 1.2, false, 103),
        p("vast", &[16540, 1140, 2], 220_000, 0, 0.7, false, 104),
        p("darpa", &[4506, 4506, 120_000], 240_000, 2, 0.05, false, 105),
        p("enron", &[1200, 1150, 48_000, 240], 300_000, 2, 0.8, false, 106),
        p("nell2", &[3030, 2295, 7210], 450_000, 2, 1.1, false, 107),
        p("fbm", &[120_000, 120_000, 166], 500_000, 2, 0.05, false, 108),
        px(p("flickr", &[10_000, 200_000, 40_000, 150], 550_000, 1, 0.3, false, 109), 11),
        px(p("delicious", &[12_000, 160_000, 40_000, 300], 600_000, 1, 0.1, false, 110), 14),
        px(p("nell1", &[40_000, 30_000, 160_000], 700_000, 2, 0.4, false, 111), 4),
        // out-of-memory on the scaled device profiles (Figure 10)
        px(p("amazon", &[120_000, 45_000, 45_000], 12_000_000, 2, 0.6, true, 112), 1),
        p("patents", &[46, 60_000, 60_000], 16_000_000, 2, 1.0, true, 113),
        px(p("reddit", &[100_000, 2_200, 100_000], 20_000_000, 2, 0.8, true, 114), 1),
    ]
}

/// The in-memory evaluation suite (Figures 1, 8, 9, 11, 12, Table 3).
pub fn in_memory() -> Vec<Preset> {
    all().into_iter().filter(|p| !p.oom).collect()
}

/// The out-of-memory suite (Figure 10).
pub fn out_of_memory() -> Vec<Preset> {
    all().into_iter().filter(|p| p.oom).collect()
}

/// Small demo presets whose padded dims match the AOT artifact variants
/// (`m3r32_*`: dims <= 1024; `m4r32_*`: dims <= (256,256,256,64)) so the
/// PJRT runtime path can execute them.
pub fn demo3() -> Preset {
    p("demo3", &[1000, 800, 600], 50_000, 2, 0.8, false, 201)
}

pub fn demo4() -> Preset {
    p("demo4", &[250, 250, 250, 60], 30_000, 2, 0.8, false, 202)
}

/// Look up any preset (paper suite + demos) by name.
pub fn by_name(name: &str) -> Option<Preset> {
    if name == "demo3" {
        return Some(demo3());
    }
    if name == "demo4" {
        return Some(demo4());
    }
    all().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linear::alto;

    #[test]
    fn suite_structure() {
        let a = all();
        assert_eq!(a.len(), 14);
        assert_eq!(a.iter().filter(|p| p.oom).count(), 3);
        // ordered by nnz like Table 2
        for w in a.windows(2) {
            assert!(w[0].nnz <= w[1].nnz);
        }
        // names unique
        let mut names: Vec<_> = a.iter().map(|p| p.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 14);
    }

    #[test]
    fn blocking_budget_mirrors_paper_excess() {
        // presets whose paper-scale originals exceed 64 encoding bits must
        // carry a tightened budget so the key-block path runs on them
        for name in ["delicious", "flickr", "nell1", "amazon", "reddit"] {
            let d = by_name(name).unwrap();
            assert!(d.orig_excess_bits > 0, "{name}");
            let cfg = d.blco_config();
            let total: u32 = d
                .dims
                .iter()
                .map(|&x| crate::util::bitops::mode_bits(x))
                .sum();
            assert!(cfg.inblock_budget < total, "{name}: no keys would be stripped");
            // the spec derived from the config really produces keys
            let spec = crate::linear::encode::BlcoSpec::with_budget(
                &d.dims,
                cfg.inblock_budget,
            );
            assert_eq!(spec.total_key_bits, d.orig_excess_bits, "{name}");
        }
        // presets within 64 bits keep the full budget
        let u = by_name("uber").unwrap();
        assert_eq!(
            u.blco_config().inblock_budget,
            crate::linear::encode::MAX_INBLOCK_BITS
        );
        let _ = alto::Encoding::new(&u.dims); // still encodable
    }

    #[test]
    fn demo_presets_fit_artifact_dims() {
        let d3 = demo3();
        assert!(d3.dims.iter().all(|&d| d <= 1024));
        let d4 = demo4();
        assert_eq!(d4.dims.len(), 4);
        assert!(d4.dims[0] <= 256 && d4.dims[3] <= 64);
    }

    #[test]
    fn by_name_roundtrip() {
        for pr in all() {
            assert_eq!(by_name(pr.name).unwrap().name, pr.name);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn small_presets_build() {
        for name in ["uber", "darpa", "demo3", "demo4"] {
            let pr = by_name(name).unwrap();
            let t = pr.build();
            t.validate().unwrap();
            assert!(
                t.nnz() as f64 >= pr.nnz as f64 * 0.5,
                "{name}: built {} of {}",
                t.nnz(),
                pr.nnz
            );
        }
    }
}
