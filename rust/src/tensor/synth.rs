//! Synthetic sparse tensor generators.
//!
//! The paper evaluates on FROSTT/HaTen2 tensors which are not redistributable
//! here (multi-GB downloads, up to 4.7B non-zeros). These generators
//! reproduce the *drivers* behind every effect the paper measures
//! (DESIGN.md §3): mode shape (→ atomics contention and the §5.3 heuristic),
//! fiber-density skew (→ MM-CSF compression quality) and total footprint
//! vs device memory (→ the out-of-memory path).

use std::collections::HashSet;

use super::coo::CooTensor;
use crate::util::prng::Rng;

/// Uniform random tensor: coordinates i.i.d. uniform per mode, values
/// standard normal. Duplicates are merged, so the resulting nnz can be
/// slightly below the request on dense shapes.
pub fn uniform(dims: &[u64], nnz: usize, seed: u64) -> CooTensor {
    let mut rng = Rng::new(seed);
    let mut t = CooTensor::with_capacity(dims, nnz);
    let mut seen = HashSet::with_capacity(nnz * 2);
    let cells: f64 = dims.iter().map(|&d| d as f64).product();
    let dedupe = (nnz as f64) / cells > 1e-4; // only worth it when collisions are likely
    let mut coord = vec![0u32; dims.len()];
    let mut attempts = 0usize;
    while t.nnz() < nnz && attempts < nnz * 4 {
        attempts += 1;
        for (n, &d) in dims.iter().enumerate() {
            coord[n] = rng.below(d) as u32;
        }
        if dedupe {
            let key = pack_coord(&coord, dims);
            if !seen.insert(key) {
                continue;
            }
        }
        t.push(&coord, rng.normal());
    }
    t
}

/// Fiber-clustered tensor: non-zeros are grouped into fibers along
/// `leaf_mode`, with the number of fibers and the per-fiber occupancy both
/// Zipf-skewed by `theta`. Large `theta` → few very dense fibers (the
/// NELL-2/Chicago regime where CSF-family compression shines); `theta ≈ 0`
/// → near-uniform, hypersparse fibers (the DARPA/FB-M regime where MM-CSF
/// degrades, Section 6.2).
pub fn fiber_clustered(
    dims: &[u64],
    nnz: usize,
    leaf_mode: usize,
    theta: f64,
    seed: u64,
) -> CooTensor {
    assert!(leaf_mode < dims.len());
    let mut rng = Rng::new(seed);
    // Pool of candidate fibers: random coordinates for every non-leaf mode.
    // Zipf over the pool concentrates non-zeros in the early (dense) fibers.
    let n_fibers = (nnz / 4).clamp(1, 1 << 20);
    let non_leaf: Vec<usize> =
        (0..dims.len()).filter(|&n| n != leaf_mode).collect();
    let mut pool: Vec<Vec<u32>> = Vec::with_capacity(n_fibers);
    for _ in 0..n_fibers {
        pool.push(non_leaf.iter().map(|&n| rng.below(dims[n]) as u32).collect());
    }

    let mut t = CooTensor::with_capacity(dims, nnz);
    let mut seen = HashSet::with_capacity(nnz * 2);
    let mut coord = vec![0u32; dims.len()];
    let mut attempts = 0usize;
    while t.nnz() < nnz && attempts < nnz * 6 {
        attempts += 1;
        let f = rng.zipf(n_fibers as u64, theta) as usize;
        for (k, &n) in non_leaf.iter().enumerate() {
            coord[n] = pool[f][k];
        }
        coord[leaf_mode] = rng.zipf(dims[leaf_mode], theta * 0.5) as u32;
        let key = pack_coord(&coord, dims);
        if !seen.insert(key) {
            continue;
        }
        t.push(&coord, rng.normal());
    }
    t
}

/// Pack coordinates into a u128 for dedup hashing (row-major).
fn pack_coord(coord: &[u32], dims: &[u64]) -> u128 {
    let mut key: u128 = 0;
    for (n, &c) in coord.iter().enumerate() {
        key = key.wrapping_mul(dims[n] as u128).wrapping_add(c as u128);
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats;

    #[test]
    fn uniform_shape_and_bounds() {
        let t = uniform(&[50, 40, 30], 5_000, 1);
        assert!(t.nnz() >= 4_500, "nnz {}", t.nnz());
        t.validate().unwrap();
    }

    #[test]
    fn uniform_is_deterministic() {
        let a = uniform(&[100, 100, 100], 1_000, 7);
        let b = uniform(&[100, 100, 100], 1_000, 7);
        assert_eq!(a.coords, b.coords);
        assert_eq!(a.vals, b.vals);
    }

    #[test]
    fn uniform_has_no_duplicates_when_dense() {
        let t = uniform(&[10, 10, 10], 500, 3);
        let mut keys: Vec<u128> = (0..t.nnz())
            .map(|e| pack_coord(&t.coord(e), &t.dims))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), t.nnz());
    }

    #[test]
    fn fiber_clustered_skews_density() {
        let dims = [200u64, 150, 100];
        let skewed = fiber_clustered(&dims, 8_000, 2, 1.3, 11);
        let flat = fiber_clustered(&dims, 8_000, 2, 0.0, 11);
        skewed.validate().unwrap();
        let fs = stats::fiber_stats(&skewed, 2);
        let ff = stats::fiber_stats(&flat, 2);
        // skew concentrates non-zeros: fewer distinct fibers, denser max
        assert!(fs.fibers < ff.fibers, "{} vs {}", fs.fibers, ff.fibers);
        assert!(fs.max_len > ff.max_len, "{} vs {}", fs.max_len, ff.max_len);
    }

    #[test]
    fn fiber_clustered_other_leaf_modes() {
        for leaf in 0..3 {
            let t = fiber_clustered(&[64, 64, 64], 2_000, leaf, 0.8, leaf as u64);
            assert!(t.nnz() > 1_000);
            t.validate().unwrap();
        }
    }
}
