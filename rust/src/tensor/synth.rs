//! Synthetic sparse tensor generators.
//!
//! The paper evaluates on FROSTT/HaTen2 tensors which are not redistributable
//! here (multi-GB downloads, up to 4.7B non-zeros). These generators
//! reproduce the *drivers* behind every effect the paper measures
//! (DESIGN.md §3): mode shape (→ atomics contention and the §5.3 heuristic),
//! fiber-density skew (→ MM-CSF compression quality) and total footprint
//! vs device memory (→ the out-of-memory path).

use std::collections::HashSet;

use super::coo::{CooChunk, CooTensor};
use crate::util::prng::Rng;

/// Chunked uniform generator: the streaming form of [`uniform`], yielding
/// bounded [`CooChunk`]s for the out-of-core builder so a synthetic tensor
/// can go straight to sorted runs without a `.tns` (or full `CooTensor`)
/// intermediate.
///
/// [`uniform`] itself is a collect-all wrapper over this type, so the
/// streamed and in-memory generators draw the *same* RNG sequence and
/// produce identical entries by construction — which is what lets
/// `convert --stream` promise a bit-for-bit identical container.
///
/// Note the dedup regime: when requested density exceeds `1e-4`, every
/// drawn coordinate is remembered in a hash set (exactly like
/// [`uniform`]), so generator memory is O(nnz) no matter the chunk size.
/// [`UniformChunks::dedup_bytes`] exposes that cost for the builder's
/// peak-memory accounting; truly out-of-core synthetic builds should use
/// sparse shapes (density ≤ 1e-4), where the set is never allocated.
pub struct UniformChunks {
    dims: Vec<u64>,
    nnz: usize,
    rng: Rng,
    seen: Option<HashSet<u128>>,
    coord: Vec<u32>,
    produced: usize,
    attempts: usize,
}

impl UniformChunks {
    pub fn new(dims: &[u64], nnz: usize, seed: u64) -> Self {
        let cells: f64 = dims.iter().map(|&d| d as f64).product();
        // only worth it when collisions are likely — same rule as uniform()
        let dedupe = (nnz as f64) / cells > 1e-4;
        UniformChunks {
            dims: dims.to_vec(),
            nnz,
            rng: Rng::new(seed),
            seen: dedupe.then(|| HashSet::with_capacity(nnz * 2)),
            coord: vec![0u32; dims.len()],
            produced: 0,
            attempts: 0,
        }
    }

    /// Generate up to `chunk_nnz` more non-zeros; `None` once the request
    /// is met (or the attempt budget is spent on a near-full shape).
    pub fn next_chunk(&mut self, chunk_nnz: usize) -> Option<CooChunk> {
        assert!(chunk_nnz > 0, "chunk_nnz must be > 0");
        let cap = chunk_nnz.min(self.nnz.saturating_sub(self.produced));
        if cap == 0 || self.attempts >= self.nnz * 4 {
            return None;
        }
        let mut chunk =
            CooChunk::with_capacity(self.dims.len(), cap, self.produced as u64);
        while chunk.len() < cap && self.attempts < self.nnz * 4 {
            self.attempts += 1;
            for (n, &d) in self.dims.iter().enumerate() {
                self.coord[n] = self.rng.below(d) as u32;
            }
            if let Some(seen) = &mut self.seen {
                let key = pack_coord(&self.coord, &self.dims);
                if !seen.insert(key) {
                    continue;
                }
            }
            chunk.push(&self.coord, self.rng.normal());
        }
        self.produced += chunk.len();
        if chunk.is_empty() {
            None
        } else {
            Some(chunk)
        }
    }

    /// Approximate bytes held by the dedup set (0 in the sparse regime).
    pub fn dedup_bytes(&self) -> usize {
        // hashbrown's raw table: one u128 slot + control byte per bucket,
        // buckets ≈ capacity / 0.875 — 20 B/slot is a fair ceiling
        self.seen.as_ref().map_or(0, |s| s.capacity() * 20)
    }
}

/// Uniform random tensor: coordinates i.i.d. uniform per mode, values
/// standard normal. Duplicates are merged, so the resulting nnz can be
/// slightly below the request on dense shapes. Collect-all wrapper over
/// [`UniformChunks`] — the streamed generator is the source of truth.
pub fn uniform(dims: &[u64], nnz: usize, seed: u64) -> CooTensor {
    let mut chunks = UniformChunks::new(dims, nnz, seed);
    let mut t = CooTensor::with_capacity(dims, nnz);
    while let Some(c) = chunks.next_chunk(nnz.max(1)) {
        for (plane, part) in t.coords.iter_mut().zip(&c.coords) {
            plane.extend_from_slice(part);
        }
        t.vals.extend_from_slice(&c.vals);
    }
    t
}

/// Fiber-clustered tensor: non-zeros are grouped into fibers along
/// `leaf_mode`, with the number of fibers and the per-fiber occupancy both
/// Zipf-skewed by `theta`. Large `theta` → few very dense fibers (the
/// NELL-2/Chicago regime where CSF-family compression shines); `theta ≈ 0`
/// → near-uniform, hypersparse fibers (the DARPA/FB-M regime where MM-CSF
/// degrades, Section 6.2).
pub fn fiber_clustered(
    dims: &[u64],
    nnz: usize,
    leaf_mode: usize,
    theta: f64,
    seed: u64,
) -> CooTensor {
    assert!(leaf_mode < dims.len());
    let mut rng = Rng::new(seed);
    // Pool of candidate fibers: random coordinates for every non-leaf mode.
    // Zipf over the pool concentrates non-zeros in the early (dense) fibers.
    let n_fibers = (nnz / 4).clamp(1, 1 << 20);
    let non_leaf: Vec<usize> =
        (0..dims.len()).filter(|&n| n != leaf_mode).collect();
    let mut pool: Vec<Vec<u32>> = Vec::with_capacity(n_fibers);
    for _ in 0..n_fibers {
        pool.push(non_leaf.iter().map(|&n| rng.below(dims[n]) as u32).collect());
    }

    let mut t = CooTensor::with_capacity(dims, nnz);
    let mut seen = HashSet::with_capacity(nnz * 2);
    let mut coord = vec![0u32; dims.len()];
    let mut attempts = 0usize;
    while t.nnz() < nnz && attempts < nnz * 6 {
        attempts += 1;
        let f = rng.zipf(n_fibers as u64, theta) as usize;
        for (k, &n) in non_leaf.iter().enumerate() {
            coord[n] = pool[f][k];
        }
        coord[leaf_mode] = rng.zipf(dims[leaf_mode], theta * 0.5) as u32;
        let key = pack_coord(&coord, dims);
        if !seen.insert(key) {
            continue;
        }
        t.push(&coord, rng.normal());
    }
    t
}

/// Pack coordinates into a u128 for dedup hashing (row-major).
fn pack_coord(coord: &[u32], dims: &[u64]) -> u128 {
    let mut key: u128 = 0;
    for (n, &c) in coord.iter().enumerate() {
        key = key.wrapping_mul(dims[n] as u128).wrapping_add(c as u128);
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats;

    #[test]
    fn uniform_shape_and_bounds() {
        let t = uniform(&[50, 40, 30], 5_000, 1);
        assert!(t.nnz() >= 4_500, "nnz {}", t.nnz());
        t.validate().unwrap();
    }

    #[test]
    fn uniform_is_deterministic() {
        let a = uniform(&[100, 100, 100], 1_000, 7);
        let b = uniform(&[100, 100, 100], 1_000, 7);
        assert_eq!(a.coords, b.coords);
        assert_eq!(a.vals, b.vals);
    }

    #[test]
    fn uniform_has_no_duplicates_when_dense() {
        let t = uniform(&[10, 10, 10], 500, 3);
        let mut keys: Vec<u128> = (0..t.nnz())
            .map(|e| pack_coord(&t.coord(e), &t.dims))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), t.nnz());
    }

    #[test]
    fn chunked_uniform_matches_collect_all() {
        // both regimes: dense enough to dedupe, sparse enough not to —
        // and chunk sizes that do and don't divide the request
        for (dims, nnz) in
            [(&[30u64, 20, 10][..], 2_000usize), (&[4000, 3000, 2000][..], 3_000)]
        {
            let whole = uniform(dims, nnz, 42);
            for chunk_nnz in [1usize, 17, 512, 1 << 20] {
                let mut gen = UniformChunks::new(dims, nnz, 42);
                let mut planes: Vec<Vec<u32>> = vec![Vec::new(); dims.len()];
                let mut vals = Vec::new();
                let mut base = 0u64;
                while let Some(c) = gen.next_chunk(chunk_nnz) {
                    assert_eq!(c.base, base);
                    base += c.len() as u64;
                    for (plane, part) in planes.iter_mut().zip(&c.coords) {
                        plane.extend_from_slice(part);
                    }
                    vals.extend_from_slice(&c.vals);
                }
                assert_eq!(planes, whole.coords, "chunk_nnz {chunk_nnz}");
                assert_eq!(vals, whole.vals, "chunk_nnz {chunk_nnz}");
            }
        }
    }

    #[test]
    fn fiber_clustered_skews_density() {
        let dims = [200u64, 150, 100];
        let skewed = fiber_clustered(&dims, 8_000, 2, 1.3, 11);
        let flat = fiber_clustered(&dims, 8_000, 2, 0.0, 11);
        skewed.validate().unwrap();
        let fs = stats::fiber_stats(&skewed, 2);
        let ff = stats::fiber_stats(&flat, 2);
        // skew concentrates non-zeros: fewer distinct fibers, denser max
        assert!(fs.fibers < ff.fibers, "{} vs {}", fs.fibers, ff.fibers);
        assert!(fs.max_len > ff.max_len, "{} vs {}", fs.max_len, ff.max_len);
    }

    #[test]
    fn fiber_clustered_other_leaf_modes() {
        for leaf in 0..3 {
            let t = fiber_clustered(&[64, 64, 64], 2_000, leaf, 0.8, leaf as u64);
            assert!(t.nnz() > 1_000);
            t.validate().unwrap();
        }
    }
}
