//! External-memory BLCO construction: build a `.blco` container from a
//! non-zero stream whose total size never has to fit in RAM (ROADMAP
//! item 1 — the regime the paper's out-of-memory claim and AMPED's
//! billion-nnz tensors live in).
//!
//! # Pipeline
//!
//! ```text
//! .tns file ──TnsChunks──┐
//!                        ├─► chunk ─► ALTO-linearize ─► par_sort ─► run_i  (spill)
//! synthetic ─UniformChunks┘             (ExecBackend)      (psort)   on disk
//!
//! run_0 ┐
//! run_1 ├─► k-way heap merge on (alto line, source index) ─► blocks ─► BlcoStoreWriter
//! run_k ┘        (bounded per-run read window)                 │
//!                                                              └─► header (norm, crcs,
//!                                                                   block index) at finish
//! ```
//!
//! # Bit-for-bit parity with the in-memory path
//!
//! `BlcoTensor::from_coo` sorts `(alto_line, source_index)` pairs, then
//! re-encodes and blocks them. The chunked path sorts each chunk by
//! `(line, local_index)` — within one chunk, local order *is* global
//! order — and the merge heap orders run heads by `(line, global_index)`,
//! so the merged stream is exactly the total order the in-memory sort
//! produces, duplicates included (duplicate coordinates stay separate
//! adjacent entries ordered by source position, exactly as `from_coo`
//! leaves them). Spill records therefore carry the *raw* 128-bit ALTO
//! line: `BlcoSpec::reencode_alto` is a bit permutation, not monotone, so
//! merging on re-encoded keys would break the order. Block boundaries
//! (key change or `max_block_nnz`) and the norm accumulation order are
//! replicated exactly, and [`BlcoStoreWriter`] shares the header/payload
//! serializers with `BlcoStore::write` — so the differential suite can
//! assert whole-file byte equality, not just semantic equality.
//!
//! # Memory model
//!
//! Peak memory is accounted in [`BuildStats::peak_bytes`] and asserted
//! against the budget by callers (`convert --build-mem-kib`, the tests):
//!
//! * **spill phase** — one chunk of coordinates/values
//!   (`chunk_nnz × (4·order + 8)` bytes) + its `(u128, u32)` sort pairs
//!   (`chunk_nnz × 32`) + a fixed spill write buffer;
//! * **merge phase** — one bounded read window per run + the heap + one
//!   open block (`≤ max_block_nnz × 32` including its serialization
//!   buffer) + the writer's growing block index.
//!
//! The chunk size is derived from the budget (half the budget to the
//! spill phase working set); the merge read windows get what the budget
//! leaves after the open block, clamped to `[2 KiB, 256 KiB]` per run. A
//! tensor is thus buildable as long as the budget covers one block plus
//! ~2 KiB per run — with the default 256 MiB budget and 64 MiB chunks
//! that is thousands of runs, i.e. hundreds of billions of non-zeros.

use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::format::blco::{Block, BlcoConfig};
use crate::format::store::{BlcoStoreReader, BlcoStoreWriter, Codec, StoreSummary};
use crate::linear::encode::BlcoSpec;
use crate::tensor::coo::CooChunk;
use crate::tensor::io::TnsChunks;
use crate::tensor::synth::UniformChunks;
use crate::util::pool::ExecBackend;
use crate::util::psort::par_sort_pairs;

/// Default construction budget when the caller does not pass one.
pub const DEFAULT_BUILD_BUDGET: usize = 256 << 20;

/// One spill record: 16 B raw ALTO line + 8 B global source index + 8 B
/// value bits, little-endian.
const RECORD_BYTES: usize = 32;

/// Fixed I/O buffer charged to both phases (spill BufWriter, payload copy).
const FIXED_IO_BYTES: usize = 64 << 10;

/// Per-run merge read window bounds.
const RUN_BUF_MIN: usize = 2 << 10;
const RUN_BUF_MAX: usize = 256 << 10;

/// Knobs for an external-memory build.
#[derive(Clone, Debug)]
pub struct BuildOptions {
    pub config: BlcoConfig,
    /// thread pool for per-chunk linearize + sort (PR-7 ExecBackend)
    pub backend: ExecBackend,
    /// peak-memory budget in bytes; `None` → [`DEFAULT_BUILD_BUDGET`]
    pub mem_budget_bytes: Option<usize>,
    /// explicit chunk size override (tests sweep this); normally derived
    /// from the budget
    pub chunk_nnz: Option<usize>,
    /// where sorted runs are spilled; `None` → the output's directory
    pub tmp_dir: Option<PathBuf>,
    /// per-block payload codec for the emitted container (container v2);
    /// [`Codec::None`] writes raw payloads, bit-identical to v1 blocks
    pub codec: Codec,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions {
            config: BlcoConfig::default(),
            backend: ExecBackend::from_env(),
            mem_budget_bytes: None,
            chunk_nnz: None,
            tmp_dir: None,
            codec: Codec::None,
        }
    }
}

impl BuildOptions {
    fn budget(&self) -> usize {
        self.mem_budget_bytes.unwrap_or(DEFAULT_BUILD_BUDGET)
    }
}

/// What an external-memory build did and what it held while doing it.
#[derive(Clone, Debug, Default)]
pub struct BuildStats {
    /// non-zeros streamed through the pipeline
    pub entries: u64,
    /// chunks parsed/generated (== sorted runs spilled)
    pub chunks: usize,
    pub runs: usize,
    /// chunk size actually used
    pub chunk_nnz: usize,
    /// blocks emitted to the container
    pub blocks: usize,
    /// bytes written to (and read back from) the spill runs
    pub spill_bytes: u64,
    /// per-run merge read window actually used
    pub run_buf_bytes: usize,
    /// high-water mark of accounted construction memory
    pub peak_bytes: usize,
    /// the budget the build was asked to stay under
    pub budget_bytes: usize,
    /// bytes held by the chunk source itself (the synthetic generator's
    /// dedup set in the dense regime; 0 for sparse shapes and .tns input)
    pub source_bytes: usize,
    /// dims-inference pre-pass seconds (0 when dims were known)
    pub infer_s: f64,
    /// parse/generate + sort + spill seconds
    pub spill_s: f64,
    /// merge + container-write seconds
    pub merge_s: f64,
}

impl BuildStats {
    fn charge(&mut self, bytes: usize) {
        self.peak_bytes = self.peak_bytes.max(bytes);
    }

    /// Throughput in millions of non-zeros per second (whole build).
    pub fn mnnz_per_s(&self) -> f64 {
        self.entries as f64 / (self.infer_s + self.spill_s + self.merge_s).max(1e-9) / 1e6
    }
}

// ------------------------------------------------------------- spill runs

static RUN_SEQ: AtomicU64 = AtomicU64::new(0);

/// One sorted on-disk run; the file is removed on drop.
struct RunFile {
    path: PathBuf,
    entries: u64,
}

impl Drop for RunFile {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}

fn run_path(tmp_dir: &Path) -> PathBuf {
    tmp_dir.join(format!(
        "blco_ooc_{}_{}.run",
        std::process::id(),
        RUN_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Buffered reader over one run, with a bounded read window.
struct RunReader {
    file: File,
    path: PathBuf,
    buf: Vec<u8>,
    pos: usize,
    len: usize,
    /// records not yet returned (buffered or still on disk)
    remaining: u64,
}

impl RunReader {
    fn open(run: &RunFile, window: usize) -> Result<Self> {
        let file = File::open(&run.path)
            .with_context(|| format!("open run {}", run.path.display()))?;
        Ok(RunReader {
            file,
            path: run.path.clone(),
            buf: vec![0u8; window],
            pos: 0,
            len: 0,
            remaining: run.entries,
        })
    }

    fn next(&mut self) -> Result<Option<(u128, u64, u64)>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        if self.pos == self.len {
            let want = self
                .buf
                .len()
                .min((self.remaining as usize).saturating_mul(RECORD_BYTES));
            self.file
                .read_exact(&mut self.buf[..want])
                .with_context(|| format!("read run {}", self.path.display()))?;
            self.pos = 0;
            self.len = want;
        }
        let rec = &self.buf[self.pos..self.pos + RECORD_BYTES];
        let line = u128::from_le_bytes(rec[0..16].try_into().unwrap());
        let gidx = u64::from_le_bytes(rec[16..24].try_into().unwrap());
        let vbits = u64::from_le_bytes(rec[24..32].try_into().unwrap());
        self.pos += RECORD_BYTES;
        self.remaining -= 1;
        Ok(Some((line, gidx, vbits)))
    }
}

/// Merge-heap entry. Field order matters: the derived `Ord` compares
/// `(line, gidx)` first, which is exactly the in-memory sort's
/// `(alto_line, source_index)` tuple order (`gidx` is globally unique, so
/// `vbits`/`run` never decide).
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct HeapItem {
    line: u128,
    gidx: u64,
    vbits: u64,
    run: usize,
}

// ------------------------------------------------------------ the builder

/// Derive the chunk size from the budget: the spill-phase working set
/// (chunk planes + values + sort pairs) gets half the budget.
fn resolve_chunk_nnz(order: usize, opts: &BuildOptions) -> Result<usize> {
    if let Some(n) = opts.chunk_nnz {
        if n == 0 {
            bail!("chunk_nnz must be > 0");
        }
        return Ok(n);
    }
    let per_entry = 4 * order + 8 + 32; // planes + vals + (u128, u32) pairs
    let avail = (opts.budget() / 2).saturating_sub(FIXED_IO_BYTES);
    let n = avail / per_entry;
    if n == 0 {
        bail!(
            "construction budget {} B cannot hold a single order-{order} \
             non-zero's working set (~{per_entry} B + {FIXED_IO_BYTES} B of \
             I/O buffers); raise --build-mem-kib",
            opts.budget()
        );
    }
    Ok(n)
}

/// Stream chunks from `next`, spill sorted runs, k-way merge them into a
/// `.blco` container at `out`. The workhorse behind [`build_from_tns`]
/// and [`build_uniform`].
fn build_from_chunk_source(
    mut next: impl FnMut(&mut BuildStats) -> Result<Option<CooChunk>>,
    dims: &[u64],
    out: &Path,
    opts: &BuildOptions,
    stats: &mut BuildStats,
) -> Result<StoreSummary> {
    let config = opts.config;
    let budget = opts.budget();
    stats.budget_bytes = budget;
    let open_block_bytes = 32 * config.max_block_nnz; // lidx+vals+serialize buf
    if open_block_bytes + FIXED_IO_BYTES > budget {
        bail!(
            "construction budget {budget} B cannot hold one open block \
             (max_block_nnz {} needs ~{open_block_bytes} B); lower \
             --max-block-nnz or raise --build-mem-kib",
            config.max_block_nnz
        );
    }
    let spec = BlcoSpec::with_budget(dims, config.inblock_budget);
    let tmp_dir = match &opts.tmp_dir {
        Some(d) => d.clone(),
        None => {
            let parent = out.parent().unwrap_or(Path::new("."));
            if parent.as_os_str().is_empty() {
                PathBuf::from(".")
            } else {
                parent.to_path_buf()
            }
        }
    };

    // ---- phase 1: chunk -> linearize -> sort -> spill ------------------
    let w = Instant::now();
    let nt = opts.backend.threads();
    let mut runs: Vec<RunFile> = Vec::new();
    let mut pairs: Vec<(u128, u32)> = Vec::new();
    while let Some(chunk) = next(stats)? {
        let len = chunk.len();
        if len == 0 {
            continue;
        }
        stats.chunks += 1;
        stats.entries += len as u64;
        debug_assert!(chunk.len() <= u32::MAX as usize, "chunk too large");

        // linearize (parallel over the chunk, like from_coo's stage 1)
        pairs.clear();
        pairs.resize(len, (0, 0));
        {
            let planes = &chunk.coords;
            let spec_ref = &spec;
            let base = pairs.as_mut_ptr() as usize;
            opts.backend.chunks(len, |_, lo, hi| {
                let ptr = base as *mut (u128, u32);
                let mut coord = vec![0u32; planes.len()];
                for e in lo..hi {
                    for (n, p) in planes.iter().enumerate() {
                        coord[n] = p[e];
                    }
                    // SAFETY: each e is written by exactly one thread
                    unsafe {
                        *ptr.add(e) = (spec_ref.alto.encode(&coord), e as u32)
                    };
                }
            });
        }

        // sort by (line, local index); local order == global order within
        // a chunk, so the merge's (line, gidx) order is the global sort
        par_sort_pairs(&mut pairs, nt, spec.alto.total_bits);

        // spill the sorted run
        let run = RunFile { path: run_path(&tmp_dir), entries: len as u64 };
        let file = File::create(&run.path)
            .with_context(|| format!("create run {}", run.path.display()))?;
        let mut spill = std::io::BufWriter::with_capacity(FIXED_IO_BYTES, file);
        let mut rec = [0u8; RECORD_BYTES];
        for &(line, local) in &pairs {
            rec[0..16].copy_from_slice(&line.to_le_bytes());
            rec[16..24]
                .copy_from_slice(&(chunk.base + local as u64).to_le_bytes());
            rec[24..32].copy_from_slice(
                &chunk.vals[local as usize].to_bits().to_le_bytes(),
            );
            spill
                .write_all(&rec)
                .with_context(|| format!("write run {}", run.path.display()))?;
        }
        // spilled runs are read back by the merge: a swallowed flush error
        // here would corrupt the build, not just lose a file
        spill
            .flush()
            .with_context(|| format!("flush run {}", run.path.display()))?;
        stats.spill_bytes += (len * RECORD_BYTES) as u64;
        stats.charge(
            chunk.alloc_bytes()
                + pairs.capacity() * std::mem::size_of::<(u128, u32)>()
                + FIXED_IO_BYTES
                + stats.source_bytes,
        );
        runs.push(run);
    }
    drop(pairs);
    stats.runs = runs.len();
    stats.spill_s = w.elapsed().as_secs_f64();

    // ---- phase 2: k-way merge -> blocks -> container -------------------
    let w = Instant::now();
    let heap_bytes = runs.len() * std::mem::size_of::<HeapItem>();
    let run_buf = if runs.is_empty() {
        0
    } else {
        let avail = budget
            .saturating_sub(open_block_bytes + FIXED_IO_BYTES + heap_bytes)
            / 8
            * 7; // keep headroom for the writer's block index
        (avail / runs.len()).clamp(RUN_BUF_MIN, RUN_BUF_MAX) / RECORD_BYTES
            * RECORD_BYTES
    };
    stats.run_buf_bytes = run_buf;

    let mut readers = runs
        .iter()
        .map(|r| RunReader::open(r, run_buf))
        .collect::<Result<Vec<_>>>()?;
    let mut heap: BinaryHeap<std::cmp::Reverse<HeapItem>> =
        BinaryHeap::with_capacity(readers.len());
    for (i, r) in readers.iter_mut().enumerate() {
        if let Some((line, gidx, vbits)) = r.next()? {
            heap.push(std::cmp::Reverse(HeapItem { line, gidx, vbits, run: i }));
        }
    }

    let mut writer = BlcoStoreWriter::create_with_codec(out, dims, config, opts.codec)?;
    let mut cur_key = 0u64;
    let mut lidx: Vec<u64> = Vec::with_capacity(config.max_block_nnz);
    let mut vals: Vec<f64> = Vec::with_capacity(config.max_block_nnz);
    // the open block's lidx + vals vectors; the writer's serialization
    // buffer (the other half of `open_block_bytes`) is counted through
    // `held_bytes()`, so it isn't charged twice
    let block_vec_bytes = 16 * config.max_block_nnz;
    while let Some(std::cmp::Reverse(item)) = heap.pop() {
        // same boundary rule as from_coo stage 4: key change or budget
        let (key, l) = spec.reencode_alto(item.line);
        if !lidx.is_empty()
            && (key != cur_key || lidx.len() >= config.max_block_nnz)
        {
            writer.add_block(cur_key, &lidx, &vals)?;
            stats.charge(
                readers.len() * run_buf
                    + heap_bytes
                    + block_vec_bytes
                    + writer.held_bytes()
                    + FIXED_IO_BYTES
                    + stats.source_bytes,
            );
            lidx.clear();
            vals.clear();
        }
        cur_key = key;
        lidx.push(l);
        vals.push(f64::from_bits(item.vbits));
        let run = item.run;
        if let Some((line, gidx, vbits)) = readers[run].next()? {
            heap.push(std::cmp::Reverse(HeapItem { line, gidx, vbits, run }));
        }
    }
    if !lidx.is_empty() {
        writer.add_block(cur_key, &lidx, &vals)?;
    }
    stats.charge(
        readers.len() * run_buf
            + heap_bytes
            + block_vec_bytes
            + writer.held_bytes()
            + FIXED_IO_BYTES
            + stats.source_bytes,
    );
    stats.blocks = writer.blocks();
    let summary = writer.finish()?;
    stats.merge_s = w.elapsed().as_secs_f64();
    Ok(summary)
}

/// Build a `.blco` container from a `.tns` file without materializing it.
/// When `dims` is `None`, a streaming inference pre-pass discovers the
/// order and per-mode maxima first (two passes over the file, still one
/// chunk of memory).
pub fn build_from_tns(
    tns: &Path,
    dims: Option<&[u64]>,
    out: &Path,
    opts: &BuildOptions,
) -> Result<(StoreSummary, BuildStats)> {
    let mut stats = BuildStats::default();
    let dims: Vec<u64> = match dims {
        Some(d) => d.to_vec(),
        None => {
            let w = Instant::now();
            let mut scan = TnsChunks::open(tns, None)?;
            // order is unknown until the first line; 64 B/entry covers the
            // chunk working set up to order 14
            let infer_chunk =
                ((opts.budget() / 2).saturating_sub(FIXED_IO_BYTES) / 64).max(1);
            while let Some(c) = scan.next_chunk(infer_chunk)? {
                stats.charge(c.alloc_bytes());
            }
            if scan.order().is_none() {
                bail!("{}: no non-zero entries", tns.display());
            }
            stats.infer_s = w.elapsed().as_secs_f64();
            scan.inferred_dims().to_vec()
        }
    };
    let chunk_nnz = resolve_chunk_nnz(dims.len(), opts)?;
    stats.chunk_nnz = chunk_nnz;
    let mut chunks = TnsChunks::open(tns, Some(&dims))?;
    let summary = build_from_chunk_source(
        |_stats| chunks.next_chunk(chunk_nnz),
        &dims,
        out,
        opts,
        &mut stats,
    )?;
    if stats.entries == 0 {
        bail!("{}: no non-zero entries", tns.display());
    }
    Ok((summary, stats))
}

/// Build a `.blco` container straight from the seeded uniform generator —
/// no `.tns` or `CooTensor` intermediate. Entry-for-entry identical to
/// `synth::uniform(dims, nnz, seed)` (same RNG stream), so the container
/// is bit-for-bit what `convert` without `--stream` writes.
pub fn build_uniform(
    dims: &[u64],
    nnz: usize,
    seed: u64,
    out: &Path,
    opts: &BuildOptions,
) -> Result<(StoreSummary, BuildStats)> {
    let mut stats = BuildStats::default();
    let chunk_nnz = resolve_chunk_nnz(dims.len(), opts)?;
    stats.chunk_nnz = chunk_nnz;
    let mut gen = UniformChunks::new(dims, nnz, seed);
    let summary = build_from_chunk_source(
        |stats| {
            let c = gen.next_chunk(chunk_nnz);
            // the dense-regime dedup set is real construction memory
            stats.source_bytes = gen.dedup_bytes();
            Ok(c)
        },
        dims,
        out,
        opts,
        &mut stats,
    )?;
    Ok((summary, stats))
}

/// Compact a container in place: fold any pending delta segments (and a
/// possible codec change) into a fresh single-base container, built
/// through the same external-memory pipeline as `convert --stream` and
/// atomically renamed over the original.
///
/// Entries are replayed in stored order — base blocks first, then each
/// delta segment in append order — and re-sorted by the builder on
/// `(alto line, replay position)`. Base entries are already
/// `(line, original source index)`-sorted and each delta segment is
/// `(line, append position)`-sorted, so for any given line the replay
/// preserves base-before-delta and per-segment relative order: the total
/// order is exactly what `from_coo` produces on the concatenated input,
/// making the compacted file **bit-for-bit identical** to a from-scratch
/// rebuild (same dims, config and codec), duplicates and norm included.
///
/// `codec: None` keeps the container's current default codec. The
/// accounted peak covers the builder's working set; one decoded source
/// block (`≤ max_block_nnz × 16` B) rides on top of it.
pub fn compact(
    path: &Path,
    codec: Option<Codec>,
    backend: ExecBackend,
    mem_budget_bytes: Option<usize>,
) -> Result<(StoreSummary, BuildStats)> {
    let reader = BlcoStoreReader::open(path)
        .with_context(|| format!("open {} for compaction", path.display()))?;
    let dims = reader.dims().to_vec();
    let opts = BuildOptions {
        config: *reader.config(),
        backend,
        mem_budget_bytes,
        chunk_nnz: None,
        tmp_dir: None,
        codec: codec.unwrap_or_else(|| reader.default_codec()),
    };
    let mut stats = BuildStats::default();
    let chunk_nnz = resolve_chunk_nnz(dims.len(), &opts)?;
    stats.chunk_nnz = chunk_nnz;
    let tmp_out = PathBuf::from(format!("{}.compact.tmp", path.display()));

    let order = dims.len();
    let total_blocks = reader.num_blocks();
    let mut block_i = 0usize;
    let mut entry_i = 0usize;
    let mut staged: Option<Block> = None;
    let mut coord = vec![0u32; order];
    let mut base = 0u64;
    let summary = build_from_chunk_source(
        |_stats| {
            if block_i >= total_blocks {
                return Ok(None);
            }
            let mut chunk = CooChunk::with_capacity(order, chunk_nnz, base);
            while chunk.len() < chunk_nnz && block_i < total_blocks {
                if staged.is_none() {
                    // bypass the cache: compaction is a single sequential
                    // scan, caching it would only evict hot blocks
                    staged = Some(reader.load_block(block_i).with_context(
                        || format!("read block {block_i} of {}", path.display()),
                    )?);
                    entry_i = 0;
                }
                let blk = staged.as_ref().unwrap();
                while entry_i < blk.lidx.len() && chunk.len() < chunk_nnz {
                    reader.spec().decode(blk.key, blk.lidx[entry_i], &mut coord);
                    chunk.push(&coord, blk.vals[entry_i]);
                    entry_i += 1;
                }
                if entry_i == blk.lidx.len() {
                    staged = None;
                    block_i += 1;
                }
            }
            base += chunk.len() as u64;
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        },
        &dims,
        &tmp_out,
        &opts,
        &mut stats,
    )
    .map_err(|e| {
        std::fs::remove_file(&tmp_out).ok();
        e
    })?;
    if summary.nnz != reader.nnz() {
        std::fs::remove_file(&tmp_out).ok();
        bail!(
            "compaction of {} replayed {} non-zeros but the container \
             holds {}",
            path.display(),
            summary.nnz,
            reader.nnz()
        );
    }
    drop(reader);
    std::fs::rename(&tmp_out, path).with_context(|| {
        format!("rename {} over {}", tmp_out.display(), path.display())
    })?;
    Ok((StoreSummary { path: path.to_path_buf(), ..summary }, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::blco::BlcoTensor;
    use crate::format::store::BlcoStore;
    use crate::tensor::synth;

    fn tmpfile(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_ooc_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn uniform_stream_matches_in_memory_bitwise() {
        let dims = [64u64, 48, 32];
        let nnz = 5_000;
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let p_mem = tmpfile("mem.blco");
        let p_ooc = tmpfile("ooc.blco");
        let t = synth::uniform(&dims, nnz, 11);
        BlcoStore::write(&BlcoTensor::from_coo_with(&t, cfg), &p_mem).unwrap();
        let opts = BuildOptions {
            config: cfg,
            chunk_nnz: Some(700),
            ..Default::default()
        };
        let (summary, stats) =
            build_uniform(&dims, nnz, 11, &p_ooc, &opts).unwrap();
        assert_eq!(summary.nnz, t.nnz());
        assert!(stats.runs > 1, "expected multiple runs, got {}", stats.runs);
        assert_eq!(
            std::fs::read(&p_mem).unwrap(),
            std::fs::read(&p_ooc).unwrap()
        );
        std::fs::remove_file(&p_mem).ok();
        std::fs::remove_file(&p_ooc).ok();
    }

    #[test]
    fn run_files_are_cleaned_up() {
        let dims = [32u64, 32, 32];
        let tmp = tmpfile("runs_dir");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("t.blco");
        let opts = BuildOptions {
            chunk_nnz: Some(200),
            tmp_dir: Some(tmp.clone()),
            ..Default::default()
        };
        build_uniform(&dims, 1_000, 3, &out, &opts).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&tmp)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().is_some_and(|x| x == "run")
                    || e.path().to_string_lossy().ends_with(".tmp")
            })
            .collect();
        assert!(leftovers.is_empty(), "leftover temp files: {leftovers:?}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn compressed_stream_matches_in_memory_bitwise() {
        // the codec threads through the external-memory writer exactly as
        // through BlcoStore::write_with: whole-file byte equality holds
        // for every codec, not just raw payloads
        let dims = [64u64, 48, 32];
        let nnz = 5_000;
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let t = synth::uniform(&dims, nnz, 11);
        for codec in [Codec::DeltaVarint, Codec::Shuffled] {
            let p_mem = tmpfile(&format!("mem_{}.blco", codec.tag()));
            let p_ooc = tmpfile(&format!("ooc_{}.blco", codec.tag()));
            BlcoStore::write_with(&BlcoTensor::from_coo_with(&t, cfg), &p_mem, codec)
                .unwrap();
            let opts = BuildOptions {
                config: cfg,
                chunk_nnz: Some(700),
                codec,
                ..Default::default()
            };
            build_uniform(&dims, nnz, 11, &p_ooc, &opts).unwrap();
            assert_eq!(
                std::fs::read(&p_mem).unwrap(),
                std::fs::read(&p_ooc).unwrap(),
                "{codec:?}"
            );
            std::fs::remove_file(&p_mem).ok();
            std::fs::remove_file(&p_ooc).ok();
        }
    }

    #[test]
    fn compact_after_append_is_bitwise_a_scratch_rebuild() {
        let dims = [60u64, 50, 40];
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let base = synth::uniform(&dims, 4_000, 3);
        let delta = synth::uniform(&dims, 1_500, 9);
        for codec in [Codec::None, Codec::DeltaVarint, Codec::Shuffled] {
            // live container: base + one appended delta segment, compacted
            let p_live = tmpfile(&format!("live_{}.blco", codec.tag()));
            BlcoStore::write_with(&BlcoTensor::from_coo_with(&base, cfg), &p_live, codec)
                .unwrap();
            BlcoStoreWriter::append(&p_live, &delta, None).unwrap();
            let (summary, stats) =
                compact(&p_live, None, ExecBackend::from_threads(2), None).unwrap();
            assert_eq!(summary.nnz, base.nnz() + delta.nnz());
            assert_eq!(stats.entries as usize, summary.nnz);

            // scratch rebuild: the same non-zeros concatenated up front
            let mut both = base.clone();
            for e in 0..delta.nnz() {
                both.push(&delta.coord(e), delta.vals[e]);
            }
            let p_scratch = tmpfile(&format!("scratch_{}.blco", codec.tag()));
            BlcoStore::write_with(
                &BlcoTensor::from_coo_with(&both, cfg),
                &p_scratch,
                codec,
            )
            .unwrap();

            assert_eq!(
                std::fs::read(&p_live).unwrap(),
                std::fs::read(&p_scratch).unwrap(),
                "{codec:?}: compacted container differs from scratch rebuild"
            );
            // the compacted container is pristine again
            let r = BlcoStoreReader::open(&p_live).unwrap();
            assert_eq!(r.segments(), 0);
            assert_eq!(r.read_amplification(), 1.0);
            std::fs::remove_file(&p_live).ok();
            std::fs::remove_file(&p_scratch).ok();
        }
    }

    #[test]
    fn compact_recompresses_with_a_new_codec() {
        let dims = [60u64, 50, 40];
        let t = synth::uniform(&dims, 4_000, 3);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let p = tmpfile("recompress.blco");
        BlcoStore::write(&BlcoTensor::from_coo_with(&t, cfg), &p).unwrap();
        let raw_bytes = std::fs::metadata(&p).unwrap().len();
        compact(&p, Some(Codec::DeltaVarint), ExecBackend::from_threads(2), None).unwrap();
        let r = BlcoStoreReader::open(&p).unwrap();
        assert_eq!(r.default_codec(), Codec::DeltaVarint);
        assert_eq!(r.nnz(), t.nnz());
        assert!(r.compression_ratio() > 1.0);
        assert!(std::fs::metadata(&p).unwrap().len() < raw_bytes);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn budget_too_small_errors_cleanly() {
        let opts = BuildOptions {
            mem_budget_bytes: Some(1 << 10),
            ..Default::default()
        };
        let err = build_uniform(&[8, 8], 100, 1, &tmpfile("tiny.blco"), &opts)
            .unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }
}
