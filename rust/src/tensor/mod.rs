//! Sparse tensor substrate: COO storage, FROSTT `.tns` IO, synthetic
//! dataset generators mirroring the paper's 14-tensor evaluation suite, and
//! the structural statistics (fiber densities, mode histograms) that the
//! MM-CSF baseline and the experiment analysis need.

pub mod coo;
pub mod datasets;
pub mod io;
pub mod ooc;
pub mod stats;
pub mod synth;
