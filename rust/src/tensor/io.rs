//! FROSTT `.tns` text IO: one non-zero per line, 1-based indices followed by
//! the value; `#` comments allowed. This is the format the paper's datasets
//! ship in, so converted real tensors drop straight into the pipeline.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::coo::CooTensor;

/// Read a `.tns` file. Mode lengths are inferred as the per-mode maxima
/// unless `dims` is given (required if any trailing mode is longer than its
/// max index suggests).
pub fn read_tns(path: &Path, dims: Option<&[u64]>) -> Result<CooTensor> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(file);

    let mut order: Option<usize> = None;
    let mut raw_coords: Vec<Vec<u32>> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() < 2 {
            bail!("{}:{}: too few fields", path.display(), lineno + 1);
        }
        let n = toks.len() - 1;
        match order {
            None => {
                order = Some(n);
                raw_coords = vec![Vec::new(); n];
            }
            Some(o) if o != n => {
                bail!("{}:{}: {} indices, expected {}", path.display(), lineno + 1, n, o)
            }
            _ => {}
        }
        for (m, tok) in toks[..n].iter().enumerate() {
            let idx: u64 = tok
                .parse()
                .with_context(|| format!("{}:{}: bad index", path.display(), lineno + 1))?;
            if idx == 0 {
                bail!("{}:{}: .tns indices are 1-based", path.display(), lineno + 1);
            }
            // coordinates are stored as u32 planes; an index past that
            // range must be a hard error, not a silent wrap
            if idx - 1 > u32::MAX as u64 {
                bail!(
                    "{}:{}: mode-{m} index {idx} overflows the u32 coordinate range",
                    path.display(),
                    lineno + 1
                );
            }
            raw_coords[m].push((idx - 1) as u32);
        }
        let v: f64 = toks[n]
            .parse()
            .with_context(|| format!("{}:{}: bad value", path.display(), lineno + 1))?;
        if !v.is_finite() {
            bail!(
                "{}:{}: non-finite value {v} (NaN/inf would poison every \
                 norm and fit downstream)",
                path.display(),
                lineno + 1
            );
        }
        vals.push(v);
    }

    let order = order.unwrap_or(0);
    if order == 0 {
        bail!("{}: no non-zero entries", path.display());
    }
    let inferred: Vec<u64> = raw_coords
        .iter()
        .map(|p| p.iter().map(|&c| c as u64 + 1).max().unwrap_or(1))
        .collect();
    let dims = match dims {
        Some(d) => {
            // a shorter (or longer) dims list must error rather than
            // silently truncating/padding the inferred order
            if d.len() != order {
                bail!(
                    "explicit dims have order {} but the file has {} indices \
                     per non-zero",
                    d.len(),
                    order
                );
            }
            for (n, (&given, &seen)) in d.iter().zip(&inferred).enumerate() {
                if given < seen {
                    bail!("mode {n}: dim {given} < max index {seen}");
                }
            }
            d.to_vec()
        }
        None => inferred,
    };
    let t = CooTensor { dims, coords: raw_coords, vals };
    t.validate()?;
    Ok(t)
}

/// Write a tensor as `.tns` (1-based indices).
pub fn write_tns(path: &Path, t: &CooTensor) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# {} modes, dims {:?}, {} nnz", t.order(), t.dims, t.nnz())?;
    for e in 0..t.nnz() {
        for n in 0..t.order() {
            write!(w, "{} ", t.coords[n][e] as u64 + 1)?;
        }
        writeln!(w, "{}", t.vals[e])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip() {
        let mut t = CooTensor::new(&[5, 6, 7]);
        t.push(&[0, 0, 0], 1.5);
        t.push(&[4, 5, 6], -2.25);
        t.push(&[2, 3, 1], 0.5);
        let p = tmpfile("roundtrip.tns");
        write_tns(&p, &t).unwrap();
        let back = read_tns(&p, Some(&[5, 6, 7])).unwrap();
        assert_eq!(back.dims, t.dims);
        assert_eq!(back.coords, t.coords);
        assert_eq!(back.vals, t.vals);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn infers_dims_and_skips_comments() {
        let p = tmpfile("infer.tns");
        std::fs::write(&p, "# header\n1 1 1 1.0\n\n3 2 5 2.0\n").unwrap();
        let t = read_tns(&p, None).unwrap();
        assert_eq!(t.dims, vec![3, 2, 5]);
        assert_eq!(t.nnz(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_zero_based() {
        let p = tmpfile("zerobased.tns");
        std::fs::write(&p, "0 1 1 1.0\n").unwrap();
        assert!(read_tns(&p, None).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_non_finite_values() {
        let p = tmpfile("nonfinite.tns");
        for bad in ["1 1 1 NaN\n", "1 1 1 inf\n", "2 2 2 -inf\n"] {
            std::fs::write(&p, bad).unwrap();
            let err = read_tns(&p, None).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{bad:?}: {err}");
        }
        // finite scientific notation still parses
        std::fs::write(&p, "1 1 1 1e-3\n").unwrap();
        assert_eq!(read_tns(&p, None).unwrap().vals, vec![1e-3]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_u32_overflowing_indices() {
        let p = tmpfile("overflow.tns");
        // 2^32 + 1 would wrap to index 0 under a silent `as u32`
        std::fs::write(&p, "4294967297 1 1 1.0\n").unwrap();
        let err = read_tns(&p, None).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
        // the largest representable index is fine
        std::fs::write(&p, "4294967296 1 1 1.0\n").unwrap();
        let t = read_tns(&p, None).unwrap();
        assert_eq!(t.coords[0][0], u32::MAX);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_dims_order_mismatch_both_ways() {
        let p = tmpfile("dimsorder.tns");
        std::fs::write(&p, "1 2 3 1.0\n").unwrap();
        // shorter than the inferred order: must error, not truncate
        let err = read_tns(&p, Some(&[4, 4])).unwrap_err();
        assert!(err.to_string().contains("order"), "{err}");
        // longer: same
        assert!(read_tns(&p, Some(&[4, 4, 4, 4])).is_err());
        // exact order passes
        assert_eq!(read_tns(&p, Some(&[4, 4, 4])).unwrap().dims, vec![4, 4, 4]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_ragged_and_small_dims() {
        let p = tmpfile("ragged.tns");
        std::fs::write(&p, "1 1 1 1.0\n1 1 2.0\n").unwrap();
        assert!(read_tns(&p, None).is_err());
        std::fs::write(&p, "5 1 1 1.0\n").unwrap();
        assert!(read_tns(&p, Some(&[2, 2, 2])).is_err());
        std::fs::remove_file(&p).ok();
    }
}
