//! FROSTT `.tns` text IO: one non-zero per line, 1-based indices followed by
//! the value; `#` comments allowed. This is the format the paper's datasets
//! ship in, so converted real tensors drop straight into the pipeline.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::coo::{CooChunk, CooTensor};

/// Chunk size [`read_tns`] collects through (large enough that the
/// per-chunk bookkeeping vanishes, small enough that reallocation waste
/// stays bounded while the planes grow).
const READ_TNS_CHUNK: usize = 1 << 20;

/// Streaming `.tns` parser: yields bounded-size [`CooChunk`]s through one
/// reusable line buffer, so peak parser memory is one chunk — not the
/// file. All validation (1-based indices, u32 overflow, non-finite
/// values, ragged rows, explicit-dims checks) lives here; [`read_tns`] is
/// a thin collect-all wrapper over this type.
///
/// When `dims` is passed to [`TnsChunks::open`], every index is
/// bounds-checked against it as it streams by (the out-of-core builder
/// encodes straight from chunks, so it cannot defer validation to a final
/// `CooTensor::validate`). Without `dims`, per-mode maxima are tracked and
/// exposed via [`TnsChunks::inferred_dims`] for a two-pass build.
pub struct TnsChunks {
    reader: BufReader<std::fs::File>,
    path: PathBuf,
    dims: Option<Vec<u64>>,
    /// reusable line buffer — the whole point of the chunked core is that
    /// parsing allocates nothing per line
    line: String,
    lineno: usize,
    order: Option<usize>,
    /// running per-mode max index + 1 (candidate inferred dims)
    maxima: Vec<u64>,
    /// non-zeros emitted so far (the next chunk's `base`)
    entries: u64,
}

impl TnsChunks {
    /// Open `path` for chunked parsing. `dims`, when given, must match the
    /// file's order and bound every index (checked as lines stream by).
    pub fn open(path: &Path, dims: Option<&[u64]>) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Ok(TnsChunks {
            reader: BufReader::new(file),
            path: path.to_path_buf(),
            dims: dims.map(|d| d.to_vec()),
            line: String::new(),
            lineno: 0,
            order: None,
            maxima: Vec::new(),
            entries: 0,
        })
    }

    /// Parse up to `chunk_nnz` non-zeros into the next chunk. Returns
    /// `Ok(None)` at end of file. Comment (`#`) and blank lines are
    /// skipped and never count against the chunk budget.
    pub fn next_chunk(&mut self, chunk_nnz: usize) -> Result<Option<CooChunk>> {
        assert!(chunk_nnz > 0, "chunk_nnz must be > 0");
        let mut chunk: Option<CooChunk> = None;
        loop {
            if chunk.as_ref().is_some_and(|c| c.len() >= chunk_nnz) {
                break;
            }
            self.line.clear();
            let n = self
                .reader
                .read_line(&mut self.line)
                .with_context(|| format!("read {}", self.path.display()))?;
            if n == 0 {
                break; // EOF
            }
            self.lineno += 1;
            let line = self.line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            // one counting pass over the tokens (no Vec<&str> per line),
            // then a parsing pass that writes straight into the planes
            let ntok = line.split_whitespace().count();
            if ntok < 2 {
                bail!("{}:{}: too few fields", self.path.display(), self.lineno);
            }
            let n_idx = ntok - 1;
            match self.order {
                None => {
                    if let Some(d) = &self.dims {
                        // a shorter (or longer) dims list must error rather
                        // than silently truncating/padding the file's order
                        if d.len() != n_idx {
                            bail!(
                                "explicit dims have order {} but the file has \
                                 {} indices per non-zero",
                                d.len(),
                                n_idx
                            );
                        }
                    }
                    self.order = Some(n_idx);
                    self.maxima = vec![1; n_idx];
                }
                Some(o) if o != n_idx => {
                    bail!(
                        "{}:{}: {} indices, expected {}",
                        self.path.display(),
                        self.lineno,
                        n_idx,
                        o
                    )
                }
                _ => {}
            }
            let chunk = chunk.get_or_insert_with(|| {
                CooChunk::with_capacity(n_idx, chunk_nnz, self.entries)
            });
            let mut toks = line.split_whitespace();
            for m in 0..n_idx {
                let tok = toks.next().expect("counted above");
                let idx: u64 = tok.parse().with_context(|| {
                    format!("{}:{}: bad index", self.path.display(), self.lineno)
                })?;
                if idx == 0 {
                    bail!(
                        "{}:{}: .tns indices are 1-based",
                        self.path.display(),
                        self.lineno
                    );
                }
                // coordinates are stored as u32 planes; an index past that
                // range must be a hard error, not a silent wrap
                if idx - 1 > u32::MAX as u64 {
                    bail!(
                        "{}:{}: mode-{m} index {idx} overflows the u32 \
                         coordinate range",
                        self.path.display(),
                        self.lineno
                    );
                }
                if let Some(d) = &self.dims {
                    if idx > d[m] {
                        bail!("mode {m}: dim {} < max index {idx}", d[m]);
                    }
                }
                self.maxima[m] = self.maxima[m].max(idx);
                chunk.coords[m].push((idx - 1) as u32);
            }
            let tok = toks.next().expect("counted above");
            let v: f64 = tok.parse().with_context(|| {
                format!("{}:{}: bad value", self.path.display(), self.lineno)
            })?;
            if !v.is_finite() {
                bail!(
                    "{}:{}: non-finite value {v} (NaN/inf would poison every \
                     norm and fit downstream)",
                    self.path.display(),
                    self.lineno
                );
            }
            chunk.vals.push(v);
            self.entries += 1;
        }
        Ok(chunk)
    }

    /// The file's order, once at least one non-zero has been parsed.
    pub fn order(&self) -> Option<usize> {
        self.order
    }

    /// Per-mode `max index` seen so far (the inferred dims after a full
    /// pass). Empty until the first non-zero.
    pub fn inferred_dims(&self) -> &[u64] {
        &self.maxima
    }

    /// Non-zeros parsed so far.
    pub fn entries(&self) -> u64 {
        self.entries
    }
}

/// Read a `.tns` file. Mode lengths are inferred as the per-mode maxima
/// unless `dims` is given (required if any trailing mode is longer than its
/// max index suggests). Thin collect-all wrapper over [`TnsChunks`]; use
/// that (or [`crate::tensor::ooc`]) when the file should not be
/// materialized at once.
pub fn read_tns(path: &Path, dims: Option<&[u64]>) -> Result<CooTensor> {
    // dims are validated here (end-of-parse, like the historical reader)
    // rather than streamed through TnsChunks, so the chunk core stays a
    // pure parser and error precedence is unchanged
    let mut chunks = TnsChunks::open(path, None)?;
    let mut raw_coords: Vec<Vec<u32>> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    while let Some(c) = chunks.next_chunk(READ_TNS_CHUNK)? {
        if raw_coords.is_empty() {
            raw_coords = vec![Vec::new(); c.order()];
        }
        for (plane, part) in raw_coords.iter_mut().zip(&c.coords) {
            plane.extend_from_slice(part);
        }
        vals.extend_from_slice(&c.vals);
    }

    let order = chunks.order().unwrap_or(0);
    if order == 0 {
        bail!("{}: no non-zero entries", path.display());
    }
    let inferred: Vec<u64> = chunks.inferred_dims().to_vec();
    let dims = match dims {
        Some(d) => {
            // a shorter (or longer) dims list must error rather than
            // silently truncating/padding the inferred order
            if d.len() != order {
                bail!(
                    "explicit dims have order {} but the file has {} indices \
                     per non-zero",
                    d.len(),
                    order
                );
            }
            for (n, (&given, &seen)) in d.iter().zip(&inferred).enumerate() {
                if given < seen {
                    bail!("mode {n}: dim {given} < max index {seen}");
                }
            }
            d.to_vec()
        }
        None => inferred,
    };
    let t = CooTensor { dims, coords: raw_coords, vals };
    t.validate()?;
    Ok(t)
}

/// Write a tensor as `.tns` (1-based indices).
pub fn write_tns(path: &Path, t: &CooTensor) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# {} modes, dims {:?}, {} nnz", t.order(), t.dims, t.nnz())?;
    for e in 0..t.nnz() {
        for n in 0..t.order() {
            write!(w, "{} ", t.coords[n][e] as u64 + 1)?;
        }
        writeln!(w, "{}", t.vals[e])?;
    }
    // a BufWriter dropped without flush swallows write errors — a full
    // disk would report Ok(()) on a truncated file
    w.flush().with_context(|| format!("flush {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("blco_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip() {
        let mut t = CooTensor::new(&[5, 6, 7]);
        t.push(&[0, 0, 0], 1.5);
        t.push(&[4, 5, 6], -2.25);
        t.push(&[2, 3, 1], 0.5);
        let p = tmpfile("roundtrip.tns");
        write_tns(&p, &t).unwrap();
        let back = read_tns(&p, Some(&[5, 6, 7])).unwrap();
        assert_eq!(back.dims, t.dims);
        assert_eq!(back.coords, t.coords);
        assert_eq!(back.vals, t.vals);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn infers_dims_and_skips_comments() {
        let p = tmpfile("infer.tns");
        std::fs::write(&p, "# header\n1 1 1 1.0\n\n3 2 5 2.0\n").unwrap();
        let t = read_tns(&p, None).unwrap();
        assert_eq!(t.dims, vec![3, 2, 5]);
        assert_eq!(t.nnz(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_zero_based() {
        let p = tmpfile("zerobased.tns");
        std::fs::write(&p, "0 1 1 1.0\n").unwrap();
        assert!(read_tns(&p, None).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_non_finite_values() {
        let p = tmpfile("nonfinite.tns");
        for bad in ["1 1 1 NaN\n", "1 1 1 inf\n", "2 2 2 -inf\n"] {
            std::fs::write(&p, bad).unwrap();
            let err = read_tns(&p, None).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{bad:?}: {err}");
        }
        // finite scientific notation still parses
        std::fs::write(&p, "1 1 1 1e-3\n").unwrap();
        assert_eq!(read_tns(&p, None).unwrap().vals, vec![1e-3]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_u32_overflowing_indices() {
        let p = tmpfile("overflow.tns");
        // 2^32 + 1 would wrap to index 0 under a silent `as u32`
        std::fs::write(&p, "4294967297 1 1 1.0\n").unwrap();
        let err = read_tns(&p, None).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
        // the largest representable index is fine
        std::fs::write(&p, "4294967296 1 1 1.0\n").unwrap();
        let t = read_tns(&p, None).unwrap();
        assert_eq!(t.coords[0][0], u32::MAX);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_dims_order_mismatch_both_ways() {
        let p = tmpfile("dimsorder.tns");
        std::fs::write(&p, "1 2 3 1.0\n").unwrap();
        // shorter than the inferred order: must error, not truncate
        let err = read_tns(&p, Some(&[4, 4])).unwrap_err();
        assert!(err.to_string().contains("order"), "{err}");
        // longer: same
        assert!(read_tns(&p, Some(&[4, 4, 4, 4])).is_err());
        // exact order passes
        assert_eq!(read_tns(&p, Some(&[4, 4, 4])).unwrap().dims, vec![4, 4, 4]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_ragged_and_small_dims() {
        let p = tmpfile("ragged.tns");
        std::fs::write(&p, "1 1 1 1.0\n1 1 2.0\n").unwrap();
        assert!(read_tns(&p, None).is_err());
        std::fs::write(&p, "5 1 1 1.0\n").unwrap();
        assert!(read_tns(&p, Some(&[2, 2, 2])).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_parse_matches_collect_all() {
        let t = crate::tensor::synth::uniform(&[40, 30, 20], 3_000, 5);
        let p = tmpfile("chunked.tns");
        write_tns(&p, &t).unwrap();
        let whole = read_tns(&p, None).unwrap();
        for chunk_nnz in [1usize, 7, 256, 100_000] {
            let mut chunks = TnsChunks::open(&p, None).unwrap();
            let mut planes: Vec<Vec<u32>> = vec![Vec::new(); 3];
            let mut vals = Vec::new();
            let mut expect_base = 0u64;
            while let Some(c) = chunks.next_chunk(chunk_nnz).unwrap() {
                assert_eq!(c.base, expect_base);
                assert!(c.len() <= chunk_nnz);
                expect_base += c.len() as u64;
                for (plane, part) in planes.iter_mut().zip(&c.coords) {
                    plane.extend_from_slice(part);
                }
                vals.extend_from_slice(&c.vals);
            }
            assert_eq!(chunks.entries(), whole.nnz() as u64);
            assert_eq!(chunks.inferred_dims(), &whole.dims[..]);
            assert_eq!(planes, whole.coords);
            assert_eq!(vals, whole.vals);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_parse_bounds_checks_explicit_dims() {
        let p = tmpfile("chunked_dims.tns");
        std::fs::write(&p, "1 1 1 1.0\n5 1 1 2.0\n").unwrap();
        // in-bounds explicit dims stream through
        let mut ok = TnsChunks::open(&p, Some(&[5, 2, 2])).unwrap();
        assert_eq!(ok.next_chunk(16).unwrap().unwrap().len(), 2);
        // the second entry exceeds mode 0 and must fail *mid-stream*
        let mut bad = TnsChunks::open(&p, Some(&[4, 2, 2])).unwrap();
        let err = bad.next_chunk(16).unwrap_err();
        assert!(err.to_string().contains("dim 4 < max index 5"), "{err}");
        // order mismatch fails on the first data line
        assert!(TnsChunks::open(&p, Some(&[4, 2]))
            .unwrap()
            .next_chunk(16)
            .is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    #[cfg(unix)]
    fn write_tns_surfaces_flush_errors() {
        // /dev/full accepts the open and buffered writes, then fails the
        // flush with ENOSPC — exactly the swallowed-error regression:
        // before the explicit flush, this returned Ok(()) on a file that
        // holds none of the data
        if !Path::new("/dev/full").exists() {
            return; // not available in this environment
        }
        let mut t = CooTensor::new(&[4, 4]);
        t.push(&[1, 2], 1.0);
        let err = write_tns(Path::new("/dev/full"), &t).unwrap_err();
        assert!(err.to_string().contains("/dev/full"), "{err}");
    }
}
