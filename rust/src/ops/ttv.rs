//! Sparse tensor-times-vector (TTV): `Y = X ×_n v`, contracting mode `n`
//! against a dense vector — the next-most-common kernel after MTTKRP in
//! tensor analytics, and a demonstration that BLCO's mode-agnostic single
//! copy serves other algorithms unchanged (the paper's concluding claim).
//!
//! The result is an (N−1)-order sparse tensor. Like MTTKRP, conflicting
//! contributions (non-zeros differing only in mode `n`) are merged
//! opportunistically: threads accumulate into per-chunk hash stashes and
//! the coordinator merges stashes, so blocks remain independent and the
//! operation streams on the out-of-memory path unchanged.

use std::collections::HashMap;

use crate::format::blco::BlcoTensor;
use crate::tensor::coo::CooTensor;
use crate::util::pool::parallel_chunks;

/// `Y = X ×_contract v`. `v.len()` must equal `dims[contract]`.
pub fn ttv(t: &BlcoTensor, contract: usize, v: &[f64], threads: usize) -> CooTensor {
    let order = t.order();
    assert!(contract < order, "contract mode out of range");
    assert_eq!(v.len(), t.dims()[contract] as usize, "vector length");
    let out_dims: Vec<u64> = (0..order)
        .filter(|&n| n != contract)
        .map(|n| t.dims()[n])
        .collect();

    // per-thread stashes keyed by the packed remaining coordinates
    let nblocks = t.blocks.len();
    let nt = threads.max(1);
    let mut stashes: Vec<HashMap<u128, f64>> = (0..nt).map(|_| HashMap::new()).collect();
    {
        let slots = stashes.as_mut_ptr() as usize;
        parallel_chunks(nt, nblocks, |tid, lo, hi| {
            // SAFETY: each thread id owns exactly one stash slot
            let stash = unsafe { &mut *(slots as *mut HashMap<u128, f64>).add(tid) };
            let mut coord = vec![0u32; order];
            for blk in &t.blocks[lo..hi] {
                for (i, &l) in blk.lidx.iter().enumerate() {
                    t.spec.decode(blk.key, l, &mut coord);
                    let w = v[coord[contract] as usize];
                    if w == 0.0 {
                        continue;
                    }
                    let mut key: u128 = 0;
                    for (n, &c) in coord.iter().enumerate() {
                        if n == contract {
                            continue;
                        }
                        key = key
                            .wrapping_mul(t.dims()[n] as u128)
                            .wrapping_add(c as u128);
                    }
                    *stash.entry(key).or_insert(0.0) += blk.vals[i] * w;
                }
            }
        });
    }

    // coordinator merge (step 7 analog): combine stashes, unpack keys
    let mut merged: HashMap<u128, f64> = HashMap::new();
    for stash in stashes {
        for (k, val) in stash {
            *merged.entry(k).or_insert(0.0) += val;
        }
    }
    let mut keys: Vec<u128> = merged.keys().copied().collect();
    keys.sort_unstable();
    let mut out = CooTensor::with_capacity(&out_dims, keys.len());
    let mut coord = vec![0u32; out_dims.len()];
    for k in keys {
        let mut rem = k;
        for n in (0..out_dims.len()).rev() {
            coord[n] = (rem % out_dims[n] as u128) as u32;
            rem /= out_dims[n] as u128;
        }
        out.push(&coord, merged[&k]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;
    use crate::util::prng::Rng;

    /// Naive TTV straight from COO.
    fn ttv_oracle(t: &CooTensor, contract: usize, v: &[f64]) -> HashMap<Vec<u32>, f64> {
        let mut out = HashMap::new();
        for e in 0..t.nnz() {
            let c = t.coord(e);
            let w = v[c[contract] as usize];
            let key: Vec<u32> = (0..t.order())
                .filter(|&n| n != contract)
                .map(|n| c[n])
                .collect();
            *out.entry(key).or_insert(0.0) += t.vals[e] * w;
        }
        out.retain(|_, val| *val != 0.0);
        out
    }

    fn check(t: &CooTensor, contract: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let v: Vec<f64> =
            (0..t.dims[contract]).map(|_| rng.normal()).collect();
        let b = crate::format::blco::BlcoTensor::from_coo(t);
        let got = ttv(&b, contract, &v, 4);
        let expect = ttv_oracle(t, contract, &v);
        assert_eq!(got.nnz(), expect.len(), "contract {contract}");
        for e in 0..got.nnz() {
            let c = got.coord(e);
            let want = expect.get(&c).unwrap_or(&f64::NAN);
            assert!(
                (got.vals[e] - want).abs() < 1e-9,
                "coord {c:?}: {} vs {want}",
                got.vals[e]
            );
        }
    }

    #[test]
    fn matches_oracle_all_contractions_3mode() {
        let t = synth::fiber_clustered(&[40, 30, 20], 3_000, 2, 0.9, 1);
        for contract in 0..3 {
            check(&t, contract, contract as u64);
        }
    }

    #[test]
    fn matches_oracle_4mode() {
        let t = synth::uniform(&[16, 12, 10, 8], 1_500, 3);
        for contract in 0..4 {
            check(&t, contract, 10 + contract as u64);
        }
    }

    #[test]
    fn duplicate_fibers_merge() {
        // two non-zeros differing only in the contracted mode fuse into one
        let mut t = CooTensor::new(&[4, 4, 4]);
        t.push(&[1, 2, 0], 2.0);
        t.push(&[1, 2, 3], 5.0);
        let b = crate::format::blco::BlcoTensor::from_coo(&t);
        let v = vec![1.0, 1.0, 1.0, 10.0];
        let y = ttv(&b, 2, &v, 2);
        assert_eq!(y.nnz(), 1);
        assert_eq!(y.coord(0), vec![1, 2]);
        assert!((y.vals[0] - 52.0).abs() < 1e-12);
    }

    #[test]
    fn zero_vector_annihilates() {
        let t = synth::uniform(&[10, 10, 10], 500, 7);
        let b = crate::format::blco::BlcoTensor::from_coo(&t);
        let y = ttv(&b, 1, &vec![0.0; 10], 2);
        assert_eq!(y.nnz(), 0);
    }
}
