//! Further tensor operations over the BLCO format — the paper's future
//! work ("other tensor algorithms") made concrete: the same unified
//! mode-agnostic block iteration that powers MTTKRP also drives
//! tensor-times-vector contraction ([`ttv`]).

pub mod ttv;
