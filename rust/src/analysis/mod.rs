//! Static conflict analysis + instrumented race checking for BLCO MTTKRP
//! schedules.
//!
//! The paper's conflict resolution (Sections 5.1–5.3) is *opportunistic*:
//! threads discover colliding output-row updates at run time and resolve
//! them with atomics or privatized copies. But which work-groups can
//! collide at all is a pure function of the BLCO metadata — block keys,
//! linearized indices and the batch → work-group maps — none of which
//! involves a tensor value. This module exploits that:
//!
//! * [`conflict`] computes, per `(tensor, mode)`, the exact
//!   inter-work-group row-overlap graph of every batch, partitions each
//!   batch's work-groups into conflict-free *waves* via an
//!   order-preserving greedy coloring, and emits a
//!   [`ConflictCertificate`](conflict::ConflictCertificate) whose
//!   per-batch recommendation (`NoSync` | `Privatize` | `Atomic`)
//!   replaces the §5.3 `target_len` threshold as the `Resolution::Auto`
//!   policy.
//! * [`racecheck`] is the verifier: a write-logging execution mode that
//!   records every output-row flush as `(thread, batch, wave, wg, row)`
//!   plus a lockset-style validator proving a certified schedule issues
//!   zero unordered conflicting writes — the sanitizer the threaded
//!   kernels of ROADMAP item 2 run under in CI.
//!
//! The two halves check each other: the race checker must observe exactly
//! the conflicts the static analysis predicted (no more, no fewer), and a
//! wave-ordered execution under a certificate must reproduce the
//! sequential result bit for bit. `blco analyze --check` hard-asserts all
//! of that on every mode.

pub mod conflict;
pub mod racecheck;
