//! Static conflict analysis over BLCO metadata — no value reads.
//!
//! For one `(tensor, mode)` pair, every work-group's set of output rows is
//! decodable from block keys + linearized indices alone
//! ([`BlcoSpec::decode_mode`]): a work-group is a `workgroup`-sized window
//! of one block's `lidx`, and its target coordinates are a shift/mask of
//! each entry. From those row sets this module derives, per batch:
//!
//! * the **inter-work-group row-overlap graph** — an edge `(i, j)` for
//!   every pair of work-groups that flush at least one common output row
//!   (the exact pairs whose unsynchronized stores could race);
//! * **conflict density** (edges over possible pairs) and the **max row
//!   sharers** (most work-groups touching one row — the contention
//!   hot-spot the §5.1 hierarchical path privatizes against);
//! * a partition of the batch's work-groups into **conflict-free waves**
//!   by greedy graph coloring. The coloring is *order-preserving*
//!   (levelized): `wave(w) = 1 + max(wave of conflicting predecessors)`,
//!   so for every edge `i < j`, `wave(i) < wave(j)`. Executing waves in
//!   order with a barrier between them therefore replays each row's
//!   flushes in work-group submission order — a waved run is bit-for-bit
//!   the sequential run, not merely numerically close (float addition is
//!   not associative; a smallest-available-color greedy coloring can
//!   reorder a row's updates and change low-order bits).
//!
//! Each batch gets a [`SyncClass`] recommendation — `NoSync` when the
//! overlap graph is empty, `Privatize` when one row is shared by most of
//! the batch (or the graph is dense), `Atomic` for sparse conflicts — and
//! the per-mode roll-up is a [`ConflictCertificate`]. Attached to a
//! [`BlcoEngine`](crate::mttkrp::blco::BlcoEngine), the certificate
//! replaces the §5.3 `target_len < SMs` threshold as the
//! `Resolution::Auto` policy and marks `NoSync` batches for the
//! streaming planner ([`StreamSchedule`](crate::coordinator::schedule::StreamSchedule)).
//! Certificates are validated against a structural [`Fingerprint`] at
//! attach time so a stale certificate can never silently certify the
//! wrong tensor.

use std::collections::{HashMap, HashSet};

use crate::device::counters::Counters;
use crate::format::store::BatchSource;
use crate::mttkrp::blco::Resolution;

/// Per-batch synchronization requirement, proven from metadata.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncClass {
    /// the row-overlap graph is empty: every work-group pair is
    /// row-disjoint, flushes need no synchronization at all
    NoSync,
    /// hot-row contention (one row shared by most work-groups, or a dense
    /// overlap graph): privatized shadow copies beat serialized atomics
    Privatize,
    /// sparse conflicts: occasional atomics are cheaper than privatizing
    /// whole output copies
    Atomic,
}

/// Per-(mode, block) conflict report: how the block's non-zeros project
/// onto the target mode.
#[derive(Clone, Debug)]
pub struct BlockConflict {
    /// global block index
    pub block: usize,
    pub nnz: usize,
    /// distinct output rows the block touches
    pub rows: usize,
    /// largest fiber: non-zeros sharing one output row within the block
    pub max_fiber_degree: usize,
}

/// One batch's certified conflict structure for one target mode.
#[derive(Clone, Debug)]
pub struct BatchCert {
    /// batch index within the tensor
    pub batch: usize,
    /// work-groups in the batch
    pub wgs: usize,
    pub nnz: usize,
    /// row-overlap graph: every pair `(i, j)` with `i < j` of work-groups
    /// sharing at least one output row. Sorted, deduplicated.
    pub edges: Vec<(u32, u32)>,
    /// `edges.len() / C(wgs, 2)` (0 for single-work-group batches)
    pub density: f64,
    /// most work-groups flushing any single output row
    pub max_row_sharers: usize,
    /// order-preserving wave (color) of each work-group
    pub wave_of: Vec<u32>,
    /// number of waves (1 = the whole batch is one conflict-free wave)
    pub waves: usize,
    pub recommendation: SyncClass,
}

impl BatchCert {
    /// Work-group ids grouped by wave, each group in submission order.
    pub fn wave_members(&self) -> Vec<Vec<u32>> {
        let mut members = vec![Vec::new(); self.waves];
        for (w, &wave) in self.wave_of.iter().enumerate() {
            members[wave as usize].push(w as u32);
        }
        members
    }
}

/// Structural identity of the tensor a certificate was computed from.
/// All fields are metadata the analysis actually depends on; equality is
/// required at [`BlcoEngine::with_certificates`](crate::mttkrp::blco::BlcoEngine::with_certificates).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    pub dims: Vec<u64>,
    pub nnz: usize,
    pub workgroup: usize,
    pub blocks: usize,
    pub batches: usize,
}

impl Fingerprint {
    pub fn of(src: &BatchSource) -> Self {
        Fingerprint {
            dims: src.dims().to_vec(),
            nnz: src.nnz(),
            workgroup: src.workgroup(),
            blocks: src.batches().last().map_or(0, |b| b.blocks.end),
            batches: src.num_batches(),
        }
    }
}

/// The per-`(tensor, mode)` certificate: block reports, per-batch wave
/// partitions and recommendations.
#[derive(Clone, Debug)]
pub struct ConflictCertificate {
    pub target: usize,
    pub fingerprint: Fingerprint,
    pub blocks: Vec<BlockConflict>,
    pub batches: Vec<BatchCert>,
}

impl ConflictCertificate {
    /// Batches whose overlap graph is empty (single-work-group batches
    /// are `NoSync` by construction).
    pub fn no_sync_batches(&self) -> usize {
        self.batches
            .iter()
            .filter(|b| b.recommendation == SyncClass::NoSync)
            .count()
    }

    /// Total row-overlap edges across all batches.
    pub fn conflict_pairs(&self) -> usize {
        self.batches.iter().map(|b| b.edges.len()).sum()
    }

    /// Deepest wave partition of any batch.
    pub fn max_waves(&self) -> usize {
        self.batches.iter().map(|b| b.waves).max().unwrap_or(0)
    }

    /// Largest `max_row_sharers` of any batch.
    pub fn max_row_sharers(&self) -> usize {
        self.batches.iter().map(|b| b.max_row_sharers).max().unwrap_or(0)
    }

    /// Batch counts by recommendation: `(no_sync, privatize, atomic)`.
    pub fn sync_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for b in &self.batches {
            match b.recommendation {
                SyncClass::NoSync => c.0 += 1,
                SyncClass::Privatize => c.1 += 1,
                SyncClass::Atomic => c.2 += 1,
            }
        }
        c
    }

    /// The engine-level strategy this certificate recommends for
    /// `Resolution::Auto`: an nnz-weighted vote between the conflicted
    /// batches. `Privatize`-dominant work wants the hierarchical
    /// shadow-copy path; otherwise register + atomics. `NoSync` batches
    /// abstain — their flushes are uncontended under either strategy.
    pub fn resolution(&self) -> Resolution {
        let (mut privatize_nnz, mut atomic_nnz) = (0u64, 0u64);
        for b in &self.batches {
            match b.recommendation {
                SyncClass::Privatize => privatize_nnz += b.nnz as u64,
                SyncClass::Atomic => atomic_nnz += b.nnz as u64,
                SyncClass::NoSync => {}
            }
        }
        if privatize_nnz > atomic_nnz {
            Resolution::Hierarchical
        } else {
            Resolution::Register
        }
    }
}

/// Analyze one target mode: decode every work-group's output-row set from
/// metadata, build the per-batch overlap graphs and wave partitions.
/// Batch fetches are charged to `counters` (host-side preprocessing I/O
/// for a disk-backed source; free for a resident one).
pub fn analyze_mode(
    src: &BatchSource,
    target: usize,
    counters: &Counters,
) -> ConflictCertificate {
    let spec = src.spec();
    assert!(target < spec.order(), "target {target} out of range");
    let wg_size = src.workgroup();
    let mut blocks_out = Vec::new();
    let mut batches_out = Vec::with_capacity(src.num_batches());

    for (bi, batch) in src.batches().iter().enumerate() {
        let fetched = src.fetch_batch(bi, counters);
        let base = batch.blocks.start;

        // per-(mode, block) report: distinct rows + max fiber degree
        for (k, blk) in fetched.iter().enumerate() {
            let mut per_row: HashMap<u32, usize> = HashMap::new();
            for &l in &blk.lidx {
                *per_row.entry(spec.decode_mode(blk.key, l, target)).or_insert(0) += 1;
            }
            blocks_out.push(BlockConflict {
                block: base + k,
                nnz: blk.nnz(),
                rows: per_row.len(),
                max_fiber_degree: per_row.values().copied().max().unwrap_or(0),
            });
        }

        // row → work-groups touching it. Work-groups are visited in
        // submission order, so each row's list is ascending and dedup-free.
        let wgs = batch.wg_block.len();
        let mut row_wgs: HashMap<u32, Vec<u32>> = HashMap::new();
        let mut seen = HashSet::new();
        for w in 0..wgs {
            let blk = &fetched[batch.wg_block[w] as usize - base];
            let offset = batch.wg_offset[w] as usize;
            let len = (blk.nnz() - offset).min(wg_size);
            seen.clear();
            for &l in &blk.lidx[offset..offset + len] {
                let row = spec.decode_mode(blk.key, l, target);
                if seen.insert(row) {
                    row_wgs.entry(row).or_default().push(w as u32);
                }
            }
        }

        let mut edge_set: HashSet<(u32, u32)> = HashSet::new();
        let mut max_row_sharers = 0usize;
        for sharers in row_wgs.values() {
            max_row_sharers = max_row_sharers.max(sharers.len());
            for i in 0..sharers.len() {
                for j in i + 1..sharers.len() {
                    edge_set.insert((sharers[i], sharers[j]));
                }
            }
        }
        let mut edges: Vec<(u32, u32)> = edge_set.into_iter().collect();
        edges.sort_unstable();

        // order-preserving (levelized) greedy coloring: each work-group
        // waits exactly one wave past its last conflicting predecessor,
        // so wave(i) < wave(j) for every edge i < j — see the module doc
        // for why this (and not smallest-available-color) preserves the
        // sequential flush order bit for bit.
        let mut preds: Vec<Vec<u32>> = vec![Vec::new(); wgs];
        for &(i, j) in &edges {
            preds[j as usize].push(i);
        }
        let mut wave_of = vec![0u32; wgs];
        for w in 0..wgs {
            let wave = preds[w].iter().map(|&p| wave_of[p as usize] + 1).max();
            wave_of[w] = wave.unwrap_or(0);
        }
        let waves = wave_of.iter().max().map_or(0, |&m| m as usize + 1);

        let pairs = wgs * wgs.saturating_sub(1) / 2;
        let density =
            if pairs == 0 { 0.0 } else { edges.len() as f64 / pairs as f64 };
        let recommendation = if edges.is_empty() {
            SyncClass::NoSync
        } else if max_row_sharers * 2 > wgs || density > 0.5 {
            SyncClass::Privatize
        } else {
            SyncClass::Atomic
        };

        batches_out.push(BatchCert {
            batch: bi,
            wgs,
            nnz: batch.nnz,
            edges,
            density,
            max_row_sharers,
            wave_of,
            waves,
            recommendation,
        });
    }

    ConflictCertificate {
        target,
        fingerprint: Fingerprint::of(src),
        blocks: blocks_out,
        batches: batches_out,
    }
}

/// Certificates for every mode of one tensor — what
/// [`BlcoEngine::with_certificates`](crate::mttkrp::blco::BlcoEngine::with_certificates)
/// consumes.
#[derive(Clone, Debug)]
pub struct CertificateSet {
    pub fingerprint: Fingerprint,
    modes: Vec<ConflictCertificate>,
}

impl CertificateSet {
    /// Analyze every mode, charging fetch I/O to a local scratch counter
    /// block (analysis is host-side preprocessing, not device traffic).
    pub fn analyze(src: &BatchSource) -> Self {
        Self::analyze_with(src, &Counters::new())
    }

    /// Analyze every mode, charging fetch I/O to `counters`.
    pub fn analyze_with(src: &BatchSource, counters: &Counters) -> Self {
        let modes = (0..src.order())
            .map(|m| analyze_mode(src, m, counters))
            .collect();
        CertificateSet { fingerprint: Fingerprint::of(src), modes }
    }

    /// The certificate for one target mode.
    pub fn mode(&self, target: usize) -> &ConflictCertificate {
        &self.modes[target]
    }

    pub fn num_modes(&self) -> usize {
        self.modes.len()
    }

    /// Does this set describe `src`'s structure?
    pub fn matches(&self, src: &BatchSource) -> bool {
        self.fingerprint == Fingerprint::of(src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::blco::{BlcoConfig, BlcoTensor};
    use crate::tensor::synth;

    fn source(dims: &[u64], nnz: usize, seed: u64, cfg: BlcoConfig) -> BatchSource {
        let t = synth::uniform(dims, nnz, seed);
        BatchSource::Resident(std::sync::Arc::new(BlcoTensor::from_coo_with(
            &t, cfg,
        )))
    }

    #[test]
    fn single_workgroup_batches_are_nosync() {
        // workgroup ≥ batch nnz → one work-group per batch → no pairs
        let cfg = BlcoConfig { max_block_nnz: 256, workgroup: 256, ..Default::default() };
        let src = source(&[40, 30, 20], 2_000, 3, cfg);
        let cert = analyze_mode(&src, 0, &Counters::new());
        for b in &cert.batches {
            assert!(b.wgs <= 1 || !b.edges.is_empty() || b.waves == 1);
            if b.wgs == 1 {
                assert_eq!(b.recommendation, SyncClass::NoSync);
                assert_eq!(b.waves, 1);
                assert_eq!(b.density, 0.0);
            }
        }
        assert!(cert.no_sync_batches() > 0);
    }

    #[test]
    fn waves_are_order_preserving_and_conflict_free() {
        let cfg = BlcoConfig { max_block_nnz: 1024, workgroup: 32, ..Default::default() };
        let src = source(&[20, 60, 50], 4_000, 7, cfg);
        for target in 0..3 {
            let cert = analyze_mode(&src, target, &Counters::new());
            for b in &cert.batches {
                for &(i, j) in &b.edges {
                    assert!(i < j, "edges stored ascending");
                    assert!(
                        b.wave_of[i as usize] < b.wave_of[j as usize],
                        "conflicting wg {i} must run a strictly earlier wave than {j}"
                    );
                }
                assert_eq!(
                    b.waves,
                    b.wave_of.iter().map(|&w| w as usize + 1).max().unwrap_or(0)
                );
                let members = b.wave_members();
                assert_eq!(
                    members.iter().map(Vec::len).sum::<usize>(),
                    b.wgs,
                    "waves partition the work-groups"
                );
            }
        }
    }

    #[test]
    fn short_contended_mode_recommends_privatize() {
        // 4 target rows across thousands of nnz: every work-group shares
        // rows with most others → privatize, i.e. hierarchical engine-wide
        let cfg = BlcoConfig { max_block_nnz: 4096, workgroup: 64, ..Default::default() };
        let src = source(&[4, 300, 300], 8_000, 11, cfg);
        let cert = analyze_mode(&src, 0, &Counters::new());
        let multi: Vec<_> =
            cert.batches.iter().filter(|b| b.wgs > 1).collect();
        assert!(!multi.is_empty());
        assert!(multi.iter().all(|b| b.recommendation == SyncClass::Privatize));
        assert_eq!(cert.resolution(), Resolution::Hierarchical);
        assert!(cert.max_row_sharers() > 1);
    }

    #[test]
    fn block_reports_cover_every_block_and_count_fibers() {
        let cfg = BlcoConfig { max_block_nnz: 512, workgroup: 64, ..Default::default() };
        let src = source(&[30, 30, 30], 3_000, 13, cfg);
        let nnz: usize = src.batches().iter().map(|b| b.nnz).sum();
        let cert = analyze_mode(&src, 1, &Counters::new());
        assert_eq!(
            cert.blocks.len(),
            src.batches().last().unwrap().blocks.end
        );
        assert_eq!(cert.blocks.iter().map(|b| b.nnz).sum::<usize>(), nnz);
        for b in &cert.blocks {
            assert!(b.rows >= 1 && b.max_fiber_degree >= 1);
            assert!(b.max_fiber_degree <= b.nnz);
            assert!(b.rows <= b.nnz);
        }
    }

    #[test]
    fn certificate_set_covers_all_modes_and_fingerprints() {
        let cfg = BlcoConfig { max_block_nnz: 512, workgroup: 64, ..Default::default() };
        let src = source(&[25, 35, 15], 2_500, 17, cfg);
        let set = CertificateSet::analyze(&src);
        assert_eq!(set.num_modes(), 3);
        assert!(set.matches(&src));
        for m in 0..3 {
            assert_eq!(set.mode(m).target, m);
            assert_eq!(set.mode(m).batches.len(), src.num_batches());
        }
        // a structurally different tensor must not match
        let other = source(&[25, 35, 15], 2_400, 17, cfg);
        assert!(!set.matches(&other));
    }
}
