//! Instrumented race checking: the dynamic verifier of the static
//! certificates in [`conflict`](super::conflict).
//!
//! The BLCO kernels gain a write-logging execution mode: every output-row
//! flush of [`process_tile`](crate::mttkrp::blco) — the single point all
//! register and hierarchical flushes funnel through — can append a
//! [`WriteRecord`] `(thread, batch, wave, wg, row)` to a shared
//! [`WriteLog`]. `wave` is the record's *ordering class*: the certified
//! wave for a wave-ordered run ([`run_waved`]), the constant 0 for a
//! plain register run (nothing orders its flushes but atomics), or the
//! shadow-copy index for a hierarchical run (copies are independent
//! destinations).
//!
//! Two checks are built on the log:
//!
//! * [`validate`] — a lockset-style pass over a waved run's records. The
//!   happens-before edges of that execution are exactly: batch order
//!   (kernel launches serialize) and wave order (a barrier between
//!   waves). Two writes to the same row are therefore ordered iff they
//!   differ in batch or wave, or come from one work-group (program
//!   order). Any same-`(batch, wave, row)` pair from two work-groups is
//!   an unordered conflicting write — a race the certificate wrongly
//!   certified away. A correct certificate yields zero.
//! * [`racecheck`] — the end-to-end harness behind `blco analyze
//!   --check`: runs the sequential register path with logging to observe
//!   every real row overlap, diffs the observation against the
//!   certificate's edges *in both directions* (a conflict the analysis
//!   missed would be unsound; a predicted conflict never observed would
//!   be imprecise — both are hard failures, since analysis and execution
//!   decode rows from the same metadata), then executes the wave
//!   schedule under [`validate`] and requires its output to be
//!   bit-for-bit the sequential result (the order-preserving coloring's
//!   guarantee — see the [`conflict`](super::conflict) module doc).
//!
//! [`run_waved`] is no longer just test scaffolding: it wraps the
//! *production* certified kernel
//! (`BlcoEngine::run_batch_certified`) — the path a certified engine's
//! `Mttkrp::mttkrp` and the streaming `mttkrp_batch` dispatch to at any
//! thread count. Within a wave every work-group owns its rows outright,
//! so flushes are plain stores — the per-wave `atomics` tally is
//! reclassified to the `nosync_flushes` counter and each barrier bumps
//! `waves`.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use super::conflict::ConflictCertificate;
use crate::device::counters::Counters;
use crate::mttkrp::atomicf::as_atomic;
use crate::mttkrp::blco::BlcoEngine;
use crate::mttkrp::check_shapes;
use crate::mttkrp::dense::Matrix;
use crate::util::pool::ExecBackend;

/// One logged output-row flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteRecord {
    /// logical thread that executed the flush
    pub thread: u32,
    /// batch (kernel launch) the work-group belonged to
    pub batch: u32,
    /// ordering class: wave index (waved run), 0 (register run), or
    /// shadow-copy index (hierarchical run)
    pub wave: u32,
    /// work-group within the batch
    pub wg: u32,
    /// output row flushed
    pub row: u32,
}

/// Shared, thread-safe flush log. Tiles append their rows in one locked
/// batch per tile, so logging does not serialize the hot loop per flush.
#[derive(Debug, Default)]
pub struct WriteLog {
    records: Mutex<Vec<WriteRecord>>,
}

impl WriteLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one tile's flushed rows under a single lock acquisition.
    pub fn append_tile(&self, thread: u32, batch: u32, wave: u32, wg: u32, rows: &[u32]) {
        let mut g = self.records.lock().expect("write log poisoned");
        g.extend(
            rows.iter().map(|&row| WriteRecord { thread, batch, wave, wg, row }),
        );
    }

    /// Drain the log (leaves it empty).
    pub fn take(&self) -> Vec<WriteRecord> {
        std::mem::take(&mut *self.records.lock().expect("write log poisoned"))
    }

    pub fn len(&self) -> usize {
        self.records.lock().expect("write log poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An unordered conflicting write pair found by [`validate`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Race {
    pub batch: u32,
    pub wave: u32,
    pub row: u32,
    pub wg_a: u32,
    pub wg_b: u32,
}

/// Lockset-style validation of a waved run's log: group records by
/// `(batch, wave, row)` — the contexts between which no happens-before
/// edge exists — and report every pair of distinct work-groups sharing a
/// group. Sorted and deduplicated; empty iff the schedule was
/// synchronization-free as certified.
pub fn validate(records: &[WriteRecord]) -> Vec<Race> {
    let mut slots: BTreeMap<(u32, u32, u32), BTreeSet<u32>> = BTreeMap::new();
    for r in records {
        slots.entry((r.batch, r.wave, r.row)).or_default().insert(r.wg);
    }
    let mut races = Vec::new();
    for ((batch, wave, row), wgs) in &slots {
        if wgs.len() < 2 {
            continue;
        }
        let wgs: Vec<u32> = wgs.iter().copied().collect();
        for i in 0..wgs.len() {
            for j in i + 1..wgs.len() {
                races.push(Race {
                    batch: *batch,
                    wave: *wave,
                    row: *row,
                    wg_a: wgs[i],
                    wg_b: wgs[j],
                });
            }
        }
    }
    races
}

/// The row-overlap pairs a log actually exhibited, per batch: every pair
/// of work-groups that flushed one common row, ignoring ordering classes.
/// On a sequential register-path log this is the ground truth the static
/// edges must equal.
pub fn observed_overlaps(records: &[WriteRecord]) -> BTreeMap<u32, BTreeSet<(u32, u32)>> {
    let mut rows: BTreeMap<(u32, u32), BTreeSet<u32>> = BTreeMap::new();
    for r in records {
        rows.entry((r.batch, r.row)).or_default().insert(r.wg);
    }
    let mut out: BTreeMap<u32, BTreeSet<(u32, u32)>> = BTreeMap::new();
    for ((batch, _row), wgs) in &rows {
        if wgs.len() < 2 {
            continue;
        }
        let wgs: Vec<u32> = wgs.iter().copied().collect();
        let set = out.entry(*batch).or_default();
        for i in 0..wgs.len() {
            for j in i + 1..wgs.len() {
                set.insert((wgs[i], wgs[j]));
            }
        }
    }
    out
}

/// Execute one MTTKRP under a certificate's wave schedule: batches in
/// order, each batch's work-groups wave by wave with a barrier between
/// waves, flushes as plain (serial) stores — the synchronization-free
/// schedule the certificate promises is safe. Within a wave, work-groups
/// are row-disjoint by construction, so unsynchronized stores from
/// parallel threads never collide; across waves the barrier orders them.
/// Flush work is charged to `nosync_flushes` instead of `atomics`, and
/// every barrier bumps `waves`.
///
/// This used to be the race checker's private scaffold; it is now the
/// *production* certified kernel
/// ([`BlcoEngine::run_batch_certified`](crate::mttkrp::blco::BlcoEngine)
/// — what a certified engine's `Mttkrp::mttkrp`/`mttkrp_batch` dispatch
/// to), and this wrapper only adds the fingerprint check, the zero-fill
/// and the instrumentation entry point the harness wants.
///
/// Overwrites `out` and, with `log`, records every flush under its wave
/// as ordering class — feed the log to [`validate`].
pub fn run_waved(
    eng: &BlcoEngine,
    cert: &ConflictCertificate,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
    log: Option<&WriteLog>,
) {
    assert!(
        cert.fingerprint == super::conflict::Fingerprint::of(&eng.src),
        "certificate does not describe this engine's tensor"
    );
    let target = cert.target;
    let rank = check_shapes(eng.src.dims(), target, factors, out);
    out.fill(0.0);
    let dest = as_atomic(&mut out.data);
    eng.run_certified(
        cert,
        target,
        factors,
        rank,
        dest,
        rank,
        ExecBackend::from_threads(threads),
        counters,
        log,
    );
}

/// What [`racecheck`] proved (or failed to prove) for one mode.
#[derive(Clone, Debug)]
pub struct RacecheckReport {
    pub target: usize,
    /// flush records logged by the waved run
    pub records: usize,
    /// unordered conflicting writes in the waved run — must be empty
    pub races: Vec<Race>,
    /// `(batch, wg_a, wg_b)` overlaps the sequential run exhibited that
    /// the certificate's edges miss — must be empty (soundness)
    pub missed_static: Vec<(u32, u32, u32)>,
    /// `(batch, wg_a, wg_b)` certificate edges the sequential run never
    /// exhibited — must be empty (exactness)
    pub stale_static: Vec<(u32, u32, u32)>,
    /// waved output equals the sequential output, bit for bit
    pub bit_identical: bool,
    /// deepest wave partition executed
    pub max_waves: usize,
}

impl RacecheckReport {
    /// All four obligations hold.
    pub fn ok(&self) -> bool {
        self.races.is_empty()
            && self.missed_static.is_empty()
            && self.stale_static.is_empty()
            && self.bit_identical
    }
}

/// Verify one mode's certificate against real executions (see the module
/// doc for the three phases). All traffic is charged to a local scratch
/// counter block: verification is a harness, not a workload.
pub fn racecheck(
    eng: &BlcoEngine,
    cert: &ConflictCertificate,
    factors: &[Matrix],
    threads: usize,
) -> RacecheckReport {
    let target = cert.target;
    let rank = factors[0].cols;
    let rows = eng.src.dims()[target] as usize;
    let counters = Counters::new();

    // phase 1: sequential register run, fully logged — the ground-truth
    // row-overlap observation and the bit-exact reference output
    let seq_log = WriteLog::new();
    let mut seq = Matrix::zeros(rows, rank);
    eng.mttkrp_logged(target, factors, &mut seq, 1, &counters, &seq_log);
    let observed = observed_overlaps(&seq_log.take());

    // phase 2: static edges vs observed overlaps, both directions
    let mut missed_static = Vec::new();
    let mut stale_static = Vec::new();
    for (bi, bc) in cert.batches.iter().enumerate() {
        let static_edges: BTreeSet<(u32, u32)> = bc.edges.iter().copied().collect();
        let empty = BTreeSet::new();
        let dynamic = observed.get(&(bi as u32)).unwrap_or(&empty);
        for &(i, j) in dynamic.difference(&static_edges) {
            missed_static.push((bi as u32, i, j));
        }
        for &(i, j) in static_edges.difference(dynamic) {
            stale_static.push((bi as u32, i, j));
        }
    }

    // phase 3: execute the certified wave schedule, validate its log,
    // compare its output against the sequential reference bit for bit
    let wav_log = WriteLog::new();
    let mut waved = Matrix::zeros(rows, rank);
    run_waved(eng, cert, factors, &mut waved, threads, &counters, Some(&wav_log));
    let records = wav_log.len();
    let races = validate(&wav_log.take());
    let bit_identical = seq.data.len() == waved.data.len()
        && seq
            .data
            .iter()
            .zip(&waved.data)
            .all(|(a, b)| a.to_bits() == b.to_bits());

    RacecheckReport {
        target,
        records,
        races,
        missed_static,
        stale_static,
        bit_identical,
        max_waves: cert.max_waves(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::conflict::CertificateSet;
    use crate::device::Profile;
    use crate::format::blco::{BlcoConfig, BlcoTensor};
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    fn engine(dims: &[u64], nnz: usize, seed: u64, cfg: BlcoConfig) -> BlcoEngine {
        let t = synth::uniform(dims, nnz, seed);
        BlcoEngine::new(BlcoTensor::from_coo_with(&t, cfg), Profile::a100())
    }

    #[test]
    fn validate_flags_same_slot_pairs_only() {
        let mk = |batch, wave, wg, row| WriteRecord { thread: 0, batch, wave, wg, row };
        // ordered: different wave / different batch / same wg / other row
        assert!(validate(&[mk(0, 0, 1, 9), mk(0, 1, 2, 9)]).is_empty());
        assert!(validate(&[mk(0, 0, 1, 9), mk(1, 0, 2, 9)]).is_empty());
        assert!(validate(&[mk(0, 0, 1, 9), mk(0, 0, 1, 9)]).is_empty());
        assert!(validate(&[mk(0, 0, 1, 9), mk(0, 0, 2, 8)]).is_empty());
        // unordered: same (batch, wave, row), distinct wgs
        let races = validate(&[mk(0, 2, 1, 9), mk(0, 2, 4, 9), mk(0, 2, 7, 9)]);
        assert_eq!(races.len(), 3, "all pairs of the 3-sharer slot");
        assert_eq!(
            races[0],
            Race { batch: 0, wave: 2, row: 9, wg_a: 1, wg_b: 4 }
        );
    }

    #[test]
    fn racecheck_passes_on_certified_schedules() {
        let cfg = BlcoConfig { max_block_nnz: 512, workgroup: 32, ..Default::default() };
        let eng = engine(&[40, 25, 30], 3_000, 5, cfg);
        let set = CertificateSet::analyze(&eng.src);
        let factors = random_factors(eng.src.dims(), 8, 7);
        for m in 0..3 {
            let rep = racecheck(&eng, set.mode(m), &factors, 4);
            assert!(rep.races.is_empty(), "mode {m}: {:?}", rep.races);
            assert!(rep.missed_static.is_empty(), "mode {m} missed");
            assert!(rep.stale_static.is_empty(), "mode {m} stale");
            assert!(rep.bit_identical, "mode {m} diverged");
            assert!(rep.ok());
            assert!(rep.records > 0);
        }
    }

    #[test]
    fn waved_run_matches_oracle_and_counts_waves() {
        let cfg = BlcoConfig { max_block_nnz: 1024, workgroup: 64, ..Default::default() };
        let t = synth::uniform(&[30, 40, 20], 4_000, 9);
        let eng = BlcoEngine::new(BlcoTensor::from_coo_with(&t, cfg), Profile::a100());
        let set = CertificateSet::analyze(&eng.src);
        let factors = random_factors(&t.dims, 8, 11);
        let c = Counters::new();
        let mut out = Matrix::zeros(30, 8);
        run_waved(&eng, set.mode(0), &factors, &mut out, 4, &c, None);
        let expect = mttkrp_oracle(&t, 0, &factors);
        assert!(out.max_abs_diff(&expect) < 1e-9);
        let s = c.snapshot();
        assert_eq!(s.atomics, 0, "certified waves issue no atomics");
        assert!(s.nosync_flushes > 0);
        assert!(s.waves as usize >= eng.src.num_batches());
    }

    #[test]
    fn sequential_logged_run_is_bitwise_the_plain_run() {
        use crate::mttkrp::blco::Resolution;
        use crate::mttkrp::Mttkrp;
        let cfg = BlcoConfig { max_block_nnz: 512, workgroup: 64, ..Default::default() };
        let eng = engine(&[25, 35, 45], 2_500, 13, cfg)
            .with_resolution(Resolution::Register);
        let factors = random_factors(eng.src.dims(), 4, 15);
        let (c1, c2) = (Counters::new(), Counters::new());
        let log = WriteLog::new();
        let mut logged = Matrix::zeros(25, 4);
        eng.mttkrp_logged(0, &factors, &mut logged, 1, &c1, &log);
        let mut plain = Matrix::zeros(25, 4);
        eng.mttkrp(0, &factors, &mut plain, 1, &c2);
        assert!(logged
            .data
            .iter()
            .zip(&plain.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // every flush the counters saw is in the log
        assert_eq!(log.len() as u64 * 4, c1.snapshot().atomics);
    }
}
