//! Artifact manifest parsing. `make artifacts` writes
//! `artifacts/manifest.txt` with one flat `key=value` line per AOT variant
//! (see `python/compile/aot.py`); this module locates and indexes it.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT-compiled computation, mirroring `python/compile/config.Variant`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactVariant {
    pub name: String,
    pub file: String,
    pub order: usize,
    pub rank: usize,
    /// block capacity (inputs are zero-padded to this many non-zeros)
    pub capacity: usize,
    pub target: usize,
    /// "fused" (in-graph segment-sum) or "partials" (L3 merges)
    pub kind: String,
    pub dtype: String,
    /// padded factor-matrix row counts
    pub dims: Vec<u64>,
}

/// The manifest index.
#[derive(Clone, Debug, Default)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub variants: Vec<ArtifactVariant>,
}

/// Default artifacts directory: `$BLCO_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("BLCO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest.display()))?;
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            variants.push(
                parse_line(line)
                    .with_context(|| format!("{}:{}", manifest.display(), lineno + 1))?,
            );
        }
        if variants.is_empty() {
            bail!("{}: no variants", manifest.display());
        }
        Ok(Artifacts { dir: dir.to_path_buf(), variants })
    }

    /// Find a variant able to run a mode-`target` MTTKRP for a tensor with
    /// `dims` (padded dims must cover the tensor's) at `rank`.
    pub fn find(
        &self,
        dims: &[u64],
        rank: usize,
        target: usize,
        kind: &str,
    ) -> Option<&ArtifactVariant> {
        self.variants.iter().find(|v| {
            v.order == dims.len()
                && v.rank == rank
                && v.target == target
                && v.kind == kind
                && v.dims.iter().zip(dims).all(|(&pad, &d)| pad >= d)
        })
    }

    pub fn path_of(&self, v: &ArtifactVariant) -> PathBuf {
        self.dir.join(&v.file)
    }
}

fn parse_line(line: &str) -> Result<ArtifactVariant> {
    let mut kv = std::collections::HashMap::new();
    for tok in line.split_whitespace() {
        let (k, v) = tok
            .split_once('=')
            .with_context(|| format!("bad token {tok:?}"))?;
        kv.insert(k.to_string(), v.to_string());
    }
    let get = |k: &str| -> Result<String> {
        kv.get(k).cloned().with_context(|| format!("missing key {k}"))
    };
    let dims: Vec<u64> = get("dims")?
        .split(',')
        .map(|d| d.parse().context("bad dim"))
        .collect::<Result<_>>()?;
    let v = ArtifactVariant {
        name: get("name")?,
        file: get("file")?,
        order: get("order")?.parse()?,
        rank: get("rank")?.parse()?,
        capacity: get("capacity")?.parse()?,
        target: get("target")?.parse()?,
        kind: get("kind")?,
        dtype: get("dtype")?,
        dims,
    };
    if v.dims.len() != v.order {
        bail!("{}: dims/order mismatch", v.name);
    }
    if v.target >= v.order {
        bail!("{}: target out of range", v.name);
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_good_line() {
        let v = parse_line(
            "name=x file=x.hlo.txt order=3 rank=32 capacity=4096 target=1 \
             kind=fused dtype=float32 dims=1024,512,256",
        )
        .unwrap();
        assert_eq!(v.name, "x");
        assert_eq!(v.dims, vec![1024, 512, 256]);
        assert_eq!(v.target, 1);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse_line("name=x").is_err());
        assert!(parse_line(
            "name=x file=f order=2 rank=4 capacity=16 target=5 kind=fused \
             dtype=float32 dims=4,4"
        )
        .is_err());
        assert!(parse_line(
            "name=x file=f order=3 rank=4 capacity=16 target=0 kind=fused \
             dtype=float32 dims=4,4"
        )
        .is_err());
    }

    #[test]
    fn find_honours_dims_and_kind() {
        let a = Artifacts {
            dir: PathBuf::from("."),
            variants: vec![parse_line(
                "name=x file=f order=3 rank=32 capacity=4096 target=0 \
                 kind=fused dtype=float32 dims=1024,1024,1024",
            )
            .unwrap()],
        };
        assert!(a.find(&[1000, 800, 600], 32, 0, "fused").is_some());
        assert!(a.find(&[2000, 800, 600], 32, 0, "fused").is_none()); // too big
        assert!(a.find(&[1000, 800, 600], 16, 0, "fused").is_none()); // rank
        assert!(a.find(&[1000, 800, 600], 32, 1, "fused").is_none()); // target
        assert!(a.find(&[1000, 800, 600], 32, 0, "partials").is_none()); // kind
    }

    #[test]
    fn load_real_manifest_if_present() {
        // exercises the end-to-end manifest when `make artifacts` has run
        let dir = default_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: no artifacts dir at {}", dir.display());
            return;
        }
        let a = Artifacts::load(&dir).unwrap();
        assert!(a.find(&[1000, 800, 600], 32, 0, "fused").is_some());
        assert!(a.find(&[250, 250, 250, 60], 32, 3, "partials").is_some());
        for v in &a.variants {
            assert!(a.path_of(v).exists(), "{} missing", v.file);
        }
    }
}
