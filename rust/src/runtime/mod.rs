//! The PJRT bridge: load the AOT-compiled HLO text produced by
//! `python/compile/aot.py` (the L2 JAX graph embedding the L1 Pallas
//! kernel), compile it once on the PJRT CPU client, and execute BLCO blocks
//! through it from the Rust request path. Python never runs here.

pub mod artifacts;
pub mod exec;

pub use artifacts::{ArtifactVariant, Artifacts};
pub use exec::PjrtRuntime;
