//! PJRT execution of AOT-compiled block MTTKRP.
//!
//! Pattern per `/opt/xla-example/load_hlo`: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. One executable per variant, compiled
//! lazily and cached; Python is never on this path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifacts::{ArtifactVariant, Artifacts};
use crate::device::counters::{Counters, Snapshot};
use crate::format::blco::BlcoTensor;
use crate::mttkrp::dense::Matrix;

/// A PJRT CPU runtime bound to an artifacts directory.
///
/// Not `Sync`: PJRT handles are used from the coordinator's executor thread
/// (kernel *launches* are serialized in this harness; parallelism lives
/// inside the XLA executable and in the Rust engines).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub artifacts: Artifacts,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    pub fn new(dir: &Path) -> Result<Self> {
        let artifacts = Artifacts::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client, artifacts, exes: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for a variant.
    pub fn executable(&self, v: &ArtifactVariant) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&v.name) {
            return Ok(e.clone());
        }
        let path = self.artifacts.path_of(v);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile {}", v.name))?,
        );
        self.exes.borrow_mut().insert(v.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Mode-`target` MTTKRP of a whole BLCO tensor through the AOT `fused`
    /// variant, one launch per `capacity`-sized chunk of each block.
    ///
    /// Factor matrices are converted to the variant dtype (f32) and padded
    /// to the variant dims once per call; the fused kernel's padded output
    /// is cropped and accumulated into `out` (f64).
    pub fn mttkrp_fused(
        &self,
        t: &BlcoTensor,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        counters: &Counters,
    ) -> Result<()> {
        let dims = t.dims().to_vec();
        let rank = factors[0].cols;
        let v = self
            .artifacts
            .find(&dims, rank, target, "fused")
            .with_context(|| {
                format!(
                    "no fused artifact for dims {dims:?} rank {rank} target {target}"
                )
            })?
            .clone();
        let exe = self.executable(&v)?;

        // padded f32 factor literals, built once
        let factor_lits: Vec<xla::Literal> = (0..v.order)
            .map(|n| {
                let padded_rows = v.dims[n] as usize;
                let mut data = vec![0.0f32; padded_rows * rank];
                for r in 0..factors[n].rows {
                    for k in 0..rank {
                        data[r * rank + k] = factors[n].row(r)[k] as f32;
                    }
                }
                xla::Literal::vec1(&data)
                    .reshape(&[padded_rows as i64, rank as i64])
                    .context("reshape factor")
            })
            .collect::<Result<_>>()?;

        out.fill(0.0);
        let cap = v.capacity;
        let out_rows = dims[target] as usize;
        let mut lidx_buf = vec![0i64; cap];
        let mut vals_buf = vec![0.0f32; cap];

        for blk in &t.blocks {
            let bases: Vec<i32> =
                t.spec.bases(blk.key).iter().map(|&b| b as i32).collect();
            let bases_lit = xla::Literal::vec1(&bases);
            let mut off = 0usize;
            while off < blk.nnz() {
                let len = (blk.nnz() - off).min(cap);
                for i in 0..cap {
                    if i < len {
                        lidx_buf[i] = blk.lidx[off + i] as i64;
                        vals_buf[i] = blk.vals[off + i] as f32;
                    } else {
                        lidx_buf[i] = 0;
                        vals_buf[i] = 0.0; // padding contributes nothing
                    }
                }
                let lidx_lit = xla::Literal::vec1(&lidx_buf);
                let vals_lit = xla::Literal::vec1(&vals_buf);
                let mut inputs: Vec<&xla::Literal> =
                    vec![&lidx_lit, &vals_lit, &bases_lit];
                inputs.extend(factor_lits.iter());

                let result = exe.execute::<&xla::Literal>(&inputs)?[0][0]
                    .to_literal_sync()?;
                // lowered with return_tuple=True → a 1-tuple
                let m = result.to_tuple1().context("unwrap fused output")?;
                let flat: Vec<f32> = m.to_vec().context("read fused output")?;
                let padded_rows = v.dims[target] as usize;
                if flat.len() != padded_rows * rank {
                    bail!(
                        "fused output size {} != {}x{}",
                        flat.len(),
                        padded_rows,
                        rank
                    );
                }
                for r in 0..out_rows {
                    let o = out.row_mut(r);
                    for k in 0..rank {
                        o[k] += flat[r * rank + k] as f64;
                    }
                }
                counters.add(&Snapshot {
                    launches: 1,
                    bytes_streamed: (len * 16) as u64,
                    bytes_gathered: (len * (v.order - 1) * rank * 4) as u64,
                    bytes_written: (out_rows * rank * 4) as u64,
                    ..Default::default()
                });
                off += len;
            }
        }
        Ok(())
    }
}

impl PjrtRuntime {
    /// Mode-`target` MTTKRP through the AOT `partials` variant: the kernel
    /// returns per-nnz rank-wise rows + decoded target ids, and *this
    /// coordinator* performs the conflict resolution (register-style
    /// segment merging over the returned tile) — the paper's Section 5
    /// merge hoisted to L3, with the XLA executable as the compute phase.
    pub fn mttkrp_partials(
        &self,
        t: &BlcoTensor,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        counters: &Counters,
    ) -> Result<()> {
        let dims = t.dims().to_vec();
        let rank = factors[0].cols;
        let v = self
            .artifacts
            .find(&dims, rank, target, "partials")
            .with_context(|| {
                format!(
                    "no partials artifact for dims {dims:?} rank {rank} target {target}"
                )
            })?
            .clone();
        let exe = self.executable(&v)?;

        let factor_lits: Vec<xla::Literal> = (0..v.order)
            .map(|n| {
                let padded_rows = v.dims[n] as usize;
                let mut data = vec![0.0f32; padded_rows * rank];
                for r in 0..factors[n].rows {
                    for k in 0..rank {
                        data[r * rank + k] = factors[n].row(r)[k] as f32;
                    }
                }
                xla::Literal::vec1(&data)
                    .reshape(&[padded_rows as i64, rank as i64])
                    .context("reshape factor")
            })
            .collect::<Result<_>>()?;

        out.fill(0.0);
        let cap = v.capacity;
        let mut lidx_buf = vec![0i64; cap];
        let mut vals_buf = vec![0.0f32; cap];

        for blk in &t.blocks {
            let bases: Vec<i32> =
                t.spec.bases(blk.key).iter().map(|&b| b as i32).collect();
            let bases_lit = xla::Literal::vec1(&bases);
            let mut off = 0usize;
            while off < blk.nnz() {
                let len = (blk.nnz() - off).min(cap);
                for i in 0..cap {
                    if i < len {
                        lidx_buf[i] = blk.lidx[off + i] as i64;
                        vals_buf[i] = blk.vals[off + i] as f32;
                    } else {
                        lidx_buf[i] = 0;
                        vals_buf[i] = 0.0;
                    }
                }
                let lidx_lit = xla::Literal::vec1(&lidx_buf);
                let vals_lit = xla::Literal::vec1(&vals_buf);
                let mut inputs: Vec<&xla::Literal> =
                    vec![&lidx_lit, &vals_lit, &bases_lit];
                inputs.extend(factor_lits.iter());

                let result = exe.execute::<&xla::Literal>(&inputs)?[0][0]
                    .to_literal_sync()?;
                let (partials, tgt) =
                    result.to_tuple2().context("unwrap partials outputs")?;
                let p: Vec<f32> = partials.to_vec().context("read partials")?;
                let ids: Vec<i32> = tgt.to_vec().context("read target ids")?;
                if p.len() != cap * rank || ids.len() != cap {
                    bail!("partials output shape mismatch");
                }
                // L3 conflict resolution: register-style accumulation over
                // the (unsorted) returned tile; padding rows carry zeros
                for i in 0..len {
                    let row = ids[i] as usize;
                    let o = out.row_mut(row);
                    for k in 0..rank {
                        o[k] += p[i * rank + k] as f64;
                    }
                }
                counters.add(&Snapshot {
                    launches: 1,
                    bytes_streamed: (len * 16) as u64,
                    bytes_gathered: (len * (v.order - 1) * rank * 4) as u64,
                    bytes_written: (len * rank * 4) as u64,
                    segments: len as u64,
                    ..Default::default()
                });
                off += len;
            }
        }
        Ok(())
    }
}

// No unit tests here: PJRT needs the compiled artifacts; see
// rust/tests/pjrt_integration.rs for the end-to-end checks against the
// Rust engines (skipped gracefully when `make artifacts` has not run).
