//! Bench harness shared by `benches/*` (criterion is unavailable offline):
//! warmup + median-of-k timing, geometric means, and fixed-width table
//! printing in the layout of the paper's figures/tables.

use std::time::Duration;

use crate::device::counters::{Counters, Snapshot};
use crate::device::model::{device_time, throughput_tbps};
use crate::device::profile::Profile;
use crate::mttkrp::dense::Matrix;
use crate::mttkrp::Mttkrp;
use crate::util::timer::time_median;

/// One measured MTTKRP: wall time, modelled device time, exact traffic.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub wall: Duration,
    pub model_s: f64,
    pub snap: Snapshot,
}

impl Measurement {
    pub fn volume_gb(&self) -> f64 {
        self.snap.volume_bytes() as f64 / 1e9
    }

    /// Modelled device throughput (Table 3 "TP"), TB/s.
    pub fn model_tp_tbps(&self) -> f64 {
        throughput_tbps(self.snap.volume_bytes(), self.model_s)
    }
}

/// Time `engine.mttkrp(target, ...)` with `reps` repetitions (median) and
/// collect one clean counter snapshot.
pub fn measure(
    engine: &dyn Mttkrp,
    target: usize,
    factors: &[Matrix],
    rows: usize,
    threads: usize,
    reps: usize,
    profile: &Profile,
) -> Measurement {
    let rank = factors[0].cols;
    let mut out = Matrix::zeros(rows, rank);
    let wall = time_median(reps, || {
        let scratch = Counters::new();
        engine.mttkrp(target, factors, &mut out, threads, &scratch);
    });
    let counters = Counters::new();
    engine.mttkrp(target, factors, &mut out, threads, &counters);
    let snap = counters.snapshot();
    let model_s = device_time(&snap, profile).total();
    Measurement { wall, model_s, snap }
}

/// Sum of per-mode measurements (the "all-mode MTTKRP" the paper reports).
pub fn total_seconds(ms: &[Measurement]) -> (f64, f64) {
    (
        ms.iter().map(|m| m.wall.as_secs_f64()).sum(),
        ms.iter().map(|m| m.model_s).sum(),
    )
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Fixed-width table printer.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(widths: &[usize]) -> Self {
        Table { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{c:>w$} "));
        }
        println!("{}", line.trim_end());
    }

    pub fn header(&self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len();
        println!("{}", "-".repeat(total));
    }
}

/// `reps` default for benches, overridable via BLCO_BENCH_REPS.
/// Smoke mode pins it to 1 unless explicitly overridden.
pub fn bench_reps() -> usize {
    std::env::var("BLCO_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke() { 1 } else { 3 })
}

/// Reduced-size CI mode: `--smoke` on the bench binary's command line or
/// `BLCO_BENCH_SMOKE=1` in the environment. Benches shrink their presets
/// and sweeps to seconds-fast sizes; the numbers trace the perf
/// *trajectory* (artifact `BENCH_smoke.json`), not the paper's figures.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("BLCO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// One bench binary's metrics record, flushed as a JSON line to the file
/// named by `BLCO_BENCH_JSON` (append mode, so the bench-smoke CI job
/// collects every figure into one stream; `tools/merge_bench_json.py`
/// consolidates and validates it into `BENCH_smoke.json`). Without the
/// env var, `flush()` is a no-op — interactive runs stay table-only.
pub struct BenchJson {
    figure: String,
    /// worker count the bench's kernels ran with (the `ExecBackend`
    /// thread count), recorded so perf history is comparable across
    /// differently-parallel CI legs
    threads: usize,
    metrics: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl BenchJson {
    pub fn new(figure: &str) -> Self {
        BenchJson {
            figure: figure.to_string(),
            threads: crate::util::pool::default_threads(),
            metrics: Vec::new(),
        }
    }

    /// Override the recorded worker count (benches that pin their own
    /// thread count rather than following `BLCO_THREADS`).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Record one named number. Non-finite values are serialized as
    /// `null` (JSON has no NaN/inf) — the merge script rejects them, so a
    /// poisoned metric fails the bench-smoke job instead of hiding.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Append this record as one JSON line to `$BLCO_BENCH_JSON`.
    pub fn flush(self) {
        let Ok(path) = std::env::var("BLCO_BENCH_JSON") else {
            return;
        };
        let mut fields: Vec<String> = Vec::with_capacity(self.metrics.len());
        for (name, v) in &self.metrics {
            let val = if v.is_finite() {
                // enough digits to round-trip an f64
                format!("{v:e}")
            } else {
                "null".to_string()
            };
            fields.push(format!("\"{}\": {val}", json_escape(name)));
        }
        let line = format!(
            "{{\"figure\": \"{}\", \"smoke\": {}, \"threads\": {}, \"metrics\": {{{}}}}}\n",
            json_escape(&self.figure),
            smoke(),
            self.threads,
            fields.join(", ")
        );
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .unwrap_or_else(|e| panic!("open {path}: {e}"));
        f.write_all(line.as_bytes())
            .unwrap_or_else(|e| panic!("append to {path}: {e}"));
    }
}

/// Banner printed by every bench binary.
pub fn banner(figure: &str, what: &str) {
    println!("\n=== {figure}: {what} ===");
    println!(
        "(synthetic scaled presets; modelled device times from exact \
         counters — see DESIGN.md §3-§4)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping_and_number_format() {
        assert_eq!(json_escape("plain_name"), "plain_name");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        // the number formatter must emit JSON-parseable tokens
        for v in [0.0f64, 2.0, -1.5, 1e-12, 3.25e9] {
            let s = format!("{v:e}");
            assert!(s.parse::<f64>().is_ok(), "{s}");
            assert!(!s.contains("NaN") && !s.contains("inf"));
        }
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn measure_runs_engine() {
        use crate::mttkrp::coo::CooAtomicEngine;
        use crate::mttkrp::oracle::random_factors;
        use crate::tensor::synth;
        let t = synth::uniform(&[20, 20, 20], 500, 1);
        let f = random_factors(&t.dims, 4, 2);
        let eng = CooAtomicEngine::new(t);
        let m = measure(&eng, 0, &f, 20, 2, 2, &Profile::a100());
        assert!(m.snap.volume_bytes() > 0);
        assert!(m.model_s > 0.0);
        assert!(m.model_tp_tbps() > 0.0);
    }
}
