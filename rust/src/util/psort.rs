//! Parallel MSB-bucket sort for (u128 key, u32 payload) pairs — the BLCO
//! construction sort (§Perf). One counting pass over the top byte of the
//! key domain, a scatter into 256 buckets, then per-bucket `sort_unstable`
//! across threads. Falls back to `sort_unstable` for small inputs.

use super::pool::parallel_dynamic;

/// Threshold below which the serial sort wins.
const PAR_THRESHOLD: usize = 1 << 16;

/// Sort pairs ascending by key (then payload), in parallel.
///
/// # Key-width contract
///
/// `key_bits` is a *balance hint*, not a precondition: buckets are drawn
/// from the top byte of the declared key range, so keys within
/// `[0, 2^key_bits)` spread across all 256 buckets. Keys *above* that
/// range are still sorted correctly — they all funnel into the last
/// bucket (`min(k >> shift, 255)` keeps bucket assignment monotone in the
/// key) and get ordered by the per-bucket sort; they only cost balance,
/// never correctness. An earlier version masked the shifted key to its
/// low byte instead, which wrapped out-of-range keys into arbitrary
/// earlier buckets and silently returned unsorted output.
pub fn par_sort_pairs(data: &mut [(u128, u32)], threads: usize, key_bits: u32) {
    let n = data.len();
    if n < PAR_THRESHOLD || threads <= 1 {
        data.sort_unstable();
        return;
    }
    // bucket by the top byte of the *used* key range so buckets are
    // balanced even when key_bits << 128; saturate (don't mask) so a key
    // wider than key_bits lands in the last bucket instead of wrapping
    let shift = key_bits.saturating_sub(8);
    let bucket_of = |k: u128| -> usize { (k >> shift).min(0xFF) as usize };

    // counting pass
    let mut counts = [0usize; 256];
    for &(k, _) in data.iter() {
        counts[bucket_of(k)] += 1;
    }
    let mut starts = [0usize; 256];
    let mut acc = 0usize;
    for b in 0..256 {
        starts[b] = acc;
        acc += counts[b];
    }

    // scatter into a scratch buffer
    let mut scratch: Vec<(u128, u32)> = vec![(0, 0); n];
    {
        let mut cursor = starts;
        for &pair in data.iter() {
            let b = bucket_of(pair.0);
            scratch[cursor[b]] = pair;
            cursor[b] += 1;
        }
    }
    data.copy_from_slice(&scratch);
    drop(scratch);

    // sort each bucket independently; buckets are contiguous and disjoint
    let ranges: Vec<(usize, usize)> = (0..256)
        .map(|b| (starts[b], starts[b] + counts[b]))
        .filter(|(lo, hi)| hi > lo)
        .collect();
    let base = data.as_mut_ptr() as usize;
    parallel_dynamic(threads, ranges.len(), 1, |_, rlo, rhi| {
        for r in rlo..rhi {
            let (lo, hi) = ranges[r];
            // SAFETY: bucket ranges are disjoint, each handled by one task
            let slice = unsafe {
                std::slice::from_raw_parts_mut(
                    (base as *mut (u128, u32)).add(lo),
                    hi - lo,
                )
            };
            slice.sort_unstable();
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_pairs(n: usize, bits: u32, seed: u64) -> Vec<(u128, u32)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let hi = if bits > 64 { rng.next_u64() as u128 } else { 0 };
                let k = ((hi << 64) | rng.next_u64() as u128)
                    & crate::util::bitops::mask128(bits);
                (k, i as u32)
            })
            .collect()
    }

    #[test]
    fn matches_serial_sort_large() {
        let mut a = random_pairs(200_000, 37, 1);
        let mut b = a.clone();
        par_sort_pairs(&mut a, 8, 37);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn matches_serial_sort_wide_keys() {
        let mut a = random_pairs(100_000, 100, 2);
        let mut b = a.clone();
        par_sort_pairs(&mut a, 4, 100);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn small_input_falls_back() {
        let mut a = random_pairs(1000, 20, 3);
        let mut b = a.clone();
        par_sort_pairs(&mut a, 8, 20);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn keys_above_declared_width_still_sort() {
        // Regression: a key at key_bits + 1 bits used to be bucketed by
        // `(k >> shift) & 0xFF`, wrapping it into bucket 0 — it sorted
        // *within* bucket 0 but stayed ahead of every larger-bucket key,
        // so the output was silently unsorted. The saturating bucket maps
        // it to the last bucket and the global order survives.
        let n = 200_000;
        let bits = 20u32;
        let mut a = random_pairs(n, bits, 9);
        // two keys one bit above the declared width, plus one max-width key
        a[0].0 = 1u128 << (bits + 1);
        a[1].0 = (1u128 << (bits + 1)) | 3;
        a[2].0 = u128::MAX;
        let mut b = a.clone();
        par_sort_pairs(&mut a, 8, bits);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn skewed_keys() {
        // everything in one bucket: correctness must not depend on balance
        let mut a: Vec<(u128, u32)> =
            (0..100_000u32).rev().map(|i| (5u128, i)).collect();
        par_sort_pairs(&mut a, 8, 10);
        for w in a.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
