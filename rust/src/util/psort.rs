//! Parallel MSB-bucket sort for (u128 key, u32 payload) pairs — the BLCO
//! construction sort (§Perf). One counting pass over the top byte of the
//! key domain, a scatter into 256 buckets, then per-bucket `sort_unstable`
//! across threads. Falls back to `sort_unstable` for small inputs.

use super::pool::parallel_dynamic;

/// Threshold below which the serial sort wins.
const PAR_THRESHOLD: usize = 1 << 16;

/// Sort pairs ascending by key (then payload), in parallel.
pub fn par_sort_pairs(data: &mut [(u128, u32)], threads: usize, key_bits: u32) {
    let n = data.len();
    if n < PAR_THRESHOLD || threads <= 1 {
        data.sort_unstable();
        return;
    }
    // bucket by the top byte of the *used* key range so buckets are
    // balanced even when key_bits << 128
    let shift = key_bits.saturating_sub(8);
    let bucket_of = |k: u128| -> usize { ((k >> shift) & 0xFF) as usize };

    // counting pass
    let mut counts = [0usize; 256];
    for &(k, _) in data.iter() {
        counts[bucket_of(k)] += 1;
    }
    let mut starts = [0usize; 256];
    let mut acc = 0usize;
    for b in 0..256 {
        starts[b] = acc;
        acc += counts[b];
    }

    // scatter into a scratch buffer
    let mut scratch: Vec<(u128, u32)> = vec![(0, 0); n];
    {
        let mut cursor = starts;
        for &pair in data.iter() {
            let b = bucket_of(pair.0);
            scratch[cursor[b]] = pair;
            cursor[b] += 1;
        }
    }
    data.copy_from_slice(&scratch);
    drop(scratch);

    // sort each bucket independently; buckets are contiguous and disjoint
    let ranges: Vec<(usize, usize)> = (0..256)
        .map(|b| (starts[b], starts[b] + counts[b]))
        .filter(|(lo, hi)| hi > lo)
        .collect();
    let base = data.as_mut_ptr() as usize;
    parallel_dynamic(threads, ranges.len(), 1, |_, rlo, rhi| {
        for r in rlo..rhi {
            let (lo, hi) = ranges[r];
            // SAFETY: bucket ranges are disjoint, each handled by one task
            let slice = unsafe {
                std::slice::from_raw_parts_mut(
                    (base as *mut (u128, u32)).add(lo),
                    hi - lo,
                )
            };
            slice.sort_unstable();
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_pairs(n: usize, bits: u32, seed: u64) -> Vec<(u128, u32)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let hi = if bits > 64 { rng.next_u64() as u128 } else { 0 };
                let k = ((hi << 64) | rng.next_u64() as u128)
                    & crate::util::bitops::mask128(bits);
                (k, i as u32)
            })
            .collect()
    }

    #[test]
    fn matches_serial_sort_large() {
        let mut a = random_pairs(200_000, 37, 1);
        let mut b = a.clone();
        par_sort_pairs(&mut a, 8, 37);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn matches_serial_sort_wide_keys() {
        let mut a = random_pairs(100_000, 100, 2);
        let mut b = a.clone();
        par_sort_pairs(&mut a, 4, 100);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn small_input_falls_back() {
        let mut a = random_pairs(1000, 20, 3);
        let mut b = a.clone();
        par_sort_pairs(&mut a, 8, 20);
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn skewed_keys() {
        // everything in one bucket: correctness must not depend on balance
        let mut a: Vec<(u128, u32)> =
            (0..100_000u32).rev().map(|i| (5u128, i)).collect();
        par_sort_pairs(&mut a, 8, 10);
        for w in a.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
