//! Shrinking-lite property testing (proptest is not in the offline vendor
//! set). A property runs against `cases` random seeds; on failure the seed
//! is reported so the case can be replayed deterministically, and the
//! harness retries the failing case with "smaller" size hints to aid
//! debugging.

use super::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// maximum "size" hint handed to generators
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xB1C0_5EED, max_size: 64 }
    }
}

/// Per-case context: a seeded RNG plus a size hint that grows with the case
/// index (small cases first, like proptest).
pub struct Ctx {
    pub rng: Rng,
    pub size: usize,
}

/// Run `prop` for `cfg.cases` cases. `prop` returns `Err(msg)` to fail.
/// Panics with seed + message on failure (after a bounded shrink attempt).
pub fn check<F>(name: &str, cfg: Config, prop: F)
where
    F: Fn(&mut Ctx) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // ramp the size hint: early cases are tiny, later ones larger
        let size = 2 + (cfg.max_size.saturating_sub(2)) * case / cfg.cases.max(1);
        let mut ctx = Ctx { rng: Rng::new(seed), size };
        if let Err(msg) = prop(&mut ctx) {
            // shrink-lite: replay the same seed with smaller size hints and
            // report the smallest size that still fails
            let mut min_fail = size;
            let mut min_msg = msg;
            let mut s = size / 2;
            while s >= 2 {
                let mut ctx = Ctx { rng: Rng::new(seed), size: s };
                if let Err(m) = prop(&mut ctx) {
                    min_fail = s;
                    min_msg = m;
                }
                s /= 2;
            }
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}, \
                 size {min_fail}): {min_msg}"
            );
        }
    }
}

impl Ctx {
    /// Random length in `[1, size]`.
    pub fn len(&mut self) -> usize {
        1 + self.rng.below(self.size as u64) as usize
    }

    /// Random dims vector for an `order`-mode tensor, each in `[1, size]`.
    pub fn dims(&mut self, order: usize) -> Vec<u64> {
        (0..order).map(|_| 1 + self.rng.below(self.size as u64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut seen = 0;
        // interior mutability not needed: run a fresh counter via Cell
        let counter = std::cell::Cell::new(0usize);
        check("always_ok", Config { cases: 10, ..Default::default() }, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        seen += counter.get();
        assert_eq!(seen, 10);
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failing_property_panics_with_seed() {
        check("always_fails", Config { cases: 3, ..Default::default() }, |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn size_hint_ramps() {
        let sizes = std::cell::RefCell::new(Vec::new());
        check(
            "sizes",
            Config { cases: 8, max_size: 64, ..Default::default() },
            |ctx| {
                sizes.borrow_mut().push(ctx.size);
                Ok(())
            },
        );
        let s = sizes.borrow();
        assert!(s.first().unwrap() < s.last().unwrap());
    }

    #[test]
    fn ctx_helpers_in_range() {
        let mut ctx = Ctx { rng: Rng::new(7), size: 10 };
        for _ in 0..100 {
            let l = ctx.len();
            assert!((1..=10).contains(&l));
        }
        let d = ctx.dims(3);
        assert_eq!(d.len(), 3);
        assert!(d.iter().all(|&x| (1..=10).contains(&x)));
    }
}
