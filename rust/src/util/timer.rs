//! Wall-clock timing helpers used by format-construction stage breakdowns,
//! the bench harness and the streaming coordinator.

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named stage durations in order.
#[derive(Debug, Default)]
pub struct Stages {
    last: Option<Instant>,
    pub stages: Vec<(String, Duration)>,
}

impl Stages {
    pub fn new() -> Self {
        Stages { last: Some(Instant::now()), stages: Vec::new() }
    }

    /// Record the time since the previous mark under `name`.
    pub fn mark(&mut self, name: &str) {
        let now = Instant::now();
        let start = self.last.replace(now).unwrap_or(now);
        self.stages.push((name.to_string(), now - start));
    }

    pub fn total(&self) -> Duration {
        self.stages.iter().map(|(_, d)| *d).sum()
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.stages.iter().find(|(n, _)| n == name).map(|(_, d)| *d)
    }
}

/// Median-of-k timing of `f`, with one untimed warmup run.
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> Duration {
    f(); // warmup
    let mut samples: Vec<Duration> = (0..reps.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    samples.sort();
    samples[samples.len() / 2]
}

/// Pretty duration, e.g. "1.23 ms".
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_accumulate_in_order() {
        let mut st = Stages::new();
        st.mark("a");
        st.mark("b");
        assert_eq!(st.stages.len(), 2);
        assert_eq!(st.stages[0].0, "a");
        assert!(st.get("b").is_some());
        assert!(st.get("c").is_none());
        assert!(st.total() >= st.get("a").unwrap());
    }

    #[test]
    fn median_timing_runs() {
        let mut n = 0u64;
        let d = time_median(3, || n += 1);
        assert_eq!(n, 4); // warmup + 3
        assert!(d.as_nanos() < 1_000_000_000);
    }

    #[test]
    fn fmt() {
        assert!(fmt_duration(Duration::from_millis(1500)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_micros(1500)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_nanos(1500)).ends_with(" µs"));
    }
}
