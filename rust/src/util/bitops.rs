//! Bit-manipulation helpers for encoding lines up to 128 bits.

/// Bits needed to represent coordinates in `[0, dim)`; at least 1.
#[inline]
pub fn mode_bits(dim: u64) -> u32 {
    if dim <= 1 {
        1
    } else {
        64 - (dim - 1).leading_zeros()
    }
}

/// Mask with the low `bits` bits set (u64, `bits <= 64`).
#[inline]
pub fn mask64(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Mask with the low `bits` bits set (u128, `bits <= 128`).
#[inline]
pub fn mask128(bits: u32) -> u128 {
    if bits >= 128 {
        u128::MAX
    } else {
        (1u128 << bits) - 1
    }
}

/// Extract `bits` bits of `x` starting at `shift`.
#[inline]
pub fn extract128(x: u128, shift: u32, bits: u32) -> u64 {
    ((x >> shift) & mask128(bits)) as u64
}

/// Ceil division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_bits_edges() {
        assert_eq!(mode_bits(0), 1);
        assert_eq!(mode_bits(1), 1);
        assert_eq!(mode_bits(2), 1);
        assert_eq!(mode_bits(3), 2);
        assert_eq!(mode_bits(4), 2);
        assert_eq!(mode_bits(5), 3);
        assert_eq!(mode_bits(1024), 10);
        assert_eq!(mode_bits(1025), 11);
        assert_eq!(mode_bits(1 << 32), 32);
        assert_eq!(mode_bits(u64::MAX), 64);
    }

    #[test]
    fn masks() {
        assert_eq!(mask64(0), 0);
        assert_eq!(mask64(3), 0b111);
        assert_eq!(mask64(64), u64::MAX);
        assert_eq!(mask128(128), u128::MAX);
        assert_eq!(mask128(65), (1u128 << 65) - 1);
    }

    #[test]
    fn extract() {
        let x: u128 = 0b1011_0110;
        assert_eq!(extract128(x, 1, 3), 0b011);
        assert_eq!(extract128(x, 4, 4), 0b1011);
        let hi = 0xABCDu128 << 100;
        assert_eq!(extract128(hi, 100, 16), 0xABCD);
    }

    #[test]
    fn rounding() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
    }
}
