//! Scoped data-parallel helpers on std threads (rayon is not in the offline
//! vendor set). These model the "massively parallel" execution of the paper:
//! a team of worker threads plays the role of the GPU's execution units.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `BLCO_THREADS` env or available
/// parallelism (min 1). Malformed values (`0`, `abc`, negative) are
/// rejected with a stderr warning instead of being silently ignored.
pub fn default_threads() -> usize {
    match std::env::var("BLCO_THREADS") {
        Ok(v) => match parse_thread_count(&v) {
            Ok(n) => n,
            Err(reason) => {
                eprintln!(
                    "warning: ignoring BLCO_THREADS={v:?} ({reason}); \
                     falling back to available parallelism"
                );
                hardware_threads()
            }
        },
        Err(_) => hardware_threads(),
    }
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Validate a thread-count string: a positive integer, nothing else.
/// Returns a human-readable rejection reason on failure.
pub fn parse_thread_count(v: &str) -> Result<usize, &'static str> {
    match v.trim().parse::<usize>() {
        Ok(0) => Err("thread count must be >= 1"),
        Ok(n) => Ok(n),
        Err(_) => Err("not a positive integer"),
    }
}

/// How an execution path runs its data-parallel loops. Every kernel and
/// executor consumes one of these instead of a bare thread count, so the
/// sequential/threaded decision is made once (CLI `--threads`, the
/// `BLCO_THREADS` env, or a caller's explicit choice) and flows through
/// the whole stack unchanged.
///
/// The invariant the backend preserves: for any `ExecBackend`, certified
/// kernel paths produce **bit-for-bit** the sequential result — waved
/// schedules replay each row's flushes in submission order (see
/// [`crate::analysis::conflict`]), and the hierarchical merge walks its
/// shadow copies in a fixed order per row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecBackend {
    /// one thread, plain loops — the reference semantics
    Sequential,
    /// `nthreads` workers over [`parallel_chunks`]/[`parallel_dynamic`]
    Threaded {
        /// worker count (>= 2; 0/1 normalize to `Sequential`)
        nthreads: usize,
    },
}

impl ExecBackend {
    /// Normalize a bare thread count: `0` and `1` mean [`Sequential`],
    /// anything larger is [`Threaded`].
    ///
    /// [`Sequential`]: ExecBackend::Sequential
    /// [`Threaded`]: ExecBackend::Threaded
    pub fn from_threads(threads: usize) -> Self {
        if threads <= 1 {
            ExecBackend::Sequential
        } else {
            ExecBackend::Threaded { nthreads: threads }
        }
    }

    /// The backend picked by the environment ([`default_threads`]).
    pub fn from_env() -> Self {
        Self::from_threads(default_threads())
    }

    /// The worker count this backend runs with (always >= 1).
    pub fn threads(&self) -> usize {
        match self {
            ExecBackend::Sequential => 1,
            ExecBackend::Threaded { nthreads } => (*nthreads).max(1),
        }
    }

    pub fn is_sequential(&self) -> bool {
        self.threads() == 1
    }

    /// Run `f(thread_id, lo, hi)` over contiguous slices of `0..len`
    /// (static partition, see [`parallel_chunks`]).
    pub fn chunks<F>(&self, len: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Sync,
    {
        parallel_chunks(self.threads(), len, f);
    }

    /// Run `f(thread_id, lo, hi)` with dynamic chunk grabbing (see
    /// [`parallel_dynamic`]).
    pub fn dynamic<F>(&self, len: usize, chunk: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Sync,
    {
        parallel_dynamic(self.threads(), len, chunk, f);
    }
}

impl Default for ExecBackend {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Run `f(thread_id, lo, hi)` over `nthreads` contiguous slices of `0..len`.
/// Slices differ in size by at most one element.
pub fn parallel_chunks<F>(nthreads: usize, len: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(len.max(1));
    if nthreads == 1 {
        f(0, 0, len);
        return;
    }
    let base = len / nthreads;
    let rem = len % nthreads;
    std::thread::scope(|s| {
        let f = &f;
        let mut lo = 0usize;
        for t in 0..nthreads {
            let sz = base + usize::from(t < rem);
            let hi = lo + sz;
            s.spawn(move || f(t, lo, hi));
            lo = hi;
        }
    });
}

/// Dynamic work-stealing-ish loop: threads grab chunks of `chunk` items from
/// a shared counter until `len` is exhausted. Mirrors the GPU hardware
/// scheduler balancing non-uniform non-zero work (Section 4.2 of the paper).
pub fn parallel_dynamic<F>(nthreads: usize, len: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1);
    let chunk = chunk.max(1);
    if nthreads == 1 || len <= chunk {
        f(0, 0, len);
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let f = &f;
        let next = &next;
        for t in 0..nthreads {
            s.spawn(move || loop {
                let lo = next.fetch_add(chunk, Ordering::Relaxed);
                if lo >= len {
                    break;
                }
                let hi = (lo + chunk).min(len);
                f(t, lo, hi);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 100, 101] {
            for nt in [1usize, 2, 3, 8, 200] {
                let sum = AtomicU64::new(0);
                let count = AtomicU64::new(0);
                parallel_chunks(nt, len, |_, lo, hi| {
                    for i in lo..hi {
                        sum.fetch_add(i as u64, Ordering::Relaxed);
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert_eq!(count.load(Ordering::Relaxed), len as u64);
                let expect: u64 = (0..len as u64).sum();
                assert_eq!(sum.load(Ordering::Relaxed), expect);
            }
        }
    }

    #[test]
    fn dynamic_covers_exactly() {
        for len in [0usize, 5, 1000] {
            for chunk in [1usize, 3, 64] {
                let hits = AtomicU64::new(0);
                parallel_dynamic(4, len, chunk, |_, lo, hi| {
                    hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
                });
                assert_eq!(hits.load(Ordering::Relaxed), len as u64);
            }
        }
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn thread_count_parsing_rejects_malformed_values() {
        // the validator default_threads() uses for BLCO_THREADS: malformed
        // values are rejected (warn + fall back), never silently ignored
        assert_eq!(parse_thread_count("1"), Ok(1));
        assert_eq!(parse_thread_count("8"), Ok(8));
        assert_eq!(parse_thread_count(" 4 "), Ok(4));
        assert!(parse_thread_count("0").is_err(), "zero threads is invalid");
        assert!(parse_thread_count("abc").is_err());
        assert!(parse_thread_count("-2").is_err());
        assert!(parse_thread_count("").is_err());
        assert!(parse_thread_count("4.5").is_err());
    }

    #[test]
    fn backend_normalizes_thread_counts() {
        assert_eq!(ExecBackend::from_threads(0), ExecBackend::Sequential);
        assert_eq!(ExecBackend::from_threads(1), ExecBackend::Sequential);
        assert_eq!(
            ExecBackend::from_threads(4),
            ExecBackend::Threaded { nthreads: 4 }
        );
        assert_eq!(ExecBackend::Sequential.threads(), 1);
        assert_eq!(ExecBackend::Threaded { nthreads: 6 }.threads(), 6);
        assert!(ExecBackend::Sequential.is_sequential());
        assert!(!ExecBackend::from_threads(2).is_sequential());
        assert!(ExecBackend::from_env().threads() >= 1);
    }

    #[test]
    fn backend_loops_cover_exactly() {
        for be in [ExecBackend::Sequential, ExecBackend::from_threads(4)] {
            let sum = AtomicU64::new(0);
            be.chunks(100, |_, lo, hi| {
                for i in lo..hi {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
            assert_eq!(sum.load(Ordering::Relaxed), (0..100u64).sum());
            let hits = AtomicU64::new(0);
            be.dynamic(1000, 16, |_, lo, hi| {
                hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 1000);
        }
    }
}
