//! Scoped data-parallel helpers on std threads (rayon is not in the offline
//! vendor set). These model the "massively parallel" execution of the paper:
//! a team of worker threads plays the role of the GPU's execution units.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `BLCO_THREADS` env or available
/// parallelism (min 1).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("BLCO_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(thread_id, lo, hi)` over `nthreads` contiguous slices of `0..len`.
/// Slices differ in size by at most one element.
pub fn parallel_chunks<F>(nthreads: usize, len: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(len.max(1));
    if nthreads == 1 {
        f(0, 0, len);
        return;
    }
    let base = len / nthreads;
    let rem = len % nthreads;
    std::thread::scope(|s| {
        let f = &f;
        let mut lo = 0usize;
        for t in 0..nthreads {
            let sz = base + usize::from(t < rem);
            let hi = lo + sz;
            s.spawn(move || f(t, lo, hi));
            lo = hi;
        }
    });
}

/// Dynamic work-stealing-ish loop: threads grab chunks of `chunk` items from
/// a shared counter until `len` is exhausted. Mirrors the GPU hardware
/// scheduler balancing non-uniform non-zero work (Section 4.2 of the paper).
pub fn parallel_dynamic<F>(nthreads: usize, len: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1);
    let chunk = chunk.max(1);
    if nthreads == 1 || len <= chunk {
        f(0, 0, len);
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let f = &f;
        let next = &next;
        for t in 0..nthreads {
            s.spawn(move || loop {
                let lo = next.fetch_add(chunk, Ordering::Relaxed);
                if lo >= len {
                    break;
                }
                let hi = (lo + chunk).min(len);
                f(t, lo, hi);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 100, 101] {
            for nt in [1usize, 2, 3, 8, 200] {
                let sum = AtomicU64::new(0);
                let count = AtomicU64::new(0);
                parallel_chunks(nt, len, |_, lo, hi| {
                    for i in lo..hi {
                        sum.fetch_add(i as u64, Ordering::Relaxed);
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert_eq!(count.load(Ordering::Relaxed), len as u64);
                let expect: u64 = (0..len as u64).sum();
                assert_eq!(sum.load(Ordering::Relaxed), expect);
            }
        }
    }

    #[test]
    fn dynamic_covers_exactly() {
        for len in [0usize, 5, 1000] {
            for chunk in [1usize, 3, 64] {
                let hits = AtomicU64::new(0);
                parallel_dynamic(4, len, chunk, |_, lo, hi| {
                    hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
                });
                assert_eq!(hits.load(Ordering::Relaxed), len as u64);
            }
        }
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
