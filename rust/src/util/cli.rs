//! Minimal CLI argument parsing (clap is not in the offline vendor set).
//! Supports `--flag`, `--key value` and `--key=value`, plus positionals.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]). The
    /// first non-option token becomes the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse option `key` as `T`, falling back to `default`. Panics with a
    /// readable message on malformed input.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|e| panic!("--{key} {s:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // note: `--flag positional` would parse the positional as the flag's
        // value (standard greedy `--key value`), so positionals come first
        let a = parse("mttkrp x.tns --tensor uber --mode 2 --rank=16 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("mttkrp"));
        assert_eq!(a.get("tensor"), Some("uber"));
        assert_eq!(a.parse_or::<usize>("mode", 0), 2);
        assert_eq!(a.parse_or::<usize>("rank", 0), 16);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["x.tns"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.parse_or::<u32>("missing", 7), 7);
        assert!(!a.flag("missing"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("cmd --dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    #[should_panic]
    fn malformed_number_panics() {
        let a = parse("cmd --n abc");
        let _: usize = a.parse_or("n", 0);
    }
}
