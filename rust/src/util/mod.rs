//! Small self-contained utilities: PRNG, bit tricks, timing, a scoped
//! parallel-for, a shrinking-lite property-test harness and a tiny CLI
//! argument parser. Everything std-only — the offline vendor set has no
//! rand/rayon/proptest/clap.

pub mod bitops;
pub mod cli;
pub mod pool;
pub mod prng;
pub mod psort;
pub mod prop;
pub mod timer;
