//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**) plus the sampling
//! helpers the synthetic dataset generators need: uniform ranges, normals
//! (Box–Muller) and a bounded Zipf sampler for fiber-density skew.

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // avoid log(0)
        let u1 = (self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Bounded Zipf(θ) sample in `[0, n)` by inverse-CDF over a cached
    /// normalizer-free rejection scheme (Gries/Jacobson style approximation):
    /// cheap and good enough to skew fiber densities.
    pub fn zipf(&mut self, n: u64, theta: f64) -> u64 {
        debug_assert!(n > 0);
        if n == 1 || theta <= 0.0 {
            return self.below(n);
        }
        // Inverse transform on the continuous approximation of the Zipf CDF:
        // P(X <= x) ≈ (x^(1-θ) - 1) / (n^(1-θ) - 1) for θ != 1.
        let a = 1.0 - theta;
        let u = self.f64();
        let x = if (theta - 1.0).abs() < 1e-9 {
            ((n as f64).ln() * u).exp()
        } else {
            let nn = (n as f64).powf(a);
            (1.0 + u * (nn - 1.0)).powf(1.0 / a)
        };
        (x as u64).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(4);
        let mut low = 0;
        let n = 20_000;
        for _ in 0..n {
            if r.zipf(1000, 1.2) < 10 {
                low += 1;
            }
        }
        // heavily skewed: far more than the uniform 1% lands in the first 10
        assert!(low > n / 10, "low {low}");
    }

    #[test]
    fn zipf_in_range_and_uniform_fallback() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.zipf(17, 0.9) < 17);
            assert!(r.zipf(1, 1.1) == 0);
            assert!(r.zipf(17, 0.0) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
