//! The BLCO re-encoding (Section 4.1–4.2): split the ALTO-linearized index
//! into a *block key* (the uppermost bits of every mode that exceed the
//! 63-bit in-block budget) and an *in-block index* whose per-mode bits are
//! rearranged into contiguous fields, so de-linearization needs only a
//! shift and a mask per mode — natively fast on accelerators.
//!
//! Layout (must match `python/compile/config.py` bit-for-bit): mode 0
//! occupies the uppermost field of both the key and the in-block index,
//! mode N-1 the lowermost (Figure 6b).

use super::alto::Encoding;
use crate::util::bitops::{mask64, mode_bits};

/// In-block indices use at most 63 bits so they round-trip through the
/// non-negative range of `i64` at the PJRT boundary.
pub const MAX_INBLOCK_BITS: u32 = 63;

/// The derived bit layout for one tensor shape.
#[derive(Clone, Debug)]
pub struct BlcoSpec {
    pub dims: Vec<u64>,
    pub alto: Encoding,
    /// per-mode bits kept inside the block
    pub inblock_bits: Vec<u32>,
    /// per-mode bits stripped into the block key (adaptive blocking)
    pub key_bits: Vec<u32>,
    /// in-block field shifts, mode 0 uppermost
    pub offsets: Vec<u32>,
    /// key field shifts, mode 0 uppermost
    pub key_offsets: Vec<u32>,
    pub total_inblock_bits: u32,
    pub total_key_bits: u32,
    /// byte-lookup re-encoding tables (§Perf): `tables[i][b]` is the
    /// (key, inblock) contribution of byte `i` of the ALTO index having
    /// value `b`. Replaces the per-bit scatter loop on the construction
    /// hot path (one table probe per ALTO byte instead of one shift/mask
    /// per bit, and no per-call allocation).
    reencode_tables: Vec<[(u64, u64); 256]>,
}

impl BlcoSpec {
    /// Derive the layout for `dims` with the given in-block bit budget
    /// (pass [`MAX_INBLOCK_BITS`] outside tests).
    ///
    /// Excess bits are stripped following the ALTO bit order from the MSB
    /// down — each stripped position removes the current top bit of the mode
    /// that owns it, so the stripped set is exactly "the uppermost bits from
    /// every mode" and block sub-spaces adapt to the tensor space (§4.2).
    pub fn with_budget(dims: &[u64], budget: u32) -> Self {
        let alto = Encoding::new(dims);
        let order = dims.len();
        let mb: Vec<u32> = dims.iter().map(|&d| mode_bits(d)).collect();
        let total: u32 = mb.iter().sum();

        let mut key_bits = vec![0u32; order];
        if total > budget {
            let excess = (total - budget) as usize;
            // the top `excess` ALTO positions, MSB down
            for p in (total as usize - excess..total as usize).rev() {
                key_bits[alto.bit_mode[p] as usize] += 1;
            }
        }
        let inblock_bits: Vec<u32> =
            mb.iter().zip(&key_bits).map(|(&b, &k)| b - k).collect();
        let total_key_bits: u32 = key_bits.iter().sum();
        assert!(total_key_bits <= 64, "block key needs {total_key_bits} bits > 64");
        let total_inblock_bits: u32 = inblock_bits.iter().sum();

        let field_offsets = |bits: &[u32]| -> Vec<u32> {
            let mut offs = Vec::with_capacity(bits.len());
            let mut acc: u32 = bits.iter().sum();
            for &b in bits {
                acc -= b;
                offs.push(acc);
            }
            offs
        };
        let offsets = field_offsets(&inblock_bits);
        let key_offsets = field_offsets(&key_bits);

        let mut spec = BlcoSpec {
            dims: dims.to_vec(),
            alto,
            inblock_bits,
            key_bits,
            offsets,
            key_offsets,
            total_inblock_bits,
            total_key_bits,
            reencode_tables: Vec::new(),
        };
        spec.build_reencode_tables();
        spec
    }

    /// Precompute the byte-granular re-encoding tables (see field docs).
    fn build_reencode_tables(&mut self) {
        let total = self.alto.total_bits as usize;
        let nbytes = total.div_ceil(8);
        // per-ALTO-bit destination: (is_key, shift) — derived exactly like
        // the reference per-bit encoders below
        let mut dest = vec![(false, 0u32); total];
        let mut filled = vec![0u32; self.order()];
        for p in 0..self.total_inblock_bits as usize {
            let m = self.alto.bit_mode[p] as usize;
            dest[p] = (false, self.offsets[m] + filled[m]);
            filled[m] += 1;
        }
        let mut remaining = self.key_bits.clone();
        for p in (self.total_inblock_bits as usize..total).rev() {
            let m = self.alto.bit_mode[p] as usize;
            remaining[m] -= 1;
            dest[p] = (true, self.key_offsets[m] + remaining[m]);
        }
        self.reencode_tables = (0..nbytes)
            .map(|i| {
                let mut table = [(0u64, 0u64); 256];
                for (b, entry) in table.iter_mut().enumerate() {
                    let (mut k, mut l) = (0u64, 0u64);
                    for bit in 0..8usize {
                        let p = i * 8 + bit;
                        if p >= total || (b >> bit) & 1 == 0 {
                            continue;
                        }
                        let (is_key, sh) = dest[p];
                        if is_key {
                            k |= 1u64 << sh;
                        } else {
                            l |= 1u64 << sh;
                        }
                    }
                    *entry = (k, l);
                }
                table
            })
            .collect();
    }

    /// Re-encode a full ALTO index in one pass: `(block_key, in_block)`.
    /// Table-driven (one probe per ALTO byte); agrees bit-for-bit with
    /// [`Self::key_of_alto`] + [`Self::inblock_of_alto`].
    #[inline]
    pub fn reencode_alto(&self, alto_idx: u128) -> (u64, u64) {
        let (mut k, mut l) = (0u64, 0u64);
        for (i, table) in self.reencode_tables.iter().enumerate() {
            let byte = ((alto_idx >> (i * 8)) & 0xFF) as usize;
            let (tk, tl) = table[byte];
            k |= tk;
            l |= tl;
        }
        (k, l)
    }

    pub fn new(dims: &[u64]) -> Self {
        Self::with_budget(dims, MAX_INBLOCK_BITS)
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Does this shape need more than one top-level block?
    #[inline]
    pub fn needs_blocking(&self) -> bool {
        self.total_key_bits > 0
    }

    /// Split a coordinate tuple into `(block_key, in_block_index)`.
    #[inline]
    pub fn encode(&self, coord: &[u32]) -> (u64, u64) {
        debug_assert_eq!(coord.len(), self.order());
        let mut key: u64 = 0;
        let mut l: u64 = 0;
        for n in 0..self.order() {
            let c = coord[n] as u64;
            let ib = self.inblock_bits[n];
            l |= (c & mask64(ib)) << self.offsets[n];
            key |= ((c >> ib) & mask64(self.key_bits[n])) << self.key_offsets[n];
        }
        (key, l)
    }

    /// Recover global coordinates from `(block_key, in_block_index)` —
    /// one shift + mask per mode plus the block base.
    #[inline]
    pub fn decode(&self, key: u64, l: u64, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.order());
        for n in 0..self.order() {
            let ib = (l >> self.offsets[n]) & mask64(self.inblock_bits[n]);
            let kb = (key >> self.key_offsets[n]) & mask64(self.key_bits[n]);
            out[n] = ((kb << self.inblock_bits[n]) | ib) as u32;
        }
    }

    /// Decode only the target-mode coordinate (the hot path of the MTTKRP
    /// computing phase needs the target first for segment detection).
    #[inline]
    pub fn decode_mode(&self, key: u64, l: u64, n: usize) -> u32 {
        let ib = (l >> self.offsets[n]) & mask64(self.inblock_bits[n]);
        let kb = (key >> self.key_offsets[n]) & mask64(self.key_bits[n]);
        ((kb << self.inblock_bits[n]) | ib) as u32
    }

    /// Per-mode factor-row bases of a block (its key's contribution to every
    /// global coordinate) — handed to the AOT kernel as the `bases` input.
    pub fn bases(&self, key: u64) -> Vec<u32> {
        (0..self.order())
            .map(|n| {
                let kb = (key >> self.key_offsets[n]) & mask64(self.key_bits[n]);
                (kb << self.inblock_bits[n]) as u32
            })
            .collect()
    }

    /// Block key of an ALTO linear index: its top `total_key_bits` bits.
    /// (The stripped positions are exactly the uppermost ALTO positions, so
    /// ALTO order groups equal keys contiguously — blocks fall out of one
    /// sort.) The key is then *re-encoded* mode-contiguously to match
    /// [`Self::encode`].
    #[inline]
    pub fn key_of_alto(&self, alto_idx: u128) -> u64 {
        if self.total_key_bits == 0 {
            return 0;
        }
        let total = self.alto.total_bits;
        let mut key: u64 = 0;
        // walk stripped positions MSB-down, depositing into per-mode fields
        let mut remaining = vec![0u32; self.order()];
        for n in 0..self.order() {
            remaining[n] = self.key_bits[n];
        }
        for p in (self.total_inblock_bits..total).rev() {
            let m = self.alto.bit_mode[p as usize] as usize;
            remaining[m] -= 1;
            let bit = ((alto_idx >> p) & 1) as u64;
            key |= bit << (self.key_offsets[m] + remaining[m]);
        }
        key
    }

    /// In-block index of an ALTO linear index: re-encode the low
    /// `total_inblock_bits` ALTO positions into contiguous mode fields.
    #[inline]
    pub fn inblock_of_alto(&self, alto_idx: u128) -> u64 {
        let mut l: u64 = 0;
        let mut filled = vec![0u32; self.order()];
        for p in 0..self.total_inblock_bits {
            let m = self.alto.bit_mode[p as usize] as usize;
            let bit = ((alto_idx >> p) & 1) as u64;
            l |= bit << (self.offsets[m] + filled[m]);
            filled[m] += 1;
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn no_blocking_when_line_fits() {
        let s = BlcoSpec::new(&[1024, 1024, 1024]);
        assert_eq!(s.total_key_bits, 0);
        assert!(!s.needs_blocking());
        assert_eq!(s.total_inblock_bits, 30);
        assert_eq!(s.offsets, vec![20, 10, 0]); // mode 0 uppermost
    }

    #[test]
    fn blocking_strips_uppermost_bits() {
        // 3 x 24 bits = 72 > 63 → 9 key bits, like the paper's 72-bit example
        let dims = vec![1 << 24, 1 << 24, 1 << 24];
        let s = BlcoSpec::new(&dims);
        assert_eq!(s.total_key_bits, 9);
        assert_eq!(s.total_inblock_bits, 63);
        // round-robin ALTO: the top 9 positions hit each mode 3 times
        assert_eq!(s.key_bits, vec![3, 3, 3]);
        assert_eq!(s.inblock_bits, vec![21, 21, 21]);
    }

    #[test]
    fn figure6b_reencoding() {
        // The paper's example (Figure 6b): 6-bit line, budget 5 → 1 key bit.
        let s = BlcoSpec::with_budget(&[4, 4, 4], 5);
        assert_eq!(s.total_key_bits, 1);
        // the stripped ALTO MSB (pos 5) belongs to mode 2 in round-robin
        assert_eq!(s.key_bits, vec![0, 0, 1]);
        // every coordinate round-trips through (key, inblock)
        let mut out = vec![0u32; 3];
        for i0 in 0..4u32 {
            for i1 in 0..4u32 {
                for i2 in 0..4u32 {
                    let (k, l) = s.encode(&[i0, i1, i2]);
                    assert!(k <= 1);
                    assert!(l < 32);
                    s.decode(k, l, &mut out);
                    assert_eq!(out, vec![i0, i1, i2]);
                }
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_prop() {
        check("blco_roundtrip", Config { cases: 96, max_size: 1 << 26, ..Default::default() }, |ctx| {
            let order = 2 + ctx.rng.below(3) as usize;
            let dims: Vec<u64> =
                (0..order).map(|_| 2 + ctx.rng.below(ctx.size as u64)).collect();
            let s = BlcoSpec::new(&dims);
            let mut out = vec![0u32; order];
            for _ in 0..40 {
                let coord: Vec<u32> =
                    dims.iter().map(|&d| ctx.rng.below(d) as u32).collect();
                let (k, l) = s.encode(&coord);
                if l >= (1u64 << s.total_inblock_bits.min(63)) && s.total_inblock_bits < 64 {
                    return Err(format!("in-block overflow {l}"));
                }
                s.decode(k, l, &mut out);
                if out != coord {
                    return Err(format!("{dims:?}: {coord:?} -> ({k},{l}) -> {out:?}"));
                }
                // decode_mode agrees with full decode
                for n in 0..order {
                    if s.decode_mode(k, l, n) != coord[n] {
                        return Err(format!("decode_mode {n} mismatch"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn table_reencode_matches_reference_encoders() {
        check("table_vs_bitloop", Config { cases: 64, max_size: 1 << 26, ..Default::default() }, |ctx| {
            let order = 2 + ctx.rng.below(3) as usize;
            let dims: Vec<u64> =
                (0..order).map(|_| 2 + ctx.rng.below(ctx.size as u64)).collect();
            let s = BlcoSpec::new(&dims);
            for _ in 0..50 {
                let coord: Vec<u32> =
                    dims.iter().map(|&d| ctx.rng.below(d) as u32).collect();
                let a = s.alto.encode(&coord);
                let fast = s.reencode_alto(a);
                let slow = (s.key_of_alto(a), s.inblock_of_alto(a));
                if fast != slow {
                    return Err(format!("{dims:?} {coord:?}: {fast:?} != {slow:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn alto_path_matches_direct_encode() {
        // key_of_alto / inblock_of_alto must agree with encode() for all
        // coordinates: the construction pipeline uses the ALTO path, the
        // kernels use the direct field layout.
        check("alto_vs_direct", Config { cases: 64, max_size: 1 << 24, ..Default::default() }, |ctx| {
            let order = 2 + ctx.rng.below(3) as usize;
            let dims: Vec<u64> =
                (0..order).map(|_| 2 + ctx.rng.below(ctx.size as u64)).collect();
            let s = BlcoSpec::new(&dims);
            for _ in 0..40 {
                let coord: Vec<u32> =
                    dims.iter().map(|&d| ctx.rng.below(d) as u32).collect();
                let a = s.alto.encode(&coord);
                let (k1, l1) = (s.key_of_alto(a), s.inblock_of_alto(a));
                let (k2, l2) = s.encode(&coord);
                if (k1, l1) != (k2, l2) {
                    return Err(format!(
                        "{dims:?} {coord:?}: alto path ({k1},{l1}) != direct ({k2},{l2})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bases_decompose_keys() {
        let dims = vec![1 << 24, 1 << 22, 1 << 20]; // 66 bits → 3 key bits
        let s = BlcoSpec::new(&dims);
        assert_eq!(s.total_key_bits, 3);
        let mut rng = crate::util::prng::Rng::new(3);
        let mut out = vec![0u32; 3];
        for _ in 0..200 {
            let coord: Vec<u32> =
                dims.iter().map(|&d| rng.below(d) as u32).collect();
            let (k, l) = s.encode(&coord);
            let bases = s.bases(k);
            s.decode(0, l, &mut out); // decode with zero key = in-block coords
            for n in 0..3 {
                assert_eq!(bases[n] + out[n], coord[n], "mode {n}");
            }
        }
    }

    #[test]
    fn keys_are_contiguous_under_alto_sort() {
        // sorting by ALTO index must group equal block keys contiguously
        let dims = vec![1 << 23, 1 << 21, 1 << 22]; // 66 bits
        let s = BlcoSpec::new(&dims);
        let mut rng = crate::util::prng::Rng::new(11);
        let mut items: Vec<u128> = (0..2000)
            .map(|_| {
                let coord: Vec<u32> =
                    dims.iter().map(|&d| rng.below(d) as u32).collect();
                s.alto.encode(&coord)
            })
            .collect();
        items.sort_unstable();
        let keys: Vec<u64> = items.iter().map(|&a| s.key_of_alto(a)).collect();
        let mut seen = std::collections::HashSet::new();
        let mut prev = None;
        for k in keys {
            if prev != Some(k) {
                assert!(seen.insert(k), "key {k} appeared in two runs");
                prev = Some(k);
            }
        }
    }
}
