//! ALTO linearization: interleave the bits of all mode indices into one
//! linear index by round-robin over modes, least-significant bits first,
//! skipping modes whose bits are exhausted. For equal mode lengths this is
//! exactly Morton-Z order; for irregular shapes the curve adapts to the
//! tensor space (the "recursive partitioning" of the ALTO paper).

use crate::util::bitops::{mask64, mode_bits};

/// A fixed bit-interleaving for a given shape.
#[derive(Clone, Debug)]
pub struct Encoding {
    pub dims: Vec<u64>,
    /// bits of each mode index
    pub mode_bits: Vec<u32>,
    /// total encoding-line length (sum of mode_bits), <= 128
    pub total_bits: u32,
    /// for output bit position `p` (LSB = 0): which mode owns it
    pub bit_mode: Vec<u8>,
    /// ... and which bit of that mode's index it carries
    pub bit_pos: Vec<u8>,
    /// per-mode list of output positions, LSB-first (inverse view)
    pub mode_positions: Vec<Vec<u8>>,
    /// byte-lookup scatter tables (§Perf): `encode_tables[n][j][b]` is the
    /// line contribution of byte `j` of mode `n`'s coordinate having value
    /// `b` — one probe per coordinate byte instead of one shift per bit.
    encode_tables: Vec<Vec<[u128; 256]>>,
}

impl Encoding {
    pub fn new(dims: &[u64]) -> Self {
        assert!(!dims.is_empty() && dims.len() <= 8, "order {} unsupported", dims.len());
        let mb: Vec<u32> = dims.iter().map(|&d| mode_bits(d)).collect();
        let total: u32 = mb.iter().sum();
        assert!(total <= 128, "encoding line {total} bits > 128");

        let mut bit_mode = Vec::with_capacity(total as usize);
        let mut bit_pos = Vec::with_capacity(total as usize);
        let mut mode_positions = vec![Vec::new(); dims.len()];
        // round-robin over modes, level = bit index within the mode
        let mut level = 0u8;
        while bit_mode.len() < total as usize {
            for (n, &b) in mb.iter().enumerate() {
                if (level as u32) < b {
                    mode_positions[n].push(bit_mode.len() as u8);
                    bit_mode.push(n as u8);
                    bit_pos.push(level);
                }
            }
            level += 1;
        }
        let encode_tables = mode_positions
            .iter()
            .zip(&mb)
            .map(|(positions, &bits)| {
                let nbytes = (bits as usize).div_ceil(8);
                (0..nbytes)
                    .map(|j| {
                        let mut table = [0u128; 256];
                        for (b, slot) in table.iter_mut().enumerate() {
                            let mut acc = 0u128;
                            for bit in 0..8usize {
                                let src = j * 8 + bit;
                                if src < positions.len() && (b >> bit) & 1 == 1 {
                                    acc |= 1u128 << positions[src];
                                }
                            }
                            *slot = acc;
                        }
                        table
                    })
                    .collect()
            })
            .collect();
        Encoding {
            dims: dims.to_vec(),
            mode_bits: mb,
            total_bits: total,
            bit_mode,
            bit_pos,
            mode_positions,
            encode_tables,
        }
    }

    /// Linearize one coordinate tuple (table-driven, one probe per
    /// coordinate byte; agrees bit-for-bit with [`Self::encode_bitwise`]).
    #[inline]
    pub fn encode(&self, coord: &[u32]) -> u128 {
        debug_assert_eq!(coord.len(), self.dims.len());
        let mut l: u128 = 0;
        for (n, &c) in coord.iter().enumerate() {
            for (j, table) in self.encode_tables[n].iter().enumerate() {
                l |= table[((c >> (j * 8)) & 0xFF) as usize];
            }
        }
        l
    }

    /// Reference per-bit encoder (kept as the oracle for the table path).
    #[inline]
    pub fn encode_bitwise(&self, coord: &[u32]) -> u128 {
        debug_assert_eq!(coord.len(), self.dims.len());
        let mut l: u128 = 0;
        for (n, &c) in coord.iter().enumerate() {
            let mut c = c as u64;
            for &pos in &self.mode_positions[n] {
                l |= ((c & 1) as u128) << pos;
                c >>= 1;
            }
        }
        l
    }

    /// Recover coordinates. The bit-level gather this performs is exactly
    /// what GPUs lack fast instructions for — the motivation for the BLCO
    /// re-encoding (Section 4.1).
    #[inline]
    pub fn decode(&self, l: u128, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.dims.len());
        out.iter_mut().for_each(|c| *c = 0);
        for (n, positions) in self.mode_positions.iter().enumerate() {
            let mut c: u64 = 0;
            for (i, &pos) in positions.iter().enumerate() {
                c |= (((l >> pos) & 1) as u64) << i;
            }
            out[n] = (c & mask64(self.mode_bits[n])) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, Config};

    #[test]
    fn morton_for_equal_dims() {
        // dims (4,4,4): 2 bits each, round-robin LSB-first →
        // l = i0b0 | i1b0<<1 | i2b0<<2 | i0b1<<3 | i1b1<<4 | i2b1<<5
        let e = Encoding::new(&[4, 4, 4]);
        assert_eq!(e.total_bits, 6);
        assert_eq!(e.encode(&[1, 0, 0]), 0b000001);
        assert_eq!(e.encode(&[0, 1, 0]), 0b000010);
        assert_eq!(e.encode(&[0, 0, 1]), 0b000100);
        assert_eq!(e.encode(&[2, 0, 0]), 0b001000);
        assert_eq!(e.encode(&[3, 3, 3]), 0b111111);
    }

    #[test]
    fn irregular_shapes_drop_exhausted_modes() {
        // dims (8,2): bits (3,1): positions: l0=m0b0, l1=m1b0, l2=m0b1, l3=m0b2
        let e = Encoding::new(&[8, 2]);
        assert_eq!(e.total_bits, 4);
        assert_eq!(e.encode(&[0b101, 0]), 0b1001);
        assert_eq!(e.encode(&[0b010, 1]), 0b0110);
    }

    #[test]
    fn paper_figure6a_ordering() {
        // Figure 4a/6a tensor: dims (4,4,4). Entries of the paper's initial
        // linearization that pure Morton order reproduces (the published
        // ALTO curve differs from Morton in a few adaptive bit choices; any
        // mode-agnostic space-filling interleaving is admissible, Section
        // 4.1 — "similar to Morton-Z ordering").
        let e = Encoding::new(&[4, 4, 4]);
        assert_eq!(e.encode(&[0, 0, 0]), 0);
        assert_eq!(e.encode(&[0, 0, 1]), 4);
        assert_eq!(e.encode(&[1, 0, 1]), 5);
        assert_eq!(e.encode(&[2, 0, 1]), 12);
        assert_eq!(e.encode(&[0, 2, 2]), 48);
        assert_eq!(e.encode(&[3, 3, 3]), 63);
    }

    #[test]
    fn table_encode_matches_bitwise() {
        check("alto_table_vs_bitwise", Config { cases: 64, max_size: 1 << 24, ..Default::default() }, |ctx| {
            let order = 1 + ctx.rng.below(5) as usize;
            let dims: Vec<u64> =
                (0..order).map(|_| 1 + ctx.rng.below(ctx.size as u64)).collect();
            let e = Encoding::new(&dims);
            for _ in 0..50 {
                let coord: Vec<u32> =
                    dims.iter().map(|&d| ctx.rng.below(d) as u32).collect();
                if e.encode(&coord) != e.encode_bitwise(&coord) {
                    return Err(format!("{dims:?} {coord:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_random_shapes() {
        check("alto_roundtrip", Config { cases: 128, max_size: 1 << 20, ..Default::default() }, |ctx| {
            let order = 1 + ctx.rng.below(5) as usize;
            let dims: Vec<u64> =
                (0..order).map(|_| 1 + ctx.rng.below(ctx.size as u64)).collect();
            let e = Encoding::new(&dims);
            let mut out = vec![0u32; order];
            for _ in 0..50 {
                let coord: Vec<u32> =
                    dims.iter().map(|&d| ctx.rng.below(d) as u32).collect();
                let l = e.encode(&coord);
                e.decode(l, &mut out);
                if out != coord {
                    return Err(format!("{dims:?}: {coord:?} -> {l} -> {out:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_wide_line_over_64_bits() {
        let dims = vec![1 << 22, 1 << 22, 1 << 22]; // 66 bits
        let e = Encoding::new(&dims);
        assert_eq!(e.total_bits, 66);
        let mut rng = Rng::new(9);
        let mut out = vec![0u32; 3];
        for _ in 0..500 {
            let coord: Vec<u32> =
                dims.iter().map(|&d| rng.below(d) as u32).collect();
            e.decode(e.encode(&coord), &mut out);
            assert_eq!(out, coord);
        }
    }

    #[test]
    fn encode_is_monotone_in_locality() {
        // nearby coordinates share high bits: flipping only low coordinate
        // bits must not change the high half of the line
        let e = Encoding::new(&[1 << 10, 1 << 10, 1 << 10]);
        let a = e.encode(&[512, 512, 512]);
        let b = e.encode(&[513, 513, 513]);
        assert_eq!(a >> 6, b >> 6);
    }

    #[test]
    #[should_panic]
    fn rejects_order_over_8() {
        Encoding::new(&[2; 9]);
    }
}
