//! Index linearization: the ALTO space-filling encoding (Section 4.1,
//! adopted from Helal et al. ICS '21) over up-to-128-bit lines, and the BLCO
//! re-encoding into contiguous per-mode bit fields decodable with shift+mask,
//! including the adaptive-blocking split into (block key, in-block index).

pub mod alto;
pub mod encode;
