//! First-order device-time model: combines the exactly-counted traffic of a
//! [`Snapshot`](super::counters::Snapshot) with a [`Profile`]'s rates.
//!
//! The model is a memory roofline over four access classes whose relative
//! costs encode the paper's performance analysis (§3, §6.4):
//!
//! * **streamed** (×1) — coalesced, independent loads/stores (index lists,
//!   values, outputs): move at full bandwidth;
//! * **gathered** (×[`GATHER_PENALTY`]) — data-dependent but *independent*
//!   row fetches (factor rows per non-zero): the GPU overlaps their
//!   latency, but row-granular randomness wastes part of each transaction;
//! * **serial** (×[`SERIAL_PENALTY`]) — accesses on dependency chains
//!   (CSF tree pointer-chasing and recursive subtree accumulation): their
//!   latency is exposed, so effective bandwidth collapses. This term is why
//!   MM-CSF can move *less* data yet deliver *lower* throughput (Table 3);
//! * **local** (×[`LOCAL_DISCOUNT`]) — shared/local-memory passes
//!   (segmented-scan sweeps, stash flushes): much faster than HBM but not
//!   free.
//!
//! Atomic updates cost twice: (i) *bandwidth* — an atomic add is an
//! uncoalescible read-modify-write through L2, charged as scattered-class
//! read traffic on top of the written bytes; (ii) *contention* — updates to
//! the same destination serialize, so the critical path is
//! `atomics / fanout × atomic_ns`, where `atomic_fanout` (reported by the
//! engines) is the number of independent destinations: target rows ×
//! output copies. A short target mode therefore bottlenecks register-based
//! resolution — the §5.3 pathology — while hierarchical resolution's
//! factor-matrix copies multiply the fanout. Kernel launches add a fixed
//! `launch_us` each (the hypersparse batching motivation).

use super::counters::Snapshot;
use super::profile::Profile;

/// Row-granular random gathers: partial-transaction waste + cache misses.
pub const GATHER_PENALTY: f64 = 1.5;

/// Fine-grained (word-granular) indirect accesses: one 32-byte transaction
/// per 8-byte word.
pub const SCATTER_PENALTY: f64 = 4.0;

/// Dependency-chain accesses: latency exposed, effective bandwidth drops
/// (calibrated to the paper's Table 3 BLCO/MM-CSF throughput ratios).
pub const SERIAL_PENALTY: f64 = 6.0;

/// Local/shared memory runs several times faster than HBM.
pub const LOCAL_DISCOUNT: f64 = 0.25;

/// Modelled execution-time decomposition, seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelTime {
    pub memory_s: f64,
    pub atomic_s: f64,
    pub launch_s: f64,
}

impl ModelTime {
    pub fn total(&self) -> f64 {
        // an atomic is a memory round-trip; memory and atomic terms overlap
        // poorly in practice, so they add; launches add on top
        self.memory_s + self.atomic_s + self.launch_s
    }
}

/// Modelled device time for one kernel/operation.
pub fn device_time(s: &Snapshot, p: &Profile) -> ModelTime {
    let gb = 1e9;
    let effective = (s.bytes_streamed + s.bytes_written) as f64
        + s.bytes_gathered as f64 * GATHER_PENALTY
        + (s.bytes_scattered + s.atomics * 8) as f64 * SCATTER_PENALTY
        + s.bytes_serial as f64 * SERIAL_PENALTY
        + s.bytes_local as f64 * LOCAL_DISCOUNT;
    let memory_s = effective / (p.hbm_gbps * gb);
    // contention: serialized depth on the hottest destinations
    let fanout = s.atomic_fanout.max(1) as f64;
    let atomic_s = (s.atomics as f64 / fanout) * p.atomic_ns * 1e-9;
    let launch_s = s.launches as f64 * p.launch_us * 1e-6;
    ModelTime { memory_s, atomic_s, launch_s }
}

/// Modelled host→device transfer time for `bytes` over the interconnect.
pub fn transfer_time(bytes: usize, p: &Profile) -> f64 {
    bytes as f64 / (p.link_gbps * 1e9)
}

/// Effective memory throughput (the paper's Table 3 "TP" column), TB/s,
/// for a measured-or-modelled execution time.
pub fn throughput_tbps(volume_bytes: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    volume_bytes as f64 / seconds / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(streamed: u64, gathered: u64, written: u64, atomics: u64) -> Snapshot {
        Snapshot {
            bytes_streamed: streamed,
            bytes_gathered: gathered,
            bytes_written: written,
            atomics,
            atomic_fanout: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fanout_parallelizes_atomics() {
        let p = Profile::a100();
        let narrow = device_time(&snap(0, 0, 0, 1_000_000), &p);
        let mut s = snap(0, 0, 0, 1_000_000);
        s.atomic_fanout = 64;
        let wide = device_time(&s, &p);
        assert!((narrow.atomic_s / wide.atomic_s - 64.0).abs() < 1e-9);
        // the RMW bandwidth term is fanout-independent
        assert!((narrow.memory_s - wide.memory_s).abs() < 1e-12);
        assert!(narrow.memory_s > 0.0);
    }

    #[test]
    fn pure_streaming_hits_roofline() {
        let p = Profile::a100();
        let s = snap(1_555_000_000_000, 0, 0, 0); // 1555 GB
        let t = device_time(&s, &p);
        assert!((t.memory_s - 1.0).abs() < 1e-9);
        assert_eq!(t.atomic_s, 0.0);
    }

    #[test]
    fn access_class_ordering() {
        // same byte count: streamed < local-inclusive < gathered < serial
        let p = Profile::a100();
        let n = 1u64 << 30;
        let st = device_time(&snap(n, 0, 0, 0), &p).memory_s;
        let ga = device_time(&snap(0, n, 0, 0), &p).memory_s;
        let se = device_time(
            &Snapshot { bytes_serial: n, ..Default::default() },
            &p,
        )
        .memory_s;
        let lo = device_time(
            &Snapshot { bytes_local: n, ..Default::default() },
            &p,
        )
        .memory_s;
        assert!(lo < st && st < ga && ga < se);
        assert!((ga / st - GATHER_PENALTY).abs() < 1e-9);
        assert!((se / st - SERIAL_PENALTY).abs() < 1e-9);
        assert!((lo / st - LOCAL_DISCOUNT).abs() < 1e-9);
    }

    #[test]
    fn serial_excluded_from_nothing_volume_includes_it() {
        let s = Snapshot {
            bytes_streamed: 10,
            bytes_serial: 5,
            bytes_local: 100,
            ..Default::default()
        };
        // volume counts global traffic only (local excluded, like Nsight)
        assert_eq!(s.volume_bytes(), 15);
    }

    #[test]
    fn atomics_dominate_on_contended_kernels() {
        let p = Profile::v100();
        let light = device_time(&snap(1 << 20, 0, 0, 1_000), &p);
        let heavy = device_time(&snap(1 << 20, 0, 0, 100_000_000), &p);
        assert!(heavy.total() > light.total() * 100.0);
    }

    #[test]
    fn transfer_slower_than_hbm() {
        let p = Profile::a100();
        let bytes = 1usize << 30;
        let link = transfer_time(bytes, &p);
        let hbm = device_time(&snap(bytes as u64, 0, 0, 0), &p).memory_s;
        assert!(link > hbm * 10.0, "link {link} vs hbm {hbm}");
    }

    #[test]
    fn throughput_calc() {
        assert!((throughput_tbps(2_000_000_000_000, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(throughput_tbps(100, 0.0), 0.0);
    }

    #[test]
    fn launches_add_fixed_cost() {
        let p = Profile::a100();
        let s = Snapshot { launches: 1000, ..Default::default() };
        let t = device_time(&s, &p);
        assert!((t.launch_s - 0.005).abs() < 1e-12);
    }
}
