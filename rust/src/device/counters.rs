//! Hardware-counter surrogate: every MTTKRP engine counts exactly what it
//! does — bytes moved by class (coalesced streams vs strided/gather
//! accesses), atomic updates, segments discovered, stash hits, kernel
//! launches. This replaces Nsight Compute in the paper's Table 3 / Figure
//! 10 methodology (DESIGN.md §3).

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe counter block. Engines accumulate per-thread deltas
/// locally and flush once per chunk, so counting does not perturb the hot
/// loop.
#[derive(Debug, Default)]
pub struct Counters {
    /// bytes read in coalesced/streamed form (index lists, values)
    pub bytes_streamed: AtomicU64,
    /// bytes read by data-dependent but *independent* gathers (factor
    /// rows addressed per non-zero — the GPU hides their latency)
    pub bytes_gathered: AtomicU64,
    /// bytes read by *fine-grained* scatters (word-granular indirect access,
    /// e.g. payload reads through a permutation): a full memory transaction
    /// per word
    pub bytes_scattered: AtomicU64,
    /// bytes on dependency chains (tree pointer-chasing, recursive subtree
    /// accumulation) whose latency cannot be hidden — the CSF-family
    /// pathology the paper's Table 3 throughput gap comes from
    pub bytes_serial: AtomicU64,
    /// bytes moved through local/shared memory (segmented-scan passes,
    /// stash flushes) — fast but not free
    pub bytes_local: AtomicU64,
    /// bytes written (outputs, flushes)
    pub bytes_written: AtomicU64,
    /// scalar atomic update operations issued
    pub atomics: AtomicU64,
    /// segments (distinct target-index runs) discovered
    pub segments: AtomicU64,
    /// updates absorbed by a local stash / register instead of memory
    pub stash_hits: AtomicU64,
    /// kernel launches (batches on the streaming path)
    pub launches: AtomicU64,
    /// number of independent atomic destinations (rows × copies) — a *max*,
    /// not a sum: the model divides atomic serialization by it (capped at
    /// the device's slice/SM parallelism). Register-based conflict
    /// resolution on a short mode has a tiny fanout (the paper's contention
    /// pathology); hierarchical resolution multiplies it by the number of
    /// factor-matrix copies.
    pub atomic_fanout: AtomicU64,
    /// bytes read from disk by the host-out-of-core tier (block-cache
    /// misses loading `.blco` payloads) — host-side traffic, excluded
    /// from the device-volume accounting
    pub bytes_disk: AtomicU64,
    /// host block-cache hits (batch fetches served from resident blocks)
    pub host_hits: AtomicU64,
    /// host block-cache misses (each one is a disk read)
    pub host_misses: AtomicU64,
    /// blocks evicted from the host block cache to stay under budget
    pub host_evictions: AtomicU64,
    /// wave barriers executed by a certified synchronization-free schedule
    /// ([`crate::analysis::racecheck::run_waved`])
    pub waves: AtomicU64,
    /// scalar output updates flushed as *plain stores* under a conflict
    /// certificate — work that would have been `atomics` without the
    /// static proof ([`crate::analysis::conflict`])
    pub nosync_flushes: AtomicU64,
}

/// Plain-value snapshot of [`Counters`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub bytes_streamed: u64,
    pub bytes_gathered: u64,
    pub bytes_scattered: u64,
    pub bytes_serial: u64,
    pub bytes_local: u64,
    pub bytes_written: u64,
    pub atomics: u64,
    pub segments: u64,
    pub stash_hits: u64,
    pub launches: u64,
    pub atomic_fanout: u64,
    pub bytes_disk: u64,
    pub host_hits: u64,
    pub host_misses: u64,
    pub host_evictions: u64,
    pub waves: u64,
    pub nosync_flushes: u64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, d: &Snapshot) {
        // one flush per chunk — Relaxed is fine, totals are read after join
        self.bytes_streamed.fetch_add(d.bytes_streamed, Ordering::Relaxed);
        self.bytes_gathered.fetch_add(d.bytes_gathered, Ordering::Relaxed);
        self.bytes_scattered.fetch_add(d.bytes_scattered, Ordering::Relaxed);
        self.bytes_serial.fetch_add(d.bytes_serial, Ordering::Relaxed);
        self.bytes_local.fetch_add(d.bytes_local, Ordering::Relaxed);
        self.bytes_written.fetch_add(d.bytes_written, Ordering::Relaxed);
        self.atomics.fetch_add(d.atomics, Ordering::Relaxed);
        self.segments.fetch_add(d.segments, Ordering::Relaxed);
        self.stash_hits.fetch_add(d.stash_hits, Ordering::Relaxed);
        self.launches.fetch_add(d.launches, Ordering::Relaxed);
        self.atomic_fanout.fetch_max(d.atomic_fanout, Ordering::Relaxed);
        self.bytes_disk.fetch_add(d.bytes_disk, Ordering::Relaxed);
        self.host_hits.fetch_add(d.host_hits, Ordering::Relaxed);
        self.host_misses.fetch_add(d.host_misses, Ordering::Relaxed);
        self.host_evictions.fetch_add(d.host_evictions, Ordering::Relaxed);
        self.waves.fetch_add(d.waves, Ordering::Relaxed);
        self.nosync_flushes.fetch_add(d.nosync_flushes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            bytes_streamed: self.bytes_streamed.load(Ordering::Relaxed),
            bytes_gathered: self.bytes_gathered.load(Ordering::Relaxed),
            bytes_scattered: self.bytes_scattered.load(Ordering::Relaxed),
            bytes_serial: self.bytes_serial.load(Ordering::Relaxed),
            bytes_local: self.bytes_local.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            atomics: self.atomics.load(Ordering::Relaxed),
            segments: self.segments.load(Ordering::Relaxed),
            stash_hits: self.stash_hits.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
            atomic_fanout: self.atomic_fanout.load(Ordering::Relaxed),
            bytes_disk: self.bytes_disk.load(Ordering::Relaxed),
            host_hits: self.host_hits.load(Ordering::Relaxed),
            host_misses: self.host_misses.load(Ordering::Relaxed),
            host_evictions: self.host_evictions.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            nosync_flushes: self.nosync_flushes.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.bytes_streamed.store(0, Ordering::Relaxed);
        self.bytes_gathered.store(0, Ordering::Relaxed);
        self.bytes_scattered.store(0, Ordering::Relaxed);
        self.bytes_serial.store(0, Ordering::Relaxed);
        self.bytes_local.store(0, Ordering::Relaxed);
        self.bytes_written.store(0, Ordering::Relaxed);
        self.atomics.store(0, Ordering::Relaxed);
        self.segments.store(0, Ordering::Relaxed);
        self.stash_hits.store(0, Ordering::Relaxed);
        self.launches.store(0, Ordering::Relaxed);
        self.atomic_fanout.store(0, Ordering::Relaxed);
        self.bytes_disk.store(0, Ordering::Relaxed);
        self.host_hits.store(0, Ordering::Relaxed);
        self.host_misses.store(0, Ordering::Relaxed);
        self.host_evictions.store(0, Ordering::Relaxed);
        self.waves.store(0, Ordering::Relaxed);
        self.nosync_flushes.store(0, Ordering::Relaxed);
    }
}

impl Snapshot {
    /// Total *global*-memory volume (the paper's Table 3 "Vol" column).
    /// Local/shared-memory traffic is excluded, matching Nsight's
    /// l1tex-to-device accounting; so is `bytes_disk` — the host
    /// out-of-core tier reads disk, not device memory.
    pub fn volume_bytes(&self) -> u64 {
        self.bytes_streamed
            + self.bytes_gathered
            + self.bytes_scattered
            + self.bytes_serial
            + self.bytes_written
    }

    /// Fraction of traffic that is coalesced/streamed — the memory-system
    /// efficiency driver the paper attributes BLCO's throughput edge to.
    pub fn coalesced_frac(&self) -> f64 {
        let total = self.volume_bytes();
        if total == 0 {
            return 1.0;
        }
        (self.bytes_streamed + self.bytes_written) as f64 / total as f64
    }
}

/// One [`Counters`] block per worker thread. Threaded kernels hand shard
/// `t` to worker `t` so the hot loop never contends on shared atomics;
/// [`ShardedCounters::merge`] folds the shards back into the totals a
/// sequential run over the same work would have produced — every field is
/// a sum except `atomic_fanout`, whose max semantics ([`Counters::add`])
/// are preserved shard-wise. Because each kernel charges counters per
/// work item (not per thread), the merged totals are invariant under the
/// thread count and the work-to-shard assignment.
#[derive(Debug, Default)]
pub struct ShardedCounters {
    shards: Vec<Counters>,
}

impl ShardedCounters {
    pub fn new(nthreads: usize) -> Self {
        ShardedCounters {
            shards: (0..nthreads.max(1)).map(|_| Counters::new()).collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The counter block worker `t` charges into (`t % shards` so a
    /// caller with more workers than shards still lands somewhere).
    pub fn shard(&self, t: usize) -> &Counters {
        &self.shards[t % self.shards.len()]
    }

    /// Fold every shard into one snapshot — bit-equal to the totals of a
    /// 1-shard (sequential) run over the same work.
    pub fn merge(&self) -> Snapshot {
        self.shards
            .iter()
            .map(Counters::snapshot)
            .fold(Snapshot::default(), |acc, s| acc + s)
    }

    /// Merge and flush into a shared [`Counters`] block.
    pub fn merge_into(&self, dest: &Counters) {
        dest.add(&self.merge());
    }

    pub fn reset(&self) {
        for s in &self.shards {
            s.reset();
        }
    }
}

impl std::ops::Add for Snapshot {
    type Output = Snapshot;
    fn add(self, o: Snapshot) -> Snapshot {
        Snapshot {
            bytes_streamed: self.bytes_streamed + o.bytes_streamed,
            bytes_gathered: self.bytes_gathered + o.bytes_gathered,
            bytes_scattered: self.bytes_scattered + o.bytes_scattered,
            bytes_serial: self.bytes_serial + o.bytes_serial,
            bytes_local: self.bytes_local + o.bytes_local,
            bytes_written: self.bytes_written + o.bytes_written,
            atomics: self.atomics + o.atomics,
            segments: self.segments + o.segments,
            stash_hits: self.stash_hits + o.stash_hits,
            launches: self.launches + o.launches,
            atomic_fanout: self.atomic_fanout.max(o.atomic_fanout),
            bytes_disk: self.bytes_disk + o.bytes_disk,
            host_hits: self.host_hits + o.host_hits,
            host_misses: self.host_misses + o.host_misses,
            host_evictions: self.host_evictions + o.host_evictions,
            waves: self.waves + o.waves,
            nosync_flushes: self.nosync_flushes + o.nosync_flushes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_snapshot() {
        let c = Counters::new();
        c.add(&Snapshot { bytes_streamed: 100, atomics: 5, ..Default::default() });
        c.add(&Snapshot { bytes_gathered: 50, atomics: 3, ..Default::default() });
        let s = c.snapshot();
        assert_eq!(s.bytes_streamed, 100);
        assert_eq!(s.bytes_gathered, 50);
        assert_eq!(s.atomics, 8);
        assert_eq!(s.volume_bytes(), 150);
    }

    #[test]
    fn concurrent_accumulation() {
        let c = Counters::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.add(&Snapshot { atomics: 1, ..Default::default() });
                    }
                });
            }
        });
        assert_eq!(c.snapshot().atomics, 8000);
    }

    #[test]
    fn coalesced_frac() {
        let s = Snapshot {
            bytes_streamed: 60,
            bytes_gathered: 30,
            bytes_written: 10,
            ..Default::default()
        };
        assert!((s.coalesced_frac() - 0.7).abs() < 1e-12);
        assert_eq!(Snapshot::default().coalesced_frac(), 1.0);
    }

    #[test]
    fn reset_clears() {
        let c = Counters::new();
        c.add(&Snapshot { launches: 7, ..Default::default() });
        c.reset();
        assert_eq!(c.snapshot(), Snapshot::default());
    }

    #[test]
    fn host_tier_fields_accumulate_but_stay_out_of_volume() {
        let c = Counters::new();
        c.add(&Snapshot {
            bytes_streamed: 100,
            bytes_disk: 4096,
            host_hits: 3,
            host_misses: 2,
            host_evictions: 1,
            ..Default::default()
        });
        c.add(&Snapshot { host_hits: 1, ..Default::default() });
        let s = c.snapshot();
        assert_eq!(s.bytes_disk, 4096);
        assert_eq!(s.host_hits, 4);
        assert_eq!(s.host_misses, 2);
        assert_eq!(s.host_evictions, 1);
        assert_eq!(s.volume_bytes(), 100, "disk reads are not device volume");
        c.reset();
        assert_eq!(c.snapshot(), Snapshot::default());
    }

    #[test]
    fn snapshot_add() {
        let a = Snapshot { segments: 2, ..Default::default() };
        let b = Snapshot { segments: 3, stash_hits: 1, ..Default::default() };
        let s = a + b;
        assert_eq!(s.segments, 5);
        assert_eq!(s.stash_hits, 1);
    }

    /// Deterministic pseudo-random per-item delta exercising every field,
    /// including the wave (`waves`/`nosync_flushes`) and host-cache
    /// (`bytes_disk`/`host_*`) counters.
    fn item_delta(i: u64) -> Snapshot {
        let mut x = i.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(0x243f6a88);
        let mut next = || {
            x ^= x >> 33;
            x = x.wrapping_mul(0xff51afd7ed558ccd);
            x ^= x >> 29;
            x % 97
        };
        Snapshot {
            bytes_streamed: next(),
            bytes_gathered: next(),
            bytes_scattered: next(),
            bytes_serial: next(),
            bytes_local: next(),
            bytes_written: next(),
            atomics: next(),
            segments: next(),
            stash_hits: next(),
            launches: next(),
            atomic_fanout: next(),
            bytes_disk: next(),
            host_hits: next(),
            host_misses: next(),
            host_evictions: next(),
            waves: next(),
            nosync_flushes: next(),
        }
    }

    #[test]
    fn sharded_merge_reproduces_sequential_totals() {
        // property: for any thread count and any work-to-shard split, the
        // merged shard totals equal the sequential single-counter run over
        // the same per-item deltas — sums everywhere, max for
        // atomic_fanout
        const ITEMS: u64 = 1000;
        let seq = Counters::new();
        for i in 0..ITEMS {
            seq.add(&item_delta(i));
        }
        let expect = seq.snapshot();

        for nthreads in [1usize, 2, 4, 8] {
            let sharded = ShardedCounters::new(nthreads);
            assert_eq!(sharded.num_shards(), nthreads);
            std::thread::scope(|s| {
                for t in 0..nthreads {
                    let sharded = &sharded;
                    s.spawn(move || {
                        // strided assignment: a different work split than
                        // the sequential loop, same item set
                        let mut i = t as u64;
                        while i < ITEMS {
                            sharded.shard(t).add(&item_delta(i));
                            i += nthreads as u64;
                        }
                    });
                }
            });
            let merged = sharded.merge();
            assert_eq!(
                merged, expect,
                "merged totals must match sequential at {nthreads} threads"
            );

            // merge_into flushes the same totals into a shared block
            let dest = Counters::new();
            sharded.merge_into(&dest);
            assert_eq!(dest.snapshot(), expect);

            sharded.reset();
            assert_eq!(sharded.merge(), Snapshot::default());
        }
    }

    #[test]
    fn wave_fields_accumulate_and_stay_out_of_volume() {
        let c = Counters::new();
        c.add(&Snapshot { waves: 2, nosync_flushes: 40, ..Default::default() });
        c.add(&Snapshot { waves: 1, nosync_flushes: 8, ..Default::default() });
        let s = c.snapshot();
        assert_eq!(s.waves, 3);
        assert_eq!(s.nosync_flushes, 48);
        assert_eq!(s.volume_bytes(), 0, "flush counts are ops, not bytes");
        let sum = s + Snapshot { waves: 1, ..Default::default() };
        assert_eq!(sum.waves, 4);
        c.reset();
        assert_eq!(c.snapshot(), Snapshot::default());
    }
}
