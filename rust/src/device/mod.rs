//! The simulated accelerator (DESIGN.md §4).
//!
//! The paper's algorithms execute for real on CPU threads; what this module
//! supplies is (i) the device *constraints* the algorithms adapt to —
//! number of subslices/SMs for the §5.3 heuristic, device-memory budget for
//! the in-/out-of-memory classification, queue reservations for streaming —
//! and (ii) *hardware counters* (bytes, atomics, segments, launches) that
//! every engine reports, from which a first-order roofline model derives
//! device-scale times for the paper's figures. Counters are counted in
//! code, never sampled.

pub mod counters;
pub mod model;
pub mod profile;

pub use counters::Counters;
pub use profile::{LinkTopology, Profile};
