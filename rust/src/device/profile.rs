//! Simulated device profiles.
//!
//! Compute-topology numbers (SMs/subslices, slices/GPCs) are the real ones
//! from Table 1 — the §5.3 heuristic depends on them. Memory capacities are
//! scaled down 256× so the scaled dataset presets exercise the same
//! in-/out-of-memory classification as the paper's originals on real
//! hardware. Bandwidths keep their real values; modelled times are
//! therefore directly comparable across profiles.

/// Host-link topology of a multi-device cluster (the knob behind the
/// cluster streamer's transfer model, [`crate::coordinator::cluster`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkTopology {
    /// every device shares one serialized host interconnect (a single
    /// PCIe root complex): transfers to different devices queue up
    Shared,
    /// each device owns a dedicated host link at the full `link_gbps`
    /// (one switch port per device): transfers overlap across devices
    Dedicated,
    /// `n` independent host links (switch ports), shared round-robin by
    /// the devices (`device % n`) — the middle ground between `Shared`
    /// (n = 1) and `Dedicated` (n = devices), e.g. a dual-root-complex
    /// host feeding four accelerators
    Ports(usize),
}

/// A massively parallel accelerator profile.
#[derive(Clone, Debug, PartialEq)]
pub struct Profile {
    pub name: &'static str,
    /// streaming multiprocessors / subslices (the §5.3 heuristic threshold)
    pub sms: usize,
    /// GPU slices / graphics processing clusters — number of factor-matrix
    /// shadow copies used by hierarchical conflict resolution
    pub slices: usize,
    /// device memory budget (scaled 256× below the real part)
    pub dev_mem_bytes: usize,
    /// host RAM budget (scaled 256× like `dev_mem_bytes`) — the
    /// [`BlockCache`](crate::format::store::BlockCache) bound of the
    /// host-out-of-core tier: a disk-resident tensor keeps at most this
    /// many payload bytes in host memory while streaming
    pub host_mem_bytes: usize,
    /// device memory bandwidth, GB/s (real value)
    pub hbm_gbps: f64,
    /// host↔device interconnect bandwidth, GB/s (real value)
    pub link_gbps: f64,
    /// same-destination atomic serialization latency, ns (the contention
    /// term of device::model; the bandwidth cost of atomics is charged
    /// separately as scattered RMW traffic). Intel's higher value reflects
    /// the paper's observation that its synchronization is costlier.
    pub atomic_ns: f64,
    /// fixed kernel-launch overhead, µs
    pub launch_us: f64,
    /// device queues available for out-of-memory streaming (paper: up to 8)
    pub queues: usize,
    /// simulated devices in the cluster; 1 = the paper's single-GPU
    /// configuration, >1 enables the sharded cluster streamer
    pub devices: usize,
    /// how the cluster's host links are shared (see [`LinkTopology`])
    pub links: LinkTopology,
    /// device↔device bandwidth, GB/s (NVLink/Xe-Link class), used by the
    /// cluster streamer's tree-merge traffic model
    pub peer_gbps: f64,
}

impl Profile {
    /// NVIDIA A100 (Ampere): 108 SMs, 7 GPCs, 40 GB @ 1555 GB/s.
    pub fn a100() -> Self {
        Profile {
            name: "a100",
            sms: 108,
            slices: 7,
            dev_mem_bytes: 40 * (1 << 30) / 256,
            host_mem_bytes: 512 * (1usize << 30) / 256,
            hbm_gbps: 1555.0,
            link_gbps: 25.0,
            atomic_ns: 20.0,
            launch_us: 5.0,
            queues: 8,
            devices: 1,
            links: LinkTopology::Shared,
            peer_gbps: 300.0,
        }
    }

    /// NVIDIA V100 (Volta): 80 SMs, 6 GPCs, 32 GB @ 900 GB/s.
    pub fn v100() -> Self {
        Profile {
            name: "v100",
            sms: 80,
            slices: 6,
            dev_mem_bytes: 32 * (1 << 30) / 256,
            host_mem_bytes: 384 * (1usize << 30) / 256,
            hbm_gbps: 900.0,
            link_gbps: 12.0,
            atomic_ns: 30.0,
            launch_us: 6.0,
            queues: 8,
            devices: 1,
            links: LinkTopology::Shared,
            peer_gbps: 150.0,
        }
    }

    /// Intel Device1 (Xe-HPC single tile). Public specs are confidential in
    /// the paper (Table 1 lists only the CPU); these values follow the Xe
    /// architecture disclosure (Hot Chips '20): 64 subslices (Xe-cores) in
    /// 4 slices, HBM2e-class bandwidth, and the paper's observation that
    /// synchronization is costlier than on NVIDIA parts.
    pub fn intel_d1() -> Self {
        Profile {
            name: "intel_d1",
            sms: 64,
            slices: 4,
            dev_mem_bytes: 28 * (1 << 30) / 256,
            host_mem_bytes: 512 * (1usize << 30) / 256,
            hbm_gbps: 1100.0,
            link_gbps: 20.0,
            atomic_ns: 45.0,
            launch_us: 8.0,
            queues: 8,
            devices: 1,
            links: LinkTopology::Shared,
            peer_gbps: 100.0,
        }
    }

    /// A tiny profile for tests and examples: a few MB of "device memory"
    /// so even demo tensors exercise the out-of-memory streaming path.
    pub fn tiny(dev_mem_bytes: usize) -> Self {
        Profile {
            name: "tiny",
            sms: 8,
            slices: 2,
            dev_mem_bytes,
            host_mem_bytes: dev_mem_bytes.saturating_mul(16).max(1 << 20),
            hbm_gbps: 100.0,
            link_gbps: 10.0,
            atomic_ns: 20.0,
            launch_us: 2.0,
            queues: 4,
            devices: 1,
            links: LinkTopology::Shared,
            peer_gbps: 20.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Profile> {
        match name {
            "a100" => Some(Self::a100()),
            "v100" => Some(Self::v100()),
            "intel_d1" => Some(Self::intel_d1()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Profile> {
        vec![Self::intel_d1(), Self::a100(), Self::v100()]
    }

    /// Does a working set of `bytes` fit in device memory?
    pub fn fits(&self, bytes: usize) -> bool {
        bytes <= self.dev_mem_bytes
    }

    /// Same part, `n` of them (builder for the cluster streamer).
    pub fn with_devices(mut self, n: usize) -> Self {
        self.devices = n.max(1);
        self
    }

    /// Same part, different host-link topology.
    pub fn with_links(mut self, links: LinkTopology) -> Self {
        self.links = links;
        self
    }

    /// Same part, different device-memory budget (builder for serving
    /// scenarios that need a specific in-/out-of-memory mix without
    /// building multi-GB tensors).
    pub fn with_memory(mut self, dev_mem_bytes: usize) -> Self {
        self.dev_mem_bytes = dev_mem_bytes;
        self
    }

    /// Same part, different host-RAM budget — the block-cache bound of
    /// the host-out-of-core tier (builder for tests/CLI runs that need a
    /// tensor to exceed "host memory" without a multi-GB payload).
    pub fn with_host_memory(mut self, host_mem_bytes: usize) -> Self {
        self.host_mem_bytes = host_mem_bytes;
        self
    }

    /// One device of this part. The serving registry plans per-device
    /// streaming pipelines, so its engines always see a single-device
    /// profile even when the fleet has many ([`crate::service`]).
    pub fn single_device(&self) -> Self {
        self.clone().with_devices(1)
    }

    /// Number of independent host links the cluster can drive at once.
    pub fn host_links(&self) -> usize {
        match self.links {
            LinkTopology::Shared => 1,
            LinkTopology::Dedicated => self.devices.max(1),
            LinkTopology::Ports(n) => n.max(1),
        }
    }

    /// Check every modelled rate and capacity. The streaming cost model
    /// divides by the bandwidth fields, so a zero/NaN rate would produce
    /// `inf`/NaN batch costs that greedy placement's NaN-tolerant sort
    /// silently accepts — engines and schedules reject such profiles at
    /// construction instead.
    pub fn validate(&self) -> Result<(), String> {
        let rate = |name: &str, v: f64| -> Result<(), String> {
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("{name} must be finite and > 0, got {v}"));
            }
            Ok(())
        };
        rate("hbm_gbps", self.hbm_gbps)?;
        rate("link_gbps", self.link_gbps)?;
        rate("peer_gbps", self.peer_gbps)?;
        if !self.atomic_ns.is_finite() || self.atomic_ns < 0.0 {
            return Err(format!(
                "atomic_ns must be finite and >= 0, got {}",
                self.atomic_ns
            ));
        }
        if !self.launch_us.is_finite() || self.launch_us < 0.0 {
            return Err(format!(
                "launch_us must be finite and >= 0, got {}",
                self.launch_us
            ));
        }
        if self.sms == 0 || self.slices == 0 {
            return Err("sms and slices must be >= 1".into());
        }
        if self.dev_mem_bytes == 0 {
            return Err("dev_mem_bytes must be > 0".into());
        }
        if self.host_mem_bytes == 0 {
            return Err("host_mem_bytes must be > 0".into());
        }
        if self.queues == 0 {
            return Err("queues must be >= 1".into());
        }
        if self.devices == 0 {
            return Err("devices must be >= 1".into());
        }
        if let LinkTopology::Ports(0) = self.links {
            return Err("Ports(n) needs n >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sound() {
        for p in Profile::all() {
            assert!(p.sms >= p.slices);
            assert!(p.hbm_gbps > p.link_gbps);
            assert!(p.dev_mem_bytes > 1 << 20);
            assert!(p.host_mem_bytes > p.dev_mem_bytes, "host RAM outsizes HBM");
            assert!(p.queues >= 1);
            assert_eq!(p.devices, 1, "presets are single-device by default");
            assert!(p.peer_gbps > p.link_gbps, "peer links outrun host links");
        }
    }

    #[test]
    fn host_memory_builder_and_validation() {
        let p = Profile::a100().with_host_memory(1 << 20);
        assert_eq!(p.host_mem_bytes, 1 << 20);
        assert!(p.validate().is_ok());
        assert!(Profile::a100().with_host_memory(0).validate().is_err());
        // tiny profiles keep a usable host tier even at tiny device sizes
        assert!(Profile::tiny(1 << 16).host_mem_bytes >= 1 << 20);
    }

    #[test]
    fn cluster_builders() {
        let p = Profile::a100().with_devices(4);
        assert_eq!(p.devices, 4);
        assert_eq!(p.host_links(), 1); // shared by default
        let d = p.with_links(LinkTopology::Dedicated);
        assert_eq!(d.host_links(), 4);
        assert_eq!(Profile::v100().with_devices(0).devices, 1);
    }

    #[test]
    fn memory_and_single_device_builders() {
        let p = Profile::a100().with_devices(4).with_memory(1 << 20);
        assert_eq!(p.dev_mem_bytes, 1 << 20);
        let s = p.single_device();
        assert_eq!(s.devices, 1);
        assert_eq!(s.dev_mem_bytes, 1 << 20);
        assert_eq!(s.name, p.name);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(Profile::by_name("a100").unwrap(), Profile::a100());
        assert!(Profile::by_name("h100").is_none());
    }

    #[test]
    fn scaled_memory_classifies_presets() {
        use crate::tensor::datasets;
        // every paper-OOM preset must exceed the scaled budget with its
        // tensor payload (16 B per nnz) + rank-32 factors on EVERY profile,
        // every in-memory preset fits everywhere — matching the paper's
        // classification in Table 2
        for prof in Profile::all() {
            for pr in datasets::all() {
                let tensor_bytes = pr.nnz * 16;
                let factor_bytes: usize =
                    pr.dims.iter().map(|&d| d as usize * 32 * 8).sum();
                if pr.oom {
                    assert!(
                        !prof.fits(tensor_bytes + factor_bytes),
                        "{} should be OOM on scaled {}",
                        pr.name,
                        prof.name
                    );
                    // ... but its factors alone must fit (the paper streams
                    // the tensor, never the factors)
                    assert!(
                        prof.fits(factor_bytes * 2),
                        "{} factors too big on {}",
                        pr.name,
                        prof.name
                    );
                } else {
                    assert!(
                        prof.fits(tensor_bytes + factor_bytes),
                        "{} should fit on scaled {}",
                        pr.name,
                        prof.name
                    );
                }
            }
        }
    }

    #[test]
    fn tiny_profile_forces_oom_on_demo() {
        let t = Profile::tiny(1 << 19);
        assert!(!t.fits(50_000 * 16));
    }

    #[test]
    fn ports_topology_sits_between_shared_and_dedicated() {
        let p = Profile::a100()
            .with_devices(4)
            .with_links(LinkTopology::Ports(2));
        assert_eq!(p.host_links(), 2);
        assert!(p.validate().is_ok());
        // degenerate port counts still behave
        assert_eq!(
            Profile::a100().with_links(LinkTopology::Ports(8)).host_links(),
            8
        );
    }

    #[test]
    fn validation_accepts_every_preset() {
        for p in Profile::all() {
            assert!(p.validate().is_ok(), "{}", p.name);
        }
        assert!(Profile::tiny(1 << 16).validate().is_ok());
        assert!(Profile::a100()
            .with_devices(4)
            .with_links(LinkTopology::Dedicated)
            .validate()
            .is_ok());
    }

    #[test]
    fn validation_rejects_degenerate_rates() {
        let zero_link = {
            let mut p = Profile::a100();
            p.link_gbps = 0.0;
            p
        };
        assert!(zero_link.validate().is_err());
        let nan_peer = {
            let mut p = Profile::v100();
            p.peer_gbps = f64::NAN;
            p
        };
        assert!(nan_peer.validate().is_err());
        let negative_hbm = {
            let mut p = Profile::intel_d1();
            p.hbm_gbps = -1.0;
            p
        };
        assert!(negative_hbm.validate().is_err());
        let zero_ports = Profile::a100().with_links(LinkTopology::Ports(0));
        assert!(zero_ports.validate().is_err());
        let no_queues = {
            let mut p = Profile::tiny(1 << 16);
            p.queues = 0;
            p
        };
        assert!(no_queues.validate().is_err());
    }
}
