//! The one front door for streamed MTTKRP execution.
//!
//! Historically the coordinator grew six free functions — `stream_mttkrp`,
//! `stream_mttkrp_scheduled`, `stream_mttkrp_fused`, `cluster_mttkrp`,
//! `cluster_mttkrp_with`, `cluster_mttkrp_scheduled` — one per
//! (planning × fusion × device-count) corner. [`StreamRequest`] collapses
//! them into a builder with a single [`run`](StreamRequest::run) entry
//! point:
//!
//! ```no_run
//! # use blco::coordinator::request::StreamRequest;
//! # use blco::mttkrp::blco::BlcoEngine;
//! # use blco::mttkrp::dense::Matrix;
//! # fn demo(eng: &BlcoEngine, factors: &[Matrix], out: &mut Matrix) {
//! let outcome = StreamRequest::new(eng, 0)
//!     .job(factors)
//!     .threads(4)
//!     .run(std::slice::from_mut(out))
//!     .expect("valid request");
//! println!("streamed {} bytes", outcome.bytes());
//! # }
//! ```
//!
//! Routing is by resolved device count: `1` runs the single-device
//! pipelined streamer (any number of fused jobs ships the tensor over the
//! host link once), `eng.profile.devices` runs the sharded cluster
//! streamer with a tree-merged output (single job only). A prebuilt
//! [`StreamSchedule`] short-circuits planning — the CP-ALS loop goes
//! through [`MttkrpEngine`](super::engine::MttkrpEngine)'s schedule cache,
//! which hands its memoized plan to a request per iteration.
//!
//! Malformed combinations return [`BlcoError::InvalidRequest`] instead of
//! panicking; the six legacy names survive as `#[deprecated]` wrappers
//! whose operation order is pinned bit-for-bit against `run()` by this
//! module's tests.

use crate::coordinator::cluster::{cluster_scheduled_impl, ClusterReport};
use crate::coordinator::schedule::{Placement, StreamSchedule};
use crate::coordinator::streamer::{stream_fused_impl, StreamReport};
use crate::device::counters::Counters;
use crate::error::BlcoError;
use crate::mttkrp::blco::BlcoEngine;
use crate::mttkrp::dense::Matrix;
use crate::util::pool::{default_threads, ExecBackend};

/// What a [`StreamRequest`] ran and how it went: the single-device
/// pipeline returns a [`StreamReport`], the sharded cluster path a
/// [`ClusterReport`]. Common scalar accessors cover callers that only
/// care about the modelled clock and traffic.
#[derive(Clone, Debug)]
pub enum StreamOutcome {
    /// single-device pipelined streaming (possibly a fused job group)
    Streamed(StreamReport),
    /// multi-device sharded streaming with a tree-merged output
    Clustered(ClusterReport),
}

impl StreamOutcome {
    /// The streamed report, if the request ran single-device.
    pub fn streamed(&self) -> Option<&StreamReport> {
        match self {
            StreamOutcome::Streamed(r) => Some(r),
            StreamOutcome::Clustered(_) => None,
        }
    }

    /// The cluster report, if the request ran sharded.
    pub fn clustered(&self) -> Option<&ClusterReport> {
        match self {
            StreamOutcome::Streamed(_) => None,
            StreamOutcome::Clustered(r) => Some(r),
        }
    }

    /// Owning form of [`streamed`](Self::streamed).
    pub fn into_streamed(self) -> Option<StreamReport> {
        match self {
            StreamOutcome::Streamed(r) => Some(r),
            StreamOutcome::Clustered(_) => None,
        }
    }

    /// Owning form of [`clustered`](Self::clustered).
    pub fn into_clustered(self) -> Option<ClusterReport> {
        match self {
            StreamOutcome::Streamed(_) => None,
            StreamOutcome::Clustered(r) => Some(r),
        }
    }

    /// Pipeline-simulated end-to-end seconds (cluster: including merge).
    pub fn overall_s(&self) -> f64 {
        match self {
            StreamOutcome::Streamed(r) => r.overall_s,
            StreamOutcome::Clustered(r) => r.overall_s,
        }
    }

    /// Total host→device bytes shipped over the interconnect.
    pub fn bytes(&self) -> usize {
        match self {
            StreamOutcome::Streamed(r) => r.bytes,
            StreamOutcome::Clustered(r) => r.bytes,
        }
    }
}

/// Builder for one streamed MTTKRP execution over a [`BlcoEngine`].
///
/// Construct with [`new`](Self::new), add at least one job, then call
/// [`run`](Self::run). Every knob the six legacy free functions spread
/// over their signatures is a builder method here:
///
/// | legacy function               | equivalent request                            |
/// |-------------------------------|-----------------------------------------------|
/// | `stream_mttkrp`               | `.job(f)` *(devices resolve to 1)*            |
/// | `stream_mttkrp_scheduled`     | `.job(f).schedule(&s)`                        |
/// | `stream_mttkrp_fused`         | `.fused(&jobs).schedule(&s)`                  |
/// | `cluster_mttkrp`              | `.job(f)` *(multi-device profile)*            |
/// | `cluster_mttkrp_with`         | `.job(f).placement(p)`                        |
/// | `cluster_mttkrp_scheduled`    | `.job(f).schedule(&s)` *(multi-device plan)*  |
///
/// The resolved device count decides the path: a prebuilt schedule's
/// `devices`, else an explicit [`devices`](Self::devices) override, else
/// `eng.profile.devices`. Only `1` (single-device pipeline) and the
/// profile's own count (sharded cluster) are runnable; anything else —
/// like fusing several jobs across devices — is
/// [`BlcoError::InvalidRequest`].
pub struct StreamRequest<'a> {
    eng: &'a BlcoEngine,
    target: usize,
    jobs: Vec<&'a [Matrix]>,
    schedule: Option<&'a StreamSchedule>,
    devices: Option<usize>,
    threads: usize,
    counters: Option<&'a Counters>,
    placement: Placement,
}

impl<'a> StreamRequest<'a> {
    /// Start a request for a mode-`target` MTTKRP of `eng`'s tensor.
    /// Threads default to [`default_threads`]; placement to
    /// [`Placement::Greedy`].
    pub fn new(eng: &'a BlcoEngine, target: usize) -> Self {
        StreamRequest {
            eng,
            target,
            jobs: Vec::new(),
            schedule: None,
            devices: None,
            threads: default_threads(),
            counters: None,
            placement: Placement::Greedy,
        }
    }

    /// Append one MTTKRP job (a full factor set; `factors[target]` is
    /// ignored like everywhere else). Call repeatedly — or use
    /// [`fused`](Self::fused) — to build a fused group that ships every
    /// BLCO batch over the host link once and runs each job's kernel on
    /// it while resident.
    pub fn job(mut self, factors: &'a [Matrix]) -> Self {
        self.jobs.push(factors);
        self
    }

    /// Append a whole fused job group at once; `jobs[j]` and `outs[j]`
    /// of [`run`](Self::run) correspond.
    pub fn fused(mut self, jobs: &[&'a [Matrix]]) -> Self {
        self.jobs.extend_from_slice(jobs);
        self
    }

    /// Use a prebuilt plan instead of planning inside `run()`. The
    /// schedule's `(target, rank, devices)` must match the request.
    pub fn schedule(mut self, sched: &'a StreamSchedule) -> Self {
        self.schedule = Some(sched);
        self
    }

    /// Override the device count: `1` forces the single-device pipeline
    /// even on a cluster profile (the legacy `stream_mttkrp` behaviour);
    /// the profile's own count forces the sharded path.
    pub fn devices(mut self, devices: usize) -> Self {
        self.devices = Some(devices);
        self
    }

    /// CPU threads for the real per-batch kernels.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// [`threads`](Self::threads) from an execution backend.
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.threads = backend.threads();
        self
    }

    /// Accumulate exact per-batch counters (and merge traffic on the
    /// cluster path) into `counters`.
    pub fn counters(mut self, counters: &'a Counters) -> Self {
        self.counters = Some(counters);
        self
    }

    /// Placement policy when `run()` plans a multi-device schedule
    /// itself; ignored when a prebuilt schedule is supplied.
    pub fn placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    /// Execute the request, writing job `j`'s MTTKRP into `outs[j]`.
    ///
    /// Validates the whole combination up front — jobs present, one
    /// output per job, consistent rank, output shapes, target in range,
    /// schedule compatibility, a runnable device count — and returns
    /// [`BlcoError::InvalidRequest`] (or the planner's
    /// [`BlcoError::InvalidProfile`]) instead of panicking. Operation
    /// order inside each path is identical to the legacy free functions,
    /// so results match them bit-for-bit.
    pub fn run(self, outs: &mut [Matrix]) -> Result<StreamOutcome, BlcoError> {
        let dims = self.eng.dims();
        if self.jobs.is_empty() {
            return Err(BlcoError::InvalidRequest {
                what: "no jobs: add at least one factor set with .job() or .fused()"
                    .into(),
            });
        }
        if self.target >= dims.len() {
            return Err(BlcoError::InvalidRequest {
                what: format!(
                    "target mode {} out of range for an order-{} tensor",
                    self.target,
                    dims.len()
                ),
            });
        }
        if outs.len() != self.jobs.len() {
            return Err(BlcoError::InvalidRequest {
                what: format!(
                    "one output per job: {} jobs but {} outputs",
                    self.jobs.len(),
                    outs.len()
                ),
            });
        }
        for (j, factors) in self.jobs.iter().enumerate() {
            if factors.len() != dims.len() {
                return Err(BlcoError::InvalidRequest {
                    what: format!(
                        "job {j}: {} factor matrices for an order-{} tensor",
                        factors.len(),
                        dims.len()
                    ),
                });
            }
        }
        let rank = self.jobs[0][0].cols;
        for (j, factors) in self.jobs.iter().enumerate() {
            if factors[0].cols != rank {
                return Err(BlcoError::InvalidRequest {
                    what: format!(
                        "fused jobs must share one rank: job 0 has {rank}, job {j} \
                         has {}",
                        factors[0].cols
                    ),
                });
            }
        }
        let nrows = dims[self.target] as usize;
        for (j, out) in outs.iter().enumerate() {
            if out.rows != nrows || out.cols != rank {
                return Err(BlcoError::InvalidRequest {
                    what: format!(
                        "output {j} is {}x{}, the mode-{} MTTKRP needs {nrows}x{rank}",
                        out.rows, out.cols, self.target
                    ),
                });
            }
        }

        let profile_devices = self.eng.profile.devices.max(1);
        let devices = self.devices.unwrap_or(match self.schedule {
            Some(s) => s.devices,
            None => profile_devices,
        });
        if devices != 1 && devices != profile_devices {
            return Err(BlcoError::InvalidRequest {
                what: format!(
                    "devices must be 1 (single-device pipeline) or the profile's \
                     own count {profile_devices}, got {devices}"
                ),
            });
        }
        if devices > 1 && self.jobs.len() > 1 {
            return Err(BlcoError::InvalidRequest {
                what: format!(
                    "fused job groups ({} jobs) only run on the single-device \
                     pipeline; the {devices}-device sharded path takes one job",
                    self.jobs.len()
                ),
            });
        }
        if let Some(s) = self.schedule {
            if s.target != self.target || s.rank != rank || s.devices != devices {
                return Err(BlcoError::InvalidRequest {
                    what: format!(
                        "schedule was planned for (target {}, rank {}, {} devices), \
                         the request is (target {}, rank {rank}, {devices} devices)",
                        s.target, s.rank, s.devices, self.target
                    ),
                });
            }
        }

        let local_counters;
        let counters = match self.counters {
            Some(c) => c,
            None => {
                local_counters = Counters::new();
                &local_counters
            }
        };

        if devices == 1 {
            let report = match self.schedule {
                Some(s) => stream_fused_impl(
                    self.eng, s, &self.jobs, outs, self.threads, counters,
                ),
                None => {
                    let s =
                        StreamSchedule::try_single_device(self.eng, self.target, rank)?;
                    stream_fused_impl(
                        self.eng, &s, &self.jobs, outs, self.threads, counters,
                    )
                }
            };
            Ok(StreamOutcome::Streamed(report))
        } else {
            let factors = self.jobs[0];
            let out = &mut outs[0];
            let report = match self.schedule {
                Some(s) => cluster_scheduled_impl(
                    self.eng, s, factors, out, self.threads, counters,
                ),
                None => {
                    let s = StreamSchedule::try_build(
                        self.eng,
                        self.target,
                        rank,
                        self.placement,
                    )?;
                    cluster_scheduled_impl(
                        self.eng, &s, factors, out, self.threads, counters,
                    )
                }
            };
            Ok(StreamOutcome::Clustered(report))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profile::Profile;
    use crate::format::blco::{BlcoConfig, BlcoTensor};
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    fn engine(devices: usize) -> (crate::tensor::coo::CooTensor, BlcoEngine) {
        let t = synth::uniform(&[60, 50, 40], 8_000, 3);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        let mut p = Profile::tiny(1 << 16);
        p.devices = devices;
        let eng = BlcoEngine::new(b, p);
        (t, eng)
    }

    #[test]
    #[allow(deprecated)]
    fn request_matches_the_deprecated_wrappers_bitwise() {
        use crate::coordinator::cluster::cluster_mttkrp;
        use crate::coordinator::streamer::{stream_mttkrp, stream_mttkrp_fused};

        // single-device path vs stream_mttkrp
        let (t, eng) = engine(1);
        let factors = random_factors(&t.dims, 8, 5);
        let mut old = Matrix::zeros(t.dims[1] as usize, 8);
        let mut new = Matrix::zeros(t.dims[1] as usize, 8);
        let ra = stream_mttkrp(&eng, 1, &factors, &mut old, 4, &Counters::new());
        let outcome = StreamRequest::new(&eng, 1)
            .job(&factors)
            .threads(4)
            .run(std::slice::from_mut(&mut new))
            .unwrap();
        let rb = outcome.streamed().unwrap();
        assert_eq!(old.data, new.data, "bit-for-bit vs stream_mttkrp");
        assert_eq!(ra.bytes, rb.bytes);
        assert_eq!(ra.transfer_s, rb.transfer_s);
        assert_eq!(ra.overall_s, rb.overall_s, "same modelled clock");

        // fused path vs stream_mttkrp_fused under one prebuilt schedule
        let sets: Vec<Vec<Matrix>> =
            [31u64, 37].iter().map(|&s| random_factors(&t.dims, 8, s)).collect();
        let refs: Vec<&[Matrix]> = sets.iter().map(|f| f.as_slice()).collect();
        let sched = StreamSchedule::single_device(&eng, 0, 8);
        let mut old2: Vec<Matrix> =
            (0..2).map(|_| Matrix::zeros(t.dims[0] as usize, 8)).collect();
        let mut new2: Vec<Matrix> =
            (0..2).map(|_| Matrix::zeros(t.dims[0] as usize, 8)).collect();
        let rf =
            stream_mttkrp_fused(&eng, &sched, &refs, &mut old2, 4, &Counters::new());
        let of = StreamRequest::new(&eng, 0)
            .fused(&refs)
            .schedule(&sched)
            .threads(4)
            .run(&mut new2)
            .unwrap();
        for (o, n) in old2.iter().zip(&new2) {
            assert_eq!(o.data, n.data, "fused bit-for-bit");
        }
        assert_eq!(rf.overall_s, of.overall_s());
        assert_eq!(rf.bytes, of.bytes());

        // sharded path vs cluster_mttkrp on a 3-device profile
        let (t, eng) = engine(3);
        let factors = random_factors(&t.dims, 8, 11);
        let mut old = Matrix::zeros(t.dims[2] as usize, 8);
        let mut new = Matrix::zeros(t.dims[2] as usize, 8);
        let rc = cluster_mttkrp(&eng, 2, &factors, &mut old, 4, &Counters::new());
        let oc = StreamRequest::new(&eng, 2)
            .job(&factors)
            .threads(4)
            .run(std::slice::from_mut(&mut new))
            .unwrap();
        let rn = oc.clustered().unwrap();
        assert_eq!(old.data, new.data, "bit-for-bit vs cluster_mttkrp");
        assert_eq!(rc.bytes, rn.bytes);
        assert_eq!(rc.merge_bytes, rn.merge_bytes);
        assert_eq!(rc.overall_s, rn.overall_s, "same modelled clock");
        assert_eq!(rn.devices, 3);
    }

    #[test]
    fn results_match_the_oracle_on_both_paths() {
        for devices in [1usize, 2] {
            let (t, eng) = engine(devices);
            let factors = random_factors(&t.dims, 8, 7);
            for target in 0..3 {
                let expect = mttkrp_oracle(&t, target, &factors);
                let mut out = Matrix::zeros(t.dims[target] as usize, 8);
                let cnt = Counters::new();
                let outcome = StreamRequest::new(&eng, target)
                    .job(&factors)
                    .threads(4)
                    .counters(&cnt)
                    .run(std::slice::from_mut(&mut out))
                    .unwrap();
                assert!(
                    out.max_abs_diff(&expect) < 1e-9,
                    "devices {devices} target {target}"
                );
                assert!(outcome.bytes() >= t.nnz() * 16);
                assert!(cnt.snapshot().launches > 0, "counters were threaded");
                match devices {
                    1 => assert!(outcome.streamed().is_some()),
                    _ => assert!(outcome.clustered().is_some()),
                }
            }
        }
    }

    #[test]
    fn devices_override_forces_the_single_device_pipeline() {
        // a cluster profile can still run the legacy single-device path
        let (t, eng) = engine(4);
        let factors = random_factors(&t.dims, 8, 13);
        let expect = mttkrp_oracle(&t, 0, &factors);
        let mut out = Matrix::zeros(t.dims[0] as usize, 8);
        let outcome = StreamRequest::new(&eng, 0)
            .job(&factors)
            .devices(1)
            .threads(4)
            .run(std::slice::from_mut(&mut out))
            .unwrap();
        assert!(outcome.streamed().is_some(), "forced single-device");
        assert!(out.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn malformed_requests_are_structured_errors() {
        let (t, eng) = engine(2);
        let factors = random_factors(&t.dims, 8, 17);
        let other = random_factors(&t.dims, 4, 17);
        let mut out = Matrix::zeros(t.dims[0] as usize, 8);

        // no jobs
        let e = StreamRequest::new(&eng, 0)
            .run(std::slice::from_mut(&mut out))
            .unwrap_err();
        assert!(matches!(&e, BlcoError::InvalidRequest { what } if what.contains("job")));

        // target out of range
        let e = StreamRequest::new(&eng, 9)
            .job(&factors)
            .run(std::slice::from_mut(&mut out))
            .unwrap_err();
        assert!(
            matches!(&e, BlcoError::InvalidRequest { what } if what.contains("target"))
        );

        // output count mismatch
        let e = StreamRequest::new(&eng, 0).job(&factors).run(&mut []).unwrap_err();
        assert!(
            matches!(&e, BlcoError::InvalidRequest { what } if what.contains("output"))
        );

        // fused ranks disagree
        let mut outs =
            vec![Matrix::zeros(t.dims[0] as usize, 8), Matrix::zeros(t.dims[0] as usize, 4)];
        let e = StreamRequest::new(&eng, 0)
            .job(&factors)
            .job(&other)
            .devices(1)
            .run(&mut outs)
            .unwrap_err();
        assert!(matches!(&e, BlcoError::InvalidRequest { what } if what.contains("rank")));

        // fused group on the sharded path
        let mut outs =
            vec![Matrix::zeros(t.dims[0] as usize, 8), Matrix::zeros(t.dims[0] as usize, 8)];
        let e = StreamRequest::new(&eng, 0)
            .job(&factors)
            .job(&factors)
            .run(&mut outs)
            .unwrap_err();
        assert!(
            matches!(&e, BlcoError::InvalidRequest { what } if what.contains("fused"))
        );

        // device count neither 1 nor the profile's
        let e = StreamRequest::new(&eng, 0)
            .job(&factors)
            .devices(3)
            .run(std::slice::from_mut(&mut out))
            .unwrap_err();
        assert!(
            matches!(&e, BlcoError::InvalidRequest { what } if what.contains("devices"))
        );

        // schedule planned for a different shape
        let sched = StreamSchedule::single_device(&eng, 1, 8);
        let e = StreamRequest::new(&eng, 0)
            .job(&factors)
            .schedule(&sched)
            .run(std::slice::from_mut(&mut out))
            .unwrap_err();
        assert!(
            matches!(&e, BlcoError::InvalidRequest { what } if what.contains("schedule"))
        );

        // wrong output shape
        let mut bad = Matrix::zeros(3, 8);
        let e = StreamRequest::new(&eng, 0)
            .job(&factors)
            .devices(1)
            .run(std::slice::from_mut(&mut bad))
            .unwrap_err();
        assert!(
            matches!(&e, BlcoError::InvalidRequest { what } if what.contains("output"))
        );

        // errors render readably through the crate error type
        let e = StreamRequest::new(&eng, 0)
            .run(std::slice::from_mut(&mut out))
            .unwrap_err();
        assert!(e.to_string().contains("invalid request"));
    }
}
