//! Out-of-memory streaming (Sections 4.2 and 6.4.2): BLCO batches are
//! dispatched to device queues with reserved memory; the transfer of
//! pending batches overlaps the compute of active ones. The computation
//! runs for real (CPU threads); the host→device link is modelled — each
//! batch is charged `bytes / link_bw` on a shared, serialized interconnect,
//! and a queue can only start computing once its transfer completes and its
//! reservation is free.

use crate::coordinator::schedule::StreamSchedule;
use crate::device::counters::Counters;
use crate::device::model::device_time;
use crate::device::profile::Profile;
use crate::format::blco::BlcoTensor;
use crate::format::store::run_with_prefetch;
use crate::mttkrp::blco::BlcoEngine;
use crate::mttkrp::dense::Matrix;

/// Host→device bytes one batch occupies on the wire. Thin delegate to
/// [`BlcoTensor::batch_wire_bytes`] (the single source of truth); engines
/// whose payload is not resident use
/// [`BatchSource::batch_bytes`](crate::format::store::BatchSource::batch_bytes),
/// which routes through the same accounting.
pub fn batch_bytes(t: &BlcoTensor, b: usize) -> usize {
    t.batch_wire_bytes(b)
}

/// Per-batch trace entry.
#[derive(Clone, Copy, Debug)]
pub struct BatchTrace {
    pub bytes: usize,
    /// modelled host→device transfer seconds
    pub transfer_s: f64,
    /// modelled device compute seconds (from exact counters)
    pub compute_s: f64,
    /// measured CPU wall seconds for the real computation
    pub wall_s: f64,
}

/// Result of streaming one full MTTKRP.
#[derive(Clone, Debug, Default)]
pub struct StreamReport {
    pub batches: Vec<BatchTrace>,
    /// pipeline-simulated end-to-end seconds (transfers + compute, overlap)
    pub overall_s: f64,
    /// compute-only seconds (the paper's "in-memory throughput" basis)
    pub compute_s: f64,
    /// total modelled transfer seconds on the link
    pub transfer_s: f64,
    /// total bytes shipped over the interconnect
    pub bytes: usize,
    /// measured CPU wall seconds of the whole streamed MTTKRP
    pub wall_s: f64,
}

impl StreamReport {
    /// Occupancy of the busier serialized resource (link or device):
    /// near 1.0 means perfect transfer/compute overlap — the pipeline is
    /// limited by one resource, idle on neither. The paper's Figure 10
    /// regime is link-bound with this ratio high.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.overall_s <= 0.0 {
            return 1.0;
        }
        (self.transfer_s.max(self.compute_s) / self.overall_s).min(1.0)
    }
}

/// Stream a mode-`target` MTTKRP of `eng`'s tensor through `profile`'s
/// queues. The output accumulates across batches exactly like the
/// in-memory path (BLCO's opportunistic conflict resolution makes blocks
/// independent, Section 4.2).
///
/// Deprecated wrapper: plans a fresh single-device [`StreamSchedule`] and
/// runs the pipeline body [`StreamRequest`] dispatches to, so
/// `StreamRequest::new(eng, target).job(factors).devices(1)` reproduces it
/// bit-for-bit (pinned by `coordinator::request`'s tests).
///
/// [`StreamRequest`]: super::request::StreamRequest
#[deprecated(
    note = "use coordinator::request::StreamRequest — \
            StreamRequest::new(eng, target).job(factors).devices(1).run(..)"
)]
pub fn stream_mttkrp(
    eng: &BlcoEngine,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
) -> StreamReport {
    let sched = StreamSchedule::single_device(eng, target, factors[0].cols);
    stream_fused_impl(
        eng,
        &sched,
        &[factors],
        std::slice::from_mut(out),
        threads,
        counters,
    )
}

/// Stream with a prebuilt plan: per-batch wire bytes, transfer times and
/// the queue skeleton all come from `sched`; only the kernels themselves
/// (and their exact counters) run here.
///
/// Deprecated wrapper over the same single-job body
/// [`StreamRequest`](super::request::StreamRequest) runs, so prebuilt-plan
/// parity holds bit-for-bit.
#[deprecated(
    note = "use coordinator::request::StreamRequest — \
            StreamRequest::new(eng, target).job(factors).schedule(&sched).run(..)"
)]
pub fn stream_mttkrp_scheduled(
    eng: &BlcoEngine,
    sched: &StreamSchedule,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
) -> StreamReport {
    stream_fused_impl(
        eng,
        sched,
        &[factors],
        std::slice::from_mut(out),
        threads,
        counters,
    )
}

/// Stream *several* same-`(target, rank)` MTTKRP jobs through one pass over
/// the tensor — the serving layer's batching primitive
/// ([`crate::service`]): each BLCO batch is shipped over the host link
/// **once** and every job's kernel runs on it while it is resident, so a
/// fused group of `k` jobs pays the Figure-10 interconnect cost once
/// instead of `k` times. `factor_sets[j]` and `outs[j]` are job `j`'s
/// factors and output; all jobs must match the schedule's rank.
#[deprecated(
    note = "use coordinator::request::StreamRequest — \
            StreamRequest::new(eng, target).fused(&jobs).run(..)"
)]
pub fn stream_mttkrp_fused(
    eng: &BlcoEngine,
    sched: &StreamSchedule,
    factor_sets: &[&[Matrix]],
    outs: &mut [Matrix],
    threads: usize,
    counters: &Counters,
) -> StreamReport {
    stream_fused_impl(eng, sched, factor_sets, outs, threads, counters)
}

/// The single-device pipeline body every entry point resolves to —
/// [`StreamRequest::run`](super::request::StreamRequest::run) with
/// `devices == 1`, the deprecated free-function wrappers above, and the
/// facade's streamed route.
///
/// The pipeline clock: one serialized link, one serialized compute
/// engine, queue reservations from the plan — with each batch's compute
/// slot holding the *sum* of the fused group's kernels.
pub(crate) fn stream_fused_impl(
    eng: &BlcoEngine,
    sched: &StreamSchedule,
    factor_sets: &[&[Matrix]],
    outs: &mut [Matrix],
    threads: usize,
    counters: &Counters,
) -> StreamReport {
    let profile: &Profile = &eng.profile;
    let target = sched.target;
    let queues = sched.queues.max(1);
    let nbatches = eng.num_batches();
    assert!(!factor_sets.is_empty(), "fused stream needs at least one job");
    assert_eq!(
        factor_sets.len(),
        outs.len(),
        "one output per fused job ({} factor sets, {} outputs)",
        factor_sets.len(),
        outs.len()
    );
    assert_eq!(
        sched.devices, 1,
        "single-device streamer given a {}-device schedule (route through \
         StreamRequest, or plan with StreamSchedule::single_device)",
        sched.devices
    );
    assert_eq!(
        sched.bytes.len(),
        nbatches,
        "schedule was planned for a different tensor"
    );
    for f in factor_sets {
        assert_eq!(
            sched.rank,
            f[0].cols,
            "schedule was planned for a different rank"
        );
    }
    let t0 = std::time::Instant::now();
    for out in outs.iter_mut() {
        out.fill(0.0);
    }

    let mut traces = Vec::with_capacity(nbatches);

    // pipeline state: one staging reservation per queue, a shared
    // serialized link, and a shared serialized compute engine (one device:
    // kernels run back-to-back; queues overlap *transfer with compute*,
    // not compute with compute)
    let mut link_free = 0.0f64;
    let mut device_free = 0.0f64;
    let mut queue_free = vec![0.0f64; queues];

    // for an on-disk source, a prefetch thread pulls batch b+1's blocks
    // into the block cache while batch b computes — real disk I/O hidden
    // behind real kernels; resident sources pay nothing for the wrapper
    run_with_prefetch(&eng.src, eng.src.is_on_disk(), counters, |notify| {
        for b in 0..nbatches {
            notify(b);
            let bytes = sched.bytes[b];
            let tr = sched.transfer_s[b];

            // real computation of this batch for every fused job, with exact
            // per-batch counters (the wire bytes above are charged once)
            let batch_counters = Counters::new();
            let w0 = std::time::Instant::now();
            for (factors, out) in factor_sets.iter().zip(outs.iter_mut()) {
                eng.mttkrp_batch(b, target, factors, out, threads, &batch_counters);
            }
            let wall_s = w0.elapsed().as_secs_f64();
            let snap = batch_counters.snapshot();
            counters.add(&snap);
            let compute_s = device_time(&snap, profile).total();

            // pipeline: queue q starts its transfer when the link and its
            // reservation are free; the kernel starts when the data has landed
            // and the device is free
            let q = sched.queue_of[b];
            let start = link_free.max(queue_free[q]);
            let landed = start + tr;
            link_free = landed;
            let compute_start = landed.max(device_free);
            device_free = compute_start + compute_s;
            queue_free[q] = device_free;

            traces.push(BatchTrace { bytes, transfer_s: tr, compute_s, wall_s });
        }
    });

    let overall_s = device_free.max(link_free);
    StreamReport {
        overall_s,
        compute_s: traces.iter().map(|t| t.compute_s).sum(),
        transfer_s: traces.iter().map(|t| t.transfer_s).sum(),
        bytes: traces.iter().map(|t| t.bytes).sum(),
        wall_s: t0.elapsed().as_secs_f64(),
        batches: traces,
    }
}

/// Snapshot-level volume of a report's kernels (helper for Figure 10).
pub fn stream_volume(counters: &Counters) -> u64 {
    counters.snapshot().volume_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::StreamRequest;
    use crate::format::blco::{BlcoConfig, BlcoTensor};
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    fn stream(
        eng: &BlcoEngine,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
    ) -> StreamReport {
        StreamRequest::new(eng, target)
            .job(factors)
            .threads(4)
            .run(std::slice::from_mut(out))
            .unwrap()
            .into_streamed()
            .unwrap()
    }

    fn small_batched_engine() -> (crate::tensor::coo::CooTensor, BlcoEngine) {
        let t = synth::uniform(&[60, 50, 40], 8_000, 3);
        // small batches force a long pipeline
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        assert!(b.batches.len() > 4);
        let eng = BlcoEngine::new(b, Profile::tiny(1 << 16));
        (t, eng)
    }

    #[test]
    fn streamed_equals_in_memory_result() {
        let (t, eng) = small_batched_engine();
        let factors = random_factors(&t.dims, 8, 5);
        for target in 0..3 {
            let expect = mttkrp_oracle(&t, target, &factors);
            let mut out = Matrix::zeros(t.dims[target] as usize, 8);
            let rep = stream(&eng, target, &factors, &mut out);
            assert!(out.max_abs_diff(&expect) < 1e-9, "target {target}");
            assert_eq!(rep.batches.len(), eng.num_batches());
        }
    }

    #[test]
    fn pipeline_overlaps_transfer_and_compute() {
        let (t, eng) = small_batched_engine();
        let factors = random_factors(&t.dims, 8, 7);
        let mut out = Matrix::zeros(t.dims[0] as usize, 8);
        let rep = stream(&eng, 0, &factors, &mut out);
        // with overlap, overall < serial sum of transfer + compute
        assert!(rep.overall_s < rep.transfer_s + rep.compute_s);
        // both serialized resources lower-bound the pipeline
        assert!(rep.overall_s >= rep.transfer_s.max(rep.compute_s) * 0.999);
        assert!(rep.bytes >= t.nnz() * 16);
    }

    #[test]
    fn scheduled_entry_point_matches_the_wrapper() {
        // one prebuilt schedule reused across calls must reproduce the
        // plan-per-call wrapper exactly (same modelled clock, same result)
        let (t, eng) = small_batched_engine();
        let factors = random_factors(&t.dims, 8, 21);
        let sched = StreamSchedule::single_device(&eng, 1, 8);
        let mut a = Matrix::zeros(t.dims[1] as usize, 8);
        let mut b = Matrix::zeros(t.dims[1] as usize, 8);
        let ra = stream(&eng, 1, &factors, &mut a);
        let scheduled = |out: &mut Matrix| {
            StreamRequest::new(&eng, 1)
                .job(&factors)
                .schedule(&sched)
                .threads(4)
                .run(std::slice::from_mut(out))
                .unwrap()
                .into_streamed()
                .unwrap()
        };
        let rb = scheduled(&mut b);
        let rb2 = scheduled(&mut b);
        assert_eq!(ra.bytes, rb.bytes);
        assert_eq!(ra.transfer_s, rb.transfer_s, "identical modelled transfers");
        assert_eq!(rb.transfer_s, rb2.transfer_s, "schedule reuse is stable");
        assert!(a.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn fused_group_ships_bytes_once_and_stays_correct() {
        // k fused jobs: every output matches its own oracle, wire bytes are
        // charged once (not k times), and the fused pipeline is strictly
        // faster than running the k jobs back-to-back
        let (t, eng) = small_batched_engine();
        let rank = 8;
        let seeds = [31u64, 37, 41];
        let factor_sets: Vec<Vec<Matrix>> =
            seeds.iter().map(|&s| random_factors(&t.dims, rank, s)).collect();
        let refs: Vec<&[Matrix]> = factor_sets.iter().map(|f| f.as_slice()).collect();
        let mut outs: Vec<Matrix> =
            seeds.iter().map(|_| Matrix::zeros(t.dims[0] as usize, rank)).collect();
        let sched = StreamSchedule::single_device(&eng, 0, rank);
        let fused = StreamRequest::new(&eng, 0)
            .fused(&refs)
            .schedule(&sched)
            .threads(4)
            .run(&mut outs)
            .unwrap()
            .into_streamed()
            .unwrap();
        let mut serial_overall = 0.0;
        let mut serial_bytes = 0usize;
        for (factors, out) in factor_sets.iter().zip(&outs) {
            let expect = mttkrp_oracle(&t, 0, factors);
            assert!(out.max_abs_diff(&expect) < 1e-9);
            let mut solo = Matrix::zeros(t.dims[0] as usize, rank);
            let rep = StreamRequest::new(&eng, 0)
                .job(factors)
                .schedule(&sched)
                .threads(4)
                .run(std::slice::from_mut(&mut solo))
                .unwrap()
                .into_streamed()
                .unwrap();
            serial_overall += rep.overall_s;
            serial_bytes += rep.bytes;
        }
        assert_eq!(fused.bytes * seeds.len(), serial_bytes, "payload shipped once");
        assert!(
            fused.overall_s < serial_overall,
            "fused {} vs serial {}",
            fused.overall_s,
            serial_overall
        );
    }

    #[test]
    fn fused_with_one_job_is_the_scheduled_path() {
        let (t, eng) = small_batched_engine();
        let factors = random_factors(&t.dims, 8, 43);
        let sched = StreamSchedule::single_device(&eng, 2, 8);
        let mut a = Matrix::zeros(t.dims[2] as usize, 8);
        let mut b = Matrix::zeros(t.dims[2] as usize, 8);
        let ra = StreamRequest::new(&eng, 2)
            .job(&factors)
            .schedule(&sched)
            .threads(4)
            .run(std::slice::from_mut(&mut a))
            .unwrap()
            .into_streamed()
            .unwrap();
        let rb = StreamRequest::new(&eng, 2)
            .fused(&[&factors])
            .schedule(&sched)
            .threads(4)
            .run(std::slice::from_mut(&mut b))
            .unwrap()
            .into_streamed()
            .unwrap();
        assert_eq!(ra.bytes, rb.bytes);
        assert_eq!(ra.transfer_s, rb.transfer_s);
        assert_eq!(ra.overall_s, rb.overall_s, "same modelled clock");
        assert_eq!(a.data, b.data, "bit-for-bit identical output");
    }

    #[test]
    fn link_bound_when_transfer_dominates() {
        // starve the interconnect (0.05 GB/s): the pipeline must become
        // link-bound with near-perfect occupancy, matching the paper's
        // Figure 10 observation that communication dominates OOM runs
        let (t, mut eng_parts) = small_batched_engine();
        let mut p = Profile::tiny(1 << 16);
        p.link_gbps = 0.05;
        eng_parts.profile = p;
        let eng = eng_parts;
        let factors = random_factors(&t.dims, 8, 9);
        let mut out = Matrix::zeros(t.dims[0] as usize, 8);
        let rep = stream(&eng, 0, &factors, &mut out);
        assert!(rep.transfer_s > rep.compute_s);
        let eff = rep.overlap_efficiency();
        assert!(eff > 0.9 && eff <= 1.0, "efficiency {eff}");
    }
}
