//! Multi-device sharded out-of-memory streaming — the scaling axis past the
//! paper's single-GPU Figure 10 regime (cf. AMPED's multi-GPU MTTKRP and
//! Nisa et al.'s load-balanced placement, PAPERS.md).
//!
//! BLCO batches are *sharded* across `D` simulated devices (one
//! [`Profile`] describes every device of the homogeneous cluster):
//!
//! 1. **planning** — every batch gets a *modelled* cost, host-link
//!    transfer time + device-model compute time, and a greedy
//!    longest-processing-time assignment puts the next-heaviest batch on
//!    the least-loaded device ([`Placement::Greedy`]; [`Placement::RoundRobin`]
//!    is kept as the ablation baseline the greedy policy must beat). The
//!    whole plan is reified as a [`StreamSchedule`]
//!    ([`super::schedule`]) — built once per `(target, rank)` and cached
//!    by the facade across CP-ALS iterations;
//! 2. **streaming** — each device runs its batches through its own queue
//!    reservations exactly like the single-device pipeline
//!    ([`super::streamer`]), computing for real on CPU threads into a
//!    per-device partial output. Host links follow the profile's
//!    [`LinkTopology`]: `Shared` serializes every transfer through one
//!    root complex, `Dedicated` gives each device its own full-rate link,
//!    and `Ports(n)` interleaves the devices over `n` links
//!    (`device % n`);
//! 3. **merge** — per-device partials are combined by a parallel binary
//!    tree reduction over the peer interconnect (`peer_gbps`), with the
//!    merge's read/write traffic charged to the counters and its modelled
//!    time appended after the last kernel retires (a conservative
//!    barrier).
//!
//! With `D = 1` the schedule, the pipeline clock and the report degenerate
//! bit-for-bit to [`super::streamer::stream_mttkrp`]'s — the regression
//! anchor of `rust/tests/cluster_streaming.rs`.

use crate::coordinator::schedule::StreamSchedule;
use crate::coordinator::streamer::BatchTrace;
use crate::device::counters::{Counters, Snapshot};
use crate::device::model::device_time;
use crate::device::profile::Profile;
use crate::format::store::run_with_prefetch;
use crate::mttkrp::blco::BlcoEngine;
use crate::mttkrp::dense::Matrix;

// Planning (placement policy, modelled batch costs, makespan) lives in the
// schedule subsystem now; re-exported here so existing call sites keep
// their import paths.
pub use crate::coordinator::schedule::{
    estimate_batch_cost, modelled_makespan, plan_placement, Placement,
};

/// One device's slice of the run.
#[derive(Clone, Debug, Default)]
pub struct DeviceTimeline {
    /// batch indices this device ran, in submission order
    pub batches: Vec<usize>,
    /// host→device bytes shipped to this device
    pub bytes: usize,
    /// sum of modelled transfer seconds for its batches
    pub transfer_s: f64,
    /// sum of modelled compute seconds (from exact counters)
    pub compute_s: f64,
    /// pipeline time at which its last kernel retires
    pub finish_s: f64,
}

impl DeviceTimeline {
    /// Modelled busy time (the load-balance quantity).
    pub fn busy_s(&self) -> f64 {
        self.transfer_s + self.compute_s
    }
}

/// Result of one sharded, streamed MTTKRP.
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    pub devices: usize,
    pub placement: Placement,
    /// per-device timelines, indexed by device id
    pub per_device: Vec<DeviceTimeline>,
    /// per-batch traces, indexed by global batch id
    pub batches: Vec<BatchTrace>,
    /// pipeline-simulated end-to-end seconds *including* the merge
    pub overall_s: f64,
    /// pipeline end of the streaming phase (before the merge barrier)
    pub stream_s: f64,
    /// modelled seconds of the parallel tree merge
    pub merge_s: f64,
    /// total modelled compute seconds across devices
    pub compute_s: f64,
    /// total modelled host-link transfer seconds
    pub transfer_s: f64,
    /// total host→device bytes shipped
    pub bytes: usize,
    /// device↔device bytes moved by the tree merge
    pub merge_bytes: usize,
    /// measured CPU wall seconds of the whole sharded MTTKRP
    pub wall_s: f64,
}

impl ClusterReport {
    /// Load-imbalance ratio: max over devices of modelled busy time,
    /// divided by the mean. 1.0 is a perfect shard; round-robin on skewed
    /// batch costs drives this up.
    pub fn imbalance(&self) -> f64 {
        if self.per_device.is_empty() {
            return 1.0;
        }
        let busy: Vec<f64> = self.per_device.iter().map(|d| d.busy_s()).collect();
        let max = busy.iter().fold(0.0f64, |a, &b| a.max(b));
        let mean = busy.iter().sum::<f64>() / busy.len() as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Occupancy of the host link(s) during the streaming phase: total
    /// transfer seconds over (streaming makespan × independent links).
    /// Near 1.0 means the run is interconnect-bound — the multi-device
    /// generalization of Figure 10's finding.
    pub fn link_occupancy(&self, profile: &Profile) -> f64 {
        if self.stream_s <= 0.0 {
            return 0.0;
        }
        (self.transfer_s / (self.stream_s * profile.host_links() as f64)).min(1.0)
    }
}

/// Stream a mode-`target` MTTKRP of `eng`'s tensor across
/// `eng.profile.devices` simulated devices with greedy load-balanced
/// placement. The real computation accumulates into per-device partials
/// merged by a tree reduction, so `out` ends exactly as the single-device
/// path leaves it.
///
/// Deprecated wrapper over the sharded body
/// [`StreamRequest`](super::request::StreamRequest) dispatches to; parity
/// is pinned bit-for-bit by `coordinator::request`'s tests.
#[deprecated(
    note = "use coordinator::request::StreamRequest — \
            StreamRequest::new(eng, target).job(factors).run(..)"
)]
pub fn cluster_mttkrp(
    eng: &BlcoEngine,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
) -> ClusterReport {
    let sched =
        StreamSchedule::build(eng, target, factors[0].cols, Placement::Greedy);
    cluster_scheduled_impl(eng, &sched, factors, out, threads, counters)
}

/// [`cluster_mttkrp`] with an explicit placement policy.
#[deprecated(
    note = "use coordinator::request::StreamRequest — \
            StreamRequest::new(eng, target).job(factors).placement(p).run(..)"
)]
pub fn cluster_mttkrp_with(
    eng: &BlcoEngine,
    target: usize,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
    placement: Placement,
) -> ClusterReport {
    let sched = StreamSchedule::build(eng, target, factors[0].cols, placement);
    cluster_scheduled_impl(eng, &sched, factors, out, threads, counters)
}

/// Sharded streaming with a prebuilt plan.
#[deprecated(
    note = "use coordinator::request::StreamRequest — \
            StreamRequest::new(eng, target).job(factors).schedule(&sched).run(..)"
)]
pub fn cluster_mttkrp_scheduled(
    eng: &BlcoEngine,
    sched: &StreamSchedule,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
) -> ClusterReport {
    cluster_scheduled_impl(eng, sched, factors, out, threads, counters)
}

/// The sharded pipeline body every entry point resolves to —
/// [`StreamRequest::run`](super::request::StreamRequest::run) with a
/// multi-device count, the deprecated free-function wrappers above, and
/// the facade's clustered route. Placement, per-batch transfer times and
/// the queue/link skeleton all come from `sched`; only the kernels (and
/// their exact counters) and the tree merge run here.
pub(crate) fn cluster_scheduled_impl(
    eng: &BlcoEngine,
    sched: &StreamSchedule,
    factors: &[Matrix],
    out: &mut Matrix,
    threads: usize,
    counters: &Counters,
) -> ClusterReport {
    let profile: &Profile = &eng.profile;
    let target = sched.target;
    let devices = sched.devices;
    let queues = sched.queues.max(1);
    let links = sched.links.max(1);
    let nbatches = eng.num_batches();
    assert_eq!(
        sched.devices,
        eng.profile.devices.max(1),
        "schedule was planned for a different device count"
    );
    assert_eq!(
        sched.bytes.len(),
        nbatches,
        "schedule was planned for a different tensor"
    );
    let rank = factors[0].cols;
    assert_eq!(sched.rank, rank, "schedule was planned for a different rank");
    let t0 = std::time::Instant::now();
    out.fill(0.0);

    // ---- per-device pipelined streaming with real compute.
    // Batches are submitted in global batch order (the ALTO-curve order the
    // host reads them in); each lands on its assigned device's next queue.
    // Device 0 accumulates directly into `out` (zeroed above), so the
    // degenerate D = 1 case allocates nothing extra and is exactly the
    // single-device streamer; devices 1.. get their own partial outputs,
    // tree-merged into `out` afterwards.
    let mut partials: Vec<Matrix> =
        (1..devices).map(|_| Matrix::zeros(out.rows, rank)).collect();
    let mut link_free = vec![0.0f64; links];
    let mut device_free = vec![0.0f64; devices];
    let mut queue_free = vec![vec![0.0f64; queues]; devices];
    let mut timelines = vec![DeviceTimeline::default(); devices];
    let mut traces = Vec::with_capacity(nbatches);

    // batches are visited in global submission order regardless of the
    // device they land on, so a single one-batch-lookahead prefetcher
    // (real disk I/O hidden behind real kernels) serves every device
    run_with_prefetch(&eng.src, eng.src.is_on_disk(), counters, |notify| {
        for b in 0..nbatches {
            notify(b);
            let d = sched.assign[b];
            let bytes = sched.bytes[b];
            let tr = sched.transfer_s[b];

            // real computation with exact per-batch counters
            let batch_counters = Counters::new();
            let w0 = std::time::Instant::now();
            if d == 0 {
                eng.mttkrp_batch(b, target, factors, out, threads, &batch_counters);
            } else {
                eng.mttkrp_batch(
                    b, target, factors, &mut partials[d - 1], threads, &batch_counters,
                );
            }
            let wall_s = w0.elapsed().as_secs_f64();
            let snap = batch_counters.snapshot();
            counters.add(&snap);
            let compute_s = device_time(&snap, profile).total();

            // pipeline clock: the transfer waits for this device's host link
            // (`device % links` — devices round-robin over the independent
            // links) and its queue reservation; the kernel waits for the data
            // and the device's compute engine
            let li = sched.link_of[b];
            let q = sched.queue_of[b];
            let start = link_free[li].max(queue_free[d][q]);
            let landed = start + tr;
            link_free[li] = landed;
            let compute_start = landed.max(device_free[d]);
            device_free[d] = compute_start + compute_s;
            queue_free[d][q] = device_free[d];

            let tl = &mut timelines[d];
            tl.batches.push(b);
            tl.bytes += bytes;
            tl.transfer_s += tr;
            tl.compute_s += compute_s;
            tl.finish_s = device_free[d];

            traces.push(BatchTrace { bytes, transfer_s: tr, compute_s, wall_s });
        }
    });

    let stream_s = device_free
        .iter()
        .chain(link_free.iter())
        .fold(0.0f64, |a, &b| a.max(b));

    // ---- parallel binary-tree merge of the partials. Round r halves
    // the live devices: pairs (i, i+stride) exchange one output-sized
    // segment over the peer interconnect concurrently, so each round costs
    // one segment of peer time; the adds run for real below. Device 0's
    // accumulator IS `out`, so the reduction finishes in place.
    let seg_bytes = out.rows * rank * 8;
    let mut merge_s = 0.0f64;
    let mut merge_bytes = 0usize;
    let mut stride = 1usize;
    while stride < devices {
        let mut round_pairs = 0usize;
        let mut i = 0usize;
        while i + stride < devices {
            // device i absorbs device i+stride; device 0 lives in `out`,
            // devices 1.. in partials[device - 1]
            if i == 0 {
                let src = &partials[stride - 1];
                for (x, &y) in out.data.iter_mut().zip(&src.data) {
                    *x += y;
                }
            } else {
                let (head, tail) = partials.split_at_mut(i + stride - 1);
                let dst = &mut head[i - 1];
                let src = &tail[0];
                for (x, &y) in dst.data.iter_mut().zip(&src.data) {
                    *x += y;
                }
            }
            round_pairs += 1;
            i += 2 * stride;
        }
        if round_pairs > 0 {
            merge_bytes += round_pairs * seg_bytes;
            merge_s += seg_bytes as f64 / (profile.peer_gbps * 1e9);
            counters.add(&Snapshot {
                // each pair reads both partials and writes the reduced one
                bytes_streamed: (round_pairs * seg_bytes * 2) as u64,
                bytes_written: (round_pairs * seg_bytes) as u64,
                launches: round_pairs as u64,
                ..Default::default()
            });
        }
        stride *= 2;
    }

    ClusterReport {
        devices,
        placement: sched.placement,
        overall_s: stream_s + merge_s,
        stream_s,
        merge_s,
        compute_s: traces.iter().map(|t| t.compute_s).sum(),
        transfer_s: traces.iter().map(|t| t.transfer_s).sum(),
        bytes: traces.iter().map(|t| t.bytes).sum(),
        merge_bytes,
        wall_s: t0.elapsed().as_secs_f64(),
        per_device: timelines,
        batches: traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_balances_skewed_costs() {
        // one heavy batch + many light ones: round-robin piles lights onto
        // the heavy device, greedy does not
        let mut costs = vec![1.0f64; 12];
        costs[0] = 6.0;
        let g = plan_placement(&costs, 4, Placement::Greedy);
        let r = plan_placement(&costs, 4, Placement::RoundRobin);
        let mg = modelled_makespan(&costs, &g, 4);
        let mr = modelled_makespan(&costs, &r, 4);
        assert!(mg < mr, "greedy {mg} vs round-robin {mr}");
        // greedy leaves the heavy device alone: its load is exactly 6.0
        assert!((mg - 6.0).abs() < 1e-12, "makespan {mg}");
    }

    #[test]
    fn greedy_is_deterministic_and_covers_all_devices() {
        let costs: Vec<f64> = (0..40).map(|i| 1.0 + (i % 7) as f64).collect();
        let a = plan_placement(&costs, 4, Placement::Greedy);
        let b = plan_placement(&costs, 4, Placement::Greedy);
        assert_eq!(a, b);
        assert_eq!(a.len(), 40);
        for d in 0..4 {
            assert!(a.iter().any(|&x| x == d), "device {d} unused");
        }
        assert!(a.iter().all(|&d| d < 4));
    }

    #[test]
    fn single_device_placement_is_trivial() {
        let costs = vec![3.0, 1.0, 2.0];
        assert_eq!(plan_placement(&costs, 1, Placement::Greedy), vec![0, 0, 0]);
        assert_eq!(plan_placement(&costs, 1, Placement::RoundRobin), vec![0, 0, 0]);
        assert_eq!(modelled_makespan(&costs, &[0, 0, 0], 1), 6.0);
    }

    #[test]
    fn empty_batch_list() {
        let costs: Vec<f64> = vec![];
        assert!(plan_placement(&costs, 4, Placement::Greedy).is_empty());
        assert_eq!(modelled_makespan(&costs, &[], 4), 0.0);
    }

    #[test]
    fn imbalance_metric() {
        let mk = |busy: &[f64]| ClusterReport {
            devices: busy.len(),
            per_device: busy
                .iter()
                .map(|&b| DeviceTimeline { compute_s: b, ..Default::default() })
                .collect(),
            ..Default::default()
        };
        assert!((mk(&[2.0, 2.0, 2.0]).imbalance() - 1.0).abs() < 1e-12);
        assert!((mk(&[4.0, 1.0, 1.0]).imbalance() - 2.0).abs() < 1e-12);
        assert_eq!(ClusterReport::default().imbalance(), 1.0);
    }
}
