//! The public facade: one object that owns a BLCO tensor + device profile
//! and routes every MTTKRP to the right path — in-memory unified kernel
//! when the working set fits the (simulated) device, out-of-memory
//! streaming otherwise — exactly the paper's "single tensor copy, unified
//! implementation" story. Also drives CP-ALS end to end.
//!
//! Routing is *mode-aware*: the working set is sized by the target mode's
//! actual output ([`MttkrpEngine::is_oom_for`]), so one ALS sweep can mix
//! in-memory short modes with streamed/clustered long modes over the same
//! tensor copy. Out-of-memory plans are memoized per `(target, rank)` in a
//! [`ScheduleCache`] — the decomposition loop reuses one
//! [`StreamSchedule`] across all its iterations instead of replanning
//! `order × max_iters` times.

use std::path::Path;
use std::sync::Arc;

use crate::analysis::conflict::CertificateSet;
use crate::coordinator::cluster::{cluster_scheduled_impl, ClusterReport};
use crate::coordinator::schedule::{
    Placement, ScheduleCache, ScheduleStats, StreamSchedule,
};
use crate::coordinator::streamer::{stream_fused_impl, StreamReport};
use crate::cpals::als::{cp_als, CpAlsOptions, CpAlsReport};
use crate::device::counters::Counters;
use crate::device::profile::Profile;
use crate::error::BlcoError;
use crate::format::blco::{BlcoConfig, BlcoTensor};
use crate::format::store::{
    AppendSummary, BatchSource, BlcoStoreReader, BlcoStoreWriter, CacheStats, Codec,
    StoreError,
};
use crate::mttkrp::blco::{BlcoEngine, Resolution};
use crate::mttkrp::dense::Matrix;
use crate::mttkrp::Mttkrp;
use crate::tensor::coo::CooTensor;
use crate::util::pool::{default_threads, ExecBackend};

/// Which path a given MTTKRP took.
#[derive(Clone, Debug)]
pub enum ExecPath {
    InMemory(Resolution),
    Streamed(StreamReport),
    /// out-of-memory on a multi-device profile: sharded cluster streaming
    Clustered(ClusterReport),
}

impl ExecPath {
    /// Short human-readable label for report lines (CLI `decompose`
    /// section, examples).
    pub fn summary(&self) -> String {
        match self {
            ExecPath::InMemory(r) => format!("{r:?}"),
            ExecPath::Streamed(s) => format!("streamed ({} batches)", s.batches.len()),
            ExecPath::Clustered(c) => format!("cluster×{}", c.devices),
        }
    }
}

/// High-level BLCO MTTKRP engine (the library's main entry point).
///
/// ```
/// use blco::{CooTensor, MttkrpEngine};
/// use blco::device::Profile;
/// use blco::tensor::synth;
///
/// let t = synth::uniform(&[100, 80, 60], 10_000, 42);
/// let engine = MttkrpEngine::from_coo(&t, Profile::a100());
/// let factors = blco::mttkrp::oracle::random_factors(&t.dims, 16, 1);
/// let (m, path) = engine.mttkrp(0, &factors);
/// assert_eq!(m.rows, 100);
/// # let _ = path;
/// ```
pub struct MttkrpEngine {
    pub eng: BlcoEngine,
    pub dims: Vec<u64>,
    pub norm_x: f64,
    pub threads: usize,
    pub counters: Counters,
    /// memoized out-of-memory plans, one per `(target, rank)`
    schedules: ScheduleCache,
    /// set false to replan every call (the cold baseline of the
    /// cached-vs-cold bench sweep)
    cache_schedules: bool,
}

impl MttkrpEngine {
    pub fn from_coo(t: &CooTensor, profile: Profile) -> Self {
        Self::from_coo_with(t, profile, BlcoConfig::default())
    }

    pub fn from_coo_with(t: &CooTensor, profile: Profile, cfg: BlcoConfig) -> Self {
        Self::from_blco(Arc::new(BlcoTensor::from_coo_with(t, cfg)), profile)
    }

    /// Construct over an already-built, possibly *shared* BLCO tensor: the
    /// payload rides in through its `Arc` with no copy, which is how the
    /// serving registry ([`crate::service`]) keeps one resident tensor
    /// serving many concurrent jobs (and how benches sweep device counts
    /// without rebuilding). Shape and Frobenius norm are recovered from
    /// the blocks, so the COO form does not need to stay alive.
    pub fn from_blco(t: Arc<BlcoTensor>, profile: Profile) -> Self {
        Self::from_source(BatchSource::Resident(t), profile)
    }

    /// Construct over a `.blco` container on disk — the host-out-of-core
    /// tier: only header metadata (dims, per-block index, rebuilt batch
    /// maps) is resident; block payloads load on demand through a
    /// [`BlockCache`](crate::format::store::BlockCache) bounded by the
    /// profile's `host_mem_bytes`, so tensors larger than host RAM stream
    /// from disk. Routing, planning and results are identical to the
    /// resident engine — bit for bit.
    pub fn from_store(path: &Path, profile: Profile) -> Result<Self, StoreError> {
        let reader = BlcoStoreReader::open_with_budget(path, profile.host_mem_bytes)?;
        Ok(Self::from_source(BatchSource::OnDisk(reader), profile))
    }

    /// [`from_store`](Self::from_store) over a **snapshot view** pinned
    /// to the container's first `max_segments` delta segments (see
    /// [`BlcoStoreReader::open_pinned`]): dims, nnz, norm, batches and
    /// every result are bit-for-bit the container as it stood before the
    /// later appends. The serving layer uses this to keep in-flight jobs
    /// on the pre-append segment set while a writer appends behind them.
    pub fn from_store_pinned(
        path: &Path,
        profile: Profile,
        max_segments: usize,
    ) -> Result<Self, StoreError> {
        let reader = BlcoStoreReader::open_pinned(
            path,
            profile.host_mem_bytes,
            Some(max_segments),
        )?;
        Ok(Self::from_source(BatchSource::OnDisk(reader), profile))
    }

    /// Construct over any [`BatchSource`]. Panics on an invalid profile;
    /// see [`try_from_source`](Self::try_from_source).
    pub fn from_source(src: BatchSource, profile: Profile) -> Self {
        Self::try_from_source(src, profile).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`from_source`](Self::from_source), reporting an invalid profile
    /// as [`BlcoError::InvalidProfile`] instead of panicking.
    pub fn try_from_source(
        src: BatchSource,
        profile: Profile,
    ) -> Result<Self, BlcoError> {
        let dims = src.dims().to_vec();
        let norm_x = src.norm();
        Ok(MttkrpEngine {
            eng: BlcoEngine::try_from_source(src, profile)?,
            dims,
            norm_x,
            threads: default_threads(),
            counters: Counters::new(),
            schedules: ScheduleCache::new(),
            cache_schedules: true,
        })
    }

    /// Append new non-zeros to this engine's **disk-backed** container as
    /// an LSM-style delta segment, then reload: the reader reopens over
    /// the grown file, the [`ScheduleCache`] is cleared (batch count,
    /// bytes and costs all changed), any attached conflict certificates
    /// are dropped (their fingerprint no longer describes the tensor),
    /// and `dims`/`norm_x` refresh from the new header. Returns
    /// [`BlcoError::InvalidRequest`] for a resident engine — appending is
    /// a container-lifecycle operation, not a tensor edit.
    pub fn append_from_coo(
        &mut self,
        t: &CooTensor,
        codec: Option<Codec>,
    ) -> Result<AppendSummary, BlcoError> {
        let path = match self.eng.src.reader() {
            Some(r) => r.path().to_path_buf(),
            None => {
                return Err(BlcoError::InvalidRequest {
                    what: "append_from_coo requires a disk-backed engine \
                           (BatchSource::OnDisk); resident tensors are \
                           immutable shared payloads"
                        .into(),
                })
            }
        };
        let summary = BlcoStoreWriter::append(&path, t, codec)?;
        self.reload_store(&path)?;
        Ok(summary)
    }

    /// Fold the container's pending delta segments into a fresh base
    /// (see [`crate::tensor::ooc::compact`]) and reload. The compacted
    /// file is bit-for-bit what a from-scratch rebuild of the same
    /// non-zeros writes; schedules and certificates are invalidated like
    /// [`append_from_coo`](Self::append_from_coo) — block boundaries
    /// move when deltas merge into the base.
    pub fn compact(&mut self) -> Result<crate::format::store::StoreSummary, BlcoError> {
        let path = match self.eng.src.reader() {
            Some(r) => r.path().to_path_buf(),
            None => {
                return Err(BlcoError::InvalidRequest {
                    what: "compact requires a disk-backed engine \
                           (BatchSource::OnDisk)"
                        .into(),
                })
            }
        };
        let (summary, _stats) =
            crate::tensor::ooc::compact(&path, None, self.backend(), None)
                .map_err(|e| BlcoError::Build { what: format!("{e:#}") })?;
        self.reload_store(&path)?;
        Ok(summary)
    }

    /// Reopen the container at `path` and drop every structure derived
    /// from the old block/batch layout.
    fn reload_store(&mut self, path: &Path) -> Result<(), StoreError> {
        let reader =
            BlcoStoreReader::open_with_budget(path, self.eng.profile.host_mem_bytes)?;
        self.eng.src = BatchSource::OnDisk(reader);
        self.eng.certs = None;
        self.schedules.clear();
        self.dims = self.eng.src.dims().to_vec();
        self.norm_x = self.eng.src.norm();
        Ok(())
    }

    /// The shared tensor payload (cloning the `Arc`, never the data).
    /// Panics for a disk-backed engine — use [`Self::try_tensor`] or
    /// [`Self::source`] when the tier is not statically known.
    pub fn tensor(&self) -> Arc<BlcoTensor> {
        Arc::clone(self.eng.resident().unwrap_or_else(|| {
            panic!("tensor(): this engine is disk-backed (BatchSource::OnDisk)")
        }))
    }

    /// The shared tensor payload, when it is resident.
    pub fn try_tensor(&self) -> Option<Arc<BlcoTensor>> {
        self.eng.resident().map(Arc::clone)
    }

    /// Where this engine's payload lives.
    pub fn source(&self) -> &BatchSource {
        &self.eng.src
    }

    /// Block-cache statistics of a disk-backed engine (`None` when the
    /// payload is resident). `peak_resident_bytes <= budget_bytes` is the
    /// host-out-of-core guarantee.
    pub fn host_cache_stats(&self) -> Option<CacheStats> {
        self.eng.src.reader().map(|r| r.cache_stats())
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Pin the execution backend explicitly (equivalent to
    /// [`Self::with_threads`] with the backend's worker count — the
    /// engine stores one number and every kernel derives its backend
    /// from it, so there is exactly one sequential/threaded decision).
    pub fn with_backend(self, backend: ExecBackend) -> Self {
        self.with_threads(backend.threads())
    }

    /// The [`ExecBackend`] this engine's kernels, streaming executors and
    /// CP-ALS sweeps run with. Certified kernel paths are bit-for-bit
    /// identical across every backend; see
    /// [`crate::analysis::conflict`].
    pub fn backend(&self) -> ExecBackend {
        ExecBackend::from_threads(self.threads)
    }

    pub fn with_resolution(mut self, r: Resolution) -> Self {
        self.eng.resolution = r;
        self
    }

    /// Run the static conflict analysis ([`crate::analysis::conflict`])
    /// over every mode and attach the resulting certificates:
    /// `Resolution::Auto` then routes through the certified per-mode
    /// strategy and streaming plans mark `NoSync` batches. Analysis I/O is
    /// charged to a local scratch block, not this engine's counters —
    /// preprocessing is not workload traffic.
    pub fn with_conflict_analysis(mut self) -> Self {
        let scratch = Counters::new();
        let set = Arc::new(CertificateSet::analyze_with(&self.eng.src, &scratch));
        self.eng = self.eng.with_certificates(set);
        self
    }

    /// The attached conflict certificates, if analysis ran.
    pub fn certificates(&self) -> Option<&Arc<CertificateSet>> {
        self.eng.certs.as_ref()
    }

    /// Enable/disable schedule memoization (on by default). With caching
    /// off every out-of-memory call replans from scratch — the cold
    /// baseline the fig10 bench sweep compares against.
    pub fn with_schedule_caching(mut self, on: bool) -> Self {
        self.cache_schedules = on;
        self
    }

    /// Working-set bytes for a mode-`target`, rank-`rank` MTTKRP: tensor
    /// blocks + all factor matrices + the *target mode's* output.
    pub fn working_set_bytes_for(&self, target: usize, rank: usize) -> usize {
        let factors: usize =
            self.dims.iter().map(|&d| d as usize * rank * 8).sum();
        let out = self.dims[target] as usize * rank * 8;
        self.eng.footprint_bytes() + factors + out
    }

    /// Conservative working-set bytes at `rank`: the output is sized by
    /// the *largest* mode, so this upper-bounds every target. Use
    /// [`Self::working_set_bytes_for`] for exact per-mode accounting.
    pub fn working_set_bytes(&self, rank: usize) -> usize {
        let factors: usize =
            self.dims.iter().map(|&d| d as usize * rank * 8).sum();
        let out = *self.dims.iter().max().unwrap_or(&0) as usize * rank * 8;
        self.eng.footprint_bytes() + factors + out
    }

    /// Does a mode-`target` MTTKRP at `rank` require the out-of-memory
    /// path? Exact per-target accounting — short modes of an otherwise
    /// out-of-memory tensor can still run in-memory.
    pub fn is_oom_for(&self, target: usize, rank: usize) -> bool {
        !self.eng.profile.fits(self.working_set_bytes_for(target, rank))
    }

    /// Does *any* mode require the out-of-memory path at `rank`? (The
    /// conservative max-mode classification; routing itself is per-target
    /// via [`Self::is_oom_for`].)
    pub fn is_oom(&self, rank: usize) -> bool {
        !self.eng.profile.fits(self.working_set_bytes(rank))
    }

    /// Bytes one streamed mode-`target`, rank-`rank` job keeps resident on
    /// device for its whole run: every factor matrix plus the target
    /// mode's output. (The tensor itself streams through and is excluded.)
    pub fn resident_job_bytes(&self, target: usize, rank: usize) -> usize {
        let factors: usize =
            self.dims.iter().map(|&d| d as usize * rank * 8).sum();
        factors + self.dims[target] as usize * rank * 8
    }

    /// The double-buffered batch staging window of the streaming pipeline:
    /// one batch computing while the next one lands.
    fn stream_buffer_bytes(&self) -> usize {
        let max_batch = (0..self.eng.num_batches())
            .map(|b| self.eng.src.batch_bytes(b))
            .max()
            .unwrap_or(0);
        2 * max_batch
    }

    /// The *minimum* resident bytes a streamed mode-`target` MTTKRP at
    /// `rank` needs on device: [`Self::resident_job_bytes`] plus the
    /// double-buffered batch window. When even this floor exceeds device
    /// memory the request cannot be served at all — the admission
    /// controller's reject threshold ([`crate::service::admission`]).
    pub fn streaming_floor_bytes(&self, target: usize, rank: usize) -> usize {
        self.resident_job_bytes(target, rank) + self.stream_buffer_bytes()
    }

    /// How many same-`(target, rank)` jobs one fused streamed pass can
    /// co-host within device memory: `k` jobs keep `k` factor/output sets
    /// resident but share one batch double buffer, so
    /// `k × resident_job_bytes + buffer ≤ dev_mem_bytes`. At least 1
    /// whenever the job is admissible at all (the fused scheduler's group
    /// cap — fusion must not overcommit what admission guaranteed).
    pub fn fused_jobs_capacity(&self, target: usize, rank: usize) -> usize {
        let per_job = self.resident_job_bytes(target, rank);
        if per_job == 0 {
            return usize::MAX;
        }
        let budget = self
            .eng
            .profile
            .dev_mem_bytes
            .saturating_sub(self.stream_buffer_bytes());
        (budget / per_job).max(1)
    }

    /// Can a mode-`target` MTTKRP at `rank` be served at all — in memory
    /// or streamed? `false` means even the streaming floor does not fit.
    pub fn can_serve(&self, target: usize, rank: usize) -> bool {
        !self.is_oom_for(target, rank)
            || self.eng.profile.fits(self.streaming_floor_bytes(target, rank))
    }

    /// The (memoized) streaming plan for `(target, rank)`. Built on first
    /// use and reused by every later call — including all CP-ALS
    /// iterations — unless caching was disabled.
    pub fn schedule(&self, target: usize, rank: usize) -> Arc<StreamSchedule> {
        if self.cache_schedules {
            self.schedules.get_or_build(&self.eng, target, rank, Placement::Greedy)
        } else {
            self.schedules.note_uncached_build();
            Arc::new(StreamSchedule::build(
                &self.eng,
                target,
                rank,
                Placement::Greedy,
            ))
        }
    }

    /// Plans built / reused so far (see [`ScheduleStats`]).
    pub fn schedule_stats(&self) -> ScheduleStats {
        self.schedules.stats()
    }

    /// Route one MTTKRP: in-memory when the target mode's working set
    /// fits, otherwise streamed (one device) or cluster-sharded (several),
    /// through the memoized schedule.
    fn route(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) -> ExecPath {
        let rank = factors[0].cols;
        if self.is_oom_for(target, rank) {
            let sched = self.schedule(target, rank);
            if self.eng.profile.devices > 1 {
                let rep = cluster_scheduled_impl(
                    &self.eng, &sched, factors, out, threads, counters,
                );
                ExecPath::Clustered(rep)
            } else {
                let rep = stream_fused_impl(
                    &self.eng,
                    &sched,
                    &[factors],
                    std::slice::from_mut(out),
                    threads,
                    counters,
                );
                ExecPath::Streamed(rep)
            }
        } else {
            self.eng.mttkrp(target, factors, out, threads, counters);
            ExecPath::InMemory(self.eng.effective_resolution(target))
        }
    }

    /// Mode-`target` MTTKRP. Chooses in-memory, streamed or (when the
    /// profile declares more than one device) cluster-sharded streaming
    /// automatically, per target mode.
    pub fn mttkrp(&self, target: usize, factors: &[Matrix]) -> (Matrix, ExecPath) {
        let rank = factors[0].cols;
        let mut out = Matrix::zeros(self.dims[target] as usize, rank);
        let path =
            self.route(target, factors, &mut out, self.threads, &self.counters);
        (out, path)
    }

    /// Full CP-ALS decomposition using this engine's routing.
    pub fn cp_als(&self, opts: CpAlsOptions) -> CpAlsReport {
        cp_als(self, &self.dims, self.norm_x, opts, &self.counters)
    }
}

impl Mttkrp for MttkrpEngine {
    fn name(&self) -> String {
        format!("engine({})", self.eng.profile.name)
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        self.route(target, factors, out, threads, counters);
    }

    fn mttkrp_traced(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) -> Option<ExecPath> {
        Some(self.route(target, factors, out, threads, counters))
    }

    fn schedule_stats(&self) -> ScheduleStats {
        self.schedules.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    #[test]
    fn in_memory_path_on_big_device() {
        let t = synth::uniform(&[50, 40, 30], 4_000, 1);
        let engine = MttkrpEngine::from_coo(&t, Profile::a100());
        assert!(!engine.is_oom(8));
        let factors = random_factors(&t.dims, 8, 3);
        let (m, path) = engine.mttkrp(1, &factors);
        assert!(matches!(path, ExecPath::InMemory(_)));
        let expect = mttkrp_oracle(&t, 1, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9);
        // no out-of-memory plan was built
        assert_eq!(engine.schedule_stats(), ScheduleStats::default());
    }

    #[test]
    fn streamed_path_on_tiny_device() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg);
        assert!(engine.is_oom(8));
        let factors = random_factors(&t.dims, 8, 5);
        let (m, path) = engine.mttkrp(2, &factors);
        match path {
            ExecPath::Streamed(rep) => {
                assert!(rep.batches.len() > 1);
                assert!(rep.transfer_s > 0.0);
            }
            _ => panic!("expected streamed path"),
        }
        let expect = mttkrp_oracle(&t, 2, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn clustered_path_on_multi_device_profile() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine = MttkrpEngine::from_coo_with(
            &t,
            Profile::tiny(32 * 1024).with_devices(2),
            cfg,
        );
        assert!(engine.is_oom(8));
        let factors = random_factors(&t.dims, 8, 5);
        let (m, path) = engine.mttkrp(2, &factors);
        match path {
            ExecPath::Clustered(rep) => {
                assert_eq!(rep.devices, 2);
                assert_eq!(rep.per_device.len(), 2);
                assert!(rep.merge_bytes > 0, "merge traffic must be charged");
            }
            other => panic!("expected clustered path, got {other:?}"),
        }
        let expect = mttkrp_oracle(&t, 2, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn cpals_runs_through_facade() {
        let t = synth::fiber_clustered(&[30, 25, 20], 3_000, 2, 0.8, 7);
        let engine = MttkrpEngine::from_coo(&t, Profile::v100()).with_threads(4);
        let opts = CpAlsOptions { rank: 4, max_iters: 5, tol: 0.0, threads: 4, seed: 1 };
        let rep = engine.cp_als(opts);
        assert_eq!(rep.fits.len(), 5);
        assert!(rep.fits.iter().all(|&f| f <= 1.0 + 1e-9));
        assert!(engine.counters.snapshot().volume_bytes() > 0);
        // every mode ran in-memory and no plan was needed
        assert_eq!(rep.schedule, ScheduleStats::default());
        assert_eq!(rep.mode_traces.len(), 3);
        for tr in &rep.mode_traces {
            assert_eq!(tr.in_memory, 5);
            assert_eq!(tr.streamed + tr.clustered, 0);
        }
    }

    #[test]
    fn working_set_accounting() {
        let t = synth::uniform(&[100, 100, 100], 1_000, 9);
        let engine = MttkrpEngine::from_coo(&t, Profile::a100());
        let ws8 = engine.working_set_bytes(8);
        let ws32 = engine.working_set_bytes(32);
        assert!(ws32 > ws8);
        assert!(ws8 >= engine.eng.footprint_bytes());
        // cube tensor: every per-target working set equals the max
        for m in 0..3 {
            assert_eq!(engine.working_set_bytes_for(m, 8), ws8);
        }
    }

    #[test]
    fn per_target_working_set_is_exact() {
        // one long mode, two short ones: the conservative max says OOM,
        // exact per-target accounting disagrees for the short modes
        let t = synth::uniform(&[4096, 8, 8], 2_000, 3);
        let cfg = BlcoConfig { max_block_nnz: 256, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(800 * 1024), cfg);
        let rank = 16;
        assert!(
            engine.working_set_bytes_for(0, rank) > engine.working_set_bytes_for(1, rank)
        );
        assert_eq!(
            engine.working_set_bytes(rank),
            engine.working_set_bytes_for(0, rank),
            "conservative accounting = largest mode"
        );
        assert!(engine.is_oom(rank), "max-mode classification says OOM");
        assert!(engine.is_oom_for(0, rank), "long mode streams");
        assert!(!engine.is_oom_for(1, rank), "short mode fits");
        assert!(!engine.is_oom_for(2, rank), "short mode fits");
    }

    #[test]
    fn mode_aware_routing_mixes_paths_in_one_sweep() {
        // regression for the old max-mode `is_oom` routing: short modes
        // of a long-mode-OOM tensor must run in-memory, and both paths
        // must stay correct
        let t = synth::uniform(&[4096, 8, 8], 2_000, 3);
        let cfg = BlcoConfig { max_block_nnz: 256, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(800 * 1024), cfg);
        let factors = random_factors(&t.dims, 16, 1);
        let (m0, p0) = engine.mttkrp(0, &factors);
        let (m1, p1) = engine.mttkrp(1, &factors);
        let (m2, p2) = engine.mttkrp(2, &factors);
        assert!(matches!(p0, ExecPath::Streamed(_)), "long mode streams");
        assert!(matches!(p1, ExecPath::InMemory(_)), "short mode in-memory");
        assert!(matches!(p2, ExecPath::InMemory(_)), "short mode in-memory");
        for (target, m) in [(0usize, &m0), (1, &m1), (2, &m2)] {
            let expect = mttkrp_oracle(&t, target, &factors);
            assert!(m.max_abs_diff(&expect) < 1e-9, "mode {target}");
        }
        // only the streamed mode needed a plan
        let stats = engine.schedule_stats();
        assert_eq!(stats.built, 1);
        assert_eq!(stats.hits, 0);
    }

    #[test]
    fn schedule_cache_counts_builds_and_hits() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg);
        let f8 = random_factors(&t.dims, 8, 5);
        let f16 = random_factors(&t.dims, 16, 5);
        let _ = engine.mttkrp(0, &f8);
        let _ = engine.mttkrp(0, &f8); // cache hit
        let _ = engine.mttkrp(1, &f8); // new target
        let _ = engine.mttkrp(0, &f16); // new rank
        let stats = engine.schedule_stats();
        assert_eq!(stats.built, 3, "distinct (target, rank) pairs");
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn caching_disabled_replans_every_call() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine = MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg)
            .with_schedule_caching(false);
        let f8 = random_factors(&t.dims, 8, 5);
        let (a, _) = engine.mttkrp(0, &f8);
        let (b, _) = engine.mttkrp(0, &f8);
        assert!(a.max_abs_diff(&b) < 1e-9);
        let stats = engine.schedule_stats();
        assert_eq!(stats.built, 2, "cold mode plans per call");
        assert_eq!(stats.hits, 0);
    }

    #[test]
    fn from_blco_shares_payload_and_recovers_metadata() {
        let t = synth::uniform(&[50, 40, 30], 4_000, 6);
        let shared = Arc::new(crate::format::blco::BlcoTensor::from_coo(&t));
        let a = MttkrpEngine::from_blco(Arc::clone(&shared), Profile::a100());
        let b = MttkrpEngine::from_blco(Arc::clone(&shared), Profile::v100());
        assert!(Arc::ptr_eq(&a.tensor(), &shared), "no payload copy");
        assert!(Arc::ptr_eq(&a.tensor(), &b.tensor()));
        assert_eq!(a.dims, t.dims);
        assert!((a.norm_x - t.norm()).abs() < 1e-9);
        // same answers as the from_coo construction
        let reference = MttkrpEngine::from_coo(&t, Profile::a100());
        let factors = random_factors(&t.dims, 8, 9);
        let (ma, _) = a.mttkrp(1, &factors);
        let (mr, _) = reference.mttkrp(1, &factors);
        assert!(ma.max_abs_diff(&mr) < 1e-12);
    }

    #[test]
    fn streaming_floor_sits_below_working_set_and_gates_serving() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(48 * 1024), cfg);
        let rank = 8;
        for m in 0..3 {
            assert!(
                engine.streaming_floor_bytes(m, rank)
                    < engine.working_set_bytes_for(m, rank),
                "the floor must not count the streamed tensor"
            );
        }
        // this tensor is OOM yet streamable on 48 KiB
        assert!(engine.is_oom_for(0, rank));
        assert!(engine.can_serve(0, rank));
        // on a device too small even for factors + output, serving fails
        let starved =
            MttkrpEngine::from_blco(engine.tensor(), Profile::tiny(4 * 1024));
        assert!(!starved.can_serve(0, rank));
    }

    #[test]
    fn fused_capacity_follows_the_memory_budget() {
        let t = synth::uniform(&[60, 50, 40], 8_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(48 * 1024), cfg);
        let rank = 8;
        let per_job = engine.resident_job_bytes(0, rank);
        let buffer = engine.streaming_floor_bytes(0, rank) - per_job;
        let cap = engine.fused_jobs_capacity(0, rank);
        assert!(cap >= 1, "admissible jobs always fit alone");
        // the cap saturates the budget without exceeding it
        assert!(cap * per_job + buffer <= 48 * 1024);
        assert!((cap + 1) * per_job + buffer > 48 * 1024);
        // doubling memory at least keeps (and here grows) the capacity
        let roomy = MttkrpEngine::from_blco(engine.tensor(), Profile::tiny(96 * 1024));
        assert!(roomy.fused_jobs_capacity(0, rank) > cap);
    }

    #[test]
    fn conflict_analysis_attaches_certificates_and_keeps_answers() {
        let t = synth::uniform(&[150, 130, 170], 8_000, 12);
        let plain = MttkrpEngine::from_coo(&t, Profile::a100());
        assert!(plain.certificates().is_none());
        let analyzed =
            MttkrpEngine::from_coo(&t, Profile::a100()).with_conflict_analysis();
        let certs = analyzed.certificates().expect("analysis attached");
        assert_eq!(certs.num_modes(), 3);
        // analysis is preprocessing: the engine's own counters stay clean
        assert_eq!(analyzed.counters.snapshot().volume_bytes(), 0);
        // the certificate only changes *which* strategy Auto picks, never
        // the kernel: output is bitwise the pre-analyzer path pinned to
        // that same strategy
        let factors = random_factors(&t.dims, 8, 13);
        // single-threaded: atomic-add order (and hence low-order bits) is
        // only deterministic when work-groups run in sequence
        let analyzed = analyzed.with_threads(1);
        for m in 0..3 {
            let res = analyzed.eng.effective_resolution(m);
            let pinned = MttkrpEngine::from_blco(plain.tensor(), Profile::a100())
                .with_resolution(res)
                .with_threads(1);
            let (a, _) = analyzed.mttkrp(m, &factors);
            let (b, _) = pinned.mttkrp(m, &factors);
            assert!(
                a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
                "mode {m}: certificate routing changed the answer"
            );
        }
    }

    #[test]
    fn engine_rejects_invalid_profile() {
        let t = synth::uniform(&[20, 20, 20], 500, 1);
        let mut p = Profile::a100();
        p.link_gbps = 0.0;
        let b = Arc::new(BlcoTensor::from_coo(&t));
        match MttkrpEngine::try_from_source(BatchSource::Resident(b), p) {
            Err(BlcoError::InvalidProfile { reason, .. }) => {
                assert!(reason.contains("link_gbps"), "{reason}");
            }
            Ok(_) => panic!("expected InvalidProfile"),
            Err(other) => panic!("expected InvalidProfile, got {other:?}"),
        }
    }

    #[test]
    fn append_reloads_and_invalidates_derived_state() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let delta = synth::uniform(&[50, 40, 30], 1_000, 8);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let p = {
            let mut p = std::env::temp_dir();
            p.push(format!("blco_eng_append_{}.blco", std::process::id()));
            p
        };
        crate::format::store::BlcoStore::write_with(
            &BlcoTensor::from_coo_with(&t, cfg),
            &p,
            Codec::DeltaVarint,
        )
        .unwrap();
        let mut engine = MttkrpEngine::from_store(&p, Profile::tiny(32 * 1024))
            .unwrap()
            .with_conflict_analysis();
        assert!(engine.certificates().is_some());
        let factors = random_factors(&t.dims, 8, 5);
        let (_before, _) = engine.mttkrp(0, &factors);
        assert_eq!(engine.schedule_stats().built, 1);
        let old_norm = engine.norm_x;

        let s = engine.append_from_coo(&delta, None).unwrap();
        assert_eq!(s.appended_nnz, delta.nnz());
        assert_eq!(s.segments, 1);
        // derived state is gone: certificates dropped, schedules cleared
        assert!(engine.certificates().is_none(), "stale certs must drop");
        assert_eq!(engine.source().nnz(), t.nnz() + delta.nnz());
        assert!(engine.norm_x > old_norm);
        // the same (target, rank) replans instead of hitting a stale plan
        let (after, _) = engine.mttkrp(0, &factors);
        let stats = engine.schedule_stats();
        assert_eq!(stats.built, 2, "append must invalidate the plan");
        assert_eq!(stats.hits, 0);

        // the streamed answer over base+delta equals the oracle over the
        // concatenated tensor (duplicates accumulate)
        let mut both = t.clone();
        for e in 0..delta.nnz() {
            both.push(&delta.coord(e), delta.vals[e]);
        }
        let expect = mttkrp_oracle(&both, 0, &factors);
        assert!(after.max_abs_diff(&expect) < 1e-9);

        // compaction folds the delta; the result is the from-scratch
        // container, so the streamed answer is bitwise what an engine over
        // a scratch rebuild computes (block boundaries moved, so only
        // 1e-9 closeness is guaranteed vs the pre-compaction answer)
        let summary = engine.compact().unwrap();
        assert_eq!(summary.nnz, both.nnz());
        assert_eq!(engine.source().reader().unwrap().segments(), 0);
        let (compacted, _) = engine.mttkrp(0, &factors);
        assert!(compacted.max_abs_diff(&expect) < 1e-9);
        let p2 = {
            let mut p2 = std::env::temp_dir();
            p2.push(format!("blco_eng_scratch_{}.blco", std::process::id()));
            p2
        };
        crate::format::store::BlcoStore::write_with(
            &BlcoTensor::from_coo_with(&both, cfg),
            &p2,
            Codec::DeltaVarint,
        )
        .unwrap();
        assert_eq!(
            std::fs::read(&p).unwrap(),
            std::fs::read(&p2).unwrap(),
            "compacted container must be bit-for-bit the scratch rebuild"
        );
        let scratch = MttkrpEngine::from_store(&p2, Profile::tiny(32 * 1024))
            .unwrap()
            .with_threads(engine.threads);
        let (reference, _) = scratch.mttkrp(0, &factors);
        assert!(
            compacted
                .data
                .iter()
                .zip(&reference.data)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "compacted streamed answer must match the scratch container's bits"
        );
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn append_rejects_resident_engines() {
        let t = synth::uniform(&[20, 20, 20], 500, 1);
        let mut engine = MttkrpEngine::from_coo(&t, Profile::a100());
        let delta = synth::uniform(&[20, 20, 20], 50, 2);
        assert!(matches!(
            engine.append_from_coo(&delta, None),
            Err(BlcoError::InvalidRequest { .. })
        ));
        assert!(matches!(engine.compact(), Err(BlcoError::InvalidRequest { .. })));
    }
}
