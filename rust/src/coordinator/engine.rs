//! The public facade: one object that owns a BLCO tensor + device profile
//! and routes every MTTKRP to the right path — in-memory unified kernel
//! when the working set fits the (simulated) device, out-of-memory
//! streaming otherwise — exactly the paper's "single tensor copy, unified
//! implementation" story. Also drives CP-ALS end to end.

use crate::coordinator::cluster::{cluster_mttkrp, ClusterReport};
use crate::coordinator::streamer::{stream_mttkrp, StreamReport};
use crate::cpals::als::{cp_als, CpAlsOptions, CpAlsReport};
use crate::device::counters::Counters;
use crate::device::profile::Profile;
use crate::format::blco::{BlcoConfig, BlcoTensor};
use crate::mttkrp::blco::{BlcoEngine, Resolution};
use crate::mttkrp::dense::Matrix;
use crate::mttkrp::Mttkrp;
use crate::tensor::coo::CooTensor;
use crate::util::pool::default_threads;

/// Which path a given MTTKRP took.
#[derive(Clone, Debug)]
pub enum ExecPath {
    InMemory(Resolution),
    Streamed(StreamReport),
    /// out-of-memory on a multi-device profile: sharded cluster streaming
    Clustered(ClusterReport),
}

/// High-level BLCO MTTKRP engine (the library's main entry point).
///
/// ```
/// use blco::{CooTensor, MttkrpEngine};
/// use blco::device::Profile;
/// use blco::tensor::synth;
///
/// let t = synth::uniform(&[100, 80, 60], 10_000, 42);
/// let engine = MttkrpEngine::from_coo(&t, Profile::a100());
/// let factors = blco::mttkrp::oracle::random_factors(&t.dims, 16, 1);
/// let (m, path) = engine.mttkrp(0, &factors);
/// assert_eq!(m.rows, 100);
/// # let _ = path;
/// ```
pub struct MttkrpEngine {
    pub eng: BlcoEngine,
    pub dims: Vec<u64>,
    pub norm_x: f64,
    pub threads: usize,
    pub counters: Counters,
}

impl MttkrpEngine {
    pub fn from_coo(t: &CooTensor, profile: Profile) -> Self {
        Self::from_coo_with(t, profile, BlcoConfig::default())
    }

    pub fn from_coo_with(t: &CooTensor, profile: Profile, cfg: BlcoConfig) -> Self {
        let blco = BlcoTensor::from_coo_with(t, cfg);
        MttkrpEngine {
            eng: BlcoEngine::new(blco, profile),
            dims: t.dims.clone(),
            norm_x: t.norm(),
            threads: default_threads(),
            counters: Counters::new(),
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_resolution(mut self, r: Resolution) -> Self {
        self.eng = BlcoEngine {
            t: self.eng.t.clone(),
            profile: self.eng.profile.clone(),
            resolution: r,
        };
        self
    }

    /// Working-set bytes for a rank-`rank` MTTKRP: tensor blocks + all
    /// factor matrices + the output.
    pub fn working_set_bytes(&self, rank: usize) -> usize {
        let factors: usize =
            self.dims.iter().map(|&d| d as usize * rank * 8).sum();
        let out = *self.dims.iter().max().unwrap_or(&0) as usize * rank * 8;
        self.eng.footprint_bytes() + factors + out
    }

    /// Does this tensor require the out-of-memory path at `rank`?
    pub fn is_oom(&self, rank: usize) -> bool {
        !self.eng.profile.fits(self.working_set_bytes(rank))
    }

    /// Mode-`target` MTTKRP. Chooses in-memory, streamed or (when the
    /// profile declares more than one device) cluster-sharded streaming
    /// automatically.
    pub fn mttkrp(&self, target: usize, factors: &[Matrix]) -> (Matrix, ExecPath) {
        let rank = factors[0].cols;
        let mut out = Matrix::zeros(self.dims[target] as usize, rank);
        if self.is_oom(rank) {
            if self.eng.profile.devices > 1 {
                let rep = cluster_mttkrp(
                    &self.eng,
                    target,
                    factors,
                    &mut out,
                    self.threads,
                    &self.counters,
                );
                return (out, ExecPath::Clustered(rep));
            }
            let rep = stream_mttkrp(
                &self.eng,
                target,
                factors,
                &mut out,
                self.threads,
                &self.counters,
            );
            (out, ExecPath::Streamed(rep))
        } else {
            self.eng
                .mttkrp(target, factors, &mut out, self.threads, &self.counters);
            (out, ExecPath::InMemory(self.eng.effective_resolution(target)))
        }
    }

    /// Full CP-ALS decomposition using this engine's routing.
    pub fn cp_als(&self, opts: CpAlsOptions) -> CpAlsReport {
        cp_als(self, &self.dims, self.norm_x, opts, &self.counters)
    }
}

impl Mttkrp for MttkrpEngine {
    fn name(&self) -> String {
        format!("engine({})", self.eng.profile.name)
    }

    fn mttkrp(
        &self,
        target: usize,
        factors: &[Matrix],
        out: &mut Matrix,
        threads: usize,
        counters: &Counters,
    ) {
        let rank = factors[0].cols;
        if self.is_oom(rank) {
            if self.eng.profile.devices > 1 {
                cluster_mttkrp(&self.eng, target, factors, out, threads, counters);
            } else {
                stream_mttkrp(&self.eng, target, factors, out, threads, counters);
            }
        } else {
            self.eng.mttkrp(target, factors, out, threads, counters);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle::{mttkrp_oracle, random_factors};
    use crate::tensor::synth;

    #[test]
    fn in_memory_path_on_big_device() {
        let t = synth::uniform(&[50, 40, 30], 4_000, 1);
        let engine = MttkrpEngine::from_coo(&t, Profile::a100());
        assert!(!engine.is_oom(8));
        let factors = random_factors(&t.dims, 8, 3);
        let (m, path) = engine.mttkrp(1, &factors);
        assert!(matches!(path, ExecPath::InMemory(_)));
        let expect = mttkrp_oracle(&t, 1, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn streamed_path_on_tiny_device() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine =
            MttkrpEngine::from_coo_with(&t, Profile::tiny(32 * 1024), cfg);
        assert!(engine.is_oom(8));
        let factors = random_factors(&t.dims, 8, 5);
        let (m, path) = engine.mttkrp(2, &factors);
        match path {
            ExecPath::Streamed(rep) => {
                assert!(rep.batches.len() > 1);
                assert!(rep.transfer_s > 0.0);
            }
            _ => panic!("expected streamed path"),
        }
        let expect = mttkrp_oracle(&t, 2, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn clustered_path_on_multi_device_profile() {
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        let engine = MttkrpEngine::from_coo_with(
            &t,
            Profile::tiny(32 * 1024).with_devices(2),
            cfg,
        );
        assert!(engine.is_oom(8));
        let factors = random_factors(&t.dims, 8, 5);
        let (m, path) = engine.mttkrp(2, &factors);
        match path {
            ExecPath::Clustered(rep) => {
                assert_eq!(rep.devices, 2);
                assert_eq!(rep.per_device.len(), 2);
                assert!(rep.merge_bytes > 0, "merge traffic must be charged");
            }
            other => panic!("expected clustered path, got {other:?}"),
        }
        let expect = mttkrp_oracle(&t, 2, &factors);
        assert!(m.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn cpals_runs_through_facade() {
        let t = synth::fiber_clustered(&[30, 25, 20], 3_000, 2, 0.8, 7);
        let engine = MttkrpEngine::from_coo(&t, Profile::v100()).with_threads(4);
        let opts = CpAlsOptions { rank: 4, max_iters: 5, tol: 0.0, threads: 4, seed: 1 };
        let rep = engine.cp_als(opts);
        assert_eq!(rep.fits.len(), 5);
        assert!(rep.fits.iter().all(|&f| f <= 1.0 + 1e-9));
        assert!(engine.counters.snapshot().volume_bytes() > 0);
    }

    #[test]
    fn working_set_accounting() {
        let t = synth::uniform(&[100, 100, 100], 1_000, 9);
        let engine = MttkrpEngine::from_coo(&t, Profile::a100());
        let ws8 = engine.working_set_bytes(8);
        let ws32 = engine.working_set_bytes(32);
        assert!(ws32 > ws8);
        assert!(ws8 >= engine.eng.footprint_bytes());
    }
}
