//! The streaming schedule subsystem: everything about an out-of-memory
//! MTTKRP that can be decided *before* any batch runs, reified as a
//! [`StreamSchedule`] value — modelled per-batch costs, the batch → device
//! assignment, and the pipeline clock skeleton (which host link and which
//! queue reservation every batch will occupy).
//!
//! A schedule depends only on `(target, rank, placement)` for a fixed
//! tensor × profile, so the CP-ALS driver reuses one plan across every
//! iteration instead of replanning `order × max_iters` times (cf. AMPED's
//! amortized multi-GPU partitioning and Nisa et al.'s precomputed
//! load-balanced placement, PAPERS.md). [`ScheduleCache`] does that
//! memoization behind interior mutability inside
//! [`MttkrpEngine`](super::engine::MttkrpEngine), and counts plans built
//! vs reused so schedule reuse is observable in reports and tests.
//!
//! Both executors consume prebuilt schedules:
//! [`stream_mttkrp_scheduled`](super::streamer::stream_mttkrp_scheduled)
//! for the single-device pipeline and
//! [`cluster_mttkrp_scheduled`](super::cluster::cluster_mttkrp_scheduled)
//! for the sharded one; the original call-and-plan entry points survive as
//! thin wrappers. Planning reads batch metadata through the engine's
//! [`BatchSource`](crate::format::store::BatchSource), so a plan built
//! over a disk-resident container is byte-identical to one built over the
//! resident tensor — schedules never require the payload in host RAM.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::analysis::conflict::SyncClass;
use crate::device::counters::Snapshot;
use crate::device::model::{device_time, transfer_time};
use crate::error::BlcoError;
use crate::mttkrp::blco::BlcoEngine;

/// Batch → device placement policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Placement {
    /// longest-processing-time greedy: heaviest remaining batch onto the
    /// least-loaded device (by modelled cost)
    #[default]
    Greedy,
    /// `batch % devices` — the naive baseline greedy must beat on skew
    RoundRobin,
}

/// Modelled cost of streaming + computing one batch, available *before*
/// execution (exact counters exist only after a batch runs): host-link
/// transfer of its bytes plus the device-model time of an estimated
/// traffic snapshot — streamed payload, factor-row gathers for every
/// non-target mode, and roughly one register flush per four non-zeros
/// (the reorder's typical segment density on the evaluation suite).
///
/// Total and finite by contract: [`crate::device::Profile::validate`]
/// rejects zero/NaN rates before an engine (and hence a schedule) can be
/// built over them, and the debug assertion below catches any profile
/// mutated into an invalid state after construction.
pub fn estimate_batch_cost(
    eng: &BlcoEngine,
    batch: usize,
    target: usize,
    rank: usize,
) -> f64 {
    let cost = transfer_time(eng.src.batch_bytes(batch), &eng.profile)
        + estimate_kernel_cost(eng, batch, target, rank);
    debug_assert!(
        cost.is_finite(),
        "modelled batch cost must be finite (batch {batch}, target {target}, \
         rank {rank}, profile {:?}): got {cost}",
        eng.profile.name
    );
    cost
}

/// The device-model (compute) half of [`estimate_batch_cost`] — split out
/// so schedule construction can combine it with the transfer times it has
/// already computed instead of re-deriving them per batch.
fn estimate_kernel_cost(eng: &BlcoEngine, batch: usize, target: usize, rank: usize) -> f64 {
    let p = &eng.profile;
    let nnz = eng.src.batches()[batch].nnz as u64;
    let order = eng.src.order() as u64;
    let rank64 = rank as u64;
    let flushes = (nnz / 4).max(1) * rank64;
    // a batch certified NoSync ([`crate::analysis::conflict`]) issues its
    // flushes as plain stores — the model drops its atomic serialization
    // term entirely. Without an attached certificate the estimate is
    // unchanged.
    let no_sync = eng
        .certificate_for(target)
        .is_some_and(|c| c.batches[batch].recommendation == SyncClass::NoSync);
    let est = Snapshot {
        bytes_streamed: nnz * 16,
        bytes_gathered: nnz * (order - 1) * rank64 * 8,
        bytes_written: flushes * 8,
        atomics: if no_sync { 0 } else { flushes },
        nosync_flushes: if no_sync { flushes } else { 0 },
        atomic_fanout: eng.src.dims()[target] * rank64,
        launches: 1,
        ..Default::default()
    };
    device_time(&est, p).total()
}

/// Assign each batch (by its modelled cost) to a device. Returns
/// `assign[batch] = device`.
pub fn plan_placement(costs: &[f64], devices: usize, placement: Placement) -> Vec<usize> {
    let devices = devices.max(1);
    match placement {
        Placement::RoundRobin => (0..costs.len()).map(|b| b % devices).collect(),
        Placement::Greedy => {
            // longest-processing-time: heaviest first, ties by index so the
            // schedule is deterministic
            let mut order: Vec<usize> = (0..costs.len()).collect();
            order.sort_by(|&a, &b| {
                costs[b]
                    .partial_cmp(&costs[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut load = vec![0.0f64; devices];
            let mut assign = vec![0usize; costs.len()];
            for &b in &order {
                let mut best = 0usize;
                for d in 1..devices {
                    if load[d] < load[best] {
                        best = d;
                    }
                }
                assign[b] = best;
                load[best] += costs[b];
            }
            assign
        }
    }
}

/// Makespan of an assignment under the modelled per-batch costs: the
/// heaviest device's total. (The quantity greedy placement minimizes and
/// the tests compare policies by.)
pub fn modelled_makespan(costs: &[f64], assign: &[usize], devices: usize) -> f64 {
    let mut load = vec![0.0f64; devices.max(1)];
    for (b, &d) in assign.iter().enumerate() {
        load[d] += costs[b];
    }
    load.into_iter().fold(0.0, f64::max)
}

/// The reified plan for one `(target, rank, placement)` streamed MTTKRP:
/// per-batch modelled costs and transfer times, the device assignment, and
/// the pipeline clock skeleton (host-link and queue-reservation indices in
/// submission order). Everything here is a pure function of the tensor and
/// the profile, so one schedule serves every ALS iteration.
#[derive(Clone, Debug)]
pub struct StreamSchedule {
    pub target: usize,
    pub rank: usize,
    pub placement: Placement,
    /// devices this plan shards across (1 = the single-device pipeline)
    pub devices: usize,
    /// queue reservations per device
    pub queues: usize,
    /// independent host links the transfers interleave over
    pub links: usize,
    /// host→device wire bytes per batch
    pub bytes: Vec<usize>,
    /// modelled host→device transfer seconds per batch
    pub transfer_s: Vec<f64>,
    /// modelled total (transfer + compute) cost per batch
    pub costs: Vec<f64>,
    /// batch → device
    pub assign: Vec<usize>,
    /// batch → queue reservation on its device (submission order % queues)
    pub queue_of: Vec<usize>,
    /// batch → host link its transfer serializes on (`device % links`)
    pub link_of: Vec<usize>,
    /// batch → certified synchronization requirement for this target
    /// ([`crate::analysis::conflict`]); conservatively all
    /// [`SyncClass::Atomic`] when the engine carries no certificates
    pub sync: Vec<SyncClass>,
}

impl StreamSchedule {
    /// Plan a sharded streamed MTTKRP across the profile's declared
    /// device count. Panics on an invalid profile; see
    /// [`try_build`](Self::try_build) for the `Result` form.
    pub fn build(
        eng: &BlcoEngine,
        target: usize,
        rank: usize,
        placement: Placement,
    ) -> Self {
        Self::try_build(eng, target, rank, placement).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`build`](Self::build), reporting an invalid profile as
    /// [`BlcoError::InvalidProfile`] instead of panicking.
    pub fn try_build(
        eng: &BlcoEngine,
        target: usize,
        rank: usize,
        placement: Placement,
    ) -> Result<Self, BlcoError> {
        Self::try_build_for_devices(eng, target, rank, placement, eng.profile.devices.max(1))
    }

    /// Plan for the single-device pipeline regardless of what the profile
    /// declares — what the plain
    /// [`stream_mttkrp`](super::streamer::stream_mttkrp) wrapper uses.
    /// Panics on an invalid profile.
    pub fn single_device(eng: &BlcoEngine, target: usize, rank: usize) -> Self {
        Self::try_single_device(eng, target, rank).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`single_device`](Self::single_device) as a `Result`.
    pub fn try_single_device(
        eng: &BlcoEngine,
        target: usize,
        rank: usize,
    ) -> Result<Self, BlcoError> {
        Self::try_build_for_devices(eng, target, rank, Placement::Greedy, 1)
    }

    fn try_build_for_devices(
        eng: &BlcoEngine,
        target: usize,
        rank: usize,
        placement: Placement,
        devices: usize,
    ) -> Result<Self, BlcoError> {
        if let Err(reason) = eng.profile.validate() {
            return Err(BlcoError::InvalidProfile {
                profile: eng.profile.name.to_string(),
                reason,
            });
        }
        let devices = devices.max(1);
        let queues = eng.profile.queues.max(1);
        // one device streams over one link; a cluster interleaves its
        // transfers across the profile's independent host links
        let links = if devices == 1 { 1 } else { eng.profile.host_links().max(1) };

        let nbatches = eng.num_batches();
        let bytes: Vec<usize> =
            (0..nbatches).map(|b| eng.src.batch_bytes(b)).collect();
        let transfer_s: Vec<f64> =
            bytes.iter().map(|&b| transfer_time(b, &eng.profile)).collect();
        // same definition as `estimate_batch_cost`, reusing the transfer
        // times computed above
        let costs: Vec<f64> = (0..nbatches)
            .map(|b| transfer_s[b] + estimate_kernel_cost(eng, b, target, rank))
            .collect();
        let assign = plan_placement(&costs, devices, placement);

        // clock skeleton: queue reservations rotate per device in global
        // submission order; each device's transfers serialize on link
        // `device % links` (Shared → everyone on link 0, Dedicated → one
        // per device, Ports(n) → round-robin over n links)
        let mut next_queue = vec![0usize; devices];
        let mut queue_of = vec![0usize; nbatches];
        let mut link_of = vec![0usize; nbatches];
        for b in 0..nbatches {
            let d = assign[b];
            queue_of[b] = next_queue[d] % queues;
            next_queue[d] += 1;
            link_of[b] = d % links;
        }

        let sync = match eng.certificate_for(target) {
            Some(cert) => cert.batches.iter().map(|b| b.recommendation).collect(),
            None => vec![SyncClass::Atomic; nbatches],
        };

        Ok(StreamSchedule {
            target,
            rank,
            placement,
            devices,
            queues,
            links,
            bytes,
            transfer_s,
            costs,
            assign,
            queue_of,
            link_of,
            sync,
        })
    }

    /// Modelled makespan of this plan (heaviest device's total cost).
    pub fn makespan(&self) -> f64 {
        modelled_makespan(&self.costs, &self.assign, self.devices)
    }
}

/// Plans-built / plans-reused counters of a [`ScheduleCache`] (or the
/// zero value for engines without one). `built` is the acceptance-criteria
/// observable: across a full CP-ALS it must equal the number of distinct
/// `(mode, rank)` pairs, not `modes × iterations`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScheduleStats {
    /// schedules computed from scratch
    pub built: usize,
    /// requests served from the cache
    pub hits: usize,
}

impl ScheduleStats {
    /// Stats accumulated since an `earlier` snapshot (what
    /// [`CpAlsReport`](crate::cpals::als::CpAlsReport) records per run).
    pub fn delta_since(self, earlier: ScheduleStats) -> ScheduleStats {
        ScheduleStats {
            built: self.built.saturating_sub(earlier.built),
            hits: self.hits.saturating_sub(earlier.hits),
        }
    }
}

/// What one memoized plan is keyed by: `(target, rank, placement)`.
type PlanKey = (usize, usize, Placement);

/// Memoized `(target, rank, placement) → Arc<StreamSchedule>` map with
/// build/hit counters. Interior-mutable so the read-only
/// [`MttkrpEngine`](super::engine::MttkrpEngine) facade can populate it
/// lazily from `&self`.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    map: Mutex<HashMap<PlanKey, Arc<StreamSchedule>>>,
    built: AtomicUsize,
    hits: AtomicUsize,
}

impl ScheduleCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The memoized schedule for `(target, rank, placement)`, building it
    /// on first request.
    pub fn get_or_build(
        &self,
        eng: &BlcoEngine,
        target: usize,
        rank: usize,
        placement: Placement,
    ) -> Arc<StreamSchedule> {
        let mut map = self.map.lock().expect("schedule cache poisoned");
        match map.entry((target, rank, placement)) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Arc::clone(e.get())
            }
            Entry::Vacant(v) => {
                let sched = Arc::new(StreamSchedule::build(eng, target, rank, placement));
                self.built.fetch_add(1, Ordering::Relaxed);
                Arc::clone(v.insert(sched))
            }
        }
    }

    /// Record a plan built outside the cache (the facade's
    /// caching-disabled mode still counts planning work, which is how the
    /// cold-vs-cached bench sweep observes the difference).
    pub fn note_uncached_build(&self) {
        self.built.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> ScheduleStats {
        ScheduleStats {
            built: self.built.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
        }
    }

    /// Drop every memoized plan. Called when the underlying container
    /// changes shape (an appended delta segment re-batches the tensor, so
    /// every cached cost/assignment is stale); the build/hit counters keep
    /// counting across the clear — they track planning work done, not
    /// current contents.
    pub fn clear(&self) {
        self.map.lock().expect("schedule cache poisoned").clear();
    }

    /// Number of distinct plans currently memoized.
    pub fn len(&self) -> usize {
        self.map.lock().expect("schedule cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Profile;
    use crate::format::blco::{BlcoConfig, BlcoTensor};
    use crate::tensor::synth;

    fn engine(devices: usize) -> BlcoEngine {
        let t = synth::uniform(&[60, 50, 40], 6_000, 3);
        let cfg = BlcoConfig {
            max_block_nnz: 512,
            workgroup: 64,
            threads: 2,
            ..Default::default()
        };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        assert!(b.batches.len() > 4);
        BlcoEngine::new(b, Profile::tiny(1 << 16).with_devices(devices))
    }

    #[test]
    fn single_device_skeleton_matches_legacy_clock() {
        // the D = 1 plan must reproduce the original streamer's
        // queue rotation (q = batch % queues) and single link
        let eng = engine(1);
        let s = StreamSchedule::single_device(&eng, 0, 8);
        assert_eq!(s.devices, 1);
        assert_eq!(s.links, 1);
        let queues = eng.profile.queues.max(1);
        for b in 0..s.queue_of.len() {
            assert_eq!(s.queue_of[b], b % queues);
            assert_eq!(s.link_of[b], 0);
            assert_eq!(s.assign[b], 0);
        }
    }

    #[test]
    fn build_is_deterministic_and_complete() {
        let eng = engine(4);
        let a = StreamSchedule::build(&eng, 1, 16, Placement::Greedy);
        let b = StreamSchedule::build(&eng, 1, 16, Placement::Greedy);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.queue_of, b.queue_of);
        assert_eq!(a.link_of, b.link_of);
        assert_eq!(a.bytes, b.bytes);
        let n = eng.num_batches();
        assert_eq!(a.bytes.len(), n);
        assert_eq!(a.transfer_s.len(), n);
        assert_eq!(a.costs.len(), n);
        assert!(a.costs.iter().all(|c| c.is_finite() && *c > 0.0));
        assert!(a.assign.iter().all(|&d| d < 4));
        assert!(a.makespan() > 0.0);
    }

    #[test]
    fn queue_rotation_is_per_device() {
        let eng = engine(2);
        let s = StreamSchedule::build(&eng, 0, 8, Placement::Greedy);
        let queues = s.queues;
        let mut next = vec![0usize; s.devices];
        for b in 0..s.assign.len() {
            let d = s.assign[b];
            assert_eq!(s.queue_of[b], next[d] % queues, "batch {b}");
            next[d] += 1;
            assert_eq!(s.link_of[b], d % s.links);
        }
    }

    #[test]
    fn cache_memoizes_per_target_rank() {
        let eng = engine(1);
        let cache = ScheduleCache::new();
        assert!(cache.is_empty());
        let a = cache.get_or_build(&eng, 0, 8, Placement::Greedy);
        let b = cache.get_or_build(&eng, 0, 8, Placement::Greedy);
        assert!(Arc::ptr_eq(&a, &b), "same plan object on a hit");
        let _c = cache.get_or_build(&eng, 1, 8, Placement::Greedy);
        let _d = cache.get_or_build(&eng, 0, 16, Placement::Greedy);
        let stats = cache.stats();
        assert_eq!(stats.built, 3, "distinct (target, rank) pairs");
        assert_eq!(stats.hits, 1);
        assert_eq!(cache.len(), 3);
        cache.note_uncached_build();
        assert_eq!(cache.stats().built, 4);
        assert_eq!(
            cache.stats().delta_since(stats),
            ScheduleStats { built: 1, hits: 0 }
        );
    }

    #[test]
    fn certificates_mark_sync_classes_and_cheapen_nosync_batches() {
        let eng = engine(1);
        // uncertified plan: conservative Atomic everywhere
        let plain = StreamSchedule::single_device(&eng, 0, 8);
        assert!(plain.sync.iter().all(|&s| s == SyncClass::Atomic));

        let set = std::sync::Arc::new(
            crate::analysis::conflict::CertificateSet::analyze(&eng.src),
        );
        let cert_eng = eng.share_with_profile(eng.profile.clone()).with_certificates(set);
        let certified = StreamSchedule::single_device(&cert_eng, 0, 8);
        assert_eq!(certified.sync.len(), cert_eng.num_batches());
        for (b, &s) in certified.sync.iter().enumerate() {
            assert_eq!(
                s,
                cert_eng.certificate_for(0).unwrap().batches[b].recommendation
            );
            // NoSync batches drop the atomic-serialization cost term;
            // everything else is modelled identically
            if s == SyncClass::NoSync {
                assert!(certified.costs[b] <= plain.costs[b]);
            } else {
                assert_eq!(certified.costs[b], plain.costs[b]);
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_cost_trips_the_debug_contract() {
        // a profile mutated into an invalid state *after* construction
        // bypasses validation; the cost contract still catches it
        let mut eng = engine(1);
        eng.profile.link_gbps = 0.0;
        let _ = estimate_batch_cost(&eng, 0, 0, 8);
    }

    #[test]
    fn schedule_build_revalidates_the_profile() {
        let mut eng = engine(1);
        eng.profile.hbm_gbps = f64::NAN;
        match StreamSchedule::try_single_device(&eng, 0, 8) {
            Err(BlcoError::InvalidProfile { reason, .. }) => {
                assert!(reason.contains("hbm_gbps"), "{reason}");
            }
            other => panic!("expected InvalidProfile, got {other:?}"),
        }
    }

    #[test]
    fn cache_clear_drops_plans_but_keeps_counters() {
        let eng = engine(1);
        let cache = ScheduleCache::new();
        let _ = cache.get_or_build(&eng, 0, 8, Placement::Greedy);
        let _ = cache.get_or_build(&eng, 1, 8, Placement::Greedy);
        assert_eq!(cache.len(), 2);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats().built, 2, "counters survive the clear");
        // the next request rebuilds
        let _ = cache.get_or_build(&eng, 0, 8, Placement::Greedy);
        assert_eq!(cache.stats(), ScheduleStats { built: 3, hits: 0 });
    }
}
