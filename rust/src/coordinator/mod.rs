//! The L3 coordination layer: out-of-memory streaming of BLCO batches
//! through simulated device queues ([`streamer`]) and the high-level
//! [`engine::MttkrpEngine`] facade that picks the in-memory or streaming
//! path per tensor × device, exposes CP-ALS, and (optionally) routes
//! per-block compute through the AOT-compiled PJRT executable.

pub mod engine;
pub mod streamer;
