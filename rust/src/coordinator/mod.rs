//! The L3 coordination layer: out-of-memory streaming of BLCO batches
//! through simulated device queues ([`streamer`]), the multi-device
//! sharded generalization with load-balanced batch placement and a
//! tree-merged output ([`cluster`]), the streaming schedule subsystem
//! that reifies and memoizes the per-`(target, rank)` plan both executors
//! consume ([`schedule`]), the [`request::StreamRequest`] builder — the
//! one public entry point both executors now sit behind — and the
//! high-level [`engine::MttkrpEngine`] facade that picks the in-memory,
//! streamed or clustered path per *target mode* × device, exposes CP-ALS,
//! and (optionally) routes per-block compute through the AOT-compiled
//! PJRT executable.
//!
//! # Pipeline model
//!
//! Both streamers share one first-order model. Every batch is charged
//! `bytes / link_gbps` on a host interconnect and its exact-counter
//! device time on a serialized compute engine; queue reservations let a
//! pending batch's transfer overlap the active batch's kernel, which is
//! how the paper reaches perfect overlap in Figure 10. The cluster
//! streamer extends this along three axes:
//!
//! * **sharding** — batches are placed onto `D` devices by modelled cost
//!   (greedy longest-processing-time), so skewed batch sizes do not
//!   serialize behind one hot device;
//! * **link topology** — [`device::LinkTopology::Shared`] serializes all
//!   `D` transfer streams through one host link (a single PCIe root
//!   complex, the pessimistic Figure-10 regime), while `Dedicated` gives
//!   each device a full-rate link and the streaming phase scales until
//!   compute binds;
//! * **merge traffic** — per-device partial outputs are combined by a
//!   binary tree reduction whose device↔device traffic is charged at
//!   `peer_gbps` and added to the counters, so the overall throughput
//!   honestly includes the cost of sharding the output.
//!
//! [`device::LinkTopology::Shared`]: crate::device::LinkTopology::Shared

pub mod cluster;
pub mod engine;
pub mod request;
pub mod schedule;
pub mod streamer;
