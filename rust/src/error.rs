//! Crate-level error taxonomy.
//!
//! Library consumers get `Result` everywhere the CLI used to catch
//! panics: container faults ([`StoreError`]) and admission rejections
//! ([`AdmissionError`]) convert into [`BlcoError`] with `?`, and the
//! construction/validation paths that historically `assert!`ed
//! (`BlcoConfig` shape checks, [`Profile::validate`] at engine and
//! schedule construction, malformed [`StreamRequest`]s) surface as the
//! structured variants below. The panicking entry points survive as thin
//! wrappers over the `try_` forms for callers that prefer to crash.
//!
//! [`Profile::validate`]: crate::device::profile::Profile::validate
//! [`StreamRequest`]: crate::coordinator::request::StreamRequest

use std::fmt;

use crate::format::store::StoreError;
use crate::service::admission::AdmissionError;

/// Any failure the blco library reports through `Result`.
///
/// Not `Clone`/`PartialEq`: [`StoreError`] wraps `std::io::Error`.
/// Match on variants (`matches!`) in tests instead.
#[derive(Debug)]
pub enum BlcoError {
    /// the `.blco` container is unreadable, unwritable, or corrupt
    Store(StoreError),
    /// the serving layer declined the job (working set, quota, …)
    Admission(AdmissionError),
    /// a construction knob is out of range (`BlcoConfig`, build budgets)
    InvalidConfig {
        /// which knob, and what shape it must have
        what: String,
    },
    /// a device [`Profile`](crate::device::profile::Profile) failed
    /// validation — its cost model would divide by zero/NaN
    InvalidProfile {
        /// profile name as reported by the device table
        profile: String,
        /// the failing field, verbatim from `Profile::validate`
        reason: String,
    },
    /// a [`StreamRequest`](crate::coordinator::request::StreamRequest) or
    /// [`ServeRequest`](crate::service::request::ServeRequest) combination
    /// that has no defined execution path
    InvalidRequest {
        /// what was asked for and why it cannot run
        what: String,
    },
    /// an external-memory build or compaction failed partway (spill I/O,
    /// budget too small, replay mismatch) — see [`crate::tensor::ooc`]
    Build {
        /// the failing stage's rendered error chain
        what: String,
    },
}

impl fmt::Display for BlcoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BlcoError::Store(e) => write!(f, "container error: {e}"),
            BlcoError::Admission(e) => write!(f, "admission rejected: {e}"),
            BlcoError::InvalidConfig { what } => {
                write!(f, "invalid configuration: {what}")
            }
            BlcoError::InvalidProfile { profile, reason } => {
                write!(f, "invalid device profile {profile:?}: {reason}")
            }
            BlcoError::InvalidRequest { what } => {
                write!(f, "invalid request: {what}")
            }
            BlcoError::Build { what } => {
                write!(f, "external-memory build failed: {what}")
            }
        }
    }
}

impl std::error::Error for BlcoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BlcoError::Store(e) => Some(e),
            BlcoError::Admission(e) => Some(e),
            _ => None,
        }
    }
}

impl From<StoreError> for BlcoError {
    fn from(e: StoreError) -> Self {
        BlcoError::Store(e)
    }
}

impl From<AdmissionError> for BlcoError {
    fn from(e: AdmissionError) -> Self {
        BlcoError::Admission(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_and_display() {
        let e: BlcoError = StoreError::Truncated {
            what: "header".into(),
            needed: 64,
            available: 8,
        }
        .into();
        assert!(matches!(e, BlcoError::Store(_)));
        assert!(e.to_string().contains("container error"));
        assert!(std::error::Error::source(&e).is_some());

        let e = BlcoError::InvalidProfile {
            profile: "a100".into(),
            reason: "hbm_gbps must be finite and > 0, got 0".into(),
        };
        assert!(e.to_string().contains("a100"));
        assert!(e.to_string().contains("hbm_gbps"));

        let e = BlcoError::InvalidRequest {
            what: "fused jobs across devices".into(),
        };
        assert!(e.to_string().contains("invalid request"));
        assert!(std::error::Error::source(&e).is_none());
    }
}
