//! Percentile math for the serving layer's tail-latency and queue-depth
//! reporting. One semantics, used everywhere a report quotes a tail:
//! **interpolated rank** (the numpy-default "linear" quantile): on `n`
//! sorted samples the p-th percentile sits at fractional index
//! `p/100 * (n-1)` and interpolates linearly between its neighbours.
//!
//! The interpolated rank is deliberate where tails meet small samples: a
//! naive nearest-rank `ceil(p/100 * n)` makes p99 (and even p95) of 10
//! samples silently *the max* — one outlier then owns the whole tail and
//! the sweep in `fig_serve_throughput` cannot tell an exploding queue
//! from a single slow job. Under interpolated rank, p99 of 10 distinct
//! samples lands strictly between the two largest. The unit tests pin
//! these semantics on known small samples so they cannot drift.

/// The percentile of `samples` (need not be sorted), `p` in `[0, 100]`.
/// Interpolated rank; 0.0 on an empty slice.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    percentile_sorted(&sorted, p)
}

/// [`percentile`] over already-sorted samples (no copy, no re-sort).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let idx = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = idx - lo as f64;
        sorted[lo] + frac * (sorted[hi] - sorted[lo])
    }
}

/// The tail summary every latency / queue-depth report carries:
/// p50/p95/p99/p999 at interpolated rank, plus mean and max.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub mean: f64,
    pub max: f64,
}

impl Percentiles {
    /// Summarize `samples` (unsorted is fine). All-zero on an empty slice.
    pub fn from_samples(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Percentiles::default();
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let sum: f64 = sorted.iter().sum();
        Percentiles {
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            p999: percentile_sorted(&sorted, 99.9),
            mean: sum / sorted.len() as f64,
            max: *sorted.last().expect("non-empty"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_pin_interpolated_rank_on_small_samples() {
        // 10 known samples: the tail must interpolate, not jump to max
        let xs: Vec<f64> = (1..=10).map(|v| v as f64).collect();
        let s = Percentiles::from_samples(&xs);
        assert_eq!(s.p50, 5.5, "median of 1..=10 interpolates");
        // p95 index = 0.95 * 9 = 8.55 -> between 9 and 10
        assert!((s.p95 - 9.55).abs() < 1e-12, "p95 = {}", s.p95);
        // p99 of 10 samples must NOT silently become the max: a naive
        // nearest-rank ceil(0.99 * 10) = 10 would return 10.0 here
        assert!((s.p99 - 9.91).abs() < 1e-12, "p99 = {}", s.p99);
        assert!(s.p99 < s.max, "p99 of 10 samples is not the max");
        assert!(s.p999 < s.max, "p999 of 10 samples is not the max");
        assert_eq!(s.max, 10.0);
        assert_eq!(s.mean, 5.5);
    }

    #[test]
    fn percentiles_on_larger_samples_and_edges() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        // index = 0.99 * 99 = 98.01 -> between 99 and 100
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        // degenerate inputs
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
        assert_eq!(percentile(&[3.0, 1.0], 50.0), 2.0, "unsorted input is sorted");
        // out-of-range p clamps instead of indexing out of bounds
        assert_eq!(percentile(&[1.0, 2.0], 150.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0], -3.0), 1.0);
    }

    #[test]
    fn order_independent_and_duplicate_safe() {
        let a = Percentiles::from_samples(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        let b = Percentiles::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a, b);
        let c = Percentiles::from_samples(&[2.0; 9]);
        assert_eq!((c.p50, c.p99, c.max), (2.0, 2.0, 2.0));
    }
}
