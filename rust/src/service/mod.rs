//! The multi-tenant decomposition service — the serving front end the
//! ROADMAP's production north star asks for, built directly on the paper's
//! central property: BLCO's unified, mode-agnostic implementation works on
//! a **single tensor copy** (no per-mode replicas like MM-CSF), so many
//! concurrent jobs can share one resident `Arc<BlcoTensor>` while the
//! engine routes each of them in-memory or streamed.
//!
//! The subsystem has four pieces:
//!
//! * [`registry`] — the shared **tensor registry**: one
//!   [`MttkrpEngine`](crate::coordinator::engine::MttkrpEngine) per
//!   registered tensor, holding the payload `Arc` and the per-tensor
//!   [`ScheduleCache`](crate::coordinator::schedule::ScheduleCache), so
//!   every job against the same tensor shares both the bytes and the
//!   out-of-memory plans;
//! * [`admission`] — the **admission controller**: per-job
//!   in-memory / streamed routing from the engine's exact
//!   `working_set_bytes_for` accounting, and a *structured*
//!   [`AdmissionError`](admission::AdmissionError) (never a panic) when
//!   even the streaming floor (factors + output + a double-buffered batch)
//!   cannot fit;
//! * [`trace`] — tenants, [`JobRequest`](trace::JobRequest)s and a seeded
//!   synthetic mixed-tenant trace generator for the `serve` CLI and the
//!   throughput bench;
//! * [`scheduler`] — the **fair scheduler**: weighted round-robin across
//!   tenants (FIFO within a tenant), least-loaded dispatch over the
//!   modelled device fleet, and *fusion* of compatible streamed jobs —
//!   same `(tensor, mode, rank)` requests ride one fused
//!   [`StreamRequest`](crate::coordinator::request::StreamRequest)
//!   pass so the tensor crosses the host link once per group. Results and
//!   per-tenant latency/throughput/queue-depth stats come back in a
//!   [`ServiceReport`](scheduler::ServiceReport), with every duration
//!   charged through the existing `Counters`/`Profile` cost model.

pub mod admission;
pub mod registry;
pub mod scheduler;
pub mod trace;

pub use admission::{admit_job, admit_mttkrp, Admission, AdmissionError, Route};
pub use registry::{TensorEntry, TensorRegistry};
pub use scheduler::{
    serve, JobOutcome, JobResult, JobStatus, ServeOptions, ServiceReport,
    TenantStats,
};
pub use trace::{synthetic_trace, JobKind, JobRequest, Tenant, TraceConfig};
