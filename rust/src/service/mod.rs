//! The multi-tenant decomposition service — the serving front end the
//! ROADMAP's production north star asks for, built directly on the paper's
//! central property: BLCO's unified, mode-agnostic implementation works on
//! a **single tensor copy** (no per-mode replicas like MM-CSF), so many
//! concurrent jobs can share one resident `Arc<BlcoTensor>` while the
//! engine routes each of them in-memory or streamed.
//!
//! The subsystem's pieces:
//!
//! * [`registry`] — the shared **tensor registry**: one
//!   [`MttkrpEngine`](crate::coordinator::engine::MttkrpEngine) per
//!   registered tensor, holding the payload `Arc` and the per-tensor
//!   [`ScheduleCache`](crate::coordinator::schedule::ScheduleCache), so
//!   every job against the same tensor shares both the bytes and the
//!   out-of-memory plans;
//! * [`admission`] — the **admission controller**: per-job
//!   in-memory / streamed routing from the engine's exact
//!   `working_set_bytes_for` accounting, and a *structured*
//!   [`AdmissionError`](admission::AdmissionError) (never a panic) when
//!   even the streaming floor (factors + output + a double-buffered batch)
//!   cannot fit;
//! * [`trace`] — tenants, [`JobRequest`](trace::JobRequest)s and the
//!   seeded trace generators: the legacy bursty replay plus **open-loop**
//!   Poisson and Markov-modulated arrival processes whose offered rate
//!   does not care how fast the fleet drains the queue — what production
//!   traffic does, and what the `fig_serve_throughput` knee sweep drives;
//! * [`stats`] — one percentile semantics (interpolated rank) for every
//!   latency and queue-depth tail the reports quote;
//! * [`scheduler`] — the serving loop: WRR / **EDF-over-priority-tiers**
//!   / global-FIFO policies, least-loaded dispatch over the modelled
//!   fleet, fusion of compatible streamed jobs, deadline accounting, and
//!   graceful **load shedding** that degrades streamed jobs to coarser
//!   ranks under pressure. Results and per-tenant tail-latency /
//!   throughput / queue-depth stats come back in a
//!   [`ServiceReport`](scheduler::ServiceReport), with every duration
//!   charged through the existing `Counters`/`Profile` cost model;
//! * [`request`] — [`ServeRequest`](request::ServeRequest), the one
//!   validated front door (mirroring the coordinator's `StreamRequest`
//!   builder), including snapshot-consistent serving across mid-trace
//!   container appends via
//!   [`append_at`](request::ServeRequest::append_at). The legacy
//!   `serve`/`ServeOptions` pair survives as `#[deprecated]` wrappers
//!   pinned bit-for-bit by the builder's parity test.

pub mod admission;
pub mod registry;
pub mod request;
pub mod scheduler;
pub mod stats;
pub mod trace;

pub use admission::{
    admit_job, admit_job_on, admit_mttkrp, Admission, AdmissionError, Route,
};
pub use registry::{TensorEntry, TensorRegistry};
pub use request::{ServeOutcome, ServeRequest};
pub use scheduler::{
    JobOutcome, JobResult, JobStatus, SchedPolicy, ServiceReport, ShedPolicy,
    SloPolicy, TenantStats,
};
#[allow(deprecated)]
pub use scheduler::{serve, ServeOptions};
pub use stats::{percentile, Percentiles};
pub use trace::{
    synthetic_trace, ArrivalProcess, JobKind, JobRequest, Tenant, TraceConfig,
};
