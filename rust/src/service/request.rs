//! The one front door for serving runs.
//!
//! Historically the service layer exposed a free function `serve(reg,
//! tenants, jobs, &ServeOptions)` whose options struct grew a knob per
//! feature. [`ServeRequest`] collapses that into a builder mirroring
//! [`StreamRequest`](crate::coordinator::request::StreamRequest) — one
//! validated [`run`](ServeRequest::run) entry point:
//!
//! ```no_run
//! # use blco::service::{ServeRequest, SchedPolicy, SloPolicy, ShedPolicy};
//! # use blco::service::{TensorRegistry, Tenant, JobRequest};
//! # fn demo(reg: &TensorRegistry, tenants: &[Tenant], jobs: &[JobRequest]) {
//! let outcome = ServeRequest::new(reg)
//!     .trace(tenants, jobs)
//!     .policy(SchedPolicy::Edf)
//!     .devices(2)
//!     .threads(4)
//!     .slo(SloPolicy { default_deadline_s: 0.05 })
//!     .shed(ShedPolicy::default())
//!     .run()
//!     .expect("valid request");
//! println!("p99 {:.3} ms", outcome.report.p99_latency_s() * 1e3);
//! # }
//! ```
//!
//! Malformed combinations (zero devices, non-positive SLO, a shed floor
//! of rank 0, an append against an unregistered tensor, …) return
//! [`BlcoError::InvalidRequest`] instead of panicking. The legacy
//! `serve`/`ServeOptions` pair survives as `#[deprecated]` wrappers whose
//! behaviour is pinned bit-for-bit against `run()` by this module's
//! parity test.
//!
//! # Snapshot-consistent serving under appends
//!
//! [`append_at`](ServeRequest::append_at) registers a delta-segment
//! append against an on-disk container at a virtual-time instant. The
//! run executes the append *before* replaying the trace, but builds one
//! pinned engine per epoch via
//! [`BlcoStoreReader::open_pinned`](crate::format::store::BlcoStoreReader::open_pinned):
//! jobs arriving before the append instant bind to the pre-append
//! segment set, jobs at or after it to the appended view. Since appends
//! only ever *grow* the container past the pinned frames, both views
//! coexist over one file — the serving-side analogue of MVCC snapshot
//! isolation, and the `service_layer` parity test proves each view
//! bit-for-bit against a resident twin of the matching tensor state.

use std::path::{Path, PathBuf};

use crate::coordinator::engine::MttkrpEngine;
use crate::error::BlcoError;
use crate::format::store::{BlcoStoreReader, BlcoStoreWriter};
use crate::tensor::coo::CooTensor;
use crate::util::pool::{default_threads, ExecBackend};

use super::registry::TensorRegistry;
use super::scheduler::{
    run_serve, EpochEngine, SchedPolicy, ServeParams, ServiceReport, ShedPolicy,
    SloPolicy,
};
use super::trace::{JobRequest, Tenant};

/// One scheduled delta-segment append, pending until [`ServeRequest::run`].
struct AppendAt<'a> {
    tensor: String,
    path: PathBuf,
    delta: &'a CooTensor,
    at_s: f64,
}

/// Builder for one serving run over a [`TensorRegistry`].
///
/// Construct with [`new`](Self::new), attach a trace, then call
/// [`run`](Self::run). Every knob of the deprecated
/// [`ServeOptions`](super::scheduler::ServeOptions) is a builder method
/// here, plus the production knobs the options struct never grew:
///
/// | legacy                        | equivalent request                    |
/// |-------------------------------|---------------------------------------|
/// | `ServeOptions::batched(d, t)` | `.devices(d).threads(t)`              |
/// | `ServeOptions::naive(d, t)`   | `.devices(d).threads(t).batching(false).policy(SchedPolicy::Fifo)` |
/// | `fair: false`                 | `.policy(SchedPolicy::Fifo)`          |
/// | —                             | `.policy(SchedPolicy::Edf)`           |
/// | —                             | `.slo(...)`, `.shed(...)`             |
/// | —                             | `.append_at(...)`                     |
pub struct ServeRequest<'a> {
    reg: &'a TensorRegistry,
    tenants: &'a [Tenant],
    jobs: &'a [JobRequest],
    policy: SchedPolicy,
    devices: usize,
    threads: usize,
    batching: bool,
    max_batch: usize,
    slo: Option<SloPolicy>,
    shed: Option<ShedPolicy>,
    appends: Vec<AppendAt<'a>>,
}

impl<'a> ServeRequest<'a> {
    /// A WRR, fusion-on, single-device request with no trace attached
    /// (defaults mirror `ServeOptions::default()`).
    pub fn new(reg: &'a TensorRegistry) -> Self {
        ServeRequest {
            reg,
            tenants: &[],
            jobs: &[],
            policy: SchedPolicy::Wrr,
            devices: 1,
            threads: default_threads(),
            batching: true,
            max_batch: 8,
            slo: None,
            shed: None,
            appends: Vec::new(),
        }
    }

    /// The tenants and jobs to replay. Jobs naming tenants absent from
    /// `tenants` are served at weight 1.
    pub fn trace(mut self, tenants: &'a [Tenant], jobs: &'a [JobRequest]) -> Self {
        self.tenants = tenants;
        self.jobs = jobs;
        self
    }

    /// Scheduling policy (default [`SchedPolicy::Wrr`]).
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Modelled fleet size (default 1).
    pub fn devices(mut self, devices: usize) -> Self {
        self.devices = devices;
        self
    }

    /// Worker threads for every real kernel in the run (default
    /// [`default_threads`]).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set threads from an [`ExecBackend`] — convenience for callers that
    /// already hold the execution-core decision.
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.threads = backend.threads();
        self
    }

    /// Fuse queued same-`(tensor, mode, rank)` streamed jobs (default on).
    pub fn batching(mut self, on: bool) -> Self {
        self.batching = on;
        self
    }

    /// Cap on fused group size (default 8).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Run-wide latency SLO: jobs without their own
    /// [`deadline_s`](JobRequest::deadline_s) inherit this default.
    pub fn slo(mut self, slo: SloPolicy) -> Self {
        self.slo = Some(slo);
        self
    }

    /// Enable graceful load shedding (degrade streamed jobs to coarser
    /// ranks under deadline pressure instead of missing or rejecting).
    pub fn shed(mut self, shed: ShedPolicy) -> Self {
        self.shed = Some(shed);
        self
    }

    /// Append `delta` to the container at `path` (registered under
    /// `tensor`) at virtual instant `at_s`: jobs arriving before `at_s`
    /// are served from the pre-append snapshot, jobs at or after it from
    /// the appended view. Multiple appends to one tensor stack in `at_s`
    /// order.
    pub fn append_at(
        mut self,
        tensor: &str,
        path: &Path,
        delta: &'a CooTensor,
        at_s: f64,
    ) -> Self {
        self.appends.push(AppendAt {
            tensor: tensor.to_string(),
            path: path.to_path_buf(),
            delta,
            at_s,
        });
        self
    }

    fn validate(&self) -> Result<(), BlcoError> {
        let invalid = |what: &str| {
            Err(BlcoError::InvalidRequest { what: what.to_string() })
        };
        if self.devices == 0 {
            return invalid("devices must be >= 1");
        }
        if self.threads == 0 {
            return invalid("threads must be >= 1");
        }
        if self.max_batch == 0 {
            return invalid("max_batch must be >= 1 (1 disables fusion)");
        }
        if let Some(slo) = self.slo {
            if !(slo.default_deadline_s > 0.0 && slo.default_deadline_s.is_finite()) {
                return invalid("slo default_deadline_s must be finite and > 0");
            }
        }
        if let Some(shed) = self.shed {
            if !(shed.wait_frac > 0.0 && shed.wait_frac <= 1.0) {
                return invalid("shed wait_frac must be in (0, 1]");
            }
            if shed.min_rank == 0 {
                return invalid("shed min_rank must be >= 1");
            }
        }
        for a in &self.appends {
            if !(a.at_s >= 0.0 && a.at_s.is_finite()) {
                return invalid("append_at instant must be finite and >= 0");
            }
            if self.reg.get(&a.tensor).is_none() {
                return Err(BlcoError::InvalidRequest {
                    what: format!(
                        "append_at names unregistered tensor {:?}",
                        a.tensor
                    ),
                });
            }
        }
        for j in self.jobs {
            if let Some(d) = j.deadline_s {
                if !(d > 0.0 && d.is_finite()) {
                    return Err(BlcoError::InvalidRequest {
                        what: format!(
                            "job {} deadline_s must be finite and > 0",
                            j.id
                        ),
                    });
                }
            }
        }
        Ok(())
    }

    /// Validate, execute any scheduled appends (building one pinned
    /// engine per snapshot epoch), and replay the trace. The heavy
    /// lifting is the scheduler's virtual-time loop; see the module docs
    /// for the snapshot-consistency contract.
    pub fn run(self) -> Result<ServeOutcome, BlcoError> {
        self.validate()?;

        // ---- appends become snapshot epochs: one pinned engine per view
        let mut appends = self.appends;
        appends.sort_by(|a, b| {
            a.tensor.cmp(&b.tensor).then(
                a.at_s.partial_cmp(&b.at_s).unwrap_or(std::cmp::Ordering::Equal),
            )
        });
        let profile = self.reg.profile().clone();
        let mut epoch_engines: Vec<(String, f64, MttkrpEngine)> = Vec::new();
        let mut i = 0;
        while i < appends.len() {
            let tensor = appends[i].tensor.clone();
            let path = appends[i].path.clone();
            // epoch 0: the pre-append view, pinned at the current segment
            // count so it survives the appends below untouched
            let pre_segments = BlcoStoreReader::open(&path)?.segments();
            epoch_engines.push((
                tensor.clone(),
                f64::NEG_INFINITY,
                MttkrpEngine::from_store_pinned(&path, profile.clone(), pre_segments)?,
            ));
            while i < appends.len() && appends[i].tensor == tensor {
                let a = &appends[i];
                let summary = BlcoStoreWriter::append(&a.path, a.delta, None)?;
                epoch_engines.push((
                    tensor.clone(),
                    a.at_s,
                    MttkrpEngine::from_store_pinned(
                        &a.path,
                        profile.clone(),
                        summary.segments,
                    )?,
                ));
                i += 1;
            }
        }

        let params = ServeParams {
            policy: self.policy,
            devices: self.devices,
            threads: self.threads,
            batching: self.batching,
            max_batch: self.max_batch,
            slo: self.slo,
            shed: self.shed,
            epochs: epoch_engines
                .iter()
                .map(|(tensor, from_s, engine)| EpochEngine {
                    tensor: tensor.clone(),
                    from_s: *from_s,
                    engine,
                })
                .collect(),
        };
        let report = run_serve(self.reg, self.tenants, self.jobs, &params);
        Ok(ServeOutcome { report })
    }
}

/// What a [`ServeRequest`] produced.
#[derive(Debug)]
pub struct ServeOutcome {
    pub report: ServiceReport,
}

impl ServeOutcome {
    pub fn report(&self) -> &ServiceReport {
        &self.report
    }

    pub fn into_report(self) -> ServiceReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profile::Profile;
    use crate::format::blco::BlcoConfig;
    use crate::service::scheduler::JobStatus;
    #[allow(deprecated)]
    use crate::service::scheduler::ServeOptions;
    use crate::service::trace::{synthetic_trace, TraceConfig};
    use crate::tensor::synth;

    fn registry(mem: usize) -> TensorRegistry {
        let mut reg = TensorRegistry::new(Profile::tiny(mem));
        let t = synth::uniform(&[40, 30, 20], 5_000, 3);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        reg.register("t", &t, cfg);
        reg
    }

    fn reports_match(a: &ServiceReport, b: &ServiceReport) {
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.route, y.route);
            assert_eq!(x.device, y.device);
            assert_eq!(x.group, y.group);
            assert_eq!(x.start_s.to_bits(), y.start_s.to_bits(), "job {}", x.id);
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits(), "job {}", x.id);
            assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
            assert_eq!(x.bytes, y.bytes);
            assert_eq!(x.served_rank, y.served_rank);
            assert!(!x.shed && !y.shed, "no shed policy in either run");
            match (&x.status, &y.status, &x.result, &y.result) {
                (
                    JobStatus::Completed,
                    JobStatus::Completed,
                    Some(crate::service::scheduler::JobResult::Mttkrp(mx)),
                    Some(crate::service::scheduler::JobResult::Mttkrp(my)),
                ) => assert_eq!(mx.data, my.data, "job {} bit-for-bit", x.id),
                (JobStatus::Completed, JobStatus::Completed, _, _) => {}
                (JobStatus::Rejected(ex), JobStatus::Rejected(ey), _, _) => {
                    assert_eq!(ex, ey)
                }
                _ => panic!("status diverged on job {}", x.id),
            }
        }
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.fused_groups, b.fused_groups);
        assert_eq!(a.fused_jobs, b.fused_jobs);
        assert_eq!(a.bytes_shipped, b.bytes_shipped);
        assert_eq!(a.volume_bytes, b.volume_bytes);
        for (name, sa) in &a.per_tenant {
            let sb = &b.per_tenant[name];
            assert_eq!(sa.completed, sb.completed);
            assert_eq!(sa.max_queue_depth, sb.max_queue_depth);
            assert_eq!(sa.mean_latency_s.to_bits(), sb.mean_latency_s.to_bits());
        }
    }

    #[test]
    #[allow(deprecated)]
    fn request_matches_the_deprecated_serve_bitwise() {
        // tight memory so jobs stream (and fuse) — the interesting path
        let reg = registry(48 * 1024);
        let cfg = TraceConfig { jobs: 14, cpals_every: 7, ..Default::default() };
        let (tenants, jobs) = synthetic_trace(&reg, &cfg);

        // batched WRR policy
        let old = super::super::scheduler::serve(
            &reg,
            &tenants,
            &jobs,
            &ServeOptions::batched(2, 3),
        );
        let new = ServeRequest::new(&reg)
            .trace(&tenants, &jobs)
            .devices(2)
            .threads(3)
            .run()
            .unwrap();
        reports_match(&old, &new.report);

        // naive global-FIFO ablation
        let old = super::super::scheduler::serve(
            &reg,
            &tenants,
            &jobs,
            &ServeOptions::naive(2, 3),
        );
        let new = ServeRequest::new(&reg)
            .trace(&tenants, &jobs)
            .devices(2)
            .threads(3)
            .batching(false)
            .policy(SchedPolicy::Fifo)
            .run()
            .unwrap();
        reports_match(&old, &new.into_report());
    }

    #[test]
    fn malformed_requests_return_structured_errors() {
        let reg = registry(1 << 20);
        let assert_invalid = |r: Result<ServeOutcome, BlcoError>, needle: &str| {
            match r {
                Err(BlcoError::InvalidRequest { what }) => {
                    assert!(what.contains(needle), "{what:?} missing {needle:?}")
                }
                Err(other) => panic!("expected InvalidRequest, got {other}"),
                Ok(_) => panic!("expected InvalidRequest, got Ok"),
            }
        };
        assert_invalid(ServeRequest::new(&reg).devices(0).run(), "devices");
        assert_invalid(ServeRequest::new(&reg).threads(0).run(), "threads");
        assert_invalid(ServeRequest::new(&reg).max_batch(0).run(), "max_batch");
        assert_invalid(
            ServeRequest::new(&reg).slo(SloPolicy { default_deadline_s: 0.0 }).run(),
            "default_deadline_s",
        );
        assert_invalid(
            ServeRequest::new(&reg)
                .shed(ShedPolicy { wait_frac: 1.5, min_rank: 4 })
                .run(),
            "wait_frac",
        );
        assert_invalid(
            ServeRequest::new(&reg)
                .shed(ShedPolicy { wait_frac: 0.5, min_rank: 0 })
                .run(),
            "min_rank",
        );
        let delta = synth::uniform(&[40, 30, 20], 10, 9);
        assert_invalid(
            ServeRequest::new(&reg)
                .append_at("nope", Path::new("/tmp/none.blco"), &delta, 1.0)
                .run(),
            "unregistered",
        );
        // errors render readably through the crate error type
        let e = ServeRequest::new(&reg).devices(0).run().unwrap_err();
        assert!(e.to_string().contains("invalid request"), "{e}");
    }
}
