//! The shared tensor registry: one engine per registered tensor, each
//! holding the payload `Arc<BlcoTensor>` and its schedule cache. Every job
//! the service runs against a tensor goes through *its* entry, so
//! same-tensor jobs share the resident bytes and same-`(target, rank)`
//! jobs share one memoized
//! [`StreamSchedule`](crate::coordinator::schedule::StreamSchedule) — the
//! single-copy story of the paper lifted to a multi-tenant front end.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::coordinator::engine::MttkrpEngine;
use crate::coordinator::schedule::ScheduleStats;
use crate::device::profile::Profile;
use crate::format::blco::{BlcoConfig, BlcoTensor};
use crate::format::store::StoreError;
use crate::tensor::coo::CooTensor;

/// One registered tensor: its name and the engine that owns the shared
/// payload `Arc` plus the schedule cache every job over it reuses.
pub struct TensorEntry {
    pub name: String,
    pub engine: MttkrpEngine,
}

/// Named map of resident tensors. All engines are built on the
/// *single-device* view of the service profile: the scheduler dispatches
/// whole jobs (or fused groups) to fleet devices, and each device runs its
/// own streaming pipeline, so per-tensor planning is always per-device.
pub struct TensorRegistry {
    profile: Profile,
    entries: BTreeMap<String, TensorEntry>,
}

impl TensorRegistry {
    /// A registry whose engines see `profile.single_device()`. The fleet
    /// size (`profile.devices`) is the scheduler's concern
    /// ([`super::scheduler::ServeOptions::devices`]).
    pub fn new(profile: Profile) -> Self {
        if let Err(e) = profile.validate() {
            panic!("invalid profile {:?}: {e}", profile.name);
        }
        TensorRegistry { profile: profile.single_device(), entries: BTreeMap::new() }
    }

    /// Build and register a tensor from COO. Replaces any same-named entry.
    pub fn register(&mut self, name: &str, t: &CooTensor, cfg: BlcoConfig) -> &TensorEntry {
        self.register_shared(name, Arc::new(BlcoTensor::from_coo_with(t, cfg)))
    }

    /// Register an *already shared* BLCO tensor — no payload copy, the
    /// entry's engine references the caller's `Arc` directly. This is how
    /// sweeps (and tests) stand up several registries over one resident
    /// tensor. Replaces any same-named entry.
    pub fn register_shared(&mut self, name: &str, t: Arc<BlcoTensor>) -> &TensorEntry {
        assert!(!name.is_empty(), "tensor name must be non-empty");
        let entry = TensorEntry {
            name: name.to_string(),
            engine: MttkrpEngine::from_blco(t, self.profile.clone()),
        };
        self.entries.insert(name.to_string(), entry);
        self.entries.get(name).expect("just inserted")
    }

    /// Register a tensor straight from a `.blco` container on disk — the
    /// admission path for working sets that exceed host memory: only
    /// header metadata becomes resident, payloads stream through the
    /// engine's block cache (bounded by the profile's `host_mem_bytes`).
    /// Replaces any same-named entry. Structured [`StoreError`] on a bad
    /// container, never a panic — the serving front end must survive a
    /// hostile file.
    pub fn register_store(
        &mut self,
        name: &str,
        path: &Path,
    ) -> Result<&TensorEntry, StoreError> {
        assert!(!name.is_empty(), "tensor name must be non-empty");
        let engine = MttkrpEngine::from_store(path, self.profile.clone())?;
        let entry = TensorEntry { name: name.to_string(), engine };
        self.entries.insert(name.to_string(), entry);
        Ok(self.entries.get(name).expect("just inserted"))
    }

    pub fn get(&self, name: &str) -> Option<&TensorEntry> {
        self.entries.get(name)
    }

    /// The single-device profile every registered engine sees — what
    /// snapshot-epoch engines must be built with so pre- and post-append
    /// views of a tensor run under identical accounting.
    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total *host-resident* bytes across registered payloads — each
    /// counted once per entry (sharing an `Arc` across *registries* is
    /// free; within one registry each name owns one engine). Disk-backed
    /// entries contribute only their block cache's current residency,
    /// which is how the registry admits tensors whose working set exceeds
    /// host memory without ever holding them.
    pub fn resident_bytes(&self) -> usize {
        self.entries
            .values()
            .map(|e| match e.engine.host_cache_stats() {
                None => e.engine.eng.footprint_bytes(),
                Some(cache) => cache.resident_bytes,
            })
            .sum()
    }

    /// Total payload bytes of the disk tier (full container footprints of
    /// every disk-backed entry; 0 when everything is resident).
    pub fn disk_bytes(&self) -> usize {
        self.entries
            .values()
            .filter(|e| e.engine.source().is_on_disk())
            .map(|e| e.engine.eng.footprint_bytes())
            .sum()
    }

    /// Aggregate schedule-cache activity across every registered tensor.
    pub fn schedule_stats(&self) -> ScheduleStats {
        let mut total = ScheduleStats::default();
        for e in self.entries.values() {
            let s = e.engine.schedule_stats();
            total.built += s.built;
            total.hits += s.hits;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth;

    #[test]
    fn register_and_share_payload() {
        let t = synth::uniform(&[40, 30, 20], 1_500, 1);
        let shared = Arc::new(BlcoTensor::from_coo(&t));
        let mut reg = TensorRegistry::new(Profile::a100().with_devices(4));
        // registry engines are single-device regardless of the fleet
        assert_eq!(reg.profile().devices, 1);
        reg.register_shared("shared", Arc::clone(&shared));
        reg.register("built", &t, BlcoConfig::default());
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["built".to_string(), "shared".to_string()]);
        let e = reg.get("shared").unwrap();
        assert!(Arc::ptr_eq(&e.engine.tensor(), &shared), "no payload copy");
        assert!(reg.get("missing").is_none());
        assert!(reg.resident_bytes() >= 2 * t.nnz() * 16);
        assert_eq!(reg.schedule_stats(), ScheduleStats::default());
    }

    #[test]
    fn reregister_replaces() {
        let t = synth::uniform(&[20, 20, 20], 500, 2);
        let mut reg = TensorRegistry::new(Profile::v100());
        reg.register("x", &t, BlcoConfig::default());
        let first = reg.get("x").unwrap().engine.tensor();
        reg.register("x", &t, BlcoConfig::default());
        assert_eq!(reg.len(), 1);
        assert!(!Arc::ptr_eq(&first, &reg.get("x").unwrap().engine.tensor()));
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn empty_name_rejected() {
        let t = synth::uniform(&[10, 10, 10], 100, 3);
        let mut reg = TensorRegistry::new(Profile::a100());
        reg.register("", &t, BlcoConfig::default());
    }
}
