//! The fair scheduler and its serving loop: weighted round-robin across
//! tenants (FIFO within a tenant), least-loaded dispatch over the modelled
//! device fleet, and fusion of compatible streamed jobs — queued requests
//! with the same `(tensor, mode, rank)` ride one fused
//! [`StreamRequest`](crate::coordinator::request::StreamRequest) pass, so
//! the tensor crosses the host link once
//! per group instead of once per job (the serving-side answer to the
//! paper's Figure-10 finding that the interconnect dominates
//! out-of-memory runs).
//!
//! Time is a deterministic virtual clock: kernels run for real on CPU
//! threads, but queue waits, start/finish instants and the makespan are
//! *modelled* — in-memory jobs are charged
//! [`device_time`] over their exactly-counted traffic, streamed groups
//! the pipeline-simulated `overall_s` of their stream report. The
//! one-job-at-a-time ablation ([`ServeOptions::naive`]) runs the same
//! loop with fusion off and global-FIFO pick, which is what the
//! `fig_serve_throughput` bench compares against.

use std::collections::{BTreeMap, VecDeque};

use crate::coordinator::request::StreamRequest;
use crate::coordinator::schedule::ScheduleStats;
use crate::cpals::als::{cp_als, CpAlsOptions, CpAlsReport};
use crate::device::counters::Counters;
use crate::device::model::device_time;
use crate::mttkrp::dense::Matrix;
use crate::mttkrp::oracle::random_factors;
use crate::mttkrp::Mttkrp;
use crate::util::pool::{default_threads, ExecBackend};

use super::admission::{admit_job, AdmissionError, Route};
use super::registry::TensorRegistry;
use super::trace::{JobKind, JobRequest, Tenant};

/// Scheduler policy knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// modelled fleet size; each device runs one job (or fused group) at a
    /// time through its own streaming pipeline
    pub devices: usize,
    /// fuse queued same-`(tensor, mode, rank)` streamed jobs into one pass
    pub batching: bool,
    /// cap on fused group size
    pub max_batch: usize,
    /// weighted round-robin across tenants; `false` = global FIFO
    pub fair: bool,
    /// worker count of the [`ExecBackend`] every real kernel in the run
    /// uses (certified paths stay bit-for-bit across any value)
    pub threads: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            devices: 1,
            batching: true,
            max_batch: 8,
            fair: true,
            threads: default_threads(),
        }
    }
}

impl ServeOptions {
    /// The full serving policy: WRR fairness + fusion.
    pub fn batched(devices: usize, threads: usize) -> Self {
        ServeOptions { devices, threads, ..Default::default() }
    }

    /// The one-job-at-a-time ablation baseline: no fusion, global FIFO.
    pub fn naive(devices: usize, threads: usize) -> Self {
        ServeOptions { devices, threads, batching: false, fair: false, ..Default::default() }
    }

    /// The execution backend this policy runs kernels with — one
    /// sequential/threaded decision for the whole serving run.
    pub fn backend(&self) -> ExecBackend {
        ExecBackend::from_threads(self.threads)
    }
}

/// Terminal state of a job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Completed,
    /// turned away at admission with a structured error (never a panic)
    Rejected(AdmissionError),
}

/// What a completed job produced.
#[derive(Debug)]
pub enum JobResult {
    Mttkrp(Matrix),
    CpAls(Box<CpAlsReport>),
}

/// Per-job record in the [`ServiceReport`].
#[derive(Debug)]
pub struct JobOutcome {
    pub id: usize,
    pub tenant: String,
    pub tensor: String,
    pub kind: JobKind,
    pub status: JobStatus,
    pub route: Option<Route>,
    /// fleet device the job (or its group) ran on
    pub device: Option<usize>,
    /// fused-group id when the job shared a streamed pass
    pub group: Option<usize>,
    /// modelled dispatch instant
    pub start_s: f64,
    /// modelled completion instant
    pub finish_s: f64,
    /// `finish - arrival`: queue wait + service, the tenant-visible number
    pub latency_s: f64,
    /// modelled service time of the job's dispatch (shared by a group)
    pub duration_s: f64,
    /// host-link bytes attributed to this job (a fused group's wire bytes
    /// split evenly across its members)
    pub bytes: usize,
    pub result: Option<JobResult>,
}

/// Per-tenant aggregate of a serving run.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub weight: usize,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    /// completed jobs that rode a fused group
    pub fused: usize,
    pub bytes_shipped: usize,
    pub mean_latency_s: f64,
    pub max_latency_s: f64,
    /// deepest this tenant's queue ever got (sampled at dispatch instants)
    pub max_queue_depth: usize,
}

/// Everything a serving run reports.
#[derive(Debug)]
pub struct ServiceReport {
    /// per-job records, in dispatch order (rejections first, at admission)
    pub outcomes: Vec<JobOutcome>,
    pub per_tenant: BTreeMap<String, TenantStats>,
    pub devices: usize,
    /// modelled end-to-end time: last completion instant
    pub makespan_s: f64,
    pub fused_groups: usize,
    /// jobs served inside fused groups (each group has >= 2)
    pub fused_jobs: usize,
    /// schedule-cache activity during this run (delta over the registry)
    pub schedule: ScheduleStats,
    /// total host-link bytes shipped
    pub bytes_shipped: usize,
    /// total global-memory volume of every kernel run (Table-3 accounting)
    pub volume_bytes: u64,
    /// measured CPU wall seconds of the whole replay
    pub wall_s: f64,
}

impl ServiceReport {
    pub fn completed(&self) -> usize {
        self.per_tenant.values().map(|s| s.completed).sum()
    }

    pub fn rejected(&self) -> usize {
        self.per_tenant.values().map(|s| s.rejected).sum()
    }

    /// Plans served from cache / plans requested (0 when nothing streamed).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.schedule.built + self.schedule.hits;
        if total == 0 {
            0.0
        } else {
            self.schedule.hits as f64 / total as f64
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        let (mut sum, mut n) = (0.0, 0usize);
        for s in self.per_tenant.values() {
            sum += s.mean_latency_s * s.completed as f64;
            n += s.completed;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Completed jobs per modelled second.
    pub fn throughput_jobs_per_s(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed() as f64 / self.makespan_s
        }
    }
}

/// An admitted job waiting in its tenant's queue.
struct Queued {
    job: JobRequest,
    route: Route,
}

/// Fusion key: only streamed single MTTKRPs fuse (in-memory jobs have no
/// transfer to share; CP-ALS owns its whole sweep).
fn fuse_key(q: &Queued) -> Option<(&str, usize, usize)> {
    match (q.route, q.job.kind) {
        (Route::Streamed, JobKind::Mttkrp { target, rank, .. }) => {
            Some((q.job.tensor.as_str(), target, rank))
        }
        _ => None,
    }
}

/// Interleaved weighted round-robin: serve the next eligible tenant with
/// remaining credit, rotating the cursor; refill credits from the weights
/// when every eligible tenant is spent. Over a saturated queue each tenant
/// is served proportionally to its weight.
fn wrr_pick(
    credits: &mut [usize],
    weights: &[usize],
    cursor: &mut usize,
    eligible: &[bool],
) -> usize {
    let n = credits.len();
    debug_assert!(eligible.iter().any(|&e| e), "caller guarantees an eligible tenant");
    loop {
        for step in 0..n {
            let t = (*cursor + step) % n;
            if eligible[t] && credits[t] > 0 {
                credits[t] -= 1;
                *cursor = (t + 1) % n;
                return t;
            }
        }
        // every eligible tenant is out of credit: start a new WRR cycle
        credits.copy_from_slice(weights);
    }
}

/// Replay `jobs` against the registry under the given policy. Kernels run
/// for real; waiting and service times follow the modelled clock (see the
/// module docs). Returns the full report, results included.
pub fn serve(
    reg: &TensorRegistry,
    tenants: &[Tenant],
    jobs: &[JobRequest],
    opts: &ServeOptions,
) -> ServiceReport {
    let wall0 = std::time::Instant::now();
    let devices = opts.devices.max(1);
    let threads = opts.backend().threads();
    let sched_before = reg.schedule_stats();
    let counters = Counters::new();

    // tenant table: declared tenants plus any the trace names (weight 1)
    let mut tnames: Vec<String> = tenants.iter().map(|t| t.name.clone()).collect();
    let mut weights: Vec<usize> = tenants.iter().map(|t| t.weight.max(1)).collect();
    for j in jobs {
        if !tnames.iter().any(|n| n == &j.tenant) {
            tnames.push(j.tenant.clone());
            weights.push(1);
        }
    }
    let ntenants = tnames.len();

    // ---- admission: rejections become outcomes immediately; admitted
    // jobs queue FIFO (arrival order) within their tenant
    let mut sorted: Vec<&JobRequest> = jobs.iter().collect();
    sorted.sort_by(|a, b| {
        a.arrival_s
            .partial_cmp(&b.arrival_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    let mut outcomes: Vec<JobOutcome> = Vec::with_capacity(jobs.len());
    let mut queues: Vec<VecDeque<Queued>> = (0..ntenants).map(|_| VecDeque::new()).collect();
    for job in sorted {
        let ti = tnames.iter().position(|n| n == &job.tenant).expect("tenant table");
        match admit_job(reg, job) {
            Err(e) => outcomes.push(JobOutcome {
                id: job.id,
                tenant: job.tenant.clone(),
                tensor: job.tensor.clone(),
                kind: job.kind,
                status: JobStatus::Rejected(e),
                route: None,
                device: None,
                group: None,
                start_s: job.arrival_s,
                finish_s: job.arrival_s,
                latency_s: 0.0,
                duration_s: 0.0,
                bytes: 0,
                result: None,
            }),
            Ok(a) => queues[ti].push_back(Queued { job: job.clone(), route: a.route }),
        }
    }

    // ---- dispatch loop over the virtual clock
    let mut device_free = vec![0.0f64; devices];
    let mut credits: Vec<usize> = weights.clone();
    let mut cursor = 0usize;
    let mut max_depth: Vec<usize> = queues.iter().map(|q| q.len()).collect();
    let mut fused_groups = 0usize;
    let mut fused_jobs = 0usize;
    let mut next_group = 0usize;

    while queues.iter().any(|q| !q.is_empty()) {
        // next free device (ties by index → deterministic)
        let d = (0..devices)
            .min_by(|&a, &b| {
                device_free[a]
                    .partial_cmp(&device_free[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            })
            .expect("devices >= 1");
        let mut now = device_free[d];
        let next_arrival = queues
            .iter()
            .filter_map(|q| q.front().map(|x| x.job.arrival_s))
            .fold(f64::INFINITY, f64::min);
        if next_arrival > now {
            now = next_arrival; // the fleet idles until work arrives
        }
        let eligible: Vec<bool> = queues
            .iter()
            .map(|q| q.front().map(|x| x.job.arrival_s <= now).unwrap_or(false))
            .collect();
        // backlog sampled at this dispatch instant: only jobs that have
        // actually arrived count (queues hold the whole future trace)
        for (depth, q) in max_depth.iter_mut().zip(&queues) {
            let arrived = q.iter().filter(|x| x.job.arrival_s <= now).count();
            *depth = (*depth).max(arrived);
        }

        // ---- pick the initiating tenant
        let t = if opts.fair {
            wrr_pick(&mut credits, &weights, &mut cursor, &eligible)
        } else {
            // global FIFO: the eligible front with the earliest (arrival, id)
            let mut best: Option<usize> = None;
            for (ti, q) in queues.iter().enumerate() {
                if !eligible[ti] {
                    continue;
                }
                let f = q.front().expect("eligible implies non-empty");
                best = match best {
                    None => Some(ti),
                    Some(b) => {
                        let g = queues[b].front().expect("tracked front");
                        if (f.job.arrival_s, f.job.id) < (g.job.arrival_s, g.job.id) {
                            Some(ti)
                        } else {
                            Some(b)
                        }
                    }
                };
            }
            best.expect("some tenant is eligible at `now`")
        };
        let head = queues[t].pop_front().expect("eligible tenant has a front");
        let head_engine =
            &reg.get(&head.job.tensor).expect("admitted tensor is registered").engine;
        let mut group = vec![head];

        // ---- fuse compatible arrived jobs (any tenant) onto this dispatch.
        // The group is capped by device memory, not just max_batch: k fused
        // jobs keep k factor/output sets resident while sharing one batch
        // double buffer, so fusion must not overcommit the budget the
        // admission controller guaranteed per job.
        if opts.batching && opts.max_batch > 1 {
            let key = fuse_key(&group[0]).map(|(s, m, r)| (s.to_string(), m, r));
            if let Some((ks, km, kr)) = key {
                let cap = opts.max_batch.min(head_engine.fused_jobs_capacity(km, kr));
                'scan: for step in 0..ntenants {
                    let ti = (t + step) % ntenants;
                    let q = &mut queues[ti];
                    let mut i = 0;
                    while i < q.len() {
                        if group.len() >= cap {
                            break 'scan;
                        }
                        let cand = &q[i];
                        let joins = cand.job.arrival_s <= now
                            && fuse_key(cand) == Some((ks.as_str(), km, kr));
                        if joins {
                            group.push(q.remove(i).expect("index in range"));
                        } else {
                            i += 1;
                        }
                    }
                }
            }
        }

        // ---- run the group for real, modelled duration from the cost model
        let gid = if group.len() > 1 {
            fused_groups += 1;
            fused_jobs += group.len();
            next_group += 1;
            Some(next_group - 1)
        } else {
            None
        };
        let engine = head_engine;
        let cnt = Counters::new();
        let (duration_s, group_bytes, results): (f64, usize, Vec<JobResult>) =
            match group[0].job.kind {
                JobKind::Mttkrp { target, rank, .. } => {
                    let factor_sets: Vec<Vec<Matrix>> = group
                        .iter()
                        .map(|g| match g.job.kind {
                            JobKind::Mttkrp { seed, .. } => {
                                random_factors(&engine.dims, rank, seed)
                            }
                            JobKind::CpAls { .. } => unreachable!("only MTTKRPs fuse"),
                        })
                        .collect();
                    let mut outs: Vec<Matrix> = group
                        .iter()
                        .map(|_| Matrix::zeros(engine.dims[target] as usize, rank))
                        .collect();
                    match group[0].route {
                        Route::Streamed => {
                            // memoized plan: repeated (tensor, mode, rank)
                            // dispatches hit the registry's schedule cache
                            let sched = engine.schedule(target, rank);
                            let refs: Vec<&[Matrix]> =
                                factor_sets.iter().map(|f| f.as_slice()).collect();
                            let rep = StreamRequest::new(&engine.eng, target)
                                .fused(&refs)
                                .schedule(&sched)
                                .threads(threads)
                                .counters(&cnt)
                                .run(&mut outs)
                                .expect("fused group was validated when queued")
                                .into_streamed()
                                .expect("single-device schedule streams");
                            (
                                rep.overall_s,
                                rep.bytes,
                                outs.into_iter().map(JobResult::Mttkrp).collect(),
                            )
                        }
                        Route::InMemory => {
                            // in-memory jobs never fuse (no transfer to share)
                            debug_assert_eq!(group.len(), 1);
                            engine.eng.mttkrp(
                                target, &factor_sets[0], &mut outs[0], threads, &cnt,
                            );
                            let d = device_time(&cnt.snapshot(), &engine.eng.profile)
                                .total();
                            (d, 0, outs.into_iter().map(JobResult::Mttkrp).collect())
                        }
                    }
                }
                JobKind::CpAls { rank, iters, seed } => {
                    debug_assert_eq!(group.len(), 1);
                    let o = CpAlsOptions { rank, max_iters: iters, tol: 0.0, threads, seed };
                    let rep = cp_als(engine, &engine.dims, engine.norm_x, o, &cnt);
                    // coarse end-to-end model: device time of every kernel,
                    // with streamed calls' compute replaced by their
                    // pipeline-simulated end-to-end time
                    let dt = device_time(&cnt.snapshot(), &engine.eng.profile).total();
                    let duration = (dt - rep.stream.compute_s).max(0.0) + rep.stream.overall_s;
                    let bytes = rep.stream.bytes;
                    (duration, bytes, vec![JobResult::CpAls(Box::new(rep))])
                }
            };
        counters.add(&cnt.snapshot());

        let start = now.max(device_free[d]);
        let finish = start + duration_s;
        device_free[d] = finish;
        let per_job_bytes = group_bytes / group.len();
        for (q, result) in group.into_iter().zip(results) {
            outcomes.push(JobOutcome {
                id: q.job.id,
                tenant: q.job.tenant,
                tensor: q.job.tensor,
                kind: q.job.kind,
                status: JobStatus::Completed,
                route: Some(q.route),
                device: Some(d),
                group: gid,
                start_s: start,
                finish_s: finish,
                latency_s: finish - q.job.arrival_s,
                duration_s,
                bytes: per_job_bytes,
                result: Some(result),
            });
        }
    }

    // ---- aggregate
    let mut per_tenant: BTreeMap<String, TenantStats> = BTreeMap::new();
    for (i, name) in tnames.iter().enumerate() {
        per_tenant.insert(
            name.clone(),
            TenantStats {
                weight: weights[i],
                max_queue_depth: max_depth[i],
                ..Default::default()
            },
        );
    }
    for o in &outcomes {
        let s = per_tenant.get_mut(&o.tenant).expect("tenant table covers the trace");
        s.submitted += 1;
        match &o.status {
            JobStatus::Completed => {
                s.completed += 1;
                s.mean_latency_s += o.latency_s; // sum; divided below
                s.max_latency_s = s.max_latency_s.max(o.latency_s);
                s.bytes_shipped += o.bytes;
                if o.group.is_some() {
                    s.fused += 1;
                }
            }
            JobStatus::Rejected(_) => s.rejected += 1,
        }
    }
    for s in per_tenant.values_mut() {
        if s.completed > 0 {
            s.mean_latency_s /= s.completed as f64;
        }
    }
    let makespan_s = outcomes
        .iter()
        .filter(|o| matches!(o.status, JobStatus::Completed))
        .map(|o| o.finish_s)
        .fold(0.0, f64::max);
    let bytes_shipped = outcomes.iter().map(|o| o.bytes).sum();

    ServiceReport {
        outcomes,
        per_tenant,
        devices,
        makespan_s,
        fused_groups,
        fused_jobs,
        schedule: reg.schedule_stats().delta_since(sched_before),
        bytes_shipped,
        volume_bytes: counters.snapshot().volume_bytes(),
        wall_s: wall0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrr_serves_proportionally_to_weight() {
        let weights = vec![2usize, 1];
        let mut credits = weights.clone();
        let mut cursor = 0usize;
        let eligible = vec![true, true];
        let picks: Vec<usize> = (0..9)
            .map(|_| wrr_pick(&mut credits, &weights, &mut cursor, &eligible))
            .collect();
        let a = picks.iter().filter(|&&p| p == 0).count();
        assert_eq!(a, 6, "weight-2 tenant gets 2/3 of dispatches: {picks:?}");
        // interleaved, not burst: no run of 3 identical picks in a cycle
        assert!(picks.windows(3).all(|w| !(w[0] == w[1] && w[1] == w[2])), "{picks:?}");
    }

    #[test]
    fn wrr_skips_ineligible_tenants() {
        let weights = vec![1usize, 1, 1];
        let mut credits = weights.clone();
        let mut cursor = 0usize;
        let eligible = vec![false, true, false];
        for _ in 0..5 {
            assert_eq!(wrr_pick(&mut credits, &weights, &mut cursor, &eligible), 1);
        }
    }
}
