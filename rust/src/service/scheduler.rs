//! The serving loop and its scheduling policies: weighted round-robin
//! across tenants (FIFO within a tenant), **earliest-deadline-first** over
//! priority tiers, and the naive global-FIFO ablation baseline —
//! least-loaded dispatch over the modelled device fleet, fusion of
//! compatible streamed jobs (same `(tensor, mode, rank)` requests ride one
//! fused [`StreamRequest`](crate::coordinator::request::StreamRequest)
//! pass, so the tensor crosses the host link once per group instead of
//! once per job), and graceful **load shedding** that degrades a streamed
//! job to a coarser rank when queue wait has eaten its deadline, instead
//! of rejecting it outright.
//!
//! Time is a deterministic virtual clock: kernels run for real on CPU
//! threads, but queue waits, start/finish instants and the makespan are
//! *modelled* — in-memory jobs are charged [`device_time`] over their
//! exactly-counted traffic, streamed groups the pipeline-simulated
//! `overall_s` of their stream report. Queue depth is tracked on **every
//! enqueue and dequeue event** of that clock (not sampled at dispatch
//! instants — sampling provably mis-reads spread traces; the regression
//! test in `rust/tests/service_layer.rs` pins the difference), and every
//! latency tail in the [`ServiceReport`] is an interpolated-rank
//! percentile from [`super::stats`].
//!
//! The entry point is the [`ServeRequest`](super::request::ServeRequest)
//! builder; [`serve`] and [`ServeOptions`] survive as `#[deprecated]`
//! wrappers pinned bit-for-bit by the builder's parity test.

use std::collections::{BTreeMap, VecDeque};

use crate::coordinator::engine::MttkrpEngine;
use crate::coordinator::request::StreamRequest;
use crate::coordinator::schedule::ScheduleStats;
use crate::cpals::als::{cp_als, CpAlsOptions, CpAlsReport};
use crate::device::counters::Counters;
use crate::device::model::device_time;
use crate::mttkrp::dense::Matrix;
use crate::mttkrp::oracle::random_factors;
use crate::mttkrp::Mttkrp;
use crate::util::pool::{default_threads, ExecBackend};

use super::admission::{admit_job_on, admit_mttkrp, AdmissionError, Route};
use super::registry::TensorRegistry;
use super::stats::Percentiles;
use super::trace::{JobKind, JobRequest, Tenant};

/// Which scheduling policy picks the next job to dispatch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// weighted round-robin across tenants, FIFO within a tenant — the
    /// fairness policy
    #[default]
    Wrr,
    /// earliest deadline first over priority tiers: strictly by tier
    /// (`JobRequest::priority`, 0 = most urgent), earliest absolute
    /// deadline within a tier, best-effort jobs last (by arrival). Note
    /// EDF is deadline-driven, not fairness-driven: tenant weights are
    /// ignored.
    Edf,
    /// global FIFO by `(arrival, id)` — the naive ablation baseline
    Fifo,
}

/// Run-wide latency SLO: a default relative deadline stamped on jobs that
/// did not carry their own (`JobRequest::deadline_s` wins when set).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    pub default_deadline_s: f64,
}

/// Graceful load shedding for **streamed MTTKRP** jobs: degrade to a
/// coarser rank instead of missing outright or rejecting.
///
/// Two trigger points:
/// * **admission** — a rank that cannot fit even the streaming floor
///   (`WontFit`) is retried at successively halved ranks down to
///   `min_rank`; the job is admitted *shed* at the first rank that fits
///   instead of being rejected;
/// * **dispatch** — a job whose queue wait has consumed more than
///   `wait_frac` of its deadline budget by dispatch time is served at
///   `max(min_rank, rank/2)`.
///
/// A shed job completes (status `Completed`, `JobOutcome::shed` set) with
/// a coarser factorization — the tenant gets a lower-fidelity answer on
/// time rather than a rejection or a blown SLO.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedPolicy {
    /// shed at dispatch once `wait / deadline > wait_frac`
    pub wait_frac: f64,
    /// rank degradation floor
    pub min_rank: usize,
}

impl Default for ShedPolicy {
    fn default() -> Self {
        ShedPolicy { wait_frac: 0.5, min_rank: 4 }
    }
}

/// Scheduler policy knobs of the deprecated [`serve`] entry point.
#[deprecated(
    note = "use service::ServeRequest — the builder carries policy, SLO and \
            shedding knobs and returns structured errors"
)]
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// modelled fleet size; each device runs one job (or fused group) at a
    /// time through its own streaming pipeline
    pub devices: usize,
    /// fuse queued same-`(tensor, mode, rank)` streamed jobs into one pass
    pub batching: bool,
    /// cap on fused group size
    pub max_batch: usize,
    /// weighted round-robin across tenants; `false` = global FIFO
    pub fair: bool,
    /// worker count of the [`ExecBackend`] every real kernel in the run
    /// uses (certified paths stay bit-for-bit across any value)
    pub threads: usize,
}

#[allow(deprecated)]
impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            devices: 1,
            batching: true,
            max_batch: 8,
            fair: true,
            threads: default_threads(),
        }
    }
}

#[allow(deprecated)]
impl ServeOptions {
    /// The full serving policy: WRR fairness + fusion.
    pub fn batched(devices: usize, threads: usize) -> Self {
        ServeOptions { devices, threads, ..Default::default() }
    }

    /// The one-job-at-a-time ablation baseline: no fusion, global FIFO.
    pub fn naive(devices: usize, threads: usize) -> Self {
        ServeOptions { devices, threads, batching: false, fair: false, ..Default::default() }
    }

    /// The execution backend this policy runs kernels with — one
    /// sequential/threaded decision for the whole serving run.
    pub fn backend(&self) -> ExecBackend {
        ExecBackend::from_threads(self.threads)
    }
}

/// Terminal state of a job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Completed,
    /// turned away at admission with a structured error (never a panic)
    Rejected(AdmissionError),
}

/// What a completed job produced.
#[derive(Debug)]
pub enum JobResult {
    Mttkrp(Matrix),
    CpAls(Box<CpAlsReport>),
}

/// Per-job record in the [`ServiceReport`].
#[derive(Debug)]
pub struct JobOutcome {
    pub id: usize,
    pub tenant: String,
    pub tensor: String,
    pub kind: JobKind,
    pub status: JobStatus,
    pub route: Option<Route>,
    /// fleet device the job (or its group) ran on
    pub device: Option<usize>,
    /// fused-group id when the job shared a streamed pass
    pub group: Option<usize>,
    /// modelled dispatch instant
    pub start_s: f64,
    /// modelled completion instant
    pub finish_s: f64,
    /// `finish - arrival`: queue wait + service, the tenant-visible number
    pub latency_s: f64,
    /// modelled service time of the job's dispatch (shared by a group)
    pub duration_s: f64,
    /// host-link bytes attributed to this job (a fused group's wire bytes
    /// split evenly across its members)
    pub bytes: usize,
    /// rank the job was actually served at (differs from the requested
    /// rank only when shed); `None` for rejected jobs
    pub served_rank: Option<usize>,
    /// degraded to a coarser rank by the [`ShedPolicy`]
    pub shed: bool,
    /// absolute deadline instant (`arrival + SLO`), when one applied
    pub deadline_s: Option<f64>,
    /// completed after its deadline instant
    pub missed_deadline: bool,
    pub result: Option<JobResult>,
}

/// Per-tenant aggregate of a serving run.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub weight: usize,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    /// completed jobs that rode a fused group
    pub fused: usize,
    /// completed jobs degraded to a coarser rank by the shed policy
    pub shed: usize,
    /// completed jobs that carried a deadline
    pub deadline_jobs: usize,
    /// ... and finished after it
    pub deadline_misses: usize,
    pub bytes_shipped: usize,
    pub mean_latency_s: f64,
    pub max_latency_s: f64,
    /// p50/p95/p99/p999 of this tenant's completed-job latencies
    pub latency: Percentiles,
    /// queue-depth distribution over this tenant's enqueue/dequeue events
    pub queue_depth: Percentiles,
    /// deepest this tenant's queue ever got (event-tracked: updated on
    /// every enqueue *and* dequeue of the virtual clock)
    pub max_queue_depth: usize,
}

impl TenantStats {
    /// Fraction of this tenant's deadline-carrying completions that
    /// finished late (0.0 when none carried a deadline).
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.deadline_jobs == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.deadline_jobs as f64
        }
    }
}

/// Everything a serving run reports.
#[derive(Debug)]
pub struct ServiceReport {
    /// per-job records, in dispatch order (rejections first, at admission)
    pub outcomes: Vec<JobOutcome>,
    pub per_tenant: BTreeMap<String, TenantStats>,
    pub devices: usize,
    /// modelled end-to-end time: last completion instant
    pub makespan_s: f64,
    pub fused_groups: usize,
    /// jobs served inside fused groups (each group has >= 2)
    pub fused_jobs: usize,
    /// completed jobs degraded to a coarser rank (aggregate)
    pub shed_jobs: usize,
    /// completed jobs that carried a deadline (aggregate)
    pub deadline_jobs: usize,
    /// ... and finished after it (aggregate)
    pub deadline_misses: usize,
    /// latency distribution over every completed job
    pub latency: Percentiles,
    /// aggregate queue-depth distribution (total backlog across tenants,
    /// sampled at every enqueue/dequeue event)
    pub queue_depth: Percentiles,
    /// schedule-cache activity during this run (delta over the registry
    /// plus any snapshot-epoch engines)
    pub schedule: ScheduleStats,
    /// total host-link bytes shipped
    pub bytes_shipped: usize,
    /// total global-memory volume of every kernel run (Table-3 accounting)
    pub volume_bytes: u64,
    /// measured CPU wall seconds of the whole replay
    pub wall_s: f64,
}

impl ServiceReport {
    pub fn completed(&self) -> usize {
        self.per_tenant.values().map(|s| s.completed).sum()
    }

    pub fn rejected(&self) -> usize {
        self.per_tenant.values().map(|s| s.rejected).sum()
    }

    /// Plans served from cache / plans requested (0 when nothing streamed).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.schedule.built + self.schedule.hits;
        if total == 0 {
            0.0
        } else {
            self.schedule.hits as f64 / total as f64
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        let (mut sum, mut n) = (0.0, 0usize);
        for s in self.per_tenant.values() {
            sum += s.mean_latency_s * s.completed as f64;
            n += s.completed;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// p99 of every completed job's latency (the SLO headline number).
    pub fn p99_latency_s(&self) -> f64 {
        self.latency.p99
    }

    /// Aggregate deadline-miss rate over completions that carried one.
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.deadline_jobs == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.deadline_jobs as f64
        }
    }

    /// Completed jobs per modelled second.
    pub fn throughput_jobs_per_s(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed() as f64 / self.makespan_s
        }
    }
}

/// A tensor view that becomes active for jobs arriving at or after
/// `from_s` — how snapshot-consistent serving maps a job's arrival to the
/// pre- or post-append engine (built by
/// [`ServeRequest::append_at`](super::request::ServeRequest::append_at)).
pub(crate) struct EpochEngine<'a> {
    pub tensor: String,
    pub from_s: f64,
    pub engine: &'a MttkrpEngine,
}

/// Validated inputs of one serving run — constructed only by
/// [`ServeRequest::run`](super::request::ServeRequest::run) and the
/// deprecated [`serve`] wrapper.
pub(crate) struct ServeParams<'a> {
    pub policy: SchedPolicy,
    pub devices: usize,
    pub threads: usize,
    pub batching: bool,
    pub max_batch: usize,
    pub slo: Option<SloPolicy>,
    pub shed: Option<ShedPolicy>,
    pub epochs: Vec<EpochEngine<'a>>,
}

impl ServeParams<'_> {
    /// The engine a job uses: the latest epoch active at its arrival,
    /// falling back to the registry entry when the tensor has no epochs.
    fn engine_for<'r>(
        &'r self,
        reg: &'r TensorRegistry,
        tensor: &str,
        arrival_s: f64,
    ) -> Option<&'r MttkrpEngine> {
        let mut best: Option<(f64, &MttkrpEngine)> = None;
        for e in &self.epochs {
            if e.tensor == tensor
                && e.from_s <= arrival_s
                && best.map_or(true, |(f, _)| e.from_s >= f)
            {
                best = Some((e.from_s, e.engine));
            }
        }
        match best {
            Some((_, eng)) => Some(eng),
            None => reg.get(tensor).map(|e| &e.engine),
        }
    }

    /// Registry schedule stats plus every epoch engine's — the combined
    /// counter the report's delta is taken over.
    fn sched_total(&self, reg: &TensorRegistry) -> ScheduleStats {
        let mut total = reg.schedule_stats();
        for e in &self.epochs {
            let s = e.engine.schedule_stats();
            total.built += s.built;
            total.hits += s.hits;
        }
        total
    }
}

/// An admitted job waiting in its tenant's queue.
struct Queued<'e> {
    job: JobRequest,
    route: Route,
    engine: &'e MttkrpEngine,
    /// absolute deadline instant, when the job (or the run's SLO default)
    /// carries one
    deadline_abs: Option<f64>,
    /// rank after any admission-time shed (requested rank otherwise; the
    /// requested rank for CP-ALS, which never sheds)
    rank_eff: usize,
    /// degraded at admission to fit the streaming floor
    admit_shed: bool,
}

/// The rank a job is served at if dispatched at `now`, plus whether that
/// is a shed. Dispatch-time shedding applies to streamed MTTKRPs whose
/// queue wait has consumed more than `wait_frac` of their deadline budget.
fn shed_decision(q: &Queued, now: f64, shed: Option<&ShedPolicy>) -> (usize, bool) {
    let base = (q.rank_eff, q.admit_shed);
    let Some(pol) = shed else { return base };
    if q.route != Route::Streamed || !matches!(q.job.kind, JobKind::Mttkrp { .. }) {
        return base;
    }
    let Some(deadline) = q.deadline_abs else { return base };
    let budget = deadline - q.job.arrival_s;
    let waited = now - q.job.arrival_s;
    if budget > 0.0 && waited > pol.wait_frac * budget && q.rank_eff > pol.min_rank {
        (pol.min_rank.max(q.rank_eff / 2), true)
    } else {
        base
    }
}

/// Fusion key: only streamed single MTTKRPs fuse (in-memory jobs have no
/// transfer to share; CP-ALS owns its whole sweep). Rank equality is
/// checked separately through [`shed_decision`], and epoch identity
/// through the engine pointer — jobs on different sides of an append see
/// different tensors and must not share a pass.
fn fuse_target(q: &Queued) -> Option<(&str, usize)> {
    match (q.route, q.job.kind) {
        (Route::Streamed, JobKind::Mttkrp { target, .. }) => {
            Some((q.job.tensor.as_str(), target))
        }
        _ => None,
    }
}

/// Interleaved weighted round-robin: serve the next eligible tenant with
/// remaining credit, rotating the cursor; refill credits from the weights
/// when every eligible tenant is spent. Over a saturated queue each tenant
/// is served proportionally to its weight.
fn wrr_pick(
    credits: &mut [usize],
    weights: &[usize],
    cursor: &mut usize,
    eligible: &[bool],
) -> usize {
    let n = credits.len();
    debug_assert!(eligible.iter().any(|&e| e), "caller guarantees an eligible tenant");
    loop {
        for step in 0..n {
            let t = (*cursor + step) % n;
            if eligible[t] && credits[t] > 0 {
                credits[t] -= 1;
                *cursor = (t + 1) % n;
                return t;
            }
        }
        // every eligible tenant is out of credit: start a new WRR cycle
        credits.copy_from_slice(weights);
    }
}

/// Queue-depth accounting over the virtual clock: depth changes on every
/// enqueue (arrival) and dequeue (dispatch or fuse-removal) event, and
/// every change is sampled — per tenant and for the aggregate backlog.
/// This replaces the old dispatch-instant sampling, which initialized
/// each tenant's max to its *whole future trace* and therefore mis-read
/// any spread trace (the regression test in `service_layer.rs` pins a
/// case where sampling reports 4× the true depth).
struct DepthTracker {
    depth: Vec<usize>,
    total: usize,
    max_depth: Vec<usize>,
    tenant_samples: Vec<Vec<f64>>,
    total_samples: Vec<f64>,
    /// admitted arrivals `(arrival_s, tenant)` in arrival order, consumed
    /// as the clock passes them
    arrivals: Vec<(f64, usize)>,
    next_arrival: usize,
}

impl DepthTracker {
    fn new(ntenants: usize, arrivals: Vec<(f64, usize)>) -> Self {
        DepthTracker {
            depth: vec![0; ntenants],
            total: 0,
            max_depth: vec![0; ntenants],
            tenant_samples: vec![Vec::new(); ntenants],
            total_samples: Vec::new(),
            arrivals,
            next_arrival: 0,
        }
    }

    /// Process every arrival event up to (and including) `now`.
    fn advance(&mut self, now: f64) {
        while self.next_arrival < self.arrivals.len()
            && self.arrivals[self.next_arrival].0 <= now
        {
            let t = self.arrivals[self.next_arrival].1;
            self.depth[t] += 1;
            self.total += 1;
            self.max_depth[t] = self.max_depth[t].max(self.depth[t]);
            self.tenant_samples[t].push(self.depth[t] as f64);
            self.total_samples.push(self.total as f64);
            self.next_arrival += 1;
        }
    }

    /// One job left tenant `t`'s queue (dispatch or fuse-removal).
    fn dequeue(&mut self, t: usize) {
        debug_assert!(self.depth[t] > 0, "dequeue from an empty accounting bucket");
        self.depth[t] -= 1;
        self.total -= 1;
        self.tenant_samples[t].push(self.depth[t] as f64);
        self.total_samples.push(self.total as f64);
    }
}

/// Replay `jobs` against the registry under the given policy — the core
/// loop behind [`ServeRequest`](super::request::ServeRequest). Kernels run
/// for real; waiting and service times follow the modelled clock (see the
/// module docs). Returns the full report, results included.
pub(crate) fn run_serve(
    reg: &TensorRegistry,
    tenants: &[Tenant],
    jobs: &[JobRequest],
    params: &ServeParams,
) -> ServiceReport {
    let wall0 = std::time::Instant::now();
    let devices = params.devices.max(1);
    let threads = params.threads.max(1);
    let sched_before = params.sched_total(reg);
    let counters = Counters::new();

    // tenant table: declared tenants plus any the trace names (weight 1)
    let mut tnames: Vec<String> = tenants.iter().map(|t| t.name.clone()).collect();
    let mut weights: Vec<usize> = tenants.iter().map(|t| t.weight.max(1)).collect();
    for j in jobs {
        if !tnames.iter().any(|n| n == &j.tenant) {
            tnames.push(j.tenant.clone());
            weights.push(1);
        }
    }
    let ntenants = tnames.len();

    let rejected_outcome = |job: &JobRequest, e: AdmissionError| JobOutcome {
        id: job.id,
        tenant: job.tenant.clone(),
        tensor: job.tensor.clone(),
        kind: job.kind,
        status: JobStatus::Rejected(e),
        route: None,
        device: None,
        group: None,
        start_s: job.arrival_s,
        finish_s: job.arrival_s,
        latency_s: 0.0,
        duration_s: 0.0,
        bytes: 0,
        served_rank: None,
        shed: false,
        deadline_s: None,
        missed_deadline: false,
        result: None,
    };

    // ---- admission: rejections become outcomes immediately; admitted
    // jobs queue FIFO (arrival order) within their tenant. Each job binds
    // to its arrival's epoch engine here — the snapshot-consistency rule.
    let mut sorted: Vec<&JobRequest> = jobs.iter().collect();
    sorted.sort_by(|a, b| {
        a.arrival_s
            .partial_cmp(&b.arrival_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    let mut outcomes: Vec<JobOutcome> = Vec::with_capacity(jobs.len());
    let mut queues: Vec<VecDeque<Queued>> = (0..ntenants).map(|_| VecDeque::new()).collect();
    let mut arrivals: Vec<(f64, usize)> = Vec::with_capacity(jobs.len());
    for job in sorted {
        let ti = tnames.iter().position(|n| n == &job.tenant).expect("tenant table");
        let Some(engine) = params.engine_for(reg, &job.tensor, job.arrival_s) else {
            outcomes.push(rejected_outcome(
                job,
                AdmissionError::UnknownTensor { tensor: job.tensor.clone() },
            ));
            continue;
        };
        let deadline_abs = job
            .deadline_s
            .or(params.slo.map(|s| s.default_deadline_s))
            .map(|d| job.arrival_s + d);
        let (requested_rank, is_mttkrp) = match job.kind {
            JobKind::Mttkrp { rank, .. } => (rank, true),
            JobKind::CpAls { rank, .. } => (rank, false),
        };
        let admitted = match admit_job_on(engine, job) {
            Ok(a) => Ok((a, requested_rank, false)),
            // admission-level shed: a WontFit MTTKRP retries at halved
            // ranks down to the floor instead of bouncing the tenant
            Err(AdmissionError::WontFit { target, .. })
                if is_mttkrp && params.shed.is_some() =>
            {
                let pol = params.shed.expect("guard");
                let mut r = requested_rank;
                let mut found = None;
                while r > pol.min_rank {
                    r = pol.min_rank.max(r / 2);
                    if let Ok(a) = admit_mttkrp(engine, target, r) {
                        found = Some((a, r, true));
                        break;
                    }
                }
                found.ok_or_else(|| {
                    admit_job_on(engine, job).expect_err("still unservable")
                })
            }
            Err(e) => Err(e),
        };
        match admitted {
            Err(e) => outcomes.push(rejected_outcome(job, e)),
            Ok((a, rank_eff, admit_shed)) => {
                arrivals.push((job.arrival_s, ti));
                queues[ti].push_back(Queued {
                    job: job.clone(),
                    route: a.route,
                    engine,
                    deadline_abs,
                    rank_eff,
                    admit_shed,
                });
            }
        }
    }

    // ---- dispatch loop over the virtual clock
    let mut device_free = vec![0.0f64; devices];
    let mut credits: Vec<usize> = weights.clone();
    let mut cursor = 0usize;
    let mut depth = DepthTracker::new(ntenants, arrivals);
    let mut fused_groups = 0usize;
    let mut fused_jobs = 0usize;
    let mut next_group = 0usize;

    while queues.iter().any(|q| !q.is_empty()) {
        // next free device (ties by index → deterministic)
        let d = (0..devices)
            .min_by(|&a, &b| {
                device_free[a]
                    .partial_cmp(&device_free[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            })
            .expect("devices >= 1");
        let mut now = device_free[d];
        let next_arrival = queues
            .iter()
            .filter_map(|q| q.front().map(|x| x.job.arrival_s))
            .fold(f64::INFINITY, f64::min);
        if next_arrival > now {
            now = next_arrival; // the fleet idles until work arrives
        }
        // every arrival event up to this dispatch instant is an enqueue
        depth.advance(now);

        // ---- pick the initiating job
        let (t, qi) = match params.policy {
            SchedPolicy::Wrr => {
                let eligible: Vec<bool> = queues
                    .iter()
                    .map(|q| q.front().map(|x| x.job.arrival_s <= now).unwrap_or(false))
                    .collect();
                (wrr_pick(&mut credits, &weights, &mut cursor, &eligible), 0)
            }
            SchedPolicy::Fifo => {
                // global FIFO: the eligible front with the earliest
                // (arrival, id); queues are arrival-ordered, so the
                // global earliest job is at some front
                let mut best: Option<usize> = None;
                for (ti, q) in queues.iter().enumerate() {
                    let Some(f) = q.front() else { continue };
                    if f.job.arrival_s > now {
                        continue;
                    }
                    best = match best {
                        None => Some(ti),
                        Some(b) => {
                            let g = queues[b].front().expect("tracked front");
                            if (f.job.arrival_s, f.job.id) < (g.job.arrival_s, g.job.id) {
                                Some(ti)
                            } else {
                                Some(b)
                            }
                        }
                    };
                }
                (best.expect("some tenant is eligible at `now`"), 0)
            }
            SchedPolicy::Edf => {
                // earliest deadline first across *all* arrived jobs (they
                // can sit mid-queue behind earlier arrivals): strictly by
                // priority tier, then absolute deadline (best-effort jobs
                // last), then (arrival, id) for determinism
                let mut best: Option<((u8, f64, f64, usize), (usize, usize))> = None;
                for (ti, q) in queues.iter().enumerate() {
                    for (i, x) in q.iter().enumerate() {
                        if x.job.arrival_s > now {
                            break; // arrival-ordered within the queue
                        }
                        let key = (
                            x.job.priority,
                            x.deadline_abs.unwrap_or(f64::INFINITY),
                            x.job.arrival_s,
                            x.job.id,
                        );
                        if best.map_or(true, |(bk, _)| key < bk) {
                            best = Some((key, (ti, i)));
                        }
                    }
                }
                best.expect("some job is eligible at `now`").1
            }
        };
        let head = queues[t].remove(qi).expect("picked index in range");
        depth.dequeue(t);
        let head_engine = head.engine;
        let (head_rank, head_shed) = shed_decision(&head, now, params.shed.as_ref());
        let mut group = vec![head];
        let mut group_shed = vec![head_shed];

        // ---- fuse compatible arrived jobs (any tenant) onto this
        // dispatch. The group is capped by device memory, not just
        // max_batch: k fused jobs keep k factor/output sets resident
        // while sharing one batch double buffer, so fusion must not
        // overcommit the budget the admission controller guaranteed per
        // job. Candidates must resolve to the *same engine* (same tensor
        // epoch) and the same post-shed rank.
        if params.batching && params.max_batch > 1 {
            let key = fuse_target(&group[0]).map(|(s, m)| (s.to_string(), m));
            if let Some((ks, km)) = key {
                let cap = params.max_batch.min(head_engine.fused_jobs_capacity(km, head_rank));
                'scan: for step in 0..ntenants {
                    let ti = (t + step) % ntenants;
                    let q = &mut queues[ti];
                    let mut i = 0;
                    while i < q.len() {
                        if group.len() >= cap {
                            break 'scan;
                        }
                        let cand = &q[i];
                        let (cand_rank, cand_shed) =
                            shed_decision(cand, now, params.shed.as_ref());
                        let joins = cand.job.arrival_s <= now
                            && fuse_target(cand) == Some((ks.as_str(), km))
                            && cand_rank == head_rank
                            && std::ptr::eq(cand.engine, head_engine);
                        if joins {
                            group.push(q.remove(i).expect("index in range"));
                            group_shed.push(cand_shed);
                            depth.dequeue(ti);
                        } else {
                            i += 1;
                        }
                    }
                }
            }
        }

        // ---- run the group for real, modelled duration from the cost model
        let gid = if group.len() > 1 {
            fused_groups += 1;
            fused_jobs += group.len();
            next_group += 1;
            Some(next_group - 1)
        } else {
            None
        };
        let engine = head_engine;
        let cnt = Counters::new();
        let (duration_s, group_bytes, results): (f64, usize, Vec<JobResult>) =
            match group[0].job.kind {
                JobKind::Mttkrp { target, .. } => {
                    let rank = head_rank;
                    let factor_sets: Vec<Vec<Matrix>> = group
                        .iter()
                        .map(|g| match g.job.kind {
                            JobKind::Mttkrp { seed, .. } => {
                                random_factors(&engine.dims, rank, seed)
                            }
                            JobKind::CpAls { .. } => unreachable!("only MTTKRPs fuse"),
                        })
                        .collect();
                    let mut outs: Vec<Matrix> = group
                        .iter()
                        .map(|_| Matrix::zeros(engine.dims[target] as usize, rank))
                        .collect();
                    match group[0].route {
                        Route::Streamed => {
                            // memoized plan: repeated (tensor, mode, rank)
                            // dispatches hit the registry's schedule cache
                            let sched = engine.schedule(target, rank);
                            let refs: Vec<&[Matrix]> =
                                factor_sets.iter().map(|f| f.as_slice()).collect();
                            let rep = StreamRequest::new(&engine.eng, target)
                                .fused(&refs)
                                .schedule(&sched)
                                .threads(threads)
                                .counters(&cnt)
                                .run(&mut outs)
                                .expect("fused group was validated when queued")
                                .into_streamed()
                                .expect("single-device schedule streams");
                            (
                                rep.overall_s,
                                rep.bytes,
                                outs.into_iter().map(JobResult::Mttkrp).collect(),
                            )
                        }
                        Route::InMemory => {
                            // in-memory jobs never fuse (no transfer to share)
                            debug_assert_eq!(group.len(), 1);
                            engine.eng.mttkrp(
                                target, &factor_sets[0], &mut outs[0], threads, &cnt,
                            );
                            let d = device_time(&cnt.snapshot(), &engine.eng.profile)
                                .total();
                            (d, 0, outs.into_iter().map(JobResult::Mttkrp).collect())
                        }
                    }
                }
                JobKind::CpAls { rank, iters, seed } => {
                    debug_assert_eq!(group.len(), 1);
                    let o = CpAlsOptions { rank, max_iters: iters, tol: 0.0, threads, seed };
                    let rep = cp_als(engine, &engine.dims, engine.norm_x, o, &cnt);
                    // coarse end-to-end model: device time of every kernel,
                    // with streamed calls' compute replaced by their
                    // pipeline-simulated end-to-end time
                    let dt = device_time(&cnt.snapshot(), &engine.eng.profile).total();
                    let duration = (dt - rep.stream.compute_s).max(0.0) + rep.stream.overall_s;
                    let bytes = rep.stream.bytes;
                    (duration, bytes, vec![JobResult::CpAls(Box::new(rep))])
                }
            };
        counters.add(&cnt.snapshot());

        let start = now.max(device_free[d]);
        let finish = start + duration_s;
        device_free[d] = finish;
        let per_job_bytes = group_bytes / group.len();
        for (q, (result, shed)) in
            group.into_iter().zip(results.into_iter().zip(group_shed))
        {
            let served_rank = match q.job.kind {
                JobKind::Mttkrp { .. } => head_rank,
                JobKind::CpAls { rank, .. } => rank,
            };
            outcomes.push(JobOutcome {
                id: q.job.id,
                tenant: q.job.tenant,
                tensor: q.job.tensor,
                kind: q.job.kind,
                status: JobStatus::Completed,
                route: Some(q.route),
                device: Some(d),
                group: gid,
                start_s: start,
                finish_s: finish,
                latency_s: finish - q.job.arrival_s,
                duration_s,
                bytes: per_job_bytes,
                served_rank: Some(served_rank),
                shed,
                deadline_s: q.deadline_abs,
                missed_deadline: q.deadline_abs.is_some_and(|dl| finish > dl),
                result: Some(result),
            });
        }
    }

    // ---- aggregate
    let mut per_tenant: BTreeMap<String, TenantStats> = BTreeMap::new();
    for (i, name) in tnames.iter().enumerate() {
        per_tenant.insert(
            name.clone(),
            TenantStats {
                weight: weights[i],
                max_queue_depth: depth.max_depth[i],
                queue_depth: Percentiles::from_samples(&depth.tenant_samples[i]),
                ..Default::default()
            },
        );
    }
    let mut tenant_latencies: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for o in &outcomes {
        let s = per_tenant.get_mut(&o.tenant).expect("tenant table covers the trace");
        s.submitted += 1;
        match &o.status {
            JobStatus::Completed => {
                s.completed += 1;
                s.mean_latency_s += o.latency_s; // sum; divided below
                s.max_latency_s = s.max_latency_s.max(o.latency_s);
                s.bytes_shipped += o.bytes;
                tenant_latencies.entry(&o.tenant).or_default().push(o.latency_s);
                if o.group.is_some() {
                    s.fused += 1;
                }
                if o.shed {
                    s.shed += 1;
                }
                if o.deadline_s.is_some() {
                    s.deadline_jobs += 1;
                    if o.missed_deadline {
                        s.deadline_misses += 1;
                    }
                }
            }
            JobStatus::Rejected(_) => s.rejected += 1,
        }
    }
    let mut all_latencies: Vec<f64> = Vec::new();
    for (name, lats) in &tenant_latencies {
        let s = per_tenant.get_mut(*name).expect("tenant table");
        s.latency = Percentiles::from_samples(lats);
        all_latencies.extend_from_slice(lats);
    }
    for s in per_tenant.values_mut() {
        if s.completed > 0 {
            s.mean_latency_s /= s.completed as f64;
        }
    }
    let makespan_s = outcomes
        .iter()
        .filter(|o| matches!(o.status, JobStatus::Completed))
        .map(|o| o.finish_s)
        .fold(0.0, f64::max);
    let bytes_shipped = outcomes.iter().map(|o| o.bytes).sum();
    let (shed_jobs, deadline_jobs, deadline_misses) = per_tenant.values().fold(
        (0, 0, 0),
        |(s, j, m), t| (s + t.shed, j + t.deadline_jobs, m + t.deadline_misses),
    );

    let mut delta = params.sched_total(reg);
    delta = delta.delta_since(sched_before);
    ServiceReport {
        outcomes,
        per_tenant,
        devices,
        makespan_s,
        fused_groups,
        fused_jobs,
        shed_jobs,
        deadline_jobs,
        deadline_misses,
        latency: Percentiles::from_samples(&all_latencies),
        queue_depth: Percentiles::from_samples(&depth.total_samples),
        schedule: delta,
        bytes_shipped,
        volume_bytes: counters.snapshot().volume_bytes(),
        wall_s: wall0.elapsed().as_secs_f64(),
    }
}

/// Replay `jobs` against the registry under the given policy.
#[deprecated(
    note = "use service::ServeRequest — the builder validates its inputs, \
            returns structured errors, and carries the SLO/EDF/shed knobs"
)]
#[allow(deprecated)]
pub fn serve(
    reg: &TensorRegistry,
    tenants: &[Tenant],
    jobs: &[JobRequest],
    opts: &ServeOptions,
) -> ServiceReport {
    let params = ServeParams {
        policy: if opts.fair { SchedPolicy::Wrr } else { SchedPolicy::Fifo },
        devices: opts.devices.max(1),
        threads: opts.backend().threads(),
        batching: opts.batching,
        max_batch: opts.max_batch,
        slo: None,
        shed: None,
        epochs: Vec::new(),
    };
    run_serve(reg, tenants, jobs, &params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrr_serves_proportionally_to_weight() {
        let weights = vec![2usize, 1];
        let mut credits = weights.clone();
        let mut cursor = 0usize;
        let eligible = vec![true, true];
        let picks: Vec<usize> = (0..9)
            .map(|_| wrr_pick(&mut credits, &weights, &mut cursor, &eligible))
            .collect();
        let a = picks.iter().filter(|&&p| p == 0).count();
        assert_eq!(a, 6, "weight-2 tenant gets 2/3 of dispatches: {picks:?}");
        // interleaved, not burst: no run of 3 identical picks in a cycle
        assert!(picks.windows(3).all(|w| !(w[0] == w[1] && w[1] == w[2])), "{picks:?}");
    }

    #[test]
    fn wrr_skips_ineligible_tenants() {
        let weights = vec![1usize, 1, 1];
        let mut credits = weights.clone();
        let mut cursor = 0usize;
        let eligible = vec![false, true, false];
        for _ in 0..5 {
            assert_eq!(wrr_pick(&mut credits, &weights, &mut cursor, &eligible), 1);
        }
    }

    #[test]
    fn depth_tracker_records_every_event() {
        // two tenants; arrivals at 0, 0, 1, 5 (tenant 0,1,0,0)
        let mut d = DepthTracker::new(2, vec![(0.0, 0), (0.0, 1), (1.0, 0), (5.0, 0)]);
        d.advance(0.0);
        assert_eq!((d.depth[0], d.depth[1], d.total), (1, 1, 2));
        d.dequeue(0); // dispatch tenant 0's job
        d.advance(2.0); // arrival at t=1 processed late, at the next dispatch
        assert_eq!((d.depth[0], d.total), (1, 2));
        d.dequeue(1);
        d.dequeue(0);
        d.advance(10.0);
        d.dequeue(0);
        assert_eq!(d.total, 0);
        assert_eq!(d.max_depth, vec![1, 1], "spread trace never stacked");
        // every enqueue and dequeue left a sample
        assert_eq!(d.total_samples.len(), 8);
    }
}
