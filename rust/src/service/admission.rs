//! Admission control: decide per job — **before** any work is queued —
//! whether it runs on the in-memory unified kernel, queues for a streamed
//! slot, or is rejected outright with a structured error. Decisions reuse
//! the engine's exact accounting: `working_set_bytes_for`/`is_oom_for`
//! for the in-memory test, and the new
//! [`streaming_floor_bytes`](crate::coordinator::engine::MttkrpEngine::streaming_floor_bytes)
//! (factors + target output + a double-buffered batch) for the
//! can-it-stream-at-all test. Rejection is a value, never a panic: the
//! serving loop must survive hostile or oversized requests.

use std::fmt;

use crate::coordinator::engine::MttkrpEngine;
use crate::mttkrp::MAX_RANK;

use super::registry::TensorRegistry;
use super::trace::{JobKind, JobRequest};

/// Which execution class an admitted job was assigned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// full working set fits: unified in-memory kernel
    InMemory,
    /// working set exceeds device memory but the streaming floor fits:
    /// queue for a streamed slot (fusible with same-key jobs)
    Streamed,
}

/// Why a request cannot be served. Variants carry the numbers the client
/// needs to fix the request (or pick a bigger device).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// no tensor registered under this name
    UnknownTensor { tensor: String },
    /// target mode index out of range for the tensor's order
    TargetOutOfRange { target: usize, order: usize },
    /// rank is zero or exceeds the engines' register budget
    /// ([`MAX_RANK`])
    InvalidRank { rank: usize, max: usize },
    /// even the streaming floor (factors + output + double-buffered
    /// batch) exceeds device memory — the job cannot run at any route
    WontFit { target: usize, rank: usize, floor_bytes: usize, budget_bytes: usize },
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::UnknownTensor { tensor } => {
                write!(f, "unknown tensor {tensor:?}")
            }
            AdmissionError::TargetOutOfRange { target, order } => {
                write!(f, "target mode {target} out of range for order {order}")
            }
            AdmissionError::InvalidRank { rank, max } => {
                write!(f, "rank {rank} outside the supported range 1..={max}")
            }
            AdmissionError::WontFit { target, rank, floor_bytes, budget_bytes } => {
                write!(
                    f,
                    "mode-{target} rank-{rank} job cannot be served: streaming \
                     floor {floor_bytes} B exceeds device memory {budget_bytes} B"
                )
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// A positive admission decision with the numbers it was based on.
#[derive(Clone, Copy, Debug)]
pub struct Admission {
    pub route: Route,
    /// exact working set for the (worst) target mode of this job
    pub working_set_bytes: usize,
    /// resident floor a streamed slot would need
    pub floor_bytes: usize,
}

/// Admit one mode-`target`, rank-`rank` MTTKRP against `engine`.
pub fn admit_mttkrp(
    engine: &MttkrpEngine,
    target: usize,
    rank: usize,
) -> Result<Admission, AdmissionError> {
    if rank == 0 || rank > MAX_RANK {
        return Err(AdmissionError::InvalidRank { rank, max: MAX_RANK });
    }
    let order = engine.dims.len();
    if target >= order {
        return Err(AdmissionError::TargetOutOfRange { target, order });
    }
    let working_set_bytes = engine.working_set_bytes_for(target, rank);
    let floor_bytes = engine.streaming_floor_bytes(target, rank);
    if !engine.is_oom_for(target, rank) {
        Ok(Admission { route: Route::InMemory, working_set_bytes, floor_bytes })
    } else if engine.eng.profile.fits(floor_bytes) {
        Ok(Admission { route: Route::Streamed, working_set_bytes, floor_bytes })
    } else {
        Err(AdmissionError::WontFit {
            target,
            rank,
            floor_bytes,
            budget_bytes: engine.eng.profile.dev_mem_bytes,
        })
    }
}

/// Admit a whole [`JobRequest`] against the registry. A CP-ALS job must
/// admit on *every* mode (its sweep touches them all); its route is
/// `Streamed` as soon as any mode streams.
pub fn admit_job(
    reg: &TensorRegistry,
    job: &JobRequest,
) -> Result<Admission, AdmissionError> {
    let entry = reg.get(&job.tensor).ok_or_else(|| AdmissionError::UnknownTensor {
        tensor: job.tensor.clone(),
    })?;
    admit_job_on(&entry.engine, job)
}

/// [`admit_job`] against an already-resolved engine — the entry point the
/// serving loop uses once a job's arrival has been mapped to its tensor
/// epoch (snapshot-consistent serving binds jobs to pre- or post-append
/// views of the same name, so the registry lookup alone cannot decide).
pub fn admit_job_on(
    engine: &MttkrpEngine,
    job: &JobRequest,
) -> Result<Admission, AdmissionError> {
    match job.kind {
        JobKind::Mttkrp { target, rank, .. } => admit_mttkrp(engine, target, rank),
        JobKind::CpAls { rank, .. } => {
            let mut route = Route::InMemory;
            let mut working_set_bytes = 0usize;
            let mut floor_bytes = 0usize;
            for m in 0..engine.dims.len() {
                let a = admit_mttkrp(engine, m, rank)?;
                working_set_bytes = working_set_bytes.max(a.working_set_bytes);
                floor_bytes = floor_bytes.max(a.floor_bytes);
                if a.route == Route::Streamed {
                    route = Route::Streamed;
                }
            }
            Ok(Admission { route, working_set_bytes, floor_bytes })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profile::Profile;
    use crate::format::blco::BlcoConfig;
    use crate::tensor::synth;

    fn registry(mem: usize) -> TensorRegistry {
        let mut reg = TensorRegistry::new(Profile::tiny(mem));
        let t = synth::uniform(&[50, 40, 30], 6_000, 2);
        let cfg = BlcoConfig { max_block_nnz: 512, ..Default::default() };
        reg.register("t", &t, cfg);
        reg
    }

    #[test]
    fn routes_follow_the_memory_budget() {
        // plenty of memory: in-memory; tight: streamed; starved: reject
        let roomy = registry(1 << 20);
        let a = admit_mttkrp(&roomy.get("t").unwrap().engine, 0, 8).unwrap();
        assert_eq!(a.route, Route::InMemory);

        let tight = registry(48 * 1024);
        let a = admit_mttkrp(&tight.get("t").unwrap().engine, 0, 8).unwrap();
        assert_eq!(a.route, Route::Streamed);
        assert!(a.floor_bytes < a.working_set_bytes);

        let starved = registry(4 * 1024);
        let e = admit_mttkrp(&starved.get("t").unwrap().engine, 0, 8).unwrap_err();
        match e {
            AdmissionError::WontFit { floor_bytes, budget_bytes, .. } => {
                assert!(floor_bytes > budget_bytes);
            }
            other => panic!("expected WontFit, got {other:?}"),
        }
    }

    #[test]
    fn structured_errors_not_panics() {
        let reg = registry(1 << 20);
        let eng = &reg.get("t").unwrap().engine;
        assert_eq!(
            admit_mttkrp(eng, 3, 8).unwrap_err(),
            AdmissionError::TargetOutOfRange { target: 3, order: 3 }
        );
        assert_eq!(
            admit_mttkrp(eng, 0, 0).unwrap_err(),
            AdmissionError::InvalidRank { rank: 0, max: MAX_RANK }
        );
        assert_eq!(
            admit_mttkrp(eng, 0, MAX_RANK + 1).unwrap_err(),
            AdmissionError::InvalidRank { rank: MAX_RANK + 1, max: MAX_RANK }
        );
        // errors render human-readable text
        let msg = admit_mttkrp(eng, 3, 8).unwrap_err().to_string();
        assert!(msg.contains("out of range"), "{msg}");
    }

    #[test]
    fn cpals_admits_over_all_modes() {
        use crate::service::trace::{JobKind, JobRequest};
        let reg = registry(48 * 1024);
        let job = JobRequest::new(
            0,
            "a",
            "t",
            JobKind::CpAls { rank: 8, iters: 2, seed: 1 },
            0.0,
        );
        let a = admit_job(&reg, &job).unwrap();
        assert_eq!(a.route, Route::Streamed, "OOM tensor: the sweep streams");
        let unknown = JobRequest { tensor: "nope".into(), ..job };
        assert!(matches!(
            admit_job(&reg, &unknown).unwrap_err(),
            AdmissionError::UnknownTensor { .. }
        ));
    }
}
