//! Tenants, job requests, and the seeded trace generators the `serve` CLI
//! and the throughput bench replay. Everything is deterministic in the
//! seed so serving runs are reproducible and comparable across scheduler
//! policies.
//!
//! Two generation regimes share one [`TraceConfig`]:
//!
//! * [`ArrivalProcess::Bursty`] — the legacy replay gaps (~1/3 of jobs
//!   land together), kept bit-compatible with the pre-open-loop trace;
//! * [`ArrivalProcess::Poisson`] / [`ArrivalProcess::Mmpp`] — **open
//!   loop**: arrivals follow the offered rate regardless of how fast the
//!   fleet drains them, which is what production traffic does. A sweep
//!   over `rate_qps` is how `fig_serve_throughput` finds the knee where
//!   p99 explodes; the Markov-modulated process adds calm/burst phases so
//!   tails are stressed by correlated arrivals, not just the mean rate.

use crate::util::prng::Rng;

use super::registry::TensorRegistry;

/// One tenant of the service. `weight` is its share of the weighted
/// round-robin scheduler (2 = twice the dispatch rate of a weight-1 tenant
/// under contention).
#[derive(Clone, Debug)]
pub struct Tenant {
    pub name: String,
    pub weight: usize,
}

/// What a job asks for. `seed` derives the job's factor matrices
/// deterministically (`random_factors(dims, rank, seed)`), so any result
/// can be re-verified against the serial oracle after the fact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// one mode-`target` MTTKRP at `rank`
    Mttkrp { target: usize, rank: usize, seed: u64 },
    /// a full CP-ALS decomposition at `rank` for `iters` iterations
    CpAls { rank: usize, iters: usize, seed: u64 },
}

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct JobRequest {
    pub id: usize,
    pub tenant: String,
    /// registry name of the tensor to decompose
    pub tensor: String,
    pub kind: JobKind,
    /// modelled arrival time (seconds since trace start)
    pub arrival_s: f64,
    /// latency SLO *relative to arrival*: the job should finish by
    /// `arrival_s + deadline_s`. `None` = best-effort (a run-wide default
    /// can still be applied via `SloPolicy`); finishing late is a
    /// *deadline miss* in the report, never a drop.
    pub deadline_s: Option<f64>,
    /// priority tier, `0` = most urgent. The EDF policy serves strictly
    /// by tier first, earliest deadline within a tier.
    pub priority: u8,
}

impl JobRequest {
    /// A best-effort tier-0 request (no deadline).
    pub fn new(
        id: usize,
        tenant: &str,
        tensor: &str,
        kind: JobKind,
        arrival_s: f64,
    ) -> Self {
        JobRequest {
            id,
            tenant: tenant.to_string(),
            tensor: tensor.to_string(),
            kind,
            arrival_s,
            deadline_s: None,
            priority: 0,
        }
    }

    /// Attach a relative latency SLO.
    pub fn with_deadline(mut self, deadline_s: f64) -> Self {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Assign a priority tier (`0` = most urgent).
    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }
}

/// How arrival instants are generated.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// legacy closed-loop-style replay gaps: ~1/3 of jobs arrive together,
    /// the rest `uniform(0, 2 * mean_gap_s)` apart (mean gap
    /// `TraceConfig::mean_gap_s`)
    Bursty,
    /// open-loop Poisson arrivals at `rate_qps` jobs per modelled second
    /// (exponential inter-arrival gaps)
    Poisson { rate_qps: f64 },
    /// Markov-modulated Poisson: a two-state process that alternates a
    /// calm phase at `rate_qps` and a burst phase at `burst * rate_qps`,
    /// dwelling an exponential `mean_dwell_s` in each — same mean load as
    /// Poisson at `(1 + burst)/2 * rate_qps`, much heavier tails
    Mmpp { rate_qps: f64, burst: f64, mean_dwell_s: f64 },
}

/// Knobs of the synthetic trace generator.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub tenants: usize,
    pub jobs: usize,
    /// mean inter-arrival gap of the [`ArrivalProcess::Bursty`] replay
    /// (ignored by the open-loop processes, which carry their own rate)
    pub mean_gap_s: f64,
    /// ranks jobs draw from — keep this short to drive schedule-cache
    /// hits and fusion on repeated `(tensor, mode, rank)` keys
    pub ranks: Vec<usize>,
    /// every `n`-th job is a small CP-ALS instead of a single MTTKRP
    /// (0 = MTTKRP only)
    pub cpals_every: usize,
    /// arrival-instant generator; the default keeps the legacy bursty
    /// replay bit-for-bit
    pub arrival: ArrivalProcess,
    /// relative latency SLO stamped on every generated job (`None` =
    /// best-effort jobs)
    pub deadline_s: Option<f64>,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            tenants: 3,
            jobs: 30,
            mean_gap_s: 2e-4,
            ranks: vec![16],
            cpals_every: 0,
            arrival: ArrivalProcess::Bursty,
            deadline_s: None,
            seed: 0x5EB0,
        }
    }
}

/// Exponential inter-arrival gap at `rate` events per second (inverse-CDF
/// of `Exp(rate)`; `1 - f64()` keeps the log argument in `(0, 1]`).
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.f64()).ln() / rate
}

/// Generate tenants and an arrival-ordered mixed trace over the
/// registry's tensors. Tenant 0 gets weight 2 (the "paying" tenant the
/// fairness tests watch), the rest weight 1.
pub fn synthetic_trace(
    reg: &TensorRegistry,
    cfg: &TraceConfig,
) -> (Vec<Tenant>, Vec<JobRequest>) {
    let names = reg.names();
    assert!(!names.is_empty(), "register tensors before generating a trace");
    assert!(!cfg.ranks.is_empty(), "TraceConfig.ranks must be non-empty");
    if let ArrivalProcess::Poisson { rate_qps } = cfg.arrival {
        assert!(rate_qps > 0.0, "Poisson rate_qps must be positive");
    }
    if let ArrivalProcess::Mmpp { rate_qps, burst, mean_dwell_s } = cfg.arrival {
        assert!(rate_qps > 0.0, "MMPP rate_qps must be positive");
        assert!(burst >= 1.0, "MMPP burst multiplies the calm rate");
        assert!(mean_dwell_s > 0.0, "MMPP mean_dwell_s must be positive");
    }
    let mut rng = Rng::new(cfg.seed);
    let tenants: Vec<Tenant> = (0..cfg.tenants.max(1))
        .map(|i| Tenant {
            name: format!("tenant{i}"),
            weight: if i == 0 { 2 } else { 1 },
        })
        .collect();

    let mut arrival = 0.0f64;
    // MMPP phase state: remaining dwell in the current phase and whether
    // we are in the burst phase (always starts calm, deterministically)
    let mut mmpp_burst = false;
    let mut mmpp_dwell_left = match cfg.arrival {
        ArrivalProcess::Mmpp { mean_dwell_s, .. } => exp_gap(&mut rng, 1.0 / mean_dwell_s),
        _ => 0.0,
    };
    let jobs = (0..cfg.jobs)
        .map(|id| {
            match cfg.arrival {
                // legacy replay: ~1/3 of jobs land together (bit-for-bit
                // the pre-open-loop generator — its trace test pins this)
                ArrivalProcess::Bursty => {
                    if rng.below(3) != 0 {
                        arrival += rng.f64() * 2.0 * cfg.mean_gap_s;
                    }
                }
                ArrivalProcess::Poisson { rate_qps } => {
                    arrival += exp_gap(&mut rng, rate_qps);
                }
                ArrivalProcess::Mmpp { rate_qps, burst, mean_dwell_s } => {
                    let mut gap =
                        exp_gap(&mut rng, if mmpp_burst { rate_qps * burst } else { rate_qps });
                    // phase switches that elapse inside the gap re-draw
                    // the remainder at the new phase's rate (memoryless)
                    while gap >= mmpp_dwell_left {
                        arrival += mmpp_dwell_left;
                        mmpp_burst = !mmpp_burst;
                        mmpp_dwell_left = exp_gap(&mut rng, 1.0 / mean_dwell_s);
                        gap = exp_gap(
                            &mut rng,
                            if mmpp_burst { rate_qps * burst } else { rate_qps },
                        );
                    }
                    mmpp_dwell_left -= gap;
                    arrival += gap;
                }
            }
            let tenant = tenants[rng.below(tenants.len() as u64) as usize].name.clone();
            let tensor = names[rng.below(names.len() as u64) as usize].clone();
            let order = reg.get(&tensor).expect("name from registry").engine.dims.len();
            let rank = cfg.ranks[rng.below(cfg.ranks.len() as u64) as usize];
            let kind = if cfg.cpals_every > 0 && (id + 1) % cfg.cpals_every == 0 {
                JobKind::CpAls { rank: rank.min(8), iters: 2, seed: rng.next_u64() }
            } else {
                JobKind::Mttkrp {
                    target: rng.below(order as u64) as usize,
                    rank,
                    seed: rng.next_u64(),
                }
            };
            JobRequest {
                id,
                tenant,
                tensor,
                kind,
                arrival_s: arrival,
                deadline_s: cfg.deadline_s,
                priority: 0,
            }
        })
        .collect();
    (tenants, jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profile::Profile;
    use crate::format::blco::BlcoConfig;
    use crate::tensor::synth;

    fn registry() -> TensorRegistry {
        let mut reg = TensorRegistry::new(Profile::a100());
        let t = synth::uniform(&[30, 20, 10], 800, 1);
        reg.register("a", &t, BlcoConfig::default());
        reg.register("b", &t, BlcoConfig::default());
        reg
    }

    #[test]
    fn trace_is_deterministic_and_well_formed() {
        let reg = registry();
        let cfg = TraceConfig { jobs: 40, cpals_every: 10, ..Default::default() };
        let (tenants, jobs) = synthetic_trace(&reg, &cfg);
        let (_, jobs2) = synthetic_trace(&reg, &cfg);
        assert_eq!(tenants.len(), 3);
        assert_eq!(tenants[0].weight, 2);
        assert_eq!(jobs.len(), 40);
        let mut prev = 0.0;
        let mut cpals = 0;
        for (j, j2) in jobs.iter().zip(&jobs2) {
            assert_eq!(j.kind, j2.kind, "same seed, same trace");
            assert!(j.arrival_s >= prev, "arrival-ordered");
            prev = j.arrival_s;
            assert!(reg.get(&j.tensor).is_some());
            assert_eq!(j.deadline_s, None, "bursty default is best-effort");
            match j.kind {
                JobKind::Mttkrp { target, rank, .. } => {
                    assert!(target < 3);
                    assert_eq!(rank, 16);
                }
                JobKind::CpAls { .. } => cpals += 1,
            }
        }
        assert_eq!(cpals, 4, "every 10th job decomposes");
        // bursts exist: at least two jobs share an arrival instant
        assert!(
            jobs.windows(2).any(|w| w[0].arrival_s == w[1].arrival_s),
            "expected bursty arrivals"
        );
    }

    #[test]
    fn poisson_trace_tracks_the_offered_rate() {
        let reg = registry();
        let rate = 2_000.0;
        let cfg = TraceConfig {
            jobs: 4_000,
            arrival: ArrivalProcess::Poisson { rate_qps: rate },
            deadline_s: Some(0.25),
            seed: 7,
            ..Default::default()
        };
        let (_, jobs) = synthetic_trace(&reg, &cfg);
        let span = jobs.last().unwrap().arrival_s;
        let observed = jobs.len() as f64 / span;
        assert!(
            (observed - rate).abs() / rate < 0.1,
            "offered {rate} qps, observed {observed:.0} qps"
        );
        // open loop: strictly increasing arrivals (no zero-gap bursts),
        // every job stamped with the configured SLO
        assert!(jobs.windows(2).all(|w| w[1].arrival_s > w[0].arrival_s));
        assert!(jobs.iter().all(|j| j.deadline_s == Some(0.25)));
        // deterministic in the seed
        let (_, jobs2) = synthetic_trace(&reg, &cfg);
        assert_eq!(jobs.len(), jobs2.len());
        assert!(jobs
            .iter()
            .zip(&jobs2)
            .all(|(a, b)| a.arrival_s.to_bits() == b.arrival_s.to_bits()));
    }

    #[test]
    fn mmpp_trace_is_burstier_than_poisson_at_the_same_mean_rate() {
        let reg = registry();
        let jobs_n = 6_000;
        let mk = |arrival| TraceConfig {
            jobs: jobs_n,
            arrival,
            seed: 11,
            ..Default::default()
        };
        // calm 1k qps, bursts at 9k, equal dwell: mean rate ~5k — compare
        // against a plain Poisson at that mean
        let (_, mmpp) = synthetic_trace(
            &reg,
            &mk(ArrivalProcess::Mmpp { rate_qps: 1_000.0, burst: 9.0, mean_dwell_s: 0.01 }),
        );
        let (_, poisson) =
            synthetic_trace(&reg, &mk(ArrivalProcess::Poisson { rate_qps: 5_000.0 }));
        let cv2 = |jobs: &[JobRequest]| {
            let gaps: Vec<f64> =
                jobs.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        // Poisson gaps have CV² ≈ 1; MMPP must be markedly over-dispersed
        let (cp, cm) = (cv2(&poisson), cv2(&mmpp));
        assert!(cp < 1.5, "Poisson CV² ≈ 1, got {cp:.2}");
        assert!(cm > 1.5, "MMPP CV² must exceed Poisson, got {cm:.2}");
        assert!(mmpp.windows(2).all(|w| w[1].arrival_s > w[0].arrival_s));
    }
}
