//! Tenants, job requests, and the seeded synthetic mixed-tenant trace the
//! `serve` CLI and the throughput bench replay. Everything is
//! deterministic in the seed so serving runs are reproducible and
//! comparable across scheduler policies.

use crate::util::prng::Rng;

use super::registry::TensorRegistry;

/// One tenant of the service. `weight` is its share of the weighted
/// round-robin scheduler (2 = twice the dispatch rate of a weight-1 tenant
/// under contention).
#[derive(Clone, Debug)]
pub struct Tenant {
    pub name: String,
    pub weight: usize,
}

/// What a job asks for. `seed` derives the job's factor matrices
/// deterministically (`random_factors(dims, rank, seed)`), so any result
/// can be re-verified against the serial oracle after the fact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// one mode-`target` MTTKRP at `rank`
    Mttkrp { target: usize, rank: usize, seed: u64 },
    /// a full CP-ALS decomposition at `rank` for `iters` iterations
    CpAls { rank: usize, iters: usize, seed: u64 },
}

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct JobRequest {
    pub id: usize,
    pub tenant: String,
    /// registry name of the tensor to decompose
    pub tensor: String,
    pub kind: JobKind,
    /// modelled arrival time (seconds since trace start)
    pub arrival_s: f64,
}

/// Knobs of the synthetic trace generator.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub tenants: usize,
    pub jobs: usize,
    /// mean inter-arrival gap; a third of arrivals are bursts (gap 0) so
    /// queues actually form and fusion/fairness have something to do
    pub mean_gap_s: f64,
    /// ranks jobs draw from — keep this short to drive schedule-cache
    /// hits and fusion on repeated `(tensor, mode, rank)` keys
    pub ranks: Vec<usize>,
    /// every `n`-th job is a small CP-ALS instead of a single MTTKRP
    /// (0 = MTTKRP only)
    pub cpals_every: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            tenants: 3,
            jobs: 30,
            mean_gap_s: 2e-4,
            ranks: vec![16],
            cpals_every: 0,
            seed: 0x5EB0,
        }
    }
}

/// Generate tenants and an arrival-ordered mixed trace over the
/// registry's tensors. Tenant 0 gets weight 2 (the "paying" tenant the
/// fairness tests watch), the rest weight 1.
pub fn synthetic_trace(
    reg: &TensorRegistry,
    cfg: &TraceConfig,
) -> (Vec<Tenant>, Vec<JobRequest>) {
    let names = reg.names();
    assert!(!names.is_empty(), "register tensors before generating a trace");
    assert!(!cfg.ranks.is_empty(), "TraceConfig.ranks must be non-empty");
    let mut rng = Rng::new(cfg.seed);
    let tenants: Vec<Tenant> = (0..cfg.tenants.max(1))
        .map(|i| Tenant {
            name: format!("tenant{i}"),
            weight: if i == 0 { 2 } else { 1 },
        })
        .collect();

    let mut arrival = 0.0f64;
    let jobs = (0..cfg.jobs)
        .map(|id| {
            // bursty arrivals: ~1/3 of jobs land together
            if rng.below(3) != 0 {
                arrival += rng.f64() * 2.0 * cfg.mean_gap_s;
            }
            let tenant = tenants[rng.below(tenants.len() as u64) as usize].name.clone();
            let tensor = names[rng.below(names.len() as u64) as usize].clone();
            let order = reg.get(&tensor).expect("name from registry").engine.dims.len();
            let rank = cfg.ranks[rng.below(cfg.ranks.len() as u64) as usize];
            let kind = if cfg.cpals_every > 0 && (id + 1) % cfg.cpals_every == 0 {
                JobKind::CpAls { rank: rank.min(8), iters: 2, seed: rng.next_u64() }
            } else {
                JobKind::Mttkrp {
                    target: rng.below(order as u64) as usize,
                    rank,
                    seed: rng.next_u64(),
                }
            };
            JobRequest { id, tenant, tensor, kind, arrival_s: arrival }
        })
        .collect();
    (tenants, jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profile::Profile;
    use crate::format::blco::BlcoConfig;
    use crate::tensor::synth;

    fn registry() -> TensorRegistry {
        let mut reg = TensorRegistry::new(Profile::a100());
        let t = synth::uniform(&[30, 20, 10], 800, 1);
        reg.register("a", &t, BlcoConfig::default());
        reg.register("b", &t, BlcoConfig::default());
        reg
    }

    #[test]
    fn trace_is_deterministic_and_well_formed() {
        let reg = registry();
        let cfg = TraceConfig { jobs: 40, cpals_every: 10, ..Default::default() };
        let (tenants, jobs) = synthetic_trace(&reg, &cfg);
        let (_, jobs2) = synthetic_trace(&reg, &cfg);
        assert_eq!(tenants.len(), 3);
        assert_eq!(tenants[0].weight, 2);
        assert_eq!(jobs.len(), 40);
        let mut prev = 0.0;
        let mut cpals = 0;
        for (j, j2) in jobs.iter().zip(&jobs2) {
            assert_eq!(j.kind, j2.kind, "same seed, same trace");
            assert!(j.arrival_s >= prev, "arrival-ordered");
            prev = j.arrival_s;
            assert!(reg.get(&j.tensor).is_some());
            match j.kind {
                JobKind::Mttkrp { target, rank, .. } => {
                    assert!(target < 3);
                    assert_eq!(rank, 16);
                }
                JobKind::CpAls { .. } => cpals += 1,
            }
        }
        assert_eq!(cpals, 4, "every 10th job decomposes");
        // bursts exist: at least two jobs share an arrival instant
        assert!(
            jobs.windows(2).any(|w| w[0].arrival_s == w[1].arrival_s),
            "expected bursty arrivals"
        );
    }
}
