//! Linearization round-trip fuzz: randomized shapes — including ones whose
//! total ALTO line exceeds `MAX_INBLOCK_BITS = 63`, so the adaptive
//! blocking strips real key bits — must satisfy, bit for bit:
//!
//! * the byte-lookup `reencode_tables` fast path (`reencode_alto`) agrees
//!   with the naive per-bit scatter reference encoders
//!   (`key_of_alto` + `inblock_of_alto`), and both agree with the direct
//!   coordinate encoder (`encode`) — three independent routes to the same
//!   `(block key, in-block index)`;
//! * `decode` inverts all of them back to the original coordinates;
//! * `BlcoTensor::to_coo` round-trips the original coordinate/value
//!   multiset through the full construction pipeline.

use std::collections::HashMap;

use blco::format::blco::{BlcoConfig, BlcoTensor};
use blco::linear::encode::{BlcoSpec, MAX_INBLOCK_BITS};
use blco::tensor::coo::CooTensor;
use blco::tensor::synth;
use blco::util::prng::Rng;

/// Random shape whose per-mode bit widths are drawn so that a healthy
/// fraction of cases exceeds the 63-bit in-block budget.
fn random_wide_dims(rng: &mut Rng) -> Vec<u64> {
    let order = 3 + rng.below(3) as usize; // 3..=5
    (0..order)
        .map(|_| {
            let bits = 2 + rng.below(23); // 2..=24 bits per mode
            // dims in (2^(bits-1), 2^bits]: exactly `bits` mode bits, with
            // jitter so non-power-of-two lengths are exercised too
            (1u64 << bits) - rng.below(1 << (bits - 1))
        })
        .collect()
}

/// Shapes that are guaranteed to exceed the 63-bit budget (72, 66, 69 and
/// 100 total ALTO bits) — the key path must run regardless of what the
/// random generator draws.
fn guaranteed_wide_shapes() -> Vec<Vec<u64>> {
    vec![
        vec![1 << 24, 1 << 24, 1 << 24],
        vec![1 << 23, 1 << 21, 1 << 22],
        vec![1 << 20, 1 << 17, 1 << 18, 1 << 14],
        vec![1 << 24, 1 << 22, 1 << 20, 1 << 18, 1 << 16],
    ]
}

#[test]
fn table_reencode_agrees_with_per_bit_scatter_and_direct_encode() {
    let mut rng = Rng::new(0xB17_F0CC);
    let mut keyed_cases = 0usize;
    let mut shapes: Vec<Vec<u64>> = guaranteed_wide_shapes();
    shapes.extend((0..60).map(|_| random_wide_dims(&mut rng)));
    for dims in shapes {
        let spec = BlcoSpec::new(&dims);
        let total_bits: u32 = spec.alto.total_bits;
        if total_bits > MAX_INBLOCK_BITS {
            keyed_cases += 1;
            assert_eq!(
                spec.total_key_bits,
                total_bits - MAX_INBLOCK_BITS,
                "every excess bit must move to the key ({dims:?})"
            );
            assert!(spec.needs_blocking());
        } else {
            assert_eq!(spec.total_key_bits, 0);
        }
        let mut decoded = vec![0u32; dims.len()];
        for _ in 0..40 {
            let coord: Vec<u32> =
                dims.iter().map(|&d| rng.below(d) as u32).collect();
            let alto = spec.alto.encode(&coord);
            // three independent routes to (key, inblock)
            let fast = spec.reencode_alto(alto);
            let scatter = (spec.key_of_alto(alto), spec.inblock_of_alto(alto));
            let direct = spec.encode(&coord);
            assert_eq!(
                fast, scatter,
                "table path vs per-bit scatter ({dims:?}, {coord:?})"
            );
            assert_eq!(
                fast, direct,
                "table path vs direct coordinate encode ({dims:?}, {coord:?})"
            );
            // the in-block index honours the budget (<= 63 bits always)
            assert!(spec.total_inblock_bits <= MAX_INBLOCK_BITS);
            assert!(
                fast.1 < (1u64 << spec.total_inblock_bits.max(1)),
                "in-block index {} overflows {} bits",
                fast.1,
                spec.total_inblock_bits
            );
            // ...and decodes back to the original coordinates
            spec.decode(fast.0, fast.1, &mut decoded);
            assert_eq!(decoded, coord, "decode must invert encode ({dims:?})");
        }
    }
    assert!(
        keyed_cases >= 4,
        "the key path must be exercised (got {keyed_cases} keyed cases)"
    );
}

fn coord_multiset(t: &CooTensor) -> HashMap<(Vec<u32>, u64), u32> {
    let mut m = HashMap::new();
    for e in 0..t.nnz() {
        *m.entry((t.coord(e), t.vals[e].to_bits())).or_insert(0u32) += 1;
    }
    m
}

#[test]
fn blco_to_coo_roundtrips_wide_shapes() {
    let mut rng = Rng::new(0x70_C00);
    let mut keyed_cases = 0usize;
    let mut shapes = guaranteed_wide_shapes();
    shapes.extend((0..4).map(|_| random_wide_dims(&mut rng)));
    for (case, dims) in shapes.into_iter().enumerate() {
        let t = synth::uniform(&dims, 1_500, 0xC0DE + case as u64);
        assert!(t.nnz() > 0);
        let b = BlcoTensor::from_coo(&t);
        if b.spec.needs_blocking() {
            keyed_cases += 1;
            assert!(b.spec.total_key_bits > 0);
        }
        assert_eq!(b.nnz, t.nnz());
        let back = b.to_coo();
        back.validate().unwrap();
        assert_eq!(
            coord_multiset(&back),
            coord_multiset(&t),
            "construction must preserve the coordinate/value multiset ({dims:?})"
        );
    }
    assert!(keyed_cases >= 4, "the guaranteed-wide cases must use block keys");
}

#[test]
fn lowered_budget_forces_keys_on_small_shapes_and_roundtrips() {
    // small dims, tiny in-block budget: every construction stage runs the
    // key path even though the shape would fit 63 bits comfortably
    let dims = [48u64, 36, 20];
    let t = synth::uniform(&dims, 3_000, 7);
    for budget in [8u32, 10, 13] {
        let cfg = BlcoConfig { inblock_budget: budget, ..Default::default() };
        let b = BlcoTensor::from_coo_with(&t, cfg);
        assert!(b.spec.needs_blocking(), "budget {budget} must force keys");
        assert_eq!(b.spec.total_inblock_bits, budget);
        assert_eq!(coord_multiset(&b.to_coo()), coord_multiset(&t), "budget {budget}");
    }
}

#[test]
fn order_boundaries_roundtrip() {
    // the extremes the linearizer supports: order 2 and order 8
    for dims in [vec![1u64 << 20, 1 << 19], vec![4u64, 3, 5, 2, 6, 3, 2, 4]] {
        let spec = BlcoSpec::new(&dims);
        let mut rng = Rng::new(dims.len() as u64);
        let mut out = vec![0u32; dims.len()];
        for _ in 0..200 {
            let coord: Vec<u32> =
                dims.iter().map(|&d| rng.below(d) as u32).collect();
            let (k, l) = spec.reencode_alto(spec.alto.encode(&coord));
            assert_eq!((k, l), spec.encode(&coord));
            spec.decode(k, l, &mut out);
            assert_eq!(out, coord);
        }
    }
}
