//! Integration: the AOT (JAX/Pallas → HLO text) computation executed via
//! PJRT from Rust must agree with the Rust engines — the L1/L2/L3
//! composition proof. Skips gracefully when `make artifacts` has not run.

use blco::device::Counters;
use blco::format::blco::BlcoTensor;
use blco::mttkrp::dense::Matrix;
use blco::mttkrp::oracle::{mttkrp_oracle, random_factors};
use blco::runtime::{artifacts, PjrtRuntime};
use blco::tensor::datasets;

fn runtime_or_skip() -> Option<PjrtRuntime> {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!(
            "SKIP: no artifacts at {} — run `make artifacts`",
            dir.display()
        );
        return None;
    }
    Some(PjrtRuntime::new(&dir).expect("create PJRT runtime"))
}

#[test]
fn fused_mode0_matches_oracle_on_demo3() {
    let Some(rt) = runtime_or_skip() else { return };
    let t = datasets::demo3().build();
    let b = BlcoTensor::from_coo(&t);
    let factors = random_factors(&t.dims, 32, 1);
    let mut out = Matrix::zeros(t.dims[0] as usize, 32);
    let c = Counters::new();
    rt.mttkrp_fused(&b, 0, &factors, &mut out, &c).unwrap();
    let expect = mttkrp_oracle(&t, 0, &factors);
    // f32 kernel vs f64 oracle: relative tolerance scaled by magnitude
    let scale = expect.norm().max(1.0);
    let d = out.max_abs_diff(&expect);
    assert!(d / scale < 1e-4, "diff {d:e} scale {scale:e}");
    assert!(c.snapshot().launches > 0);
}

#[test]
fn fused_all_modes_match_oracle_on_demo3() {
    let Some(rt) = runtime_or_skip() else { return };
    let t = datasets::demo3().build();
    let b = BlcoTensor::from_coo(&t);
    let factors = random_factors(&t.dims, 32, 3);
    for target in 0..3 {
        let mut out = Matrix::zeros(t.dims[target] as usize, 32);
        rt.mttkrp_fused(&b, target, &factors, &mut out, &Counters::new())
            .unwrap();
        let expect = mttkrp_oracle(&t, target, &factors);
        let rel = out.max_abs_diff(&expect) / expect.norm().max(1.0);
        assert!(rel < 1e-4, "mode {target}: rel {rel:e}");
    }
}

#[test]
fn pjrt_agrees_with_rust_blco_engine() {
    // the two execution backends of the same coordinator must agree with
    // each other (not just with the oracle)
    use blco::device::Profile;
    use blco::mttkrp::blco::BlcoEngine;
    use blco::mttkrp::Mttkrp;
    let Some(rt) = runtime_or_skip() else { return };
    let t = datasets::demo3().build();
    let factors = random_factors(&t.dims, 32, 5);

    let b = BlcoTensor::from_coo(&t);
    let mut pjrt_out = Matrix::zeros(t.dims[1] as usize, 32);
    rt.mttkrp_fused(&b, 1, &factors, &mut pjrt_out, &Counters::new())
        .unwrap();

    let eng = BlcoEngine::new(b, Profile::a100());
    let mut rust_out = Matrix::zeros(t.dims[1] as usize, 32);
    eng.mttkrp(1, &factors, &mut rust_out, 4, &Counters::new());

    let rel = pjrt_out.max_abs_diff(&rust_out) / rust_out.norm().max(1.0);
    assert!(rel < 1e-4, "backends disagree: rel {rel:e}");
}

#[test]
fn partials_path_with_l3_merge_matches_oracle() {
    // the architecture's headline variant: the XLA executable computes the
    // per-nnz partial rows, the Rust coordinator resolves the conflicts
    let Some(rt) = runtime_or_skip() else { return };
    let t = datasets::demo3().build();
    let b = BlcoTensor::from_coo(&t);
    let factors = random_factors(&t.dims, 32, 7);
    for target in 0..3 {
        let mut out = Matrix::zeros(t.dims[target] as usize, 32);
        rt.mttkrp_partials(&b, target, &factors, &mut out, &Counters::new())
            .unwrap();
        let expect = mttkrp_oracle(&t, target, &factors);
        let rel = out.max_abs_diff(&expect) / expect.norm().max(1.0);
        assert!(rel < 1e-4, "mode {target}: rel {rel:e}");
    }
}

#[test]
fn partials_and_fused_backends_agree() {
    let Some(rt) = runtime_or_skip() else { return };
    let t = datasets::demo4().build(); // 4-mode: only partials variants exist
    let b = BlcoTensor::from_coo(&t);
    let factors = random_factors(&t.dims, 32, 9);
    let mut out = Matrix::zeros(t.dims[2] as usize, 32);
    rt.mttkrp_partials(&b, 2, &factors, &mut out, &Counters::new())
        .unwrap();
    let expect = mttkrp_oracle(&t, 2, &factors);
    let rel = out.max_abs_diff(&expect) / expect.norm().max(1.0);
    assert!(rel < 1e-4, "4-mode partials: rel {rel:e}");
}

#[test]
fn manifest_covers_demo_presets() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = &rt.artifacts;
    let d3 = datasets::demo3();
    for target in 0..3 {
        assert!(a.find(&d3.dims, 32, target, "fused").is_some());
        assert!(a.find(&d3.dims, 32, target, "partials").is_some());
    }
    let d4 = datasets::demo4();
    for target in 0..4 {
        assert!(a.find(&d4.dims, 32, target, "partials").is_some());
    }
}
